"""Property tests for the fused fold-eval path and the bf16_gram mode.

Three layers of the fusion claim, each pinned independently:

  * kernel — fused ``fold_eval`` == the unfused two-launch Pallas pair
    (``hat_apply``-style contraction → (N, B) Ê → ``foldsolve``) == host
    NumPy/LAPACK, ≤ 1e-5 relative in f32, across K/m/B shapes including
    ragged fold coverage (K·m < N). The deterministic sweep runs on every
    environment; hypothesis additionally drives the same checker across
    the shape space when installed (the ``[test]`` extra).
  * estimator — every registered estimator family (binary LDA, CV ridge,
    multi-class LDA, RSA pair dissimilarities) produces identical results
    with ``fused=True`` and ``fused=False``, adjust_bias on and off (the
    two routes exercise the fully fused no-train kernel and the
    train-block solve-stage fusion respectively).
  * plan — ``precision="bf16_gram"`` plans stay inside the documented
    Gram error bound end-to-end (decision values vs the fp32 plan), key
    separately in the plan cache, and reject primal mode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastcv, folds as foldlib, multiclass
from repro.data import synthetic
from repro.kernels.fold_eval.ops import fold_eval
from repro.kernels.fold_eval.ref import (
    fold_eval_np,
    fold_eval_ref,
    fold_eval_two_kernel,
)
from repro.rsa import rdm as rsa_rdm

# ---------------------------------------------------------------------------
# kernel layer: fused == two-kernel == NumPy
# ---------------------------------------------------------------------------


def _problem(k, m, n, b, dtype, seed=0):
    """PSD small-norm hat + random fold gathers (ragged when K·m < N)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.normal(k1, (n, n), dtype) / (3.0 * n**0.5)
    h = a @ a.T
    te = jax.random.permutation(k2, n)[: k * m].reshape(k, m)
    y = jax.random.normal(k3, (n, b), dtype)
    return h[te], h[te[:, :, None], te[:, None, :]], y, y[te]


def _check_fused_triple(k, m, n, b, dtype, seed=0):
    """fused == two-kernel == NumPy within the ISSUE tolerance."""
    h_rows, h_te, y, y_te = _problem(k, m, n, b, dtype, seed)
    t_np, _ = fold_eval_np(h_rows, h_te, y, y_te)
    scale = 1.0 + float(np.max(np.abs(t_np)))
    tol = 1e-5 if dtype == jnp.float32 else 1e-10

    fused = np.asarray(fold_eval(h_rows, h_te, y, y_te, interpret=True))
    two, _ = fold_eval_two_kernel(h_rows, h_te, y, y_te, interpret=True)
    ref, _ = fold_eval_ref(h_rows, h_te, y, y_te)

    assert float(np.max(np.abs(fused - t_np))) / scale < tol
    assert float(np.max(np.abs(np.asarray(two) - t_np))) / scale < tol
    assert float(np.max(np.abs(fused - np.asarray(two)))) / scale < tol
    assert float(np.max(np.abs(fused - np.asarray(ref)))) / scale < tol


_SWEEP = [
    # (k, m, n, b) — ragged coverage (K·m < N), b straddling the block
    (4, 8, 40, 5),
    (3, 7, 33, 17),
    (5, 16, 80, 1),
    (2, 12, 50, 130),
    (6, 4, 24, 3),
]


@pytest.mark.parametrize("k,m,n,b", _SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_fused_matches_two_kernel_and_numpy(k, m, n, b, dtype):
    _check_fused_triple(k, m, n, b, dtype, seed=k * 31 + b)


# ---------------------------------------------------------------------------
# estimator layer: fused == reference through every eval family
# ---------------------------------------------------------------------------

N, P, K, LAM = 36, 72, 4, 1.0


@pytest.fixture(scope="module")
def plans():
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(0), N, P, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    f = foldlib.kfold(N, K, seed=1)
    full = fastcv.prepare(x, f, LAM)                        # train blocks
    slim = fastcv.prepare(x, f, LAM, with_train_block=False)  # fully fused
    return full, slim, y, yc


def _close(a, b, tol=1e-5):
    a, b = np.asarray(a), np.asarray(b)
    assert float(np.max(np.abs(a - b))) / (1.0 + float(np.max(np.abs(a)))) < tol


def test_cv_errors_fused_parity(plans):
    full, slim, y, _ = plans
    for plan in (full, slim):
        te_r, tr_r = fastcv.cv_errors(plan, y)
        te_f, tr_f = fastcv.cv_errors(plan, y, fused=True)
        _close(te_r, te_f)
        if tr_r is None:
            assert tr_f is None  # no-train plans have no ė_Tr either way
        else:
            _close(tr_r, tr_f)


def test_binary_dvals_fused_parity(plans):
    full, slim, y, _ = plans
    _close(fastcv.binary_dvals(full, y, adjust_bias=True),
           fastcv.binary_dvals(full, y, adjust_bias=True, fused=True))
    _close(fastcv.binary_dvals(slim, y, adjust_bias=False),
           fastcv.binary_dvals(slim, y, adjust_bias=False, fused=True))


def test_multiclass_fused_parity(plans):
    full, _, _, yc = plans
    batch = jnp.stack([yc, (yc + 1) % 3])
    ref = multiclass.batch_predict(full, batch, 3)
    fus = multiclass.batch_predict(full, batch, 3, fused=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fus))


def test_rsa_pairs_fused_parity(plans):
    full, slim, _, yc = plans
    cols = rsa_rdm.pair_contrast_columns(yc, 3, full.h.dtype)
    _close(rsa_rdm.pair_dissimilarities(full, cols),
           rsa_rdm.pair_dissimilarities(full, cols, fused=True))
    _close(rsa_rdm.pair_dissimilarities(slim, cols, adjust_bias=False),
           rsa_rdm.pair_dissimilarities(slim, cols, adjust_bias=False,
                                        fused=True))


def test_make_eval_factories_thread_fused(plans):
    """The jit factories route fused= through to identical results."""
    full, _, y, yc = plans
    for make, args in [
        (lambda f: fastcv.make_eval_cv(fused=f), (full, y[:, None])),
        (lambda f: fastcv.make_eval_binary(fused=f), (full, y[:, None])),
        (lambda f: multiclass.make_eval_multiclass(3, fused=f),
         (full, yc[None, :])),
        (lambda f: rsa_rdm.make_eval_pairs(fused=f),
         (full, rsa_rdm.pair_contrast_columns(yc, 3, full.h.dtype))),
    ]:
        ref, fus = make(False), make(True)
        out_r, out_f = ref(*args), fus(*args)
        out_r = out_r[0] if isinstance(out_r, tuple) else out_r
        out_f = out_f[0] if isinstance(out_f, tuple) else out_f
        _close(out_r, out_f)


# ---------------------------------------------------------------------------
# plan layer: bf16_gram
# ---------------------------------------------------------------------------


def test_bf16_gram_plan_within_documented_bound(plans):
    """Dual-mode bf16_gram decision values track fp32 within the Gram
    bound (~2·2⁻⁸‖X_c‖²) times a small solve-conditioning factor.

    The strict 2⁻⁸-scale bound is pinned on the Gram itself in
    test_kernels; downstream decision values see the Gram perturbation
    through (G_c + λI)⁻¹, so the check here allows an O(1) amplification
    (empirically ~1.5× at these shapes) — still far from fp32 parity,
    which is what the assertion on a strictly positive error guards."""
    _, _, y, _ = plans
    x, _ = synthetic.make_classification(jax.random.PRNGKey(3), N, P)
    f = foldlib.kfold(N, K, seed=1)
    x32 = x.astype(jnp.float32)
    p32 = fastcv.prepare(x32, f, LAM, mode="dual")
    pbf = fastcv.prepare(x32, f, LAM, mode="dual", precision="bf16_gram")
    a = np.asarray(fastcv.binary_dvals(p32, y.astype(jnp.float32)))
    b = np.asarray(fastcv.binary_dvals(pbf, y.astype(jnp.float32)))
    rel = float(np.max(np.abs(a - b))) / (1.0 + float(np.max(np.abs(a))))
    assert rel < 16.0 * 2.0**-8  # 2⁻⁸ bf16 rounding × conditioning headroom
    assert rel > 0.0             # and it genuinely ran the bf16 contraction


def test_bf16_gram_rejects_primal_mode():
    x, _ = synthetic.make_classification(jax.random.PRNGKey(4), 48, 12)
    f = foldlib.kfold(48, 4, seed=0)
    with pytest.raises(ValueError, match="dual"):
        fastcv.prepare(x, f, LAM, mode="primal", precision="bf16_gram")
    with pytest.raises(ValueError, match="precision"):
        fastcv.prepare(x, f, LAM, precision="fp16_gram")


def test_plan_key_separates_precisions():
    x, _ = synthetic.make_classification(jax.random.PRNGKey(5), N, P)
    f = foldlib.kfold(N, K, seed=1)
    k32 = fastcv.plan_key(x, f, LAM)
    kbf = fastcv.plan_key(x, f, LAM, precision="bf16_gram")
    assert k32 != kbf
    assert k32 == fastcv.plan_key(x, f, LAM, precision="fp32")
    # with_train_block stays the trailing element (the key[:-1] idiom)
    assert k32[-1] is True
    assert fastcv.plan_key(x, f, LAM, with_train_block=False)[-1] is False


# ---------------------------------------------------------------------------
# hypothesis drives the kernel checker across the shape space (when
# installed; the deterministic sweep above runs regardless)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - sweep-only environments
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _SETTINGS = dict(max_examples=12, deadline=None, derandomize=True)

    @given(
        k=st.integers(min_value=1, max_value=5),
        m=st.integers(min_value=1, max_value=12),
        spare=st.integers(min_value=0, max_value=9),
        b=st.integers(min_value=1, max_value=40),
        f32=st.booleans(),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    @settings(**_SETTINGS)
    def test_fused_property(k, m, spare, b, f32, seed):
        n = k * m + spare  # spare > 0 => ragged coverage
        _check_fused_triple(k, m, n, b,
                            jnp.float32 if f32 else jnp.float64, seed)
