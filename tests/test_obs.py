"""Tests for the observability layer (PR 6): the metrics registry
(repro.serve.obs), request-scoped tracing (repro.serve.trace), the
engine/server/edge instrumentation, and the /v1/metrics + /v1/trace
exposition routes.

Two invariants matter more than any individual counter:

* **Zero overhead when disabled** — tracing off (the default) must leave
  responses without ``timings``, add no compiles, and keep the wire
  payload byte-identical to the pre-observability schema.
* **stats() schema preserved** — the registry is a *view* over existing
  counters (cache stats, compile_count); ``engine.stats()`` keeps its
  key set, with ``per_dataset`` as the only addition.
"""

import asyncio
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import (
    STAGES,
    Client,
    CVEngine,
    DatasetSpec,
    EngineConfig,
    MetricsRegistry,
    Workload,
)
from repro.serve.http import EdgeThread, HTTPClient
from repro.serve.trace import Trace, Tracer, attach_trace, trace_of

N, P, K, LAM = 48, 64, 4, 1.0


@pytest.fixture(scope="module")
def problem():
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(0), N, P, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    f = foldlib.kfold(N, K, seed=1)
    return x, y, yc, f


@pytest.fixture()
def engine():
    return CVEngine(EngineConfig(cache_bytes=64 << 20))


def _kinds_workloads(problem, client):
    """One workload per kind (cv, permutation, rsa, tune, grid)."""
    x, y, yc, f = problem
    handle = client.register(x, f, LAM)
    return [
        Workload(kind="cv", dataset=handle, y=y, estimator="binary"),
        Workload(kind="permutation", dataset=handle, y=y, n_perm=8, seed=3),
        Workload(
            kind="rsa",
            dataset=handle,
            y=yc,
            num_classes=3,
            model_rdms=jnp.ones((1, 3, 3)),
            n_perm=8,
            seed=2,
        ),
        Workload(kind="tune", x=x, y=y),
        Workload(kind="grid", dataset=DatasetSpec(None, f, LAM), y=y, xs=jnp.stack([x])),
    ]


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------


def test_counter_semantics():
    reg = MetricsRegistry()
    c = reg.counter("reqs", "requests", labels=("kind",))
    c.inc(kind="cv")
    c.inc(2, kind="cv")
    c.inc(kind="rsa")
    assert c.value(kind="cv") == 3
    assert c.value(kind="rsa") == 1
    assert c.value(kind="tune") == 0
    with pytest.raises(ValueError):
        c.inc(-1, kind="cv")
    reg.inc("reqs", kind="cv")  # by-name dispatch
    assert c.value(kind="cv") == 4
    with pytest.raises(KeyError):
        reg.inc("no_such_metric")
    g = reg.gauge("g", "a gauge")
    g.set(5)
    with pytest.raises(TypeError):
        reg.inc("g")  # wrong metric kind


def test_gauge_callback_semantics():
    reg = MetricsRegistry()
    state = {"v": 7}
    g = reg.gauge("live", "callback-backed", fn=lambda: state["v"])
    assert g.value() == 7
    state["v"] = 11
    assert g.value() == 11  # lazy: source of truth stays canonical
    assert "live 11" in reg.render_prometheus()
    with pytest.raises(ValueError):
        g.set(3)  # callback gauges cannot be set directly


def test_histogram_observe_snapshot_and_cumulative_render():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0, 10.0), labels=("stage",))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):  # last one overflows every edge
        h.observe(v, stage="eval")
    snap = h.snapshot(stage="eval")
    assert snap["count"] == 5
    assert snap["buckets"] == [1, 2, 1]  # per-bucket, non-cumulative
    assert snap["sum"] == pytest.approx(56.05)
    text = "\n".join(h.render())
    # exposition is cumulative-le, with +Inf == count
    assert 'lat_bucket{stage="eval",le="0.1"} 1' in text
    assert 'lat_bucket{stage="eval",le="1"} 3' in text
    assert 'lat_bucket{stage="eval",le="10"} 4' in text
    assert 'lat_bucket{stage="eval",le="+Inf"} 5' in text
    assert 'lat_count{stage="eval"} 5' in text


def test_registration_idempotent_but_type_mismatch_raises():
    reg = MetricsRegistry()
    c1 = reg.counter("x", "first")
    c2 = reg.counter("x", "second registration returns the first")
    assert c1 is c2
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.histogram("x", buckets=(1.0,))


def test_cardinality_cap_folds_into_other():
    reg = MetricsRegistry(max_series_per_metric=4)
    c = reg.counter("labelled", "capped", labels=("who",))
    for i in range(10):
        c.inc(who=f"client-{i}")
    assert len(c._series) <= 5  # 4 real + 1 overflow
    assert reg.dropped_series == 6
    assert c.value(who="_other") == 6
    text = reg.render_prometheus()
    assert 'labelled{who="_other"} 6' in text
    assert "obs_dropped_series 6" in text


_PROM_LINE = re.compile(
    r"^(# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]* ?.*"
    r"|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? -?[0-9][0-9eE+.\-]*)$"
)


def test_prometheus_text_parses_line_by_line(engine):
    text = engine.metrics.render_prometheus()
    assert text.endswith("\n")
    for line in text.rstrip("\n").split("\n"):
        assert _PROM_LINE.match(line), f"malformed exposition line: {line!r}"


def test_stage_histograms_pre_declared(engine):
    """A fresh engine's exposition lists every stage series before any
    traffic — CI greps these to prove instrumentation is wired."""
    text = engine.metrics.render_prometheus()
    for stage in STAGES:
        assert f'stage_latency_seconds_bucket{{stage="{stage}"' in text, stage
    assert "\ncompile_events 0" in text
    assert "requests_total 0" in text  # unlabelled zero placeholder


# ---------------------------------------------------------------------------
# stats() schema is preserved (+ the handle-scoped view)
# ---------------------------------------------------------------------------

_GOLDEN_STATS_KEYS = {
    "hits",
    "misses",
    "evictions",
    "oversized",
    "pinned",
    "pinned_bytes",
    "bytes_in_use",
    "byte_budget",
    "plans_built",
    "plans_updated",
    "labels_evaluated",
    "compiles",
    "datasets_registered",
    "rdm_hits",
    "rdm_entries",
    "store_hits",
    "store_misses",
    "store_writes",
    "store_bytes",
    "per_dataset",
}


def test_stats_schema_golden(problem, engine):
    x, y, _, f = problem
    handle = engine.register(x, f, LAM)
    Client(engine).submit(Workload(kind="cv", dataset=handle, y=y, estimator="binary"))
    s = engine.stats()
    assert set(s) == _GOLDEN_STATS_KEYS
    per = s["per_dataset"]
    assert len(per) == 1
    (rec,) = per.values()
    assert set(rec) == {"n", "p", "version", "n_appended", "served",
                        "plan_bytes", "resident", "pinned", "last_used"}
    assert rec["version"] == 0 and rec["n_appended"] == 0
    assert rec["n"] == N and rec["p"] == P
    assert rec["served"] == 1
    assert rec["resident"] and rec["plan_bytes"] > 0
    assert rec["last_used"] > 0.0


# ---------------------------------------------------------------------------
# Tracing: span mechanics
# ---------------------------------------------------------------------------


def test_span_nesting_and_top_level_timings():
    tr = Trace(kind="cv")
    with tr.span("eval"):
        with tr.span("null_chunk"):
            pass
    with tr.span("encode"):
        pass
    assert [s.name for s in tr.spans] == ["eval", "encode"]
    assert [c.name for c in tr.spans[0].children] == ["null_chunk"]
    t = tr.timings()
    assert set(t) == {"eval", "encode"}  # children never double-count
    d = tr.to_dict()
    assert d["spans"][0]["children"][0]["name"] == "null_chunk"


def test_tracer_disabled_hooks_are_noops():
    tracer = Tracer()
    assert tracer.trace() is None
    with tracer.activate(None):
        with tracer.span("eval"):
            pass
    assert tracer.current() is None
    assert tracer.last() == []
    assert tracer.summary() == {}


def test_attach_trace_and_finished_reuse_guard():
    tracer = Tracer(enabled=True)
    w = Workload(kind="tune", x=jnp.ones((8, 4)), y=jnp.ones(8))
    tr = tracer.trace()
    attach_trace(w, tr)
    assert trace_of(w) is tr
    tracer.finish(tr)
    assert trace_of(w) is None  # finished traces are never reused
    assert len(tracer.last()) == 1


def test_ring_is_bounded():
    tracer = Tracer(enabled=True, ring=4)
    for _ in range(10):
        tracer.finish(tracer.trace())
    assert tracer.ring_size == 4
    assert len(tracer.last(100)) == 4


# ---------------------------------------------------------------------------
# End-to-end: disabled == invisible, enabled == full span trees
# ---------------------------------------------------------------------------


def test_disabled_tracing_no_timings_no_extra_compiles(problem, engine):
    ws = _kinds_workloads(problem, Client(engine))
    client = Client(engine)
    first = client.gather(ws)
    compiles = engine.compile_count()
    second = client.gather(ws)
    assert engine.compile_count() == compiles
    for resp in first + second:
        assert resp.timings is None
    assert engine.tracer.last() == []


def test_enabled_tracing_all_kinds_sync(problem, engine):
    client = Client(engine)
    ws = _kinds_workloads(problem, client)
    client.gather(ws)  # warm: plans built, programs compiled
    compiles = engine.compile_count()
    engine.enable_tracing(ring=32)
    responses = client.gather(ws)
    assert engine.compile_count() == compiles  # tracing adds no compiles
    for w, resp in zip(ws, responses):
        assert resp.timings, f"no timings for kind={w.kind}"
        assert set(resp.timings) <= set(STAGES)
        assert "validate" in resp.timings and "encode" in resp.timings
        assert ("eval" in resp.timings) or ("null_chunk" in resp.timings)
    kinds = {t["kind"] for t in engine.tracer.last()}
    assert kinds == {"cv", "permutation", "rsa", "tune", "grid"}
    # requests_total counted per kind
    reqs = engine.metrics.get("requests_total")
    assert reqs.value(kind="cv", estimator="binary") >= 1
    assert reqs.value(kind="tune", estimator="") >= 1
    # per-stage histogram fed by finished traces
    h = engine.metrics.get("stage_latency_seconds")
    assert h.snapshot(stage="eval")["count"] >= 1
    assert h.snapshot(stage="encode")["count"] >= len(ws)


def test_thread_transport_batch_wait_and_stage_sum(problem, engine):
    x, y, _, f = problem
    handle = engine.register(x, f, LAM)
    w = Workload(kind="cv", dataset=handle, y=y, estimator="binary")
    with Client(engine, transport="thread") as client:
        client.submit(w).result(timeout=300)  # warm
        engine.enable_tracing()
        resp = client.submit(w).result(timeout=300)
    assert "batch_wait" in resp.timings
    (trace,) = engine.tracer.last(1)
    stage_sum = sum(trace["timings"].values())
    dur = trace["duration_s"]
    # warm path: the instrumented stages account for the request end-to-end
    assert abs(stage_sum - dur) <= max(0.05 * dur, 1e-3), (stage_sum, dur)
    occ = engine.metrics.get("gather_window_occupancy")
    assert occ.snapshot()["count"] >= 2


def test_async_transport_timings(problem, engine):
    x, y, _, f = problem
    handle = engine.register(x, f, LAM)
    w = Workload(kind="cv", dataset=handle, y=y, estimator="binary")

    async def go():
        async with Client(engine, transport="async") as client:
            await client.submit(w)  # warm
            engine.enable_tracing()
            return await client.submit(w)

    resp = asyncio.run(go())
    assert resp.timings and "batch_wait" in resp.timings and "eval" in resp.timings


def test_streamed_workload_carries_timings(problem, engine):
    x, y, _, f = problem
    handle = engine.register(x, f, LAM)
    engine.enable_tracing()
    events = list(
        Client(engine).stream(
            Workload(kind="permutation", dataset=handle, y=y, n_perm=16, seed=1)
        )
    )
    done = events[-1]
    assert done.kind == "done"
    assert done.payload.timings and "null_chunk" in done.payload.timings


def test_batch_coalesced_size_observed(problem, engine):
    x, y, _, f = problem
    handle = engine.register(x, f, LAM)
    ws = [
        Workload(kind="cv", dataset=handle, y=jnp.roll(y, i), estimator="binary")
        for i in range(3)
    ]
    Client(engine).gather(ws)
    h = engine.metrics.get("batch_coalesced_size")
    snap = h.snapshot()
    assert snap["count"] >= 1
    assert snap["sum"] >= 3  # the three queries coalesced into one batch


# ---------------------------------------------------------------------------
# The HTTP edge: timings on the wire, /v1/metrics, /v1/trace
# ---------------------------------------------------------------------------


def test_http_edge_metrics_trace_and_wire_timings(problem):
    x, y, _, f = problem
    engine = CVEngine(EngineConfig(cache_bytes=64 << 20))
    with EdgeThread(engine) as edge, HTTPClient(edge.url) as client:
        handle = client.register(np.asarray(x), f, LAM)
        w = Workload(kind="cv", dataset=handle, y=y, estimator="binary")
        r0 = client.submit(w)
        assert r0.timings is None  # tracing off: wire schema untouched
        engine.enable_tracing(ring=16)
        resp = client.submit(w)
        assert resp.timings and "decode" in resp.timings and "eval" in resp.timings
        assert "batch_wait" in resp.timings

        text = client.metrics_text()
        for line in text.rstrip("\n").split("\n"):
            assert _PROM_LINE.match(line), f"malformed exposition line: {line!r}"
        assert re.search(r"^compile_events \d+$", text, re.M)
        for stage in STAGES:
            assert f'stage_latency_seconds_bucket{{stage="{stage}"' in text

        payload = client.trace(8)
        assert payload["enabled"] is True
        assert payload["ring"] == 16
        assert payload["traces"], "ring should hold the traced request"
        tree = payload["traces"][0]
        assert tree["kind"] == "cv"
        span_names = {s["name"] for s in tree["spans"]}
        assert {"decode", "validate", "eval", "encode"} <= span_names
        assert payload["summary"]["eval"]["count"] >= 1
