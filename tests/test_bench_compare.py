"""Unit tests for benchmarks/compare.py — the CI bench-regression gate.

The gate must (a) fail on an injected 2x warm-latency regression, (b) not
fail on uniform machine-speed differences between the baseline host and
the CI runner (median normalisation), and (c) ignore cold rows.
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import compare  # noqa: E402


def _artifact(rows):
    return {"meta": {"backend": "cpu"}, "rows": rows}


def _row(section, name, us):
    return {"section": section, "name": name, "us_per_call": us}


BASE_ROWS = [
    _row("serve(engine)", "serve_perm_warm_N96", 100.0),
    _row("serve(engine)", "serve_perm_cold_N96", 90000.0),
    _row("rsa(serve+kernel)", "bench_rsa_warm_N96", 200.0),
    _row("async(serve.aio)", "async_8clients_warm_64req", 400.0),
    _row("async(serve.aio)", "async_sequential_warm_64req", 800.0),
]


def _write(tmp_path, name, rows):
    path = tmp_path / name
    path.write_text(json.dumps(_artifact(rows)))
    return str(path)


@pytest.fixture()
def baseline_path(tmp_path):
    return _write(tmp_path, "baseline.json", BASE_ROWS)


def _scaled(factor, only=None):
    rows = []
    for r in BASE_ROWS:
        f = factor if (only is None or r["name"] == only) else 1.0
        rows.append(_row(r["section"], r["name"], r["us_per_call"] * f))
    return rows


def test_identical_artifacts_pass(baseline_path, tmp_path):
    fresh = _write(tmp_path, "fresh.json", _scaled(1.0))
    assert compare.main([baseline_path, fresh]) == 0


def test_injected_2x_regression_fails(baseline_path, tmp_path):
    """The acceptance case: one warm row regressing 2x must gate CI."""
    fresh = _write(tmp_path, "fresh.json", _scaled(2.0, only="serve_perm_warm_N96"))
    assert compare.main([baseline_path, fresh]) == 1


def test_uniform_machine_slowdown_passes(baseline_path, tmp_path):
    """A 3x-slower CI runner is hardware, not a code regression."""
    fresh = _write(tmp_path, "fresh.json", _scaled(3.0))
    assert compare.main([baseline_path, fresh]) == 0


def test_correlated_slowdown_hits_median_backstop(baseline_path, tmp_path):
    """A slowdown broad enough to drag the median past --max-median must
    fail even though every row's *normalised* ratio stays at 1.0."""
    fresh = _write(tmp_path, "fresh.json", _scaled(5.0))
    assert compare.main([baseline_path, fresh]) == 1


def test_max_median_flag_loosens_backstop(baseline_path, tmp_path):
    fresh = _write(tmp_path, "fresh.json", _scaled(5.0))
    assert compare.main([baseline_path, fresh, "--max-median", "6.0"]) == 0


def test_speedup_of_most_rows_does_not_flag_untouched_row(baseline_path, tmp_path):
    """4 of 5 rows getting 2.5x faster must not report the unchanged fifth
    row as a regression (the median is clamped at 1 for normalisation)."""
    rows = [
        _row(r["section"], r["name"], r["us_per_call"] * (1.0 if i == 0 else 0.4))
        for i, r in enumerate(BASE_ROWS)
    ]
    fresh = _write(tmp_path, "fresh.json", rows)
    assert compare.main([baseline_path, fresh]) == 0


def test_cold_rows_do_not_gate(baseline_path, tmp_path):
    fresh = _write(tmp_path, "fresh.json", _scaled(10.0, only="serve_perm_cold_N96"))
    assert compare.main([baseline_path, fresh]) == 0


def test_missing_rows_warn_but_pass(baseline_path, tmp_path):
    fresh = _write(tmp_path, "fresh.json", _scaled(1.0)[:-1])
    assert compare.main([baseline_path, fresh]) == 0


def test_within_tolerance_passes(baseline_path, tmp_path):
    fresh = _write(tmp_path, "fresh.json", _scaled(1.4, only="bench_rsa_warm_N96"))
    assert compare.main([baseline_path, fresh]) == 0


def test_unreadable_artifact_is_usage_error(baseline_path, tmp_path):
    assert compare.main([baseline_path, str(tmp_path / "missing.json")]) == 2


def test_zero_shared_warm_rows_is_an_error(baseline_path, tmp_path):
    """Renaming every row must not silently disable the gate."""
    renamed = [_row(r["section"], r["name"] + "_v2", r["us_per_call"]) for r in BASE_ROWS]
    fresh = _write(tmp_path, "fresh.json", renamed)
    assert compare.main([baseline_path, fresh]) == 2


def test_compare_function_reports_normalised_ratio():
    base = {("s", f"warm_{i}"): 100.0 for i in range(4)}
    fresh = dict(base)
    fresh[("s", "warm_0")] = 250.0
    regressions, checked, missing, median = compare.compare(base, fresh)
    assert checked == 4
    assert missing == []
    assert median == 1.0
    ((key, base_us, fresh_us, ratio),) = regressions
    assert key == ("s", "warm_0")
    assert ratio == pytest.approx(2.5)


def test_few_rows_gate_raw_ratios():
    """Below min_rows the median is meaningless; raw ratios must gate."""
    base = {("s", "warm_only"): 100.0}
    fresh = {("s", "warm_only"): 300.0}
    regressions, checked, _, _ = compare.compare(base, fresh)
    assert checked == 1
    assert len(regressions) == 1
