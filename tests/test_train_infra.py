"""Training infrastructure: checkpointing, restart, straggler, compression,
schedules, end-to-end tiny training convergence."""

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.optim import optimizer as O
from repro.optim import compression
from repro.train import checkpoint as ckpt
from repro.train import steps
from repro.train.straggler import SliceQueue, StepTimeMonitor
from repro.train.trainer import Trainer, TrainerConfig


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    ckpt.save(tmp_path, 7, tree, metadata={"cursor": 42})
    assert ckpt.latest_step(tmp_path) == 7
    like = jax.tree.map(lambda t: jnp.zeros_like(t), tree)
    restored, meta = ckpt.restore(tmp_path, 7, like)
    assert meta["cursor"] == 42
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    tree = {"a": jnp.zeros(3)}
    ckpt.save(tmp_path, 1, tree)
    # simulate a crash mid-write of step 2
    (tmp_path / "step_00000002.tmp").mkdir()
    assert ckpt.latest_step(tmp_path) == 1


def test_checkpoint_gc(tmp_path):
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, {"a": jnp.zeros(2)}, keep=2)
    kept = sorted(p.name for p in Path(tmp_path).iterdir())
    assert kept == ["step_00000003", "step_00000004"]


def test_async_checkpoint(tmp_path):
    t = ckpt.save_async(tmp_path, 5, {"w": jnp.ones((8, 8))})
    ckpt.wait_for_pending()
    assert ckpt.latest_step(tmp_path) == 5


def test_elastic_restore_changes_sharding(tmp_path):
    """Save unsharded, restore with an explicit device placement — the
    elastic path (real elasticity swaps mesh shapes; placement API is the
    same)."""
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(tmp_path, 3, tree)
    dev = jax.devices()[0]
    sharding_tree = {"w": jax.sharding.SingleDeviceSharding(dev)}
    restored, _ = ckpt.restore(tmp_path, 3, tree, sharding_tree)
    assert restored["w"].sharding == sharding_tree["w"]


def test_step_time_monitor_flags_stragglers():
    mon = StepTimeMonitor(threshold=2.0, warmup_steps=0)
    flagged = [mon.record(i, 0.1) for i in range(10)]
    assert not any(flagged)
    assert mon.record(10, 0.5)          # 5x median
    assert len(mon.events) == 1


def test_slice_queue_reassigns_expired_leases():
    now = [0.0]
    q = SliceQueue(3, lease_seconds=10.0, clock=lambda: now[0])
    s0 = q.acquire("pod0")
    s1 = q.acquire("pod1")
    assert {s0, s1} == {0, 1}
    q.complete(s1, "pod1")
    now[0] = 11.0                        # pod0's lease expires
    s0b = q.acquire("pod2")
    assert s0b in (0, 2)
    sx = q.acquire("pod2")
    q.complete(s0b, "pod2")
    q.complete(sx, "pod2")
    assert q.finished
    assert q.reassignments and q.reassignments[0][1] == "pod0"
    # late completion from the evicted worker is idempotent, not an error
    assert q.complete(s0, "pod0") in (True, False)


def test_int8_compression_error_feedback_preserves_signal():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    # accumulated compressed gradients converge to accumulated true gradients
    acc_true = jnp.zeros_like(g)
    for _ in range(20):
        (deq,), (err,) = (lambda d, e: (d, e))(*compression.compress_decompress([g], [err]))
        total = total + deq
        acc_true = acc_true + g
    rel = float(jnp.linalg.norm(total - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel


def test_wsd_schedule_shape():
    cfg = O.AdamWConfig(lr_peak=1.0, warmup_steps=10, total_steps=100,
                        decay_frac=0.2, schedule="wsd")
    lrs = [float(O.wsd_schedule(jnp.asarray(s), cfg)) for s in range(100)]
    assert lrs[0] < 0.2                      # warmup starts low
    assert abs(lrs[50] - 1.0) < 1e-6         # stable plateau at peak
    assert lrs[-1] < 0.5                     # decayed
    assert all(l <= 1.0 + 1e-6 for l in lrs)


def test_trainer_end_to_end_with_restart(tmp_path):
    """Train a tiny model, kill, restart from checkpoint, finish; loss
    decreases overall and the restart resumes the data cursor."""
    cfg = get_config("starcoder2-3b", smoke=True)
    opt_cfg = O.AdamWConfig(lr_peak=3e-3, warmup_steps=2, total_steps=20,
                            schedule="cosine")
    scfg = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=16,
                             global_batch=4, seed=1)
    tcfg = TrainerConfig(total_steps=10, log_every=2, checkpoint_every=5,
                         checkpoint_dir=str(tmp_path / "ck"))

    t1 = Trainer(cfg, opt_cfg, tcfg, TokenStream(scfg))
    r1 = t1.run()
    assert r1["steps"] == 10 and np.isfinite(r1["final_loss"])

    # "crash" and restart: a new Trainer picks up at step 10
    tcfg2 = TrainerConfig(total_steps=16, log_every=2, checkpoint_every=5,
                          checkpoint_dir=str(tmp_path / "ck"))
    t2 = Trainer(cfg, opt_cfg, tcfg2, TokenStream(scfg))
    assert t2.start_step == 10
    assert t2.stream.step == 10              # data cursor restored
    r2 = t2.run()
    assert r2["steps"] == 6
    first_loss = r1["log"][0]["loss"]
    last_loss = r2["log"][-1]["loss"]
    assert last_loss < first_loss            # training is actually learning


def test_compressed_training_still_converges(tmp_path):
    cfg = get_config("xlstm-125m", smoke=True)
    opt = O.AdamWConfig(lr_peak=3e-3, warmup_steps=2, total_steps=30,
                        compress_grads=True)
    params, opt_state = steps.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(steps.make_train_step(cfg, opt))
    scfg = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=16,
                             global_batch=4, seed=3)
    stream = TokenStream(scfg)
    losses = []
    for _ in range(12):
        params, opt_state, m = step(params, opt_state, stream.next_batch())
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
