"""Seeded RL001 violations: eager jnp assembly + wall clock on a host path."""

import time

import jax.numpy as jnp
import numpy as np

# reprolint: host-path
# reprolint: monotonic-time


def coalesce(blocks):
    batch = jnp.concatenate(blocks)  # seeded: RL001 (eager assembly)
    padded = jnp.pad(batch, (0, 3))  # seeded: RL001 (eager assembly)
    ok = jnp.asarray(np.concatenate([np.asarray(b) for b in blocks]))  # allowed
    return padded, ok


def deadline(window_s):
    return time.time() + window_s  # seeded: RL001 (wall clock)
