"""Seeded RL005 violations: sub-float64 dtypes in a host-float64 region."""

import numpy as np

# reprolint: host-float64


def correction(a, b):
    a64 = np.asarray(a, dtype=np.float64)  # allowed
    small = np.asarray(b, dtype=np.float32)  # seeded: RL005
    tiny = a64.astype("float16")  # seeded: RL005
    return small, tiny
