"""A violation suppressed *with* a justification: reprolint honors it."""

import jax.numpy as jnp

# reprolint: host-path


def grow(x2, x_new):
    return jnp.concatenate(  # reprolint: ignore[RL001] -- steady-state shapes repeat
        [x2, jnp.asarray(x_new)]
    )
