"""Seeded RL004 violations: guarded attrs mutated outside their lock."""

import threading


class StatCounter:
    _GUARDED_BY = {"served": "_lock", "_entries": "_lock"}
    _LOCKED_HELPERS = ("_evict",)

    def __init__(self):
        self._lock = threading.Lock()
        self.served = 0  # allowed: __init__ is exempt
        self._entries = {}

    def record(self, key, value):
        self.served += 1  # seeded: RL004 (no lock)
        self._entries[key] = value  # seeded: RL004 (no lock)
        self._entries.pop(key, None)  # seeded: RL004 (no lock)

    def record_locked(self, key, value):
        with self._lock:
            self.served += 1  # allowed
            self._entries[key] = value  # allowed
        self._entries.clear()  # seeded: RL004 (after the with-block)

    def _evict(self):
        self._entries.popitem()  # allowed: declared lock-held helper
