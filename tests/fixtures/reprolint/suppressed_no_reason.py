"""A bare suppression: it suppresses nothing and is itself an RL000 error."""

import jax.numpy as jnp

# reprolint: host-path


def grow(x2, x_new):
    return jnp.concatenate([x2, x_new])  # reprolint: ignore[RL001]
