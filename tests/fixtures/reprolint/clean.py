"""A clean host-path module: reprolint exits 0 here."""

import time

import jax.numpy as jnp
import numpy as np

# reprolint: host-path
# reprolint: monotonic-time


def coalesce(blocks):
    batch = np.concatenate([np.asarray(b) for b in blocks])
    return jnp.asarray(batch)


def deadline(window_s):
    return time.monotonic() + window_s
