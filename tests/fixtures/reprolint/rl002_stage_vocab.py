"""Seeded RL002 violations: stage strings outside the STAGES vocabulary."""


def instrument(tracer, tr, registry, dt):
    with tracer.span("warp_speed"):  # seeded: RL002 (not a stage)
        pass
    tr.add("decoed", dt)  # seeded: RL002 (typo'd stage)
    registry.observe("stage_latency_seconds", dt, stage="telemetry")  # seeded: RL002
    with tracer.span("plan_build"):  # allowed: in STAGES
        pass
    tr.add("encode", dt)  # allowed: in STAGES
