"""Seeded RL003 violations: undeclared names, label drift, cardinality."""


def instrument(metrics, w, user_id):
    metrics.inc("request_totals")  # seeded: RL003 (undeclared name)
    metrics.inc("requests_total", kind=w.kind)  # seeded: RL003 (missing label key)
    metrics.inc(
        "requests_total", kind=f"kind-{user_id}", estimator=w.estimator
    )  # seeded: RL003 (unbounded label value)
    metrics.observe("requests_total", 1.0)  # seeded: RL003 (counter observed)
    metrics.counter("plan_updates_total", "drifted", labels=("operation",))  # seeded: RL003
    metrics.inc("requests_total", kind=w.kind, estimator=w.estimator)  # allowed
    metrics.observe("plan_update_rank", 4.0)  # allowed
