"""Per-architecture smoke tests (reduced configs, single CPU device).

For each of the 10 assigned archs: instantiate the SMOKE config, run one
forward pass and one train step, assert output shapes and absence of
NaNs; for decode-capable archs additionally check that incremental
decoding with the KV/recurrent cache matches the full forward logits.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.configs import shapes as shp
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import optimizer as O
from repro.train import steps

ARCHS = list_archs()
SEQ = 32
BATCH = 2


def _batch_for(cfg, key, seq=SEQ, batch=BATCH):
    kt, kl, kv = jax.random.split(key, 3)
    if cfg.num_codebooks:
        tokens = jax.random.randint(kt, (batch, cfg.num_codebooks, seq), 0,
                                    cfg.vocab_size)
        labels = jax.random.randint(kl, (batch, cfg.num_codebooks, seq), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)
        labels = jax.random.randint(kl, (batch, seq), 0, cfg.vocab_size)
    out = {"tokens": tokens, "labels": labels}
    if cfg.vision_tokens:
        out["vision_embeds"] = jax.random.normal(
            kv, (batch, cfg.vision_tokens, cfg.vision_dim), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    logits, _, aux = M.forward(params, batch["tokens"], cfg,
                               vision_embeds=batch.get("vision_embeds"))
    if cfg.num_codebooks:
        assert logits.shape == (BATCH, SEQ, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_and_is_finite(arch):
    cfg = get_config(arch, smoke=True)
    opt_cfg = O.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    params, opt_state = steps.init_train_state(jax.random.PRNGKey(0), cfg,
                                               opt_cfg)
    train_step = jax.jit(steps.make_train_step(cfg, opt_cfg))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    params, opt_state, m1 = train_step(params, opt_state, batch)
    assert np.isfinite(float(m1["loss"])), arch
    assert float(m1["grad_norm"]) > 0
    params, opt_state, m2 = train_step(params, opt_state, batch)
    assert np.isfinite(float(m2["loss"]))
    # same batch twice: loss should not explode
    assert float(m2["loss"]) < float(m1["loss"]) * 1.5


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    """Prefill t<S tokens, then decode the rest one-by-one; logits must
    match the full-sequence forward at every decoded position."""
    cfg = get_config(arch, smoke=True)
    if cfg.moe_experts:
        # capacity dropping differs between full-seq and single-token paths
        # by construction; disable drops to compare the routing math exactly
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.moe_experts))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, jax.random.PRNGKey(1), seq=SEQ)
    tokens = batch["tokens"]
    vis = batch.get("vision_embeds")

    full_logits, _, _ = M.forward(params, tokens, cfg, vision_embeds=vis)

    # build an empty cache sized SEQ and replay the sequence through decode
    caches = T.init_trunk_cache(cfg, BATCH, SEQ)
    if vis is not None:
        # pre-compute vision kv into cross caches by a 1-token prefill pass
        _, caches_init = M.prefill_step(params, {**batch, "tokens": tokens[..., :1]}, cfg)
        pat, n_rep, tail = T._pattern_split(cfg)
        for i, kind in enumerate(pat):
            if kind == "cross":
                caches["stack"][i] = jax.tree.map(
                    lambda t: t, caches_init["stack"][i])
        for i, kind in enumerate(tail):
            if kind == "cross":
                caches["tail"][i] = caches_init["tail"][i]

    decode = jax.jit(lambda tok, pos, c: M.decode_step(params, tok, pos, c, cfg))
    got = []
    for t in range(SEQ):
        tok = tokens[..., t:t + 1]
        logits_t, caches = decode(tok, jnp.asarray(t, jnp.int32), caches)
        got.append(logits_t[:, 0] if not cfg.num_codebooks else logits_t[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_assigned_scale():
    """Full configs instantiate *metadata only* here: check the analytic
    param count lands in the right ballpark for the named scale."""
    expected = {
        "llama-3.2-vision-11b": (9e9, 13e9),
        "recurrentgemma-2b": (2e9, 3.6e9),
        "musicgen-medium": (1.2e9, 2.2e9),
        "gemma2-2b": (2e9, 3.5e9),
        "minicpm-2b": (2e9, 3.2e9),
        "starcoder2-3b": (2.5e9, 3.8e9),
        "internlm2-20b": (17e9, 23e9),
        "xlstm-125m": (9e7, 2.1e8),
        "olmoe-1b-7b": (6e9, 8e9),
        "qwen3-moe-30b-a3b": (25e9, 33e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_cells_for_respects_sub_quadratic():
    long_ok = {a for a in ARCHS if get_config(a).sub_quadratic}
    assert long_ok == {"recurrentgemma-2b", "xlstm-125m"}
    for a in ARCHS:
        cells = shp.cells_for(get_config(a))
        assert ("long_500k" in cells) == (a in long_ok)
