"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret mode on CPU (the kernels target TPU; interpret
executes the kernel bodies exactly). Tolerances: f64 near-exact; f32/bf16
allow accumulation-order noise.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gram.ops import gram, centered_gram
from repro.kernels.gram.ref import gram_ref, centered_gram_ref
from repro.kernels.hat_apply.ops import hat_errors
from repro.kernels.hat_apply.ref import hat_apply_ref
from repro.kernels.foldsolve.ops import foldsolve, fold_jitter
from repro.kernels.foldsolve.ref import foldsolve_ref
from repro.kernels.fold_eval.ops import fold_eval
from repro.kernels.fold_eval.ref import fold_eval_np, fold_eval_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.pairdist.ops import pairwise_sq_dists
from repro.kernels.pairdist.ref import pairwise_sq_dists_ref

_TOL = {
    jnp.float64: dict(rtol=1e-9, atol=1e-9),
    jnp.float32: dict(rtol=2e-3, atol=2e-3),
}


def _key(i=0):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------- gram ----

@pytest.mark.parametrize("n,p", [(8, 16), (100, 300), (256, 512), (130, 70),
                                 (33, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_gram_sweep(n, p, dtype):
    x = jax.random.normal(_key(n + p), (n, p), dtype)
    got = gram(x, interpret=True)
    want = gram_ref(x)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=_TOL[dtype]["rtol"],
                               atol=_TOL[dtype]["atol"] * scale)


def test_centered_gram():
    x = jax.random.normal(_key(3), (64, 200), jnp.float64)
    np.testing.assert_allclose(np.asarray(centered_gram(x, interpret=True)),
                               np.asarray(centered_gram_ref(x)), rtol=1e-9,
                               atol=1e-9)


def test_gram_block_shapes():
    x = jax.random.normal(_key(5), (96, 160), jnp.float64)
    want = gram_ref(x)
    for bn, bp in [(32, 32), (48, 80), (96, 160)]:
        got = gram(x, block_n=bn, block_p=bp, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-9)


# ------------------------------------------------------------ pairdist ----

@pytest.mark.parametrize("c,p", [(5, 30), (8, 128), (33, 500), (17, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_pairdist_sweep(c, p, dtype):
    u = jax.random.normal(_key(c * p), (c, p), dtype)
    got = pairwise_sq_dists(u, interpret=True)
    want = pairwise_sq_dists_ref(u)
    scale = float(jnp.max(jnp.abs(want))) + 1.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=_TOL[dtype]["rtol"],
                               atol=_TOL[dtype]["atol"] * scale)
    # the diagonal cancels ‖u‖² + ‖u‖² − 2u·u, so its absolute error scales
    # with the distance magnitudes (visible in f32)
    d = np.asarray(got)
    assert np.all(d >= 0.0)
    assert np.allclose(np.diag(d), 0.0, atol=_TOL[dtype]["atol"] * scale)


def test_pairdist_block_shapes():
    u = jax.random.normal(_key(6), (24, 160), jnp.float64)
    want = pairwise_sq_dists_ref(u)
    for bc, bp in [(8, 32), (24, 80), (24, 160)]:
        got = pairwise_sq_dists(u, block_c=bc, block_p=bp, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-9)


# ----------------------------------------------------------- hat_apply ----

@pytest.mark.parametrize("n,b", [(16, 1), (100, 7), (256, 128), (73, 33)])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_hat_apply_sweep(n, b, dtype):
    h = jax.random.normal(_key(n), (n, n), dtype) / n
    y = jax.random.normal(_key(b + 1), (n, b), dtype)
    got = hat_errors(h, y, interpret=True)
    want = hat_apply_ref(h, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL[dtype])


def test_hat_apply_1d():
    h = jax.random.normal(_key(9), (50, 50), jnp.float64) / 50
    y = jax.random.normal(_key(10), (50,), jnp.float64)
    got = hat_errors(h, y, interpret=True)
    assert got.shape == (50,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y - h @ y),
                               rtol=1e-10)


# ----------------------------------------------------------- foldsolve ----

@pytest.mark.parametrize("k,m,b", [(5, 8, 1), (10, 20, 4), (4, 50, 16),
                                   (2, 1, 3)])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_foldsolve_sweep(k, m, b, dtype):
    key1, key2 = jax.random.split(_key(k * m))
    # realistic H_Te blocks: contraction-like, spectrum well inside (0,1)
    a = jax.random.normal(key1, (k, m, m), dtype) / (3.0 * m**0.5)
    h_te = jnp.einsum("kij,klj->kil", a, a)      # PSD, small norm
    e = jax.random.normal(key2, (k, m, b), dtype)
    got = foldsolve(h_te, e, interpret=True)
    want = foldsolve_ref(h_te, e)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3 if dtype == jnp.float32 else 1e-8,
                               atol=5e-4 if dtype == jnp.float32 else 1e-9)


def test_foldsolve_2d_rhs():
    h_te = jnp.zeros((3, 4, 4), jnp.float64)
    e = jax.random.normal(_key(2), (3, 4), jnp.float64)
    got = foldsolve(h_te, e, interpret=True)     # (I-0)^{-1} e = e
    np.testing.assert_allclose(np.asarray(got), np.asarray(e), rtol=1e-12)


def test_foldsolve_matches_cv_plan_solves():
    """End-to-end: kernel solves == the fastcv cho_solve path on real H."""
    from repro.core import fastcv, folds as foldlib
    from repro.data import synthetic
    x, yc = synthetic.make_classification(_key(0), 40, 120)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(40, 5, seed=1)
    plan = fastcv.prepare(x, f, 1.0, with_train_block=False)
    e_hat = y - plan.h @ y
    h_te = plan.h[f.te_idx[:, :, None], f.te_idx[:, None, :]]
    got = foldsolve(h_te, e_hat[f.te_idx], interpret=True)
    y_dot_te, _ = fastcv.cv_errors(plan, y)
    want = y[f.te_idx] - y_dot_te                 # ė_Te
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-8,
                               atol=1e-9)


# ----------------------------------------------------- flash attention ----

@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("s", [32, 128, 200])
def test_flash_causal_gqa_sweep(hq, hkv, s):
    b, d = 2, 16
    dtype = jnp.float32
    q = jax.random.normal(_key(1), (b, hq, s, d), dtype)
    k = jax.random.normal(_key(2), (b, hkv, s, d), dtype)
    v = jax.random.normal(_key(3), (b, hkv, s, d), dtype)
    scale = 1.0 / d**0.5
    got = flash_attention(q, k, v, scale=scale, block_q=64, block_k=64,
                          interpret=True)
    want = attention_ref(q, k, v, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_local_window(window):
    b, h, s, d = 1, 2, 128, 8
    q = jax.random.normal(_key(4), (b, h, s, d), jnp.float32)
    k = jax.random.normal(_key(5), (b, h, s, d), jnp.float32)
    v = jax.random.normal(_key(6), (b, h, s, d), jnp.float32)
    got = flash_attention(q, k, v, scale=0.3, window=window, block_q=32,
                          block_k=32, interpret=True)
    want = attention_ref(q, k, v, scale=0.3, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_softcap():
    b, h, s, d = 1, 2, 64, 8
    q = jax.random.normal(_key(7), (b, h, s, d), jnp.float32) * 3
    k = jax.random.normal(_key(8), (b, h, s, d), jnp.float32) * 3
    v = jax.random.normal(_key(9), (b, h, s, d), jnp.float32)
    got = flash_attention(q, k, v, scale=0.5, softcap=20.0, block_q=32,
                          block_k=32, interpret=True)
    want = attention_ref(q, k, v, scale=0.5, softcap=20.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_bf16_io():
    b, h, s, d = 1, 2, 64, 16
    q = jax.random.normal(_key(10), (b, h, s, d)).astype(jnp.bfloat16)
    k = jax.random.normal(_key(11), (b, h, s, d)).astype(jnp.bfloat16)
    v = jax.random.normal(_key(12), (b, h, s, d)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, scale=0.25, block_q=32, block_k=32,
                          interpret=True)
    want = attention_ref(q, k, v, scale=0.25)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want, dtype=np.float32),
                               rtol=5e-2, atol=5e-2)


# ----------------------------------------------------------- fold_eval ----

def _fold_eval_problem(k, m, n, b, dtype, seed=0):
    """Realistic fused-eval inputs: PSD small-norm hat, random fold gathers."""
    k1, k2, k3 = jax.random.split(_key(seed + k * m + n + b), 3)
    a = jax.random.normal(k1, (n, n), dtype) / (3.0 * n**0.5)
    h = a @ a.T                                   # PSD, spectrum in (0, 1)
    te = jax.random.permutation(k2, n)[: k * m].reshape(k, m)
    h_rows = h[te]
    h_te = h[te[:, :, None], te[:, None, :]]
    y = jax.random.normal(k3, (n, b), dtype)
    return h_rows, h_te, y, y[te]


@pytest.mark.parametrize("k,m,n,b", [(4, 8, 40, 5), (3, 7, 33, 17),
                                     (5, 16, 80, 1), (2, 12, 50, 130)])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_fold_eval_sweep(k, m, n, b, dtype):
    """Fused kernel vs jnp oracle and host-LAPACK ground truth.

    Shapes include ragged fold coverage (K·m < N) and B both smaller and
    larger than the batch block.
    """
    h_rows, h_te, y, y_te = _fold_eval_problem(k, m, n, b, dtype)
    got = fold_eval(h_rows, h_te, y, y_te, interpret=True)
    t_ref, _ = fold_eval_ref(h_rows, h_te, y, y_te)
    t_np, _ = fold_eval_np(h_rows, h_te, y, y_te)
    scale = 1.0 + float(np.max(np.abs(t_np)))
    tol = 1e-5 if dtype == jnp.float32 else 1e-10
    assert float(np.max(np.abs(np.asarray(got) - t_np))) / scale < tol
    np.testing.assert_allclose(np.asarray(got), np.asarray(t_ref),
                               rtol=5e-4 if dtype == jnp.float32 else 1e-9,
                               atol=tol * scale)


def test_fold_eval_block_shapes():
    """Grid tiling is numerically invisible, dividing blocks or not."""
    h_rows, h_te, y, y_te = _fold_eval_problem(3, 8, 48, 20, jnp.float64)
    t_np, _ = fold_eval_np(h_rows, h_te, y, y_te)
    for bn, bb in [(16, 8), (48, 32), (32, 16), (64, 128)]:
        got = fold_eval(h_rows, h_te, y, y_te, block_n=bn, block_b=bb,
                        interpret=True)
        np.testing.assert_allclose(np.asarray(got), t_np, rtol=1e-9,
                                   atol=1e-9)


def _near_singular_h_te(k, m, dtype, seed=7):
    """H_Te blocks making I − H_Te singular to machine precision."""
    q, _ = jnp.linalg.qr(jax.random.normal(_key(seed), (m, m), dtype))
    d = jnp.concatenate([jnp.ones((m - 1,), dtype), jnp.array([1e-14], dtype)])
    a = (q * d[None, :]) @ q.T                    # I − H_Te = Q diag(d) Qᵀ
    h_te = jnp.eye(m, dtype=dtype)[None] - a[None]
    return jnp.tile(h_te, (k, 1, 1))


def test_foldsolve_jitter_near_singular():
    """The docstring's λ→0 lifeline: the residual-checked retry keeps
    near-singular folds finite and matches the shifted LAPACK solve."""
    k, m, b = 3, 12, 4
    h_te = _near_singular_h_te(k, m, jnp.float64)
    e = jax.random.normal(_key(8), (k, m, b), jnp.float64)
    raw = foldsolve(h_te, e, interpret=True, jitter=None)
    got = foldsolve(h_te, e, interpret=True)
    assert bool(jnp.all(jnp.isfinite(got)))
    # the retry solves A + εI exactly: compare against LAPACK on the
    # shifted system (relative tolerance — solutions are O(1/ε)-large)
    eps = np.asarray(fold_jitter(h_te))
    eye = np.eye(m)
    want = np.stack([
        np.linalg.solve(eye - np.asarray(h_te[i]) + eps[i] * eye,
                        np.asarray(e[i])) for i in range(k)
    ])
    rel = np.max(np.abs(np.asarray(got) - want)) / np.max(np.abs(want))
    assert rel < 1e-8
    # and the raw path really was pathological (else the test is vacuous)
    assert (not bool(jnp.all(jnp.isfinite(raw)))
            or float(jnp.max(jnp.abs(raw))) > 1e6 * np.max(np.abs(want)))


def test_foldsolve_jitter_noop_when_well_conditioned():
    """jitter="auto" must be bit-identical to jitter=None off the edge."""
    k1, k2 = jax.random.split(_key(13))
    a = jax.random.normal(k1, (4, 10, 10), jnp.float64) / 10.0
    h_te = jnp.einsum("kij,klj->kil", a, a)
    e = jax.random.normal(k2, (4, 10, 6), jnp.float64)
    np.testing.assert_array_equal(
        np.asarray(foldsolve(h_te, e, interpret=True)),
        np.asarray(foldsolve(h_te, e, interpret=True, jitter=None)))


def test_fold_eval_jitter_near_singular():
    """The fused wrapper ports the same retry: finite output matching the
    shifted solve, with ê_Te reused from the fused launch."""
    k, m, n, b = 2, 8, 32, 5
    h_rows, _, y, y_te = _fold_eval_problem(k, m, n, b, jnp.float64)
    h_te = _near_singular_h_te(k, m, jnp.float64)
    got = fold_eval(h_rows, h_te, y, y_te, interpret=True)
    assert bool(jnp.all(jnp.isfinite(got)))
    e = np.asarray(y_te) - np.einsum("kmn,nb->kmb", np.asarray(h_rows),
                                     np.asarray(y))
    eps = np.asarray(fold_jitter(h_te))
    eye = np.eye(m)
    want = np.stack([
        np.linalg.solve(eye - np.asarray(h_te[i]) + eps[i] * eye, e[i])
        for i in range(k)
    ])
    rel = np.max(np.abs(np.asarray(got) - want)) / np.max(np.abs(want))
    assert rel < 1e-8


# ------------------------------------------------------- bf16_gram mode ----

def test_gram_bf16_precision_bound():
    """bf16_gram stays inside the documented ~2·2⁻⁸‖X_c‖² bound and the
    Pallas kernel matches the XLA fallback's numerics."""
    from repro.kernels.gram.ops import centered_gram_xla
    x = jax.random.normal(_key(21), (96, 300), jnp.float32)
    exact = np.asarray(centered_gram_ref(x))
    scale = float(np.max(np.abs(exact)))
    bound = 4.0 * 2.0**-8 * scale                 # 2× headroom on the bound
    for got in (gram(x, center=True, precision="bf16_gram", interpret=True),
                centered_gram_xla(x, precision="bf16_gram")):
        got = np.asarray(got)
        assert got.dtype == exact.dtype
        assert float(np.max(np.abs(got - exact))) < bound
    pallas = np.asarray(gram(x, center=True, precision="bf16_gram",
                             interpret=True))
    xla = np.asarray(centered_gram_xla(x, precision="bf16_gram"))
    np.testing.assert_allclose(pallas, xla, rtol=1e-6, atol=1e-6 * scale)


def test_gram_fp32_precision_is_default():
    x = jax.random.normal(_key(22), (32, 64), jnp.float64)
    np.testing.assert_array_equal(
        np.asarray(gram(x, center=True, interpret=True)),
        np.asarray(gram(x, center=True, precision="fp32", interpret=True)))
    with pytest.raises(ValueError, match="precision"):
        gram(x, precision="fp8")
