"""Int8 KV-cache quantisation: decode must track the bf16-cache decode
closely (serving memory lever; EXPERIMENTS §Dry-run)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models import layers as L
from repro.models import model as M
from repro.models import transformer as T


def test_quantize_roundtrip_error_bounded():
    k = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 4, 16), jnp.float32)
    q, s = L.quantize_kv(k)
    back = L.dequantize_kv(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - k)) / jnp.max(jnp.abs(k)))
    assert rel < 1.0 / 127.0 + 1e-3


@pytest.mark.parametrize("arch", ["gemma2-2b", "internlm2-20b"])
def test_int8_decode_tracks_bf16_decode(arch):
    cfg = get_config(arch, smoke=True)
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    seq, batch = 16, 2
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                                cfg.vocab_size)

    def run(cfg_run):
        caches = T.init_trunk_cache(cfg_run, batch, seq)
        decode = jax.jit(
            lambda tok, pos, c: M.decode_step(params, tok, pos, c, cfg_run))
        outs = []
        for t in range(seq):
            logits, caches = decode(tokens[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32), caches)
            outs.append(logits[:, 0])
        return jnp.stack(outs, 1)

    full = run(cfg)
    quant = run(cfg_q)
    # int8 KV: small logit perturbation, same argmax almost everywhere
    err = float(jnp.mean(jnp.abs(full - quant)))
    scale = float(jnp.mean(jnp.abs(full))) + 1e-9
    assert err / scale < 0.05, err / scale
    agree = float(jnp.mean((jnp.argmax(full, -1) == jnp.argmax(quant, -1))
                           .astype(jnp.float32)))
    assert agree > 0.9, agree
