"""Property-based tests (hypothesis) for the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import fastcv, folds as foldlib, lda, multiclass
from repro.data import synthetic

_SETTINGS = dict(max_examples=12, deadline=None, derandomize=True)


@st.composite
def cv_problem(draw):
    n = draw(st.integers(min_value=24, max_value=60))
    p = draw(st.integers(min_value=4, max_value=80))
    k = draw(st.sampled_from([2, 3, 4, 6]))
    lam = draw(st.floats(min_value=0.05, max_value=20.0))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return n, p, k, lam, seed


@given(cv_problem())
@settings(**_SETTINGS)
def test_analytical_cv_exactness_property(problem):
    """∀ (N,P,K,λ): analytical dvals == retrained regression dvals."""
    n, p, k, lam, seed = problem
    x, yc = synthetic.make_classification(jax.random.PRNGKey(seed), n, p)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, k, seed=seed % 1000)
    dv_fast, _ = fastcv.binary_cv(x, y, f, lam=lam, adjust_bias=False)
    dv_std, _ = lda.standard_cv_binary(x, y, f, lam=lam, form="regression")
    np.testing.assert_allclose(np.asarray(dv_fast), np.asarray(dv_std),
                               rtol=1e-6, atol=1e-7)


@given(cv_problem())
@settings(**_SETTINGS)
def test_hat_matrix_spectrum_property(problem):
    """H is symmetric with eigenvalues in [0, 1] (ridge smoother + intercept)."""
    n, p, _, lam, seed = problem
    x, _ = synthetic.make_classification(jax.random.PRNGKey(seed), n, p)
    h = fastcv.hat_matrix(x, lam)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h).T, atol=1e-8)
    ev = np.linalg.eigvalsh(np.asarray(h))
    assert ev.min() > -1e-8
    assert ev.max() < 1.0 + 1e-8


@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.floats(min_value=0.1, max_value=5.0))
@settings(**_SETTINGS)
def test_label_coding_invariance_property(seed, scale):
    """App. A: the direction of w is invariant to the numeric class codes."""
    n, p = 40, 12
    x, yc = synthetic.make_classification(jax.random.PRNGKey(seed), n, p)
    y1 = jnp.where(yc == 0, -1.0, 1.0)
    y2 = jnp.where(yc == 0, 0.0, scale)          # arbitrary coding
    w1, _ = lda.fit_binary_regression(x, y1, 0.5)
    w2, _ = lda.fit_binary_regression(x, y2, 0.5)
    cos = jnp.dot(w1, w2) / (jnp.linalg.norm(w1) * jnp.linalg.norm(w2))
    assert abs(float(cos)) > 1 - 1e-7


@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=3, max_value=6))
@settings(**_SETTINGS)
def test_multiclass_exactness_property(seed, c):
    n, p, k, lam = 60, 24, 4, 1.0
    x, y = synthetic.make_classification(jax.random.PRNGKey(seed), n, p, c,
                                         class_sep=2.0)
    f = foldlib.stratified_kfold(np.asarray(y), k, seed=seed % 997)
    pred_fast, _ = multiclass.analytical_cv_multiclass(x, y, f, c, lam)
    pred_std, _ = multiclass.standard_cv_multiclass(x, y, f, c, lam)
    np.testing.assert_array_equal(np.asarray(pred_fast), np.asarray(pred_std))


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(**_SETTINGS)
def test_fold_solve_consistency_property(seed):
    """Σ_folds ẏ_Te errors reproduce per-fold retrained residual norms —
    (I − H_Te) ė_Te == ê_Te exactly (Eq. 14 rearranged)."""
    n, p, k, lam = 36, 50, 3, 2.0
    x, yc = synthetic.make_classification(jax.random.PRNGKey(seed), n, p)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, k, seed=seed % 911)
    plan = fastcv.prepare(x, f, lam, with_train_block=False)
    y_hat = plan.h @ y
    e_hat = y - y_hat
    y_dot_te, _ = fastcv.cv_errors(plan, y)
    for i in range(k):
        te = np.asarray(f.te_idx[i])
        h_te = np.asarray(plan.h)[np.ix_(te, te)]
        e_dot = np.asarray(y[te] - y_dot_te[i])
        lhs = (np.eye(len(te)) - h_te) @ e_dot
        np.testing.assert_allclose(lhs, np.asarray(e_hat)[te], rtol=1e-7,
                                   atol=1e-9)
