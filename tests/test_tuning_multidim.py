"""Beyond-paper extensions: analytical λ tuning, fold weights, multi-dim."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastcv, folds as foldlib, multidim, regression, tuning
from repro.data import synthetic


def test_loo_curve_matches_explicit_loo():
    """Spectral LOO per λ == explicit plan-based LOO per λ."""
    n, p = 40, 120
    x, y = synthetic.make_regression(jax.random.PRNGKey(0), n, p)
    lambdas = jnp.asarray([0.5, 5.0, 50.0])
    curve = tuning.loo_curve(x, y, lambdas, criterion="mse")
    f = foldlib.loo(n)
    for i, lam in enumerate(np.asarray(lambdas)):
        preds, y_te = regression.analytical_cv(x, y, f, lam=float(lam))
        mse = float(jnp.mean((preds - y_te) ** 2))
        assert float(curve[i]) == pytest.approx(mse, rel=1e-6), (i, lam)


def test_tune_ridge_picks_generalising_lambda():
    """On noisy high-dim data, tuned λ beats the extremes of the grid."""
    n, p = 60, 400
    x, y = synthetic.make_regression(jax.random.PRNGKey(1), n, p, noise=0.5)
    res = tuning.tune_ridge(x, y)
    assert float(res.scores.min()) == pytest.approx(float(res.best_score))
    # best beats both grid endpoints
    assert float(res.best_score) <= float(res.scores[0])
    assert float(res.best_score) <= float(res.scores[-1])


def test_tune_ridge_classification_criterion():
    x, yc = synthetic.make_classification(jax.random.PRNGKey(2), 50, 200,
                                          class_sep=2.0)
    y = jnp.where(yc == 0, -1.0, 1.0)
    res = tuning.tune_ridge(x, y, criterion="error")
    assert 0.0 <= float(res.best_score) <= 0.5


def test_fold_weights_match_retrained_ridge():
    n, p, k, lam = 36, 90, 4, 2.0
    x, yc = synthetic.make_classification(jax.random.PRNGKey(3), n, p)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, k, seed=0)
    ws, bs = multidim.fold_weights(x, y, f, lam)
    for i in range(k):
        tr = np.asarray(f.tr_idx[i])
        w_ref, b_ref = regression.fit_ridge(x[tr], y[tr], lam)
        np.testing.assert_allclose(np.asarray(ws[i]), np.asarray(w_ref),
                                   rtol=1e-6, atol=1e-8)
        assert float(bs[i]) == pytest.approx(float(b_ref), rel=1e-6)


def test_fold_weights_reproduce_analytical_dvals():
    """x_te @ w_k + b_k must equal the Eq.-14 decision values."""
    n, p, k, lam = 40, 150, 5, 1.0
    x, yc = synthetic.make_classification(jax.random.PRNGKey(4), n, p)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, k, seed=1)
    ws, bs = multidim.fold_weights(x, y, f, lam)
    dv_fast, _ = fastcv.binary_cv(x, y, f, lam=lam, adjust_bias=False)
    dv_w = jnp.einsum("kmp,kp->km", x[f.te_idx], ws) + bs[:, None]
    np.testing.assert_allclose(np.asarray(dv_w), np.asarray(dv_fast),
                               rtol=1e-6, atol=1e-8)


def test_cv_grid_matches_pointwise():
    n, p, q = 32, 64, 4
    keys = jax.random.split(jax.random.PRNGKey(5), q)
    xs = jnp.stack([synthetic.make_classification(kk, n, p, class_sep=2.0)[0]
                    for kk in keys])
    _, yc = synthetic.make_classification(keys[0], n, p)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, 4, seed=2)
    accs = multidim.cv_grid(xs, y, f, lam=1.0)
    for i in range(q):
        dv, y_te = fastcv.binary_cv(xs[i], y, f, lam=1.0)
        pred = jnp.where(dv >= 0, 1.0, -1.0)
        want = float(jnp.mean(pred == jnp.sign(y_te)))
        assert float(accs[i]) == pytest.approx(want)


def test_time_generalization_diagonal_and_transfer():
    """Diagonal ≈ per-point CV; an informative point does not transfer to
    a pure-noise point (off-diagonal ≈ chance)."""
    n, p = 48, 80
    key = jax.random.PRNGKey(6)
    x_sig, yc = synthetic.make_classification(key, n, p, class_sep=3.0)
    y = jnp.where(yc == 0, -1.0, 1.0)
    x_noise = jax.random.normal(jax.random.fold_in(key, 1), (n, p),
                                x_sig.dtype)
    xs = jnp.stack([x_sig, x_noise])
    f = foldlib.kfold(n, 4, seed=3)
    tg = np.asarray(multidim.time_generalization(xs, y, f, lam=1.0))
    assert tg.shape == (2, 2)
    assert tg[0, 0] > 0.8                  # signal decodes
    assert abs(tg[0, 1] - 0.5) < 0.25      # no transfer to noise
    assert abs(tg[1, 1] - 0.5) < 0.3       # noise point at chance
