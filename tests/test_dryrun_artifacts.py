"""Validate the multi-pod dry-run artifacts (deliverable e).

These tests read the JSON records produced by ``repro.launch.dryrun``.
They are skipped when the sweep has not been run (CI without the
results directory), and act as the regression gate when it has: every
runnable cell must have compiled on both meshes.
"""

import json
from pathlib import Path

import pytest

from repro.configs.base import get_config, list_archs
from repro.configs import shapes as shp

RESULTS = Path(__file__).parent.parent / "results" / "dryrun_final"

pytestmark = pytest.mark.skipif(
    not RESULTS.exists() or not any(RESULTS.glob("*.json")),
    reason="dry-run sweep not present (run repro.launch.dryrun first)")


def _load(arch, shape, mesh):
    f = RESULTS / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def _cells():
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in shp.cells_for(cfg):
            yield arch, shape


def test_every_cell_compiles_on_both_meshes():
    missing, failed = [], []
    for arch, shape in _cells():
        for mesh in ("16x16", "2x16x16"):
            rec = _load(arch, shape, mesh)
            if rec is None:
                missing.append((arch, shape, mesh))
            elif not rec.get("ok"):
                failed.append((arch, shape, mesh, rec.get("error", "")[:120]))
    assert not failed, f"failed cells: {failed}"
    assert not missing, f"missing cells: {missing}"


def test_cell_count_is_complete():
    runnable = list(_cells())
    assert len(runnable) == 32          # 40 assigned − 8 documented skips
    skipped = [(a, "long_500k") for a in list_archs()
               if not get_config(a).sub_quadratic]
    assert len(skipped) == 8


def test_multipod_cells_record_the_pod_axis():
    for arch, shape in _cells():
        rec = _load(arch, shape, "2x16x16")
        if rec and rec.get("ok"):
            assert rec["num_chips"] == 512, (arch, shape)


def test_roofline_inputs_present():
    for arch, shape in _cells():
        rec = _load(arch, shape, "16x16")
        if rec and rec.get("ok"):
            la = rec["loop_aware"]
            assert la["flops"] > 0, (arch, shape)
            assert rec["memory"]["temp_bytes"] is not None


def test_memory_within_hbm_budget():
    """16 GB/chip v5e budget: argument+temp must fit for every shipped
    cell. 2% slack absorbs XLA-CPU layout-padding differences vs TPU HLO
    (internlm2-20b train sits at the boundary: 16.0-16.1 GB, see
    EXPERIMENTS §Dry-run)."""
    hbm = int(16 * 2**30 * 1.02)
    over = []
    for arch, shape in _cells():
        rec = _load(arch, shape, "16x16")
        if not rec or not rec.get("ok"):
            continue
        m = rec["memory"]
        total = (m["argument_bytes"] or 0) + (m["temp_bytes"] or 0)
        if total > hbm:
            over.append((arch, shape, round(total / 2**30, 1)))
    assert not over, f"cells over 16GB/chip: {over}"
