"""Tests for the repro.serve subsystem: engine exactness, plan-cache
eviction, micro-batcher round-trips, and the no-recompile guarantee.

Written against the unified Workload API (the deprecated request shims
were removed at 0.3; see the README migration table)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastcv, folds as foldlib, multiclass, permutation, regression
from repro.data import synthetic
from repro.serve import (CVEngine, DatasetSpec, EngineConfig, EngineServer,
                         MicroBatcher, PlanCache, Workload, bucket_size,
                         serve)

N, P, K, LAM = 48, 96, 4, 1.0


@pytest.fixture(scope="module")
def problem():
    x, yc = synthetic.make_classification(jax.random.PRNGKey(0), N, P,
                                          num_classes=3, class_sep=2.0)
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    f = foldlib.kfold(N, K, seed=1)
    return x, y, yc, f


@pytest.fixture()
def engine():
    return CVEngine(EngineConfig(cache_bytes=64 << 20))


# ---------------------------------------------------------------------------
# Engine results are bit-identical to the direct library calls
# ---------------------------------------------------------------------------


def test_engine_binary_bit_identical(problem, engine):
    x, y, _, f = problem
    _, plan = engine.plan(x, f, LAM)
    dv_direct, _ = fastcv.binary_cv(x, y, f, lam=LAM)
    dv_engine = engine.eval_binary(plan, y)
    assert dv_direct.shape == dv_engine.shape
    assert bool(jnp.all(dv_direct == dv_engine))


def test_engine_multiclass_bit_identical(problem, engine):
    x, _, yc, f = problem
    _, plan = engine.plan(x, f, LAM)
    pred_direct, y_te = multiclass.analytical_cv_multiclass(x, yc, f, 3, LAM)
    pred_engine = engine.eval_multiclass(plan, yc, 3)
    assert bool(jnp.all(pred_direct == pred_engine))


def test_engine_ridge_bit_identical(problem, engine):
    x, y, _, f = problem
    # ridge is served from the superset (train-block) plan when cached
    _, plan = engine.plan(x, f, LAM)
    r_direct, _ = regression.analytical_cv(x, y, f, lam=LAM)
    r_engine = engine.eval_ridge(plan, y)
    assert bool(jnp.all(r_direct == r_engine))


def test_engine_batched_columns_match_singles(problem, engine):
    """Each column of a (N, B) batch matches the single-query answer.

    Only numerically (tight tolerance), not bitwise: XLA blocks the H·Y
    matmul differently for different padded batch shapes."""
    x, y, _, f = problem
    _, plan = engine.plan(x, f, LAM)
    cols = jnp.stack([y, -y, jnp.roll(y, 3)], axis=1)
    batched = engine.eval_binary(plan, cols)
    for b in range(cols.shape[1]):
        single = engine.eval_binary(plan, cols[:, b])
        np.testing.assert_allclose(np.asarray(batched[..., b]),
                                   np.asarray(single), rtol=1e-9, atol=1e-12)


def test_engine_permutation_matches_library(problem, engine):
    x, y, _, f = problem
    _, plan = engine.plan(x, f, LAM)
    key = jax.random.PRNGKey(7)
    res_e = engine.permutation_binary(plan, y, 20, key)
    res_l = permutation.analytical_permutation_binary(x, y, f, LAM, 20, key)
    np.testing.assert_allclose(np.asarray(res_e.null), np.asarray(res_l.null),
                               atol=1e-12)
    assert abs(float(res_e.observed) - float(res_l.observed)) < 1e-12
    assert abs(float(res_e.p) - float(res_l.p)) < 1e-12


def test_engine_gram_impl_pallas_matches_xla(problem):
    x, y, _, f = problem
    e_xla = CVEngine(EngineConfig(gram_impl="xla"))
    e_pal = CVEngine(EngineConfig(gram_impl="pallas"))
    _, p_xla = e_xla.plan(x, f, LAM)
    _, p_pal = e_pal.plan(x, f, LAM)
    np.testing.assert_allclose(np.asarray(p_xla.h), np.asarray(p_pal.h),
                               atol=1e-10)


# ---------------------------------------------------------------------------
# Plan cache: LRU under a byte budget
# ---------------------------------------------------------------------------


def _dummy_plan(n=32, k=2, m=8):
    z = jnp.zeros
    return fastcv.CVPlan(z((n, n)), z((k, m), jnp.int32),
                         z((k, n - m), jnp.int32), z((k, m, m)), None)


def test_cache_eviction_respects_byte_budget():
    one = _dummy_plan().nbytes
    cache = PlanCache(byte_budget=2 * one + one // 2)   # fits exactly two
    cache.put("a", _dummy_plan())
    cache.put("b", _dummy_plan())
    assert cache.stats.evictions == 0
    cache.put("c", _dummy_plan())                        # evicts LRU = "a"
    assert cache.stats.evictions == 1
    assert cache.stats.bytes_in_use <= cache.stats.byte_budget
    assert "a" not in cache and "b" in cache and "c" in cache


def test_cache_lru_order_respects_recency():
    one = _dummy_plan().nbytes
    cache = PlanCache(byte_budget=2 * one + one // 2)
    cache.put("a", _dummy_plan())
    cache.put("b", _dummy_plan())
    assert cache.get("a") is not None                    # refresh "a"
    cache.put("c", _dummy_plan())                        # now evicts "b"
    assert "b" not in cache and "a" in cache and "c" in cache
    assert cache.stats.hits == 1


def test_cache_rejects_oversized_plan():
    """Admission control: a plan that can never fit is served un-cached."""
    one = _dummy_plan().nbytes
    cache = PlanCache(byte_budget=one // 2)
    admitted = cache.put("big", _dummy_plan())
    assert not admitted
    assert "big" not in cache
    assert cache.stats.oversized == 1
    assert cache.stats.bytes_in_use == 0


def test_cache_oversized_plan_does_not_evict_residents():
    """An oversized build must NOT flush the cache to make room it can
    never use (ROADMAP admission-control item)."""
    one = _dummy_plan().nbytes
    cache = PlanCache(byte_budget=2 * one + one // 2)
    cache.put("a", _dummy_plan())
    cache.put("b", _dummy_plan())
    big = _dummy_plan(n=96, k=4, m=24)                   # > whole budget
    assert big.nbytes > cache.stats.byte_budget
    plan, was_hit = cache.get_or_build("big", lambda: big)
    assert plan is big and not was_hit                   # still served
    assert "big" not in cache
    assert "a" in cache and "b" in cache                 # residents survive
    assert cache.stats.evictions == 0
    assert cache.stats.oversized == 1
    # the counter keeps counting on repeat builds (it never becomes a hit)
    cache.get_or_build("big", lambda: big)
    assert cache.stats.oversized == 2


def test_cache_pin_exempts_from_eviction_and_pressure():
    one = _dummy_plan().nbytes
    cache = PlanCache(byte_budget=2 * one + one // 2)   # fits exactly two
    cache.put("a", _dummy_plan())
    assert cache.pin("a")
    assert cache.stats.pinned == 1 and cache.stats.pinned_bytes == one
    cache.put("b", _dummy_plan())
    cache.put("c", _dummy_plan())
    cache.put("d", _dummy_plan())
    # "a" is LRU by recency but pinned: "b" is evicted instead, and the
    # pinned bytes don't count against the pressure budget
    assert "a" in cache
    assert "b" not in cache and "c" in cache and "d" in cache
    assert cache.stats.bytes_in_use - cache.stats.pinned_bytes <= cache.stats.byte_budget
    # pin is idempotent; pinning a missing key is a no-op
    assert cache.pin("a") and cache.stats.pinned == 1
    assert not cache.pin("zzz")


def test_cache_unpin_resubjects_to_pressure():
    one = _dummy_plan().nbytes
    cache = PlanCache(byte_budget=2 * one + one // 2)
    cache.put("a", _dummy_plan())
    cache.pin("a")
    cache.put("b", _dummy_plan())
    cache.put("c", _dummy_plan())
    assert len(cache) == 3                               # a pinned + b + c
    assert cache.unpin("a")
    assert not cache.unpin("a")                          # already unpinned
    assert cache.stats.pinned == 0 and cache.stats.pinned_bytes == 0
    # unpinned "a" counts again: 3 * one > budget -> one eviction, and "a"
    # itself was refreshed most-recent so the LRU victim is "b"
    assert len(cache) == 2
    assert "a" in cache and "b" not in cache
    cache.clear()
    assert cache.stats.pinned == 0 and len(cache) == 0


def test_engine_cache_eviction_end_to_end(problem):
    x, y, _, f = problem
    _, probe = CVEngine().plan(x, f, LAM)
    engine = CVEngine(EngineConfig(cache_bytes=2 * probe.nbytes + 1))
    for lam in (0.5, 1.0, 2.0, 4.0):                     # 4 distinct plans
        engine.plan(x, f, lam)
    stats = engine.stats()
    assert stats["evictions"] >= 2
    assert stats["bytes_in_use"] <= stats["byte_budget"]


# ---------------------------------------------------------------------------
# Micro-batcher: ragged round-trips
# ---------------------------------------------------------------------------


def test_bucket_size():
    assert bucket_size(1) == 1
    assert bucket_size(3) == 4
    assert bucket_size(33) == 64
    assert bucket_size(1024) == 1024
    assert bucket_size(1500) == 2048                     # multiple of top


def test_batcher_ragged_columns_round_trip():
    mb = MicroBatcher()
    n = 10
    rng = np.random.default_rng(0)
    widths = [1, 3, 2, 5]
    ys = [jnp.asarray(rng.normal(size=(n,)))] + [
        jnp.asarray(rng.normal(size=(n, w))) for w in widths[1:]]
    outs = mb.run_columns(ys, lambda batch: batch * 2.0)
    assert outs[0].shape == (n,)
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(ys[0]) * 2)
    for y, out, w in zip(ys[1:], outs[1:], widths[1:]):
        assert out.shape == (n, w)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(y) * 2)


def test_batcher_ragged_rows_round_trip():
    mb = MicroBatcher()
    n = 10
    ys = [jnp.arange(n), jnp.stack([jnp.arange(n)] * 3) + 1,
          jnp.arange(n)[None, :] + 2]
    outs = mb.run_rows(ys, lambda batch: batch + 100)
    assert outs[0].shape == (n,)
    assert outs[1].shape == (3, n)
    assert outs[2].shape == (1, n)
    np.testing.assert_array_equal(np.asarray(outs[0]),
                                  np.arange(n) + 100)


def test_plan_key_distinguishes_train_indices(problem):
    """Same te_idx but different tr_idx must NOT collide in the cache:
    the plan's train blocks and bias adjustment depend on tr_idx."""
    x, _, _, f = problem
    f2 = foldlib.Folds.with_indices(f.te_idx, f.tr_idx[:, ::2])
    assert fastcv.plan_key(x, f, LAM) != fastcv.plan_key(x, f2, LAM)
    engine = CVEngine()
    _, p1 = engine.plan(x, f, LAM)
    _, p2 = engine.plan(x, f2, LAM)
    assert engine.stats()["plans_built"] == 2
    assert p1.tr_idx.shape != p2.tr_idx.shape


def test_permutation_indices_prefix_stable():
    """Larger T (bucket rounding in the engine) keeps the leading rows."""
    key = jax.random.PRNGKey(3)
    small = permutation.permutation_indices(key, 48, 20)
    big = permutation.permutation_indices(key, 48, 32)
    np.testing.assert_array_equal(np.asarray(small), np.asarray(big[:20]))


def test_folds_with_indices_matches_kfold(problem):
    x, y, _, f = problem
    f2 = foldlib.Folds.with_indices(f.te_idx, f.tr_idx)
    assert f2.k == f.k and f2.test_size == f.test_size
    dv1, _ = fastcv.binary_cv(x, y, f, lam=LAM)
    dv2, _ = fastcv.binary_cv(x, y, f2, lam=LAM)
    assert bool(jnp.all(dv1 == dv2))


# ---------------------------------------------------------------------------
# No-recompile guarantee (compile-counter assertion)
# ---------------------------------------------------------------------------


def test_second_same_bucket_request_triggers_no_recompile(problem, engine):
    x, y, _, f = problem
    _, plan = engine.plan(x, f, LAM)
    engine.permutation_binary(plan, y, 17, jax.random.PRNGKey(0))
    warm = engine.compile_count()
    # different T, same bucket (32); different seed; same plan
    engine.permutation_binary(plan, y, 23, jax.random.PRNGKey(1))
    engine.permutation_binary(plan, y, 30, jax.random.PRNGKey(2))
    assert engine.compile_count() == warm
    # a second *dataset* with identical shapes also reuses the programs
    x2, yc2 = synthetic.make_classification(jax.random.PRNGKey(9), N, P)
    y2 = jnp.where(yc2 == 0, -1.0, 1.0)
    _, plan2 = engine.plan(x2, f, LAM)
    engine.permutation_binary(plan2, y2, 20, jax.random.PRNGKey(3))
    assert engine.compile_count() == warm


def test_cv_eval_no_recompile_across_batch_sizes(problem, engine):
    x, y, _, f = problem
    _, plan = engine.plan(x, f, LAM)
    engine.eval_binary(plan, jnp.stack([y] * 3, axis=1))    # bucket 4
    warm = engine.compile_count()
    engine.eval_binary(plan, jnp.stack([y] * 4, axis=1))    # same bucket
    engine.eval_binary(plan, y[:, None])                    # bucket 1: +1
    engine.eval_binary(plan, y)                             # bucket 1 again
    assert engine.compile_count() == warm + 1


# ---------------------------------------------------------------------------
# Driver + threaded server
# ---------------------------------------------------------------------------


def _requests(problem, n_perm=12):
    x, y, yc, f = problem
    spec = DatasetSpec(x, f, LAM)
    return [
        Workload(kind="cv", dataset=spec, y=y, estimator="binary"),
        Workload(kind="cv", dataset=spec, y=-y, estimator="binary"),
        Workload(kind="cv", dataset=spec, y=y, estimator="ridge"),
        Workload(kind="cv", dataset=spec, y=yc, estimator="multiclass", num_classes=3),
        Workload(kind="permutation", dataset=spec, y=y, n_perm=n_perm, seed=4),
        Workload(kind="tune", x=x, y=y),
    ]


def test_serve_driver_mixed_batch(problem):
    x, y, yc, f = problem
    engine = CVEngine()
    responses = serve(engine, _requests(problem))
    dv, _ = fastcv.binary_cv(x, y, f, lam=LAM)
    # coalesced into a (N, 2) batch -> numerically equal, not bitwise
    np.testing.assert_allclose(np.asarray(responses[0].values),
                               np.asarray(dv), rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(responses[1].values),
                               np.asarray(-dv), rtol=1e-9, atol=1e-12)
    pred, _ = multiclass.analytical_cv_multiclass(x, yc, f, 3, LAM)
    assert bool(jnp.all(responses[3].values == pred))
    assert responses[4].null.shape == (12,)
    assert 0.0 < float(responses[4].p) <= 1.0
    assert float(responses[5].result.best_lambda) > 0.0
    # whole mixed batch shares ONE plan build
    assert engine.stats()["plans_built"] == 1


def test_serve_raw_index_folds(problem):
    """Requests may carry bare (te_idx, tr_idx) arrays instead of Folds."""
    x, y, _, f = problem
    spec = DatasetSpec(x, (np.asarray(f.te_idx), np.asarray(f.tr_idx)), LAM)
    engine = CVEngine()
    (resp,) = serve(engine, [Workload(kind="cv", dataset=spec, y=y, estimator="binary")])
    dv, _ = fastcv.binary_cv(x, y, f, lam=LAM)
    assert bool(jnp.all(resp.values == dv))


def test_threaded_server_matches_sync(problem):
    engine = CVEngine()
    requests = _requests(problem) * 3
    sync = serve(CVEngine(), requests)
    with EngineServer(engine, max_batch=8, max_wait_ms=5.0) as server:
        futures = [server.submit(r) for r in requests]
        results = [fu.result(timeout=300) for fu in futures]
    assert server.requests_served == len(requests)
    for got, want in zip(results, sync):
        assert type(got) is type(want)
        # worker micro-batches may split differently than one sync batch,
        # so padded shapes (and hence last-bit rounding) can differ
        if hasattr(want, "values"):
            np.testing.assert_allclose(np.asarray(got.values),
                                       np.asarray(want.values),
                                       rtol=1e-9, atol=1e-12)
        elif hasattr(want, "null"):
            np.testing.assert_allclose(np.asarray(got.null),
                                       np.asarray(want.null),
                                       rtol=1e-9, atol=1e-12)


def test_threaded_server_propagates_errors(problem):
    x, y, _, f = problem
    engine = CVEngine()
    # Workload validates estimator names eagerly, so smuggle an invalid one
    # past construction to exercise the serve-time error path through the
    # server's futures.
    bad = Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=y)
    object.__setattr__(bad, "estimator", "nonsense")
    with EngineServer(engine) as server:
        fut = server.submit(bad)
        with pytest.raises(ValueError):
            fut.result(timeout=300)


def test_workload_rejects_unknown_estimator_eagerly(problem):
    x, y, _, f = problem
    with pytest.raises(ValueError):
        Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=y,
                 estimator="nonsense")


def test_engine_distributed_paths_single_device(problem):
    """gram_impl='distributed' + mesh-sharded permutations on a 1-device
    mesh must agree with the local paths (real multi-device coverage lives
    in tests/distributed_worker.py)."""
    x, y, _, f = problem
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    e_dist = CVEngine(EngineConfig(gram_impl="distributed", mesh=mesh))
    e_loc = CVEngine()
    _, p_dist = e_dist.plan(x, f, LAM)
    _, p_loc = e_loc.plan(x, f, LAM)
    np.testing.assert_allclose(np.asarray(p_dist.h), np.asarray(p_loc.h),
                               atol=1e-10)
    key = jax.random.PRNGKey(11)
    r_dist = e_dist.permutation_binary(p_dist, y, 10, key)
    r_loc = e_loc.permutation_binary(p_loc, y, 10, key)
    np.testing.assert_allclose(np.asarray(r_dist.null),
                               np.asarray(r_loc.null), atol=1e-12)
