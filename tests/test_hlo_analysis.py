"""Validate the loop-aware HLO analyzer against known-cost programs."""

import pytest
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    a = jnp.zeros((256, 512), jnp.float32)
    b = jnp.zeros((512, 128), jnp.float32)
    txt = _compile_text(lambda x, y: x @ y, a, b)
    res = H.analyze_hlo(txt)
    want = 2 * 256 * 512 * 128
    assert res["flops"] == pytest.approx(want, rel=0.01), res["flops"]


def test_scan_multiplies_by_trip_count():
    x = jnp.zeros((128, 128), jnp.float32)

    def scanned(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=17)
        return out

    txt = _compile_text(scanned, x)
    res = H.analyze_hlo(txt)
    one = 2 * 128**3
    assert res["flops"] == pytest.approx(17 * one, rel=0.05), \
        (res["flops"], 17 * one)


def test_scan_matches_unrolled():
    x = jnp.zeros((64, 64), jnp.float32)
    n = 9

    def scanned(x):
        out, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=n)
        return out

    def unrolled(x):
        for _ in range(n):
            x = x @ x
        return x

    f_scan = H.analyze_hlo(_compile_text(scanned, x))["flops"]
    f_unroll = H.analyze_hlo(_compile_text(unrolled, x))["flops"]
    assert f_scan == pytest.approx(f_unroll, rel=0.05), (f_scan, f_unroll)


def test_nested_scan():
    x = jnp.zeros((32, 32), jnp.float32)

    def inner(c):
        out, _ = jax.lax.scan(lambda c, _: (c @ c, None), c, None, length=4)
        return out

    def outer(x):
        out, _ = jax.lax.scan(lambda c, _: (inner(c), None), x, None, length=5)
        return out

    res = H.analyze_hlo(_compile_text(outer, x))
    want = 20 * 2 * 32**3
    assert res["flops"] == pytest.approx(want, rel=0.05), (res["flops"], want)


def test_grad_of_scan_counts_forward_and_backward():
    x = jnp.zeros((64, 64), jnp.float32)
    w = jnp.zeros((64, 64), jnp.float32)

    def loss(w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return jnp.sum(out)

    res = H.analyze_hlo(_compile_text(jax.grad(loss), w))
    fwd = 8 * 2 * 64**3
    # backward: dL/dc (c@w backward: 2 matmuls per step) => total >= 3x fwd
    assert res["flops"] >= 2.5 * fwd, (res["flops"], fwd)
    assert res["flops"] <= 5 * fwd
