"""Wire-conformance suite for the HTTP/SSE edge (repro.serve.http).

The contract under test: the HTTP edge is a *transport*, not a second
implementation — every byte of JSON decodes to the exact Workload the
in-process Client would construct, so results are bit-identical, SSE
chunks are the same chunks stream_workload yields (prefix-stable,
identical draws to the monolithic path), and a warm engine serves wire
traffic with zero extra compiles. Error paths (malformed JSON, unknown
schema, unknown/evicted handles, oversized bodies, mid-stream
disconnects) return structured JSON errors and leave the engine's
stats()/compile_count untouched. Per-workload failures surface as
result-or-error entries without aborting sibling workloads — on the
in-process transports and over the wire alike.
"""

import json
import socket
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rsa
from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import (
    Client,
    CVEngine,
    DatasetHandle,
    EdgeThread,
    HTTPClient,
    Workload,
    WireError,
    estimators,
)
from repro.serve.http import assert_responses_equal

N, P, K, LAM = 48, 96, 4, 1.0


@pytest.fixture(scope="module")
def problem():
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(0), N, P, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    f = foldlib.kfold(N, K, seed=1)
    return x, y, yc, f


def _register_over_wire(hclient, problem):
    x, _, _, f = problem
    return hclient.register(
        np.asarray(x), (np.asarray(f.te_idx), np.asarray(f.tr_idx)), LAM
    )


def _workload_matrix(problem, dataset):
    """All five kinds; cv covers every registered estimator."""
    x, y, yc, _ = problem
    q = jnp.stack([y, -y, jnp.roll(y, 5)], axis=1)
    models = jnp.stack([rsa.ring_rdm(3), rsa.ring_rdm(3) * 0.5 + 0.1])
    return [
        ("cv/binary", Workload(kind="cv", dataset=dataset, y=y)),
        ("cv/ridge", Workload(kind="cv", dataset=dataset, y=y, estimator="ridge")),
        ("cv/multiclass", Workload(kind="cv", dataset=dataset, y=yc,
                                   estimator="multiclass", num_classes=3)),
        ("cv/ridge_multi", Workload(kind="cv", dataset=dataset, y=q,
                                    estimator="ridge_multi")),
        ("permutation/binary", Workload(kind="permutation", dataset=dataset, y=y,
                                        n_perm=12, seed=4)),
        ("permutation/multiclass", Workload(kind="permutation", dataset=dataset, y=yc,
                                            estimator="multiclass", num_classes=3,
                                            n_perm=10, seed=2)),
        ("rsa/binary+models", Workload(kind="rsa", dataset=dataset, y=yc, num_classes=3,
                                       model_rdms=models, n_perm=8, seed=2)),
        ("rsa/multiclass", Workload(kind="rsa", dataset=dataset, y=yc, num_classes=3,
                                    contrast="multiclass")),
        ("tune", Workload(kind="tune", x=x, y=y)),
        ("grid", Workload(kind="grid", dataset=dataset, y=y,
                          xs=jnp.stack([x, x * 1.05]))),
    ]


# the one equality contract, shared with the live-server smoke harness
_assert_responses_equal = assert_responses_equal


def _recv_response(s, raw=b""):
    """Read one HTTP response (headers + Content-Length body) off a socket."""
    while True:
        head_part, sep, body_part = raw.partition(b"\r\n\r\n")
        if sep:
            length = 0
            for hline in head_part.split(b"\r\n")[1:]:
                if hline.lower().startswith(b"content-length:"):
                    length = int(hline.split(b":")[1])
            if len(body_part) >= length:
                return raw
        b = s.recv(65536)
        if not b:
            return raw
        raw += b


def _raw_request(edge, payload: bytes, path="/v1/workloads", extra_headers=""):
    """One hand-rolled POST; returns (status, parsed-or-None body)."""
    with socket.create_connection(("127.0.0.1", edge.port), timeout=60) as s:
        head = (f"POST {path} HTTP/1.1\r\nHost: t\r\n"
                f"Content-Length: {len(payload)}\r\n{extra_headers}\r\n")
        s.sendall(head.encode() + payload)
        raw = _recv_response(s)
    status = int(raw.split(b" ", 2)[1])
    body = raw.partition(b"\r\n\r\n")[2]
    try:
        return status, json.loads(body.decode())
    except ValueError:
        return status, None


# ---------------------------------------------------------------------------
# The differential harness: HTTP == in-process, bit for bit, compile-flat
# ---------------------------------------------------------------------------


def test_wire_conformance_bit_identical_and_compile_flat(problem):
    """Every workload kind and every registered estimator: the HTTP result
    is bit-identical to the in-process Client result, and a second wire
    pass adds zero compiles and zero plan builds to the engine."""
    x, _, _, f = problem
    assert {"binary", "ridge", "multiclass", "ridge_multi"} <= set(estimators())

    ref_client = Client(CVEngine())
    ref_handle = ref_client.register(x, f, LAM)
    refs = [ref_client.submit(w) for _, w in _workload_matrix(problem, ref_handle)]

    with EdgeThread() as edge, HTTPClient(edge.url) as hc:
        handle = _register_over_wire(hc, problem)
        assert handle.key == ref_handle.key  # same bytes -> same fingerprint
        ws = _workload_matrix(problem, handle)
        got_cold = [hc.submit(w) for _, w in ws]
        for (name, _), a, b in zip(ws, got_cold, refs):
            _assert_responses_equal(a, b)

        warm_compiles = edge.engine.compile_count()
        warm_plans = edge.engine.stats()["plans_built"]
        got_warm = [hc.submit(w) for _, w in ws]
        assert edge.engine.compile_count() == warm_compiles
        assert edge.engine.stats()["plans_built"] == warm_plans
        for (name, _), a, b in zip(ws, got_warm, refs):
            _assert_responses_equal(a, b)


def test_inline_dataset_spec_over_the_wire(problem):
    """Workloads may also ship the feature matrix inline (DatasetSpec)."""
    from repro.serve import DatasetSpec

    x, y, _, f = problem
    ref = Client(CVEngine()).submit(Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=y))
    with EdgeThread() as edge, HTTPClient(edge.url) as hc:
        got = hc.submit(Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=y))
        _assert_responses_equal(got, ref)


def test_http_batch_gather_matches_in_process(problem):
    """A whole batch through POST /v1/workloads coalesces in the async
    gather window; per-request results match the library answers (allclose
    at 1e-9, matching the repo's concurrent-coalescing precedent)."""
    x, y, yc, f = problem
    ref_client = Client(CVEngine())
    ref_handle = ref_client.register(x, f, LAM)
    batch_of = lambda h: [
        Workload(kind="cv", dataset=h, y=jnp.roll(y, i)) for i in range(3)
    ] + [
        Workload(kind="cv", dataset=h, y=yc, estimator="multiclass", num_classes=3),
        Workload(kind="permutation", dataset=h, y=y, n_perm=12, seed=7),
    ]
    refs = [ref_client.submit(w) for w in batch_of(ref_handle)]
    with EdgeThread() as edge, HTTPClient(edge.url) as hc:
        handle = _register_over_wire(hc, problem)
        got = hc.gather(batch_of(handle))
        assert edge.edge.server.batches_served < len(got)  # actually coalesced
        for a, b in zip(got, refs):
            assert type(a) is type(b)
            for field in ("values", "null"):
                va, vb = getattr(a, field, None), getattr(b, field, None)
                if va is not None:
                    np.testing.assert_allclose(
                        np.asarray(va), np.asarray(vb), rtol=1e-9, atol=1e-12
                    )


# ---------------------------------------------------------------------------
# SSE streaming: same chunks as stream_workload, ragged concurrent clients
# ---------------------------------------------------------------------------


def test_sse_chunks_bit_identical_ragged_concurrent(problem, monkeypatch):
    """Streamed permutation-null and RSA chunks over concurrent ragged HTTP
    clients are byte-identical to the monolithic responses, prefix by
    prefix — and the chunks really are evaluated chunk-wise on the engine
    (call-counting monkeypatch, as in test_workload's mesh test)."""
    x, y, yc, f = problem
    chunk = 8
    perms = (12, 20, 28)
    models = jnp.stack([rsa.ring_rdm(3), rsa.ring_rdm(3) * 0.5 + 0.1])

    # monolithic references from a fresh in-process engine
    ref_client = Client(CVEngine())
    ref_handle = ref_client.register(x, f, LAM)
    mono = {
        t: ref_client.submit(
            Workload(kind="permutation", dataset=ref_handle, y=y, n_perm=t, seed=t)
        )
        for t in perms
    }
    mono_rsa = ref_client.submit(
        Workload(kind="rsa", dataset=ref_handle, y=yc, num_classes=3,
                 model_rdms=models, n_perm=16, seed=3)
    )

    calls = {"n": 0}
    real = CVEngine.null_binary

    def counting(self, *args, **kwargs):
        calls["n"] += 1
        return real(self, *args, **kwargs)

    monkeypatch.setattr(CVEngine, "null_binary", counting)

    with EdgeThread(stream_chunk=chunk) as edge:
        hc0 = HTTPClient(edge.url)
        handle = _register_over_wire(hc0, problem)
        hc0.close()

        results = {}

        def one_client(t):
            with HTTPClient(edge.url) as hc:
                w = Workload(kind="permutation", dataset=handle, y=y, n_perm=t, seed=t)
                results[t] = list(hc.stream(w))

        def rsa_client():
            with HTTPClient(edge.url) as hc:
                w = Workload(kind="rsa", dataset=handle, y=yc, num_classes=3,
                             model_rdms=models, n_perm=16, seed=3)
                results["rsa"] = list(hc.stream(w))

        threads = [threading.Thread(target=one_client, args=(t,)) for t in perms]
        threads.append(threading.Thread(target=rsa_client))
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=600)

        # chunk-wise evaluation actually happened (>= one call per chunk)
        assert calls["n"] >= sum(-(-t // chunk) for t in perms)

        for t in perms:
            events = results[t]
            assert [e.kind for e in events[:2]] == ["plan", "observed"]
            lo = 0
            for ev in events:
                if ev.kind != "null":
                    continue
                block = np.asarray(ev.payload)
                np.testing.assert_array_equal(  # prefix-stable chunks
                    block, np.asarray(mono[t].null)[lo : lo + block.shape[0]]
                )
                lo += block.shape[0]
            final = events[-1].payload
            np.testing.assert_array_equal(np.asarray(final.null), np.asarray(mono[t].null))
            np.testing.assert_array_equal(np.asarray(final.p), np.asarray(mono[t].p))

        rsa_events = results["rsa"]
        kinds = [e.kind for e in rsa_events]
        assert kinds[:3] == ["plan", "rdm", "scores"] and kinds[-1] == "done"
        lo = 0
        for ev in rsa_events:
            if ev.kind != "null":
                continue
            block = np.asarray(ev.payload)
            np.testing.assert_array_equal(
                block, np.asarray(mono_rsa.null)[:, lo : lo + block.shape[1]]
            )
            lo += block.shape[1]
        _assert_responses_equal(rsa_events[-1].payload, mono_rsa)


# ---------------------------------------------------------------------------
# Error paths: structured JSON, engine untouched
# ---------------------------------------------------------------------------


def _engine_fingerprint(engine):
    s = engine.stats()
    return (s["compiles"], s["plans_built"], s["labels_evaluated"])


def test_error_paths_are_structured_and_leave_engine_untouched(problem):
    x, y, _, f = problem
    with EdgeThread(max_body_bytes=1 << 20) as edge, HTTPClient(edge.url) as hc:
        handle = _register_over_wire(hc, problem)
        hc.submit(Workload(kind="cv", dataset=handle, y=y))  # prime/warm
        before = _engine_fingerprint(edge.engine)

        # malformed JSON
        status, body = _raw_request(edge, b"{this is not json")
        assert status == 400 and body["error"]["type"] == "bad_json"

        # unknown schema version (the eager from_dict validation message)
        d = Workload(kind="cv", dataset=handle, y=y).to_dict()
        d["schema"] = 99
        status, body = _raw_request(edge, json.dumps(d).encode())
        entry = body["results"][0]
        assert status == 200 and not entry["ok"]
        assert entry["error"]["status"] == 400
        assert "unsupported workload schema" in entry["error"]["message"]
        # ...and on the stream route, which rejects before any SSE bytes
        status, body = _raw_request(edge, json.dumps(d).encode(),
                                    path="/v1/workloads/stream")
        assert status == 400 and body["error"]["type"] == "validation"
        assert "unsupported workload schema" in body["error"]["message"]

        # eager Workload validation message travels verbatim
        bad = Workload(kind="cv", dataset=handle, y=y).to_dict()
        bad["y"]["__array__"] = [2.0] * N  # not ±1-coded
        status, body = _raw_request(edge, json.dumps(bad).encode())
        entry = body["results"][0]
        assert status == 200 and not entry["ok"]
        assert "±1" in entry["error"]["message"]
        assert entry["error"]["status"] == 400

        # unknown handle
        fake = DatasetHandle(key=("bogus", "te", "tr", 1.0, "dual", True),
                             n=N, p=P, lam=LAM)
        with pytest.raises(WireError, match="not registered") as ei:
            hc.submit(Workload(kind="cv", dataset=fake, y=y))
        assert ei.value.status == 404 and ei.value.etype == "unknown_dataset"

        # evicted + deregistered handle
        x2 = x * 1.25
        h2 = hc.register(np.asarray(x2), (np.asarray(f.te_idx), np.asarray(f.tr_idx)), LAM)
        edge.engine.evict(h2, deregister=True)
        with pytest.raises(WireError, match="not registered") as ei:
            hc.submit(Workload(kind="cv", dataset=h2, y=y))
        assert ei.value.status == 404

        # oversized body: rejected from Content-Length alone — the edge
        # answers without reading a single body byte (none is ever sent)
        with socket.create_connection(("127.0.0.1", edge.port), timeout=60) as s:
            s.sendall(
                (f"POST /v1/workloads HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {(1 << 20) + 1}\r\n\r\n").encode()
            )
            raw = _recv_response(s)
        status = int(raw.split(b" ", 2)[1])
        body = json.loads(raw.partition(b"\r\n\r\n")[2].decode())
        assert status == 413 and body["error"]["type"] == "oversized"

        # chunked request bodies: explicit 411, not a desynced parser
        with socket.create_connection(("127.0.0.1", edge.port), timeout=60) as s:
            s.sendall(
                b"POST /v1/workloads HTTP/1.1\r\nHost: t\r\n"
                b"Transfer-Encoding: chunked\r\n\r\n5\r\nhello\r\n0\r\n\r\n"
            )
            raw = _recv_response(s)
        assert raw.split(b" ", 2)[1] == b"411"
        err = json.loads(raw.partition(b"\r\n\r\n")[2].decode())
        assert err["error"]["type"] == "length_required"

        # unknown routes / methods
        status, body = _raw_request(edge, b"{}", path="/v1/nonsense")
        assert status == 404 and body["error"]["type"] == "not_found"

        assert _engine_fingerprint(edge.engine) == before
        # the edge counted its errors, and stays fully serviceable
        assert hc.stats()["edge"]["http_errors"] >= 5
        assert hc.healthz() == {"status": "ok"}


def test_expect_100_continue_handshake(problem):
    """curl adds `Expect: 100-continue` to >1KB POSTs (any real dataset
    registration) and stalls ~1s unless the edge answers the interim 100."""
    x, y, _, f = problem
    with EdgeThread() as edge, HTTPClient(edge.url) as hc:
        handle = _register_over_wire(hc, problem)
        body = json.dumps(Workload(kind="cv", dataset=handle, y=y).to_dict()).encode()
        with socket.create_connection(("127.0.0.1", edge.port), timeout=60) as s:
            s.sendall(
                (f"POST /v1/workloads HTTP/1.1\r\nHost: t\r\n"
                 f"Expect: 100-continue\r\nContent-Length: {len(body)}\r\n\r\n").encode()
            )
            interim = s.recv(1024)
            assert interim.startswith(b"HTTP/1.1 100 Continue")
            s.sendall(body)
            raw = _recv_response(s, interim.partition(b"\r\n\r\n")[2])
        assert raw.split(b" ", 2)[1] == b"200"
        out = json.loads(raw.partition(b"\r\n\r\n")[2].decode())
        assert out["results"][0]["ok"] is True


def test_client_disconnect_mid_stream_keeps_serving(problem):
    x, y, _, f = problem
    with EdgeThread(stream_chunk=8) as edge, HTTPClient(edge.url) as hc:
        handle = _register_over_wire(hc, problem)
        w = Workload(kind="permutation", dataset=handle, y=y, n_perm=40, seed=1)
        full = list(hc.stream(w))  # prime: all chunk programs compiled
        compiles = edge.engine.compile_count()

        # a client that reads the headers plus a little and hangs up
        body = json.dumps(w.to_dict()).encode()
        with socket.create_connection(("127.0.0.1", edge.port), timeout=60) as s:
            s.sendall(
                (f"POST /v1/workloads/stream HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n").encode() + body
            )
            s.recv(1024)

        # the edge survives: same stream again, bit-identical, no recompiles
        again = list(hc.stream(w))
        assert [e.kind for e in again] == [e.kind for e in full]
        np.testing.assert_array_equal(
            np.asarray(again[-1].payload.null), np.asarray(full[-1].payload.null)
        )
        assert edge.engine.compile_count() == compiles
        assert hc.healthz() == {"status": "ok"}


# ---------------------------------------------------------------------------
# Per-workload failures never abort siblings (both transports)
# ---------------------------------------------------------------------------


def _bad_handle():
    return DatasetHandle(key=("bogus", "te", "tr", 1.0, "dual", True), n=N, p=P, lam=LAM)


def test_gather_surfaces_per_entry_errors_in_process(problem):
    x, y, yc, f = problem
    engine = CVEngine()
    client = Client(engine)
    handle = client.register(x, f, LAM)
    good1 = Workload(kind="cv", dataset=handle, y=y)
    bad = Workload(kind="cv", dataset=_bad_handle(), y=y)
    good2 = Workload(kind="cv", dataset=handle, y=yc, estimator="multiclass", num_classes=3)
    ref1, ref2 = client.submit(good1), client.submit(good2)

    for transport in ("sync", "thread", "async"):
        if transport == "async":
            import asyncio

            async def drive():
                async with Client(engine, transport="async") as ac:
                    return await ac.gather([good1, bad, good2], return_errors=True)

            out = asyncio.run(drive())
        elif transport == "thread":
            with Client(engine, transport="thread") as tc:
                out = tc.gather([good1, bad, good2], return_errors=True)
        else:
            out = client.gather([good1, bad, good2], return_errors=True)
        assert isinstance(out[1], KeyError), transport
        _assert_responses_equal(out[0], ref1)
        _assert_responses_equal(out[2], ref2)

    # default semantics unchanged: raise on the first failure
    with pytest.raises(KeyError, match="not registered"):
        client.gather([good1, bad, good2])


def test_http_gather_surfaces_per_entry_errors(problem):
    x, y, _, f = problem
    ref_client = Client(CVEngine())
    ref_handle = ref_client.register(x, f, LAM)
    ref = ref_client.submit(Workload(kind="cv", dataset=ref_handle, y=y))
    with EdgeThread() as edge, HTTPClient(edge.url) as hc:
        handle = _register_over_wire(hc, problem)
        good = Workload(kind="cv", dataset=handle, y=y)
        bad = Workload(kind="cv", dataset=_bad_handle(), y=y)
        out = hc.gather([good, bad, good], return_errors=True)
        assert isinstance(out[1], WireError)
        assert out[1].status == 404 and "not registered" in str(out[1])
        # the two good siblings coalesced into one padded eval (width 2),
        # so compare at the repo's concurrent-coalescing tolerance
        for got in (out[0], out[2]):
            assert type(got) is type(ref)
            np.testing.assert_allclose(
                np.asarray(got.values), np.asarray(ref.values), rtol=1e-9, atol=1e-12
            )
        with pytest.raises(WireError, match="not registered"):
            hc.gather([good, bad])


# ---------------------------------------------------------------------------
# Ops surface: registration, introspection, stats
# ---------------------------------------------------------------------------


def test_register_is_idempotent_and_introspectable_over_wire(problem):
    x, y, _, f = problem
    with EdgeThread() as edge, HTTPClient(edge.url) as hc:
        h1 = _register_over_wire(hc, problem)
        h2 = _register_over_wire(hc, problem)
        assert h1 == h2 and h1.n == N and h1.p == P
        (info,) = hc.datasets()
        assert info["handle"] == h1 and info["resident"] is False
        hc.submit(Workload(kind="cv", dataset=h1, y=y))
        (info,) = hc.datasets()
        assert info["resident"] is True and info["served"] == 1

        s = hc.stats()
        assert s["engine"]["datasets_registered"] == 1
        assert s["server"]["requests_served"] == 1
        assert s["edge"]["http_requests"] >= 4
