"""Permutation-testing engine (paper §2.7, Algorithms 1 & 2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastcv, folds as foldlib, permutation
from repro.data import synthetic


def test_hat_matrix_invariant_under_label_permutation():
    """§2.7: H depends on features alone."""
    x, _ = synthetic.make_classification(jax.random.PRNGKey(0), 40, 100)
    h1 = fastcv.hat_matrix(x, 1.0)
    h2 = fastcv.hat_matrix(x, 1.0)       # same features -> same H, trivially
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


def test_analytical_binary_null_matches_standard_per_permutation():
    """For the SAME permutations, analytical and standard retraining must
    produce identical per-permutation accuracies."""
    n, p, k, lam = 48, 30, 4, 1.0
    x, yc = synthetic.make_classification(jax.random.PRNGKey(1), n, p)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, k, seed=0)
    key = jax.random.PRNGKey(42)
    res_fast = permutation.analytical_permutation_binary(
        x, y, f, lam, n_perm=20, key=key, metric="accuracy")
    res_std = permutation.standard_permutation_binary(
        x, y, f, lam, n_perm=20, key=key, metric="accuracy")
    # identical permutation streams (same key) -> identical label predictions.
    # dvals differ by positive per-fold scaling between regression/LDA forms,
    # but accuracies coincide exactly.
    np.testing.assert_allclose(np.asarray(res_fast.null),
                               np.asarray(res_std.null), atol=1e-12)
    assert float(res_fast.observed) == pytest.approx(float(res_std.observed))
    assert float(res_fast.p) == pytest.approx(float(res_std.p))


def test_observed_significant_on_separable_data():
    n, p = 64, 50
    x, yc = synthetic.make_classification(jax.random.PRNGKey(2), n, p,
                                          class_sep=4.0)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, 8, seed=1)
    res = permutation.analytical_permutation_binary(
        x, y, f, 1.0, n_perm=99, key=jax.random.PRNGKey(7))
    assert float(res.p) < 0.05
    assert float(res.observed) > 0.8
    # null should hover around chance
    assert 0.3 < float(jnp.mean(res.null)) < 0.7


def test_null_uniformity_on_pure_noise():
    """On label-independent features the observed statistic should NOT be
    systematically extreme: p should not be tiny."""
    n, p = 60, 40
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (n, p), jnp.float64)
    y = jnp.where(jnp.arange(n) % 2 == 0, -1.0, 1.0)
    f = foldlib.kfold(n, 5, seed=2)
    res = permutation.analytical_permutation_binary(
        x, y, f, 1.0, n_perm=99, key=jax.random.PRNGKey(8))
    assert float(res.p) > 0.01


def test_multiclass_analytical_equals_standard_nulls():
    n, p, c, k, lam = 60, 25, 3, 5, 1.0
    x, y = synthetic.make_classification(jax.random.PRNGKey(4), n, p, c)
    f = foldlib.stratified_kfold(np.asarray(y), k, seed=0)
    key = jax.random.PRNGKey(9)
    res_fast = permutation.analytical_permutation_multiclass(
        x, y, f, c, lam, n_perm=10, key=key)
    res_std = permutation.standard_permutation_multiclass(
        x, y, f, c, lam, n_perm=10, key=key)
    np.testing.assert_allclose(np.asarray(res_fast.null),
                               np.asarray(res_std.null), atol=1e-12)


def test_chunking_is_invisible():
    n, p = 40, 60
    x, yc = synthetic.make_classification(jax.random.PRNGKey(5), n, p)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, 4, seed=1)
    key = jax.random.PRNGKey(10)
    r1 = permutation.analytical_permutation_binary(x, y, f, 1.0, 17, key, chunk=5)
    r2 = permutation.analytical_permutation_binary(x, y, f, 1.0, 17, key, chunk=17)
    np.testing.assert_allclose(np.asarray(r1.null), np.asarray(r2.null),
                               atol=1e-12)
