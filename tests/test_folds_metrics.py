"""Fold construction and metric correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import folds as foldlib, metrics, shrinkage
from repro.data import synthetic


def test_kfold_partition_properties():
    f = foldlib.kfold(103, 5, seed=0)
    te = np.asarray(f.te_idx)
    tr = np.asarray(f.tr_idx)
    assert te.shape == (5, 20)
    assert tr.shape == (5, 83)
    for i in range(5):
        assert len(np.intersect1d(te[i], tr[i])) == 0
        assert len(np.union1d(te[i], tr[i])) == 103
    # test sets are disjoint across folds
    flat = te.reshape(-1)
    assert len(np.unique(flat)) == len(flat)


def test_loo():
    f = foldlib.loo(7)
    assert f.k == 7 and f.test_size == 1
    np.testing.assert_array_equal(np.sort(np.asarray(f.te_idx).ravel()),
                                  np.arange(7))


def test_stratified_preserves_proportions():
    y = np.array([0] * 60 + [1] * 30 + [2] * 30)
    f = foldlib.stratified_kfold(y, 5, seed=1)
    for i in range(5):
        labels = y[np.asarray(f.te_idx[i])]
        counts = np.bincount(labels, minlength=3)
        assert counts[0] >= counts[1] and counts[0] >= counts[2]
        assert counts.min() >= 1


def test_auc_against_sklearn_style_reference():
    rng = np.random.default_rng(0)
    for _ in range(5):
        d = rng.standard_normal(50)
        y = np.where(rng.random(50) > 0.4, 1.0, -1.0)
        # reference: probability a positive outranks a negative (ties=0.5)
        pos, neg = d[y > 0], d[y < 0]
        cmp = (pos[:, None] > neg[None, :]).mean() + 0.5 * (
            pos[:, None] == neg[None, :]).mean()
        got = float(metrics.auc(jnp.asarray(d), jnp.asarray(y)))
        assert got == pytest.approx(float(cmp), abs=1e-9)


def test_auc_handles_ties():
    d = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    y = jnp.asarray([1.0, -1.0, 1.0, -1.0])
    assert float(metrics.auc(d, y)) == pytest.approx(0.5)


def test_auc_bias_invariance():
    """Paper §2.5: AUC does not depend on the bias term."""
    rng = np.random.default_rng(1)
    d = jnp.asarray(rng.standard_normal(40))
    y = jnp.asarray(np.where(rng.random(40) > 0.5, 1.0, -1.0))
    a1 = float(metrics.auc(d, y))
    a2 = float(metrics.auc(d + 37.5, y))
    assert a1 == pytest.approx(a2, abs=1e-12)


def test_confusion_matrix():
    pred = jnp.asarray([0, 1, 2, 1, 0])
    y = jnp.asarray([0, 1, 1, 1, 2])
    cm = np.asarray(metrics.confusion_matrix(pred, y, 3))
    assert cm[0, 0] == 1 and cm[1, 1] == 2 and cm[1, 2] == 1 and cm[2, 0] == 1
    assert cm.sum() == 5


def test_shrink_to_ridge_equivalence():
    """Eq. 18: shrinkage-regularised and converted-ridge scatter matrices are
    proportional -> identical classifiers up to dval scaling."""
    x, yc = synthetic.make_classification(jax.random.PRNGKey(0), 50, 20)
    y = jnp.where(yc == 0, -1.0, 1.0)
    from repro.core import lda
    sw, m1, m2 = lda.scatter_within(x, y)
    p = x.shape[1]
    nu = shrinkage.trace_scaling(x, y)
    lam_s = 0.3
    lam_r = float(shrinkage.shrink_to_ridge(lam_s, nu))
    a_shrink = (1 - lam_s) * sw + lam_s * nu * jnp.eye(p)
    a_ridge = sw + lam_r * jnp.eye(p)
    ratio = np.asarray(a_shrink) / np.asarray(a_ridge)
    np.testing.assert_allclose(ratio, (1 - lam_s) * np.ones_like(ratio),
                               rtol=1e-9)


def test_ledoit_wolf_in_unit_interval():
    x, _ = synthetic.make_classification(jax.random.PRNGKey(1), 40, 60)
    lw = float(shrinkage.ledoit_wolf_lambda(x))
    assert 0.0 <= lw <= 1.0
