"""Analytical CV for linear/ridge regression (paper §2.4, §4.3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import folds as foldlib, regression
from repro.data import synthetic


@pytest.mark.parametrize("n,p,k,lam", [
    (80, 20, 5, 0.0),
    (80, 20, 8, 1.0),
    (50, 300, 5, 5.0),     # P >> N
])
def test_analytical_equals_standard_cv(n, p, k, lam):
    x, y = synthetic.make_regression(jax.random.PRNGKey(0), n, p)
    f = foldlib.kfold(n, k, seed=1)
    pred_fast, y_te = regression.analytical_cv(x, y, f, lam=lam)
    pred_std, y_te_std = regression.standard_cv(x, y, f, lam=lam)
    np.testing.assert_allclose(np.asarray(pred_fast), np.asarray(pred_std),
                               rtol=1e-7, atol=1e-8)
    np.testing.assert_array_equal(np.asarray(y_te), np.asarray(y_te_std))


def test_primal_and_dual_ridge_fits_agree():
    n, p, lam = 60, 40, 2.0
    x, y = synthetic.make_regression(jax.random.PRNGKey(2), n, p)
    # primal via explicit augmented solve
    xa = jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)
    i0 = jnp.eye(p + 1, dtype=x.dtype).at[p, p].set(0.0)
    beta = jnp.linalg.solve(xa.T @ xa + lam * i0, xa.T @ y)
    w_d, b_d = regression.fit_ridge(x, y, lam)  # p < n -> primal branch
    np.testing.assert_allclose(np.asarray(w_d), np.asarray(beta[:-1]), rtol=1e-8)
    assert float(b_d) == pytest.approx(float(beta[-1]), rel=1e-8)


def test_dual_fit_matches_primal_in_overdetermined_overlap():
    """For λ>0 both forms solve the same problem; compare on N=P+margin."""
    n, p, lam = 50, 48, 1.0
    x, y = synthetic.make_regression(jax.random.PRNGKey(3), n, p)
    xa = jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)
    i0 = jnp.eye(p + 1, dtype=x.dtype).at[p, p].set(0.0)
    beta = jnp.linalg.solve(xa.T @ xa + lam * i0, xa.T @ y)

    # force dual path by transposing regime: use fit on P >= N slice
    x2, y2 = x[:p // 2], y[:p // 2]            # now P > N
    w2, b2 = regression.fit_ridge(x2, y2, lam)
    xa2 = jnp.concatenate([x2, jnp.ones((x2.shape[0], 1), x.dtype)], axis=1)
    i02 = jnp.eye(p + 1, dtype=x.dtype).at[p, p].set(0.0)
    beta2 = jnp.linalg.solve(xa2.T @ xa2 + lam * i02, xa2.T @ y2)
    np.testing.assert_allclose(np.asarray(x2 @ w2 + b2),
                               np.asarray(xa2 @ beta2), rtol=1e-6, atol=1e-7)


def test_unregularised_highdim_raises():
    x, y = synthetic.make_regression(jax.random.PRNGKey(4), 20, 50)
    f = foldlib.kfold(20, 4)
    with pytest.raises(ValueError):
        regression.analytical_cv(x, y, f, lam=0.0)
