"""Donation-safety regressions for the bucketed eval family.

Donation lets XLA alias a label batch into the eval output, so a donated
buffer is dead after the call. Three safety properties keep that invisible
to clients:

  * cache keying — jitted evals compiled with donation must never be
    reused by a non-donating engine state (and vice versa): the eval
    caches key on ``donate`` (and ``fused``), and :meth:`set_donate`
    flips route, not recompile-in-place;
  * defensive copies — a caller's array that lands exactly on a shape
    bucket (no padding ⇒ no implicit copy) is copied before a donating
    eval, so the caller's buffer stays alive;
  * consumer discipline — the micro-batcher reads only eval *outputs*
    after the call (the coalesced input may be donated away), pinned
    here by an eval_fn that deletes its input buffer the way XLA
    donation would.

CPU note: the CPU backend declines donation (jit emits "donated buffers
were not usable" warnings), so these tests simulate the aliasing with
explicit ``jax.Array.delete()`` where liveness matters, and assert the
cache/copy structure directly elsewhere — both are backend-independent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import CVEngine, EngineConfig
from repro.serve.batching import MicroBatcher
from repro.rsa import rdm as rsa_rdm

N, P, K, LAM = 32, 64, 4, 1.0


@pytest.fixture(scope="module")
def problem():
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(0), N, P, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    return x, y, yc, foldlib.kfold(N, K, seed=1)


def _engine(problem, **cfg):
    x, _, _, f = problem
    eng = CVEngine(EngineConfig(**cfg))
    handle = eng.register(x, f, LAM)
    _, plan = eng.resolve(handle)
    return eng, plan


# ---------------------------------------------------------------------------
# cache keying: donate (and fused) are part of every eval-cache key
# ---------------------------------------------------------------------------


def test_eval_cache_keys_on_donate_toggle(problem):
    _, y, _, _ = problem
    eng, plan = _engine(problem, donate=False)
    a = np.asarray(eng.eval_estimator(plan, y, "binary"))
    warm = eng.compile_count()
    eng.set_donate(True)
    b = np.asarray(eng.eval_estimator(plan, jnp.array(y), "binary"))
    # new cache entry (no stale non-donating fn reused), same numbers
    assert eng.compile_count() == warm + 1
    np.testing.assert_array_equal(a, b)
    # flipping back reuses the original entry — no recompile
    eng.set_donate(False)
    eng.eval_estimator(plan, y, "binary")
    assert eng.compile_count() == warm + 1
    keys = [k for k in eng._evals if k[0] == "binary"]
    assert {k[2] for k in keys} == {False, True}


def test_rsa_pairs_cache_keys_on_donate_toggle(problem):
    """Regression: the pair-eval factory cache must key on donate — a
    donating jit served to a non-donating caller invalidates its cols."""
    _, _, yc, _ = problem
    eng, plan = _engine(problem, donate=False)
    cols = rsa_rdm.pair_contrast_columns(yc, 3, plan.h.dtype)
    a = np.asarray(eng.eval_rsa_pairs(plan, cols, "accuracy", True))
    n_fns = len(eng._rsa_pairs)
    eng.set_donate(True)
    b = np.asarray(eng.eval_rsa_pairs(plan, jnp.array(cols), "accuracy", True))
    assert len(eng._rsa_pairs) == n_fns + 1
    assert {k[2] for k in eng._rsa_pairs} == {False, True}
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# defensive copies: exact-bucket batches survive a donating engine
# ---------------------------------------------------------------------------


def test_caller_array_survives_exact_bucket_donating_eval(problem):
    _, y, _, _ = problem
    eng, plan = _engine(problem, donate=True)
    bucket = eng.config.buckets[0]
    yb = jnp.tile(y[:, None], (1, bucket))  # exact bucket: no pad copy
    before = float(jnp.sum(yb))
    eng.eval_estimator(plan, yb, "binary")
    # donated exact-size batches are defensively copied: still readable
    assert float(jnp.sum(yb)) == before


def test_owned_batches_skip_the_defensive_copy(problem):
    _, y, _, _ = problem
    eng, plan = _engine(problem, donate=True)
    bucket = eng.config.buckets[0]
    yb = jnp.tile(y[:, None], (1, bucket))
    padded, b = eng._pad_cols(yb, owned=True)
    assert padded is yb and b == bucket     # owned + exact bucket: no copy
    padded, _ = eng._pad_cols(yb)
    assert padded is not yb                 # unowned: copied before donation


def test_donating_and_plain_engines_agree_end_to_end(problem):
    x, y, yc, f = problem
    from repro.serve import Client, DatasetSpec, Workload
    ws = lambda: [
        Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=y),
        Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=yc,
                 estimator="multiclass", num_classes=3),
        Workload(kind="permutation", dataset=DatasetSpec(x, f, LAM), y=y,
                 n_perm=8, seed=3),
    ]
    plain = Client(CVEngine())
    donating = Client(CVEngine(EngineConfig(donate=True)))
    for got, want in zip([donating.submit(w) for w in ws()],
                         [plain.submit(w) for w in ws()]):
        for field in ("values", "observed", "null", "p"):
            a, b = getattr(got, field, None), getattr(want, field, None)
            assert (a is None) == (b is None)
            if a is not None:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# micro-batcher: never reads a coalesced input after the eval ran
# ---------------------------------------------------------------------------


def _deleting(eval_fn):
    """Wrap an eval to destroy its input buffer like XLA donation would."""
    def run(batch):
        out = eval_fn(batch)
        jax.block_until_ready(out)
        batch.delete()
        return out
    return run


def test_microbatcher_columns_survive_input_donation():
    batcher = MicroBatcher(buckets=(8, 32))
    ys = [jnp.arange(6, dtype=jnp.float64).reshape(3, 2) + i for i in range(3)]
    outs = batcher.run_columns(ys, _deleting(lambda b: b * 2.0))
    for y, out in zip(ys, outs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(y) * 2.0)


def test_microbatcher_rows_survive_input_donation():
    batcher = MicroBatcher(buckets=(8, 32))
    ys = [jnp.arange(10, dtype=jnp.float64).reshape(2, 5) + i for i in range(2)]
    outs = batcher.run_rows(ys, _deleting(lambda b: b + 1.0))
    for y, out in zip(ys, outs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(y) + 1.0)


def test_engine_eval_survives_input_donation(problem):
    """End to end: delete the engine-owned batch after eval (as TPU
    donation would) — results must already be safely materialised."""
    _, y, _, _ = problem
    eng, plan = _engine(problem, donate=True)
    batch = jnp.tile(jnp.array(y)[:, None], (1, 3))
    out = eng.eval_estimator(plan, batch, "binary", owned=True)
    jax.block_until_ready(out)
    batch.delete()
    ref_eng, ref_plan = _engine(problem, donate=False)
    want = ref_eng.eval_estimator(ref_plan, jnp.tile(y[:, None], (1, 3)),
                                  "binary")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
