"""Exactness of the analytical CV approach for binary LDA (paper Eq. 14/15).

The central claim: the analytical decision values equal, to machine
precision, the decision values of a regression-form model *retrained from
scratch* on every training fold. We verify both hat-matrix paths
(primal/dual), k-fold and LOO, N>P and P>N regimes, and the bias
adjustment against explicitly recomputed LDA biases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastcv, folds as foldlib, lda, metrics
from repro.data import synthetic


def _data(n, p, seed=0, classes=2):
    return synthetic.make_classification(jax.random.PRNGKey(seed), n, p, classes)


@pytest.mark.parametrize("n,p,k,lam", [
    (60, 10, 5, 0.0),       # N > P, unregularised, primal
    (60, 10, 5, 1.0),       # N > P, ridge
    (64, 40, 8, 0.1),       # N > P
    (40, 200, 5, 1.0),      # P >> N (paper's regime), dual path
    (30, 500, 10, 10.0),    # P >> N, strong ridge
])
def test_analytical_equals_retrained_regression(n, p, k, lam):
    x, yc = _data(n, p)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, k, seed=1)
    dv_fast, y_te = fastcv.binary_cv(x, y, f, lam=lam, adjust_bias=False)
    dv_std, y_te_std = lda.standard_cv_binary(x, y, f, lam=lam, form="regression")
    np.testing.assert_allclose(np.asarray(dv_fast), np.asarray(dv_std),
                               rtol=1e-8, atol=1e-8)
    np.testing.assert_array_equal(np.asarray(y_te), np.asarray(y_te_std))


def test_loo_matches_retrained():
    n, p = 40, 12
    x, yc = _data(n, p, seed=3)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.loo(n)
    dv_fast, _ = fastcv.binary_cv(x, y, f, lam=0.5, adjust_bias=False)
    dv_std, _ = lda.standard_cv_binary(x, y, f, lam=0.5, form="regression")
    np.testing.assert_allclose(np.asarray(dv_fast), np.asarray(dv_std),
                               rtol=1e-8, atol=1e-8)


def test_primal_dual_hat_matrices_agree():
    n, p, lam = 50, 30, 2.0
    x, _ = _data(n, p, seed=5)
    h_primal = fastcv.hat_matrix_primal(x, lam)
    h_dual = fastcv.hat_matrix_dual(x, lam)
    np.testing.assert_allclose(np.asarray(h_primal), np.asarray(h_dual),
                               rtol=1e-8, atol=1e-10)


def test_hat_matrix_maps_y_to_fullfit_predictions():
    n, p, lam = 80, 20, 1.5
    x, yc = _data(n, p, seed=7)
    y = jnp.where(yc == 0, -1.0, 1.0)
    h = fastcv.hat_matrix(x, lam)
    w, b = lda.fit_binary_regression(x, y, lam)
    np.testing.assert_allclose(np.asarray(h @ y), np.asarray(x @ w + b),
                               rtol=1e-8, atol=1e-10)


def test_hat_matrix_reproduces_constants():
    """H·1 = 1 — the unpenalised intercept reproduces constant responses."""
    n, p = 30, 100
    x, _ = _data(n, p, seed=11)
    h = fastcv.hat_matrix(x, 3.0)
    np.testing.assert_allclose(np.asarray(h @ jnp.ones(n)), np.ones(n),
                               rtol=0, atol=1e-9)


def test_bias_adjustment_matches_explicit_lda_bias():
    """dvals with adjust_bias must equal x·ẇ + b_LDA(ẇ) for the retrained
    regression-form ẇ with the bias replaced per paper Eq. (4)."""
    n, p, k, lam = 60, 15, 5, 0.7
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (n, p), jnp.float64)
    # unbalanced classes: 2/3 vs 1/3 (bias adjustment actually matters)
    yc = (jnp.arange(n) % 3 == 0).astype(jnp.int32)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.kfold(n, k, seed=2)
    dv_fast, _ = fastcv.binary_cv(x, y, f, lam=lam, adjust_bias=True)

    dv_expected = []
    for i in range(f.k):
        tr = np.asarray(f.tr_idx[i])
        te = np.asarray(f.te_idx[i])
        w, _ = lda.fit_binary_regression(x[tr], y[tr], lam)
        m1 = jnp.mean(x[tr][np.asarray(y)[tr] > 0], axis=0)
        m2 = jnp.mean(x[tr][np.asarray(y)[tr] < 0], axis=0)
        b_lda = -0.5 * jnp.dot(w, m1 + m2)
        dv_expected.append(np.asarray(x[te] @ w + b_lda))
    np.testing.assert_allclose(np.asarray(dv_fast), np.stack(dv_expected),
                               rtol=1e-7, atol=1e-8)


def test_regression_form_direction_matches_lda(seed=17):
    """Appendix A: regression-form w ∝ (S_w+λI)⁻¹(m1−m2)."""
    n, p, lam = 100, 20, 0.3
    x, yc = _data(n, p, seed=seed)
    y = jnp.where(yc == 0, -1.0, 1.0)
    w_reg, _ = lda.fit_binary_regression(x, y, lam)
    model = lda.fit_binary(x, y, lam)
    cos = jnp.dot(w_reg, model.w) / (jnp.linalg.norm(w_reg) * jnp.linalg.norm(model.w))
    assert abs(float(cos)) > 1.0 - 1e-10


def test_accuracy_matches_standard_lda_predictions():
    """Predicted labels from the analytical approach equal the standard
    (direct-LDA, retrained) predictions — equal accuracy per fold."""
    n, p, k, lam = 90, 45, 6, 1.0
    x, yc = _data(n, p, seed=19)
    y = jnp.where(yc == 0, -1.0, 1.0)
    f = foldlib.stratified_kfold(np.asarray(yc), k, seed=3)
    dv_fast, y_te = fastcv.binary_cv(x, y, f, lam=lam, adjust_bias=True)
    dv_std, _ = lda.standard_cv_binary(x, y, f, lam=lam, form="lda")
    # decision values differ by a positive per-fold scale (App. A), labels agree
    np.testing.assert_array_equal(np.asarray(dv_fast) >= 0, np.asarray(dv_std) >= 0)
    acc_fast = metrics.binary_accuracy(dv_fast, y_te)
    acc_std = metrics.binary_accuracy(dv_std, y_te)
    assert float(acc_fast) == pytest.approx(float(acc_std))


def test_batched_labels_match_loop():
    """(N, B) label batches (permutation path) ≡ per-vector evaluation."""
    n, p, k, lam = 48, 96, 4, 2.0
    x, yc = _data(n, p, seed=23)
    f = foldlib.kfold(n, k, seed=4)
    plan = fastcv.prepare(x, f, lam)
    rng = np.random.default_rng(0)
    ys = np.stack([rng.permutation(np.where(np.asarray(yc) == 0, -1.0, 1.0))
                   for _ in range(5)], axis=1)  # (N, 5)
    batched = fastcv.binary_dvals(plan, jnp.asarray(ys))
    for b in range(5):
        single = fastcv.binary_dvals(plan, jnp.asarray(ys[:, b]))
        np.testing.assert_allclose(np.asarray(batched[..., b]),
                                   np.asarray(single), rtol=1e-10, atol=1e-12)
