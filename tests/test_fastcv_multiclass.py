"""Exactness of the analytical multi-class LDA CV (paper §2.10, Alg. 2).

Chain of equivalences verified:
  1. step-1 CV regression fits Ẏ ≡ retrained multivariate ridge fits
  2. optimal-scoring W ≡ direct-LDA W (Hastie 1995, paper Eq. 20)
  3. analytical CV predictions ≡ standard retrained direct-LDA predictions
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastcv, folds as foldlib, metrics, multiclass
from repro.data import synthetic


def _data(n, p, c, seed=0, sep=2.0):
    return synthetic.make_classification(jax.random.PRNGKey(seed), n, p, c,
                                         class_sep=sep)


@pytest.mark.parametrize("n,p,c,k,lam", [
    (60, 10, 3, 5, 0.5),
    (90, 30, 5, 6, 1.0),
    (40, 120, 4, 5, 2.0),    # P >> N dual path
])
def test_step1_fits_match_retrained_multivariate_ridge(n, p, c, k, lam):
    x, y = _data(n, p, c)
    y1h = multiclass.onehot(y, c)
    f = foldlib.kfold(n, k, seed=1)
    plan = fastcv.prepare(x, f, lam)
    y_dot_te, y_dot_tr = fastcv.cv_errors(plan, y1h)

    xa = jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)
    i0 = jnp.eye(p + 1, dtype=x.dtype).at[p, p].set(0.0)
    for i in range(f.k):
        tr = np.asarray(f.tr_idx[i]); te = np.asarray(f.te_idx[i])
        a = xa[tr].T @ xa[tr] + lam * i0
        b = jnp.linalg.solve(a, xa[tr].T @ y1h[tr])
        np.testing.assert_allclose(np.asarray(y_dot_te[i]),
                                   np.asarray(xa[te] @ b), rtol=1e-7, atol=1e-8)
        np.testing.assert_allclose(np.asarray(y_dot_tr[i]),
                                   np.asarray(xa[tr] @ b), rtol=1e-7, atol=1e-8)


@pytest.mark.parametrize("balanced", [True, False])
def test_optimal_scoring_equals_direct_lda(balanced):
    n, p, c, lam = 120, 15, 4, 0.8
    x, y = _data(n, p, c, seed=2)
    if not balanced:
        # skew: relabel a third of class 0 as class 1
        y = jnp.where((jnp.arange(n) % 9 == 0) & (y == 0), 1, y)
    y1h = multiclass.onehot(y, c)
    w_os, a2 = multiclass.optimal_scoring_fit(x, y1h, lam)
    model = multiclass.fit_multiclass(x, y1h, lam)
    # columns equal up to sign
    for j in range(c - 1):
        cos = jnp.dot(w_os[:, j], model.w[:, j]) / (
            jnp.linalg.norm(w_os[:, j]) * jnp.linalg.norm(model.w[:, j]))
        assert abs(float(cos)) > 1 - 1e-8, f"column {j}: |cos|={abs(float(cos))}"
        ratio = jnp.linalg.norm(w_os[:, j]) / jnp.linalg.norm(model.w[:, j])
        assert float(ratio) == pytest.approx(1.0, rel=1e-6), f"column {j} scale"
    assert np.all(np.asarray(a2) < 1.0) and np.all(np.asarray(a2) > 0.0)


@pytest.mark.parametrize("n,p,c,k,lam", [
    (100, 20, 5, 5, 0.5),
    (100, 20, 10, 10, 1.0),
    (60, 200, 5, 6, 3.0),    # P >> N
])
def test_analytical_predictions_match_standard(n, p, c, k, lam):
    x, y = _data(n, p, c, seed=4)
    f = foldlib.stratified_kfold(np.asarray(y), k, seed=3)
    pred_fast, y_te = multiclass.analytical_cv_multiclass(x, y, f, c, lam)
    pred_std, y_te_std = multiclass.standard_cv_multiclass(x, y, f, c, lam)
    np.testing.assert_array_equal(np.asarray(y_te), np.asarray(y_te_std))
    np.testing.assert_array_equal(np.asarray(pred_fast), np.asarray(pred_std))


def test_accuracy_beats_chance_on_separable_data():
    n, p, c = 150, 30, 3
    x, y = _data(n, p, c, seed=6, sep=4.0)
    f = foldlib.stratified_kfold(np.asarray(y), 5, seed=1)
    pred, y_te = multiclass.analytical_cv_multiclass(x, y, f, c, lam=1.0)
    acc = float(metrics.multiclass_accuracy(pred, y_te))
    assert acc > 0.8, acc


def test_trivial_eigenpair_is_exact():
    """M θ = α² D_π θ has the exact pair (α²=1, θ=1_C) — §multiclass docs."""
    n, p, c, lam = 80, 25, 4, 1.0
    x, y = _data(n, p, c, seed=8)
    y1h = multiclass.onehot(y, c)
    xa = jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)
    i0 = jnp.eye(p + 1, dtype=x.dtype).at[p, p].set(0.0)
    b = jnp.linalg.solve(xa.T @ xa + lam * i0, xa.T @ y1h)
    m = (xa @ b).T @ y1h / n
    d_pi = jnp.sum(y1h, axis=0) / n
    lhs = m @ jnp.ones(c)
    rhs = d_pi * 1.0
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-9)
