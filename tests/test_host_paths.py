"""Regression tests for the host-path fixes reprolint (RL001/RL004) drove.

Each test pins the *behavior* behind a flagged-and-fixed site: streamed
null assembly and batch update coalescing now run on the host
(``np.concatenate`` over ``np.asarray`` chunks) instead of eager ``jnp``
assembly, and the engine's stat counters are mutated under ``_lock``.
The numeric contract is that the host path is bit-identical to the old
device path — the device-to-host transfer preserves every bit and the
dtype — so every comparison here is exact.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import (
    CVEngine,
    DatasetSpec,
    EngineConfig,
    Workload,
    serve,
    stream_workload,
)

N, P, K, LAM = 48, 96, 4, 1.0


def _problem(seed=0):
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(seed), N, P, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    return x, y, yc, foldlib.kfold(N, K, seed=1)


# ---------------------------------------------------------------------------
# RL004 fix: stat counters are exact under concurrent submissions
# ---------------------------------------------------------------------------


def test_stat_counters_exact_under_concurrent_submissions():
    x, y, _, f = _problem()
    workers, per_worker = 8, 6

    # Measure the per-call increment on a warm serial engine first, so the
    # threaded assertion is exact rather than a lower bound.
    serial = CVEngine()
    h = serial.register(x, f, LAM)
    w = Workload(kind="cv", dataset=h, y=y)
    serve(serial, [w])  # absorb plan build + first-shape compiles
    before = serial.stats()["labels_evaluated"]
    serve(serial, [w])
    per_call = serial.stats()["labels_evaluated"] - before
    assert per_call > 0

    engine = CVEngine()
    handle = engine.register(x, f, LAM)
    wt = Workload(kind="cv", dataset=handle, y=y)
    serve(engine, [wt])  # warm the plan so threads only contend on evals
    start = engine.stats()["labels_evaluated"]
    barrier = threading.Barrier(workers)

    def drive():
        barrier.wait()
        for _ in range(per_worker):
            serve(engine, [wt])

    threads = [threading.Thread(target=drive) for _ in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    got = engine.stats()["labels_evaluated"] - start
    assert got == workers * per_worker * per_call
    assert engine.stats()["plans_built"] == 1  # the warmup build, exactly once


# ---------------------------------------------------------------------------
# RL001 fix: streamed permutation nulls assemble on the host, bit-exactly
# ---------------------------------------------------------------------------


def test_streamed_permutation_null_is_host_and_bit_exact():
    x, y, _, f = _problem()
    engine = CVEngine()
    spec = DatasetSpec(x, f, LAM)
    w = Workload(kind="permutation", dataset=spec, y=y, n_perm=20, seed=4)
    events = list(stream_workload(engine, w, chunk=8))
    final = events[-1].payload

    # The assembled null is a host array...
    assert isinstance(final.null, np.ndarray)
    # ...bit-identical to its own streamed chunks...
    streamed = np.concatenate(
        [np.asarray(ev.payload) for ev in events if ev.kind == "null"]
    )
    np.testing.assert_array_equal(streamed, final.null)
    # ...and to the monolithic engine entry point (same seed, same draws).
    _, plan = engine.resolve(spec)
    mono = engine.permutation_binary(plan, y, 20, jax.random.PRNGKey(4))
    assert final.null.dtype == np.asarray(mono.null).dtype  # transfer keeps dtype
    np.testing.assert_array_equal(final.null, np.asarray(mono.null))
    np.testing.assert_array_equal(np.asarray(final.p), np.asarray(mono.p))


def test_streamed_rsa_null_and_p_match_batch_exactly():
    x, _, yc, f = _problem()
    rdm = np.abs(np.arange(3)[:, None] - np.arange(3)[None, :]).astype(np.float64)
    engine = CVEngine()
    spec = DatasetSpec(x, f, LAM)
    w = Workload(
        kind="rsa",
        dataset=spec,
        y=yc,
        num_classes=3,
        model_rdms=rdm[None],
        n_perm=12,
        seed=7,
    )
    events = list(stream_workload(engine, w, chunk=4))
    final = events[-1].payload
    assert isinstance(final.null, np.ndarray)

    (batch,) = serve(CVEngine(), [w])
    np.testing.assert_array_equal(final.null, np.asarray(batch.null))
    np.testing.assert_array_equal(np.asarray(final.p), np.asarray(batch.p))
    np.testing.assert_array_equal(
        np.asarray(final.model_scores), np.asarray(batch.model_scores)
    )


# ---------------------------------------------------------------------------
# RL001 fix: batch update coalescing stacks appends on the host
# ---------------------------------------------------------------------------


def test_update_batch_coalescing_matches_single_concatenated_update():
    x, _, _, f = _problem()
    rng = np.random.default_rng(3)
    x1 = rng.normal(size=(K, P))
    x2 = rng.normal(size=(K, P))

    coalesced = CVEngine(EngineConfig(cache_bytes=64 << 20))
    h0 = coalesced.register(x, f, LAM)
    r1, r2 = serve(
        coalesced,
        [
            Workload(kind="update", dataset=h0, x=x1),
            Workload(kind="update", dataset=h0, x=x2),
        ],
    )
    # One rank-2K correction: both members share the same version-1 handle
    # with their own appended counts.
    assert r1.handle.key == r2.handle.key and r1.handle.version == 1
    assert (r1.appended, r2.appended) == (K, K)
    assert coalesced.stats()["plans_updated"] == 1

    single = CVEngine(EngineConfig(cache_bytes=64 << 20))
    g0 = single.register(x, f, LAM)
    g1 = single.update_dataset(g0, x_new=np.concatenate([x1, x2]))
    assert g1.n == r1.handle.n == N + 2 * K

    np.testing.assert_array_equal(
        np.asarray(coalesced.dataset_record(r1.handle).x),
        np.asarray(single.dataset_record(g1).x),
    )
