"""Durable plan-store semantics (repro.serve.store.PlanStore).

The warm-boot contract: a fresh engine pointed at yesterday's store dir
serves every registered estimator bit-identically with *zero* plan
builds; damage (corruption, truncation, schema skew) degrades to
cold-boot behaviour via quarantine, never an exception; byte-budget GC
never evicts entries whose plans are pinned in memory; and concurrent
writers sharing one directory can't corrupt each other. Plus the key
durability prerequisite: ``plan_key`` is stable across processes (the
fingerprint memo is an in-process accelerator, never part of the
digest).
"""

import json
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastcv
from repro.core import folds as foldlib
from repro.serve import Client, CVEngine, EngineConfig, PlanStore, Workload
from repro.serve.store import SCHEMA_VERSION, _MANIFEST

N, P, K, LAM = 32, 48, 4, 1.0


@pytest.fixture
def problem():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (N, P), dtype=jnp.float64)
    y_int = np.asarray(jnp.arange(N) % 3, dtype=np.int32)
    y_bin = jnp.where(jnp.arange(N) % 2 == 0, -1.0, 1.0)
    return x, y_bin, y_int, foldlib.kfold(N, K, seed=1)


def _plan_and_key(x, folds, lam=LAM):
    key = fastcv.plan_key(x, folds, lam, "auto", True)
    return key, fastcv.prepare(x, folds, lam, with_train_block=True)


def _workloads(handle, y_bin, y_int):
    """One workload per registered estimator family."""
    y_multi = jnp.stack([jnp.asarray(y_bin), 2.0 * jnp.asarray(y_bin)], axis=1)
    return {
        "binary": Workload(kind="cv", dataset=handle, y=y_bin),
        "ridge": Workload(kind="cv", dataset=handle, y=y_bin, estimator="ridge"),
        "multiclass": Workload(
            kind="cv", dataset=handle, y=y_int, estimator="multiclass", num_classes=3
        ),
        "ridge_multi": Workload(
            kind="cv", dataset=handle, y=y_multi, estimator="ridge_multi"
        ),
    }


# ---------------------------------------------------------------------------
# Warm boot: rehydrated plans serve bit-identically, zero builds
# ---------------------------------------------------------------------------


def test_roundtrip_bit_identical_all_estimators(problem, tmp_path):
    x, y_bin, y_int, folds = problem
    cold = CVEngine(EngineConfig(plan_store=str(tmp_path), save_plans=True))
    handle = cold.register(x, folds, LAM)
    expected = {
        name: Client(cold).submit(w) for name, w in _workloads(handle, y_bin, y_int).items()
    }
    cold.flush_store()
    assert cold.plans_built == 1
    assert cold.store.stats.writes == 1

    warm = CVEngine(EngineConfig(plan_store=str(tmp_path)))
    handle2 = warm.register(x, folds, LAM)
    assert handle2.key == handle.key
    got = {
        name: Client(warm).submit(w) for name, w in _workloads(handle2, y_bin, y_int).items()
    }
    assert warm.plans_built == 0, "warm boot must not rebuild any plan"
    s = warm.stats()
    assert s["store_hits"] == 1 and s["plans_built"] == 0
    for name, resp in expected.items():
        np.testing.assert_array_equal(
            np.asarray(resp.values), np.asarray(got[name].values), err_msg=name
        )
        np.testing.assert_array_equal(np.asarray(resp.score), np.asarray(got[name].score))


def test_store_load_is_a_traced_stage(problem, tmp_path):
    x, _, _, folds = problem
    key, plan = _plan_and_key(x, folds)
    PlanStore(tmp_path).save(key, plan)

    engine = CVEngine(EngineConfig(plan_store=str(tmp_path)))
    handle = engine.register(x, folds, LAM)
    engine.enable_tracing()
    tr = engine.tracer.trace(kind="cv")
    with engine.tracer.activate(tr):
        engine.resolve(handle)
    engine.tracer.finish(tr)
    timings = tr.timings()
    assert "store_load" in timings and "plan_build" not in timings


def test_stats_keys_present_without_store(problem):
    s = CVEngine().stats()
    assert s["store_hits"] == s["store_misses"] == s["store_writes"] == s["store_bytes"] == 0


# ---------------------------------------------------------------------------
# Damage: quarantined, never fatal
# ---------------------------------------------------------------------------


def _saved_store(x, folds, root):
    key, plan = _plan_and_key(x, folds)
    store = PlanStore(root)
    assert store.save(key, plan)
    return store, key, plan


def test_corrupt_leaf_quarantined(problem, tmp_path):
    x, _, _, folds = problem
    store, key, _ = _saved_store(x, folds, tmp_path)
    (store.path_for(key) / "h.npy").write_bytes(b"not an array")
    assert store.load(key) is None
    assert store.stats.quarantined == 1
    assert not store.path_for(key).exists()
    assert (tmp_path / "quarantine").exists()
    # a second probe is a clean miss, not a second quarantine
    assert store.load(key) is None
    assert store.stats.quarantined == 1


def test_bitflip_detected_by_digest(problem, tmp_path):
    x, _, _, folds = problem
    store, key, plan = _saved_store(x, folds, tmp_path)
    path = store.path_for(key) / "h.npy"
    arr = np.load(path)
    arr[0, 0] += 1e-9  # same shape/dtype, different content
    np.save(path, arr)
    assert store.load(key) is None
    assert store.stats.quarantined == 1


def test_truncated_entry_quarantined(problem, tmp_path):
    x, _, _, folds = problem
    store, key, _ = _saved_store(x, folds, tmp_path)
    (store.path_for(key) / "chol_ih.npy").unlink()
    assert store.load(key) is None
    assert store.stats.quarantined == 1


def test_schema_mismatch_quarantined(problem, tmp_path):
    x, _, _, folds = problem
    store, key, _ = _saved_store(x, folds, tmp_path)
    mpath = store.path_for(key) / _MANIFEST
    manifest = json.loads(mpath.read_text())
    manifest["schema"] = SCHEMA_VERSION + 1
    mpath.write_text(json.dumps(manifest))
    assert store.load(key) is None
    assert store.stats.quarantined == 1


def test_garbled_manifest_quarantined(problem, tmp_path):
    x, _, _, folds = problem
    store, key, _ = _saved_store(x, folds, tmp_path)
    (store.path_for(key) / _MANIFEST).write_text("{ not json")
    assert store.load(key) is None
    assert store.stats.quarantined == 1


def test_damaged_store_degrades_to_cold_boot(problem, tmp_path):
    """An engine over a damaged store rebuilds instead of crashing."""
    x, y_bin, _, folds = problem
    store, key, _ = _saved_store(x, folds, tmp_path)
    (store.path_for(key) / "h.npy").write_bytes(b"garbage")

    engine = CVEngine(EngineConfig(plan_store=str(tmp_path)))
    handle = engine.register(x, folds, LAM)
    resp = Client(engine).submit(Workload(kind="cv", dataset=handle, y=y_bin))
    assert resp.values is not None
    assert engine.plans_built == 1  # rebuilt the quarantined entry
    assert engine.store.stats.quarantined == 1


# ---------------------------------------------------------------------------
# GC: byte budget + memory-pin protection
# ---------------------------------------------------------------------------


def _distinct_plans(n_plans, seed0=10):
    out = []
    for i in range(n_plans):
        x = jax.random.normal(jax.random.PRNGKey(seed0 + i), (N, P), dtype=jnp.float64)
        folds = foldlib.kfold(N, K, seed=i)
        out.append((x, folds) + _plan_and_key(x, folds))
    return out


def test_gc_respects_byte_budget(tmp_path):
    plans = _distinct_plans(3)
    entry_bytes = None
    store = PlanStore(tmp_path, byte_budget=1 << 40)
    for _, _, key, plan in plans:
        store.save(key, plan)
    entry_bytes = store.total_bytes() // 3
    # budget for two entries: oldest must go
    store.stats.byte_budget = int(entry_bytes * 2.5)
    evicted = store.gc()
    assert evicted == 1
    assert store.load(plans[0][2]) is None  # oldest evicted
    assert store.load(plans[1][2]) is not None
    assert store.load(plans[2][2]) is not None
    assert store.total_bytes() <= store.stats.byte_budget


def test_gc_never_evicts_memory_pinned(problem, tmp_path):
    plans = _distinct_plans(3)
    store = PlanStore(tmp_path, byte_budget=1 << 40)
    for _, _, key, plan in plans:
        store.save(key, plan)
    pinned_key = plans[0][2]  # oldest AND protected
    store.stats.byte_budget = store.total_bytes() // 3  # room for ~one entry
    store.gc(protect=[pinned_key])
    assert store.load(pinned_key) is not None, "pinned entry must survive GC"
    assert store.stats.evictions == 2


def test_engine_write_behind_protects_pins(tmp_path):
    """The engine's save path shields cache-pinned keys from store GC."""
    plans = _distinct_plans(2)
    (x0, f0, key0, _), (x1, f1, _, _) = plans
    entry_bytes = None
    probe = PlanStore(tmp_path / "probe")
    probe.save(key0, plans[0][3])
    entry_bytes = probe.total_bytes()

    engine = CVEngine(
        EngineConfig(
            plan_store=str(tmp_path / "store"),
            save_plans=True,
            store_bytes=int(entry_bytes * 1.5),  # one entry fits, two don't
        )
    )
    h0 = engine.register(x0, f0, LAM)
    engine.resolve(h0)
    engine.pin(h0)
    engine.flush_store()
    h1 = engine.register(x1, f1, LAM)
    engine.resolve(h1)
    engine.flush_store()
    # over budget: GC ran, but the pinned (older) entry survived
    assert engine.store.load(h0.key) is not None


# ---------------------------------------------------------------------------
# Concurrency: two engines, one dir
# ---------------------------------------------------------------------------


def test_concurrent_writers_do_not_corrupt(tmp_path):
    plans = _distinct_plans(4)
    stores = [PlanStore(tmp_path) for _ in range(2)]

    def hammer(store, order):
        for i in order:
            _, _, key, plan = plans[i]
            store.save(key, plan)

    threads = [
        threading.Thread(target=hammer, args=(stores[0], [0, 1, 2, 3])),
        threading.Thread(target=hammer, args=(stores[1], [3, 2, 1, 0])),
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    check = PlanStore(tmp_path)
    assert len(check) == 4
    for _, _, key, plan in plans:
        loaded = check.load(key)
        assert loaded is not None
        np.testing.assert_array_equal(np.asarray(plan.h), np.asarray(loaded.h))
    assert check.stats.quarantined == 0
    # exactly one commit per key across both writers
    assert stores[0].stats.writes + stores[1].stats.writes == 4


# ---------------------------------------------------------------------------
# Key durability: stable across processes, memo keyed by sampling cap
# ---------------------------------------------------------------------------

_KEY_SCRIPT = """
import jax
jax.config.update("jax_enable_x64", True)
import json, jax.numpy as jnp
from repro.core import fastcv, folds as foldlib
x = jax.random.normal(jax.random.PRNGKey(7), (24, 16), dtype=jnp.float64)
key = fastcv.plan_key(x, foldlib.kfold(24, 4, seed=2), 0.5, "auto", True)
print(json.dumps(list(key)))
"""


def test_plan_key_stable_across_processes():
    x = jax.random.normal(jax.random.PRNGKey(7), (24, 16), dtype=jnp.float64)
    here = fastcv.plan_key(x, foldlib.kfold(24, 4, seed=2), 0.5, "auto", True)
    out = subprocess.run(
        [sys.executable, "-c", _KEY_SCRIPT], capture_output=True, text=True, timeout=300
    )
    assert out.returncode == 0, out.stderr
    there = tuple(json.loads(out.stdout.strip()))
    assert there == tuple(here), "plan_key must not depend on process state"


def test_fingerprint_memo_keyed_by_sample_cap():
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 64), dtype=jnp.float64)
    fresh = fastcv.fingerprint(jnp.array(x))  # un-memoised reference digest
    sampled = fastcv.fingerprint(x, sample_cap=16)
    assert sampled != fresh  # above the cap: sampling changes the digest
    # the small-cap memo entry must not poison the default-cap lookup
    assert fastcv.fingerprint(x) == fresh
    # and memoisation still works per cap
    assert fastcv.fingerprint(x, sample_cap=16) == sampled
    assert fastcv.fingerprint(x) == fresh
