"""Tests for repro.rsa and its serving integration: RDM exactness against
a NumPy reference, comparison statistics against scipy, permutation nulls,
the pairdist kernel path, searchlight sharding, and the engine's
no-recompile guarantee for RSA traffic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rsa
from repro.core import fastcv, folds as foldlib, multiclass, permutation
from repro.data import synthetic
from repro.serve import (CVEngine, DatasetSpec, EngineConfig, EngineServer,
                         Workload, serve)

C, N_PER, P, K, LAM = 5, 12, 150, 4, 1.0
N = C * N_PER


@pytest.fixture(scope="module")
def problem():
    x, y = synthetic.make_classification(jax.random.PRNGKey(0), N, P,
                                         num_classes=C, class_sep=2.0)
    f = foldlib.stratified_kfold(np.asarray(y), K, seed=1)
    return x, y, f


@pytest.fixture(scope="module")
def models(problem):
    x, y, _ = problem
    mu = rsa.condition_means(x, y, C)
    rng = np.random.default_rng(3)
    rnd = rng.normal(size=(C, C))
    rnd = np.abs(rnd + rnd.T)
    np.fill_diagonal(rnd, 0.0)
    return jnp.stack([rsa.euclidean_rdm(mu), jnp.asarray(rnd)])


# ---------------------------------------------------------------------------
# NumPy reference: hat matrix, Eq. 14/15 fold solves, pairwise scoring
# ---------------------------------------------------------------------------


def _np_reference_rdm(x, y_cond, folds, lam, dissimilarity="accuracy",
                      adjust_bias=True):
    x = np.asarray(x, dtype=np.float64)
    y_cond = np.asarray(y_cond)
    te_idx = np.asarray(folds.te_idx)
    tr_idx = np.asarray(folds.tr_idx)
    n = x.shape[0]
    xc = x - x.mean(axis=0, keepdims=True)
    g = xc @ xc.T
    hc = g @ np.linalg.inv(g + lam * np.eye(n))
    hc = 0.5 * (hc + hc.T)
    h = hc + np.full((n, n), 1.0 / n)

    rdm = np.zeros((C, C))
    for a in range(C):
        for b in range(a + 1, C):
            yy = np.where(y_cond == a, 1.0,
                          np.where(y_cond == b, -1.0, 0.0))
            e = yy - h @ yy
            hits, total = 0.0, 0.0
            pos_vals, neg_vals = [], []
            for k in range(te_idx.shape[0]):
                te, tr = te_idx[k], tr_idx[k]
                ih = np.eye(len(te)) - h[np.ix_(te, te)]
                e_dot_te = np.linalg.solve(ih, e[te])
                dv = yy[te] - e_dot_te
                if adjust_bias:
                    e_dot_tr = e[tr] + h[np.ix_(tr, te)] @ e_dot_te
                    y_dot_tr = yy[tr] - e_dot_tr
                    ptr, ntr = yy[tr] > 0, yy[tr] < 0
                    mu1 = y_dot_tr[ptr].mean() if ptr.any() else 0.0
                    mu2 = y_dot_tr[ntr].mean() if ntr.any() else 0.0
                    dv = dv - 0.5 * (mu1 + mu2)
                lab = yy[te]
                if dissimilarity == "accuracy":
                    pred = np.where(dv >= 0, 1.0, -1.0)
                    hits += np.sum((pred == lab) & (lab != 0))
                    total += np.sum(lab != 0)
                else:
                    pos_vals.extend(dv[lab > 0])
                    neg_vals.extend(dv[lab < 0])
            if dissimilarity == "accuracy":
                val = hits / max(total, 1.0)
            else:
                val = np.mean(pos_vals) - np.mean(neg_vals)
            rdm[a, b] = rdm[b, a] = val
    return rdm


# ---------------------------------------------------------------------------
# Serve-path exactness vs the NumPy reference (acceptance criterion)
# ---------------------------------------------------------------------------


def test_serve_rdm_matches_numpy_reference(problem):
    x, y, f = problem
    engine = CVEngine()
    (resp,) = serve(
        engine, [Workload(kind="rsa", dataset=DatasetSpec(x, f, LAM), y=y, num_classes=C)]
    )
    want = _np_reference_rdm(x, y, f, LAM)
    np.testing.assert_allclose(np.asarray(resp.rdm), want, atol=1e-5)
    assert engine.stats()["plans_built"] == 1
    assert resp.pair_values.shape == (C * (C - 1) // 2,)


def test_serve_contrast_rdm_matches_numpy_reference(problem):
    x, y, f = problem
    engine = CVEngine()
    (resp,) = serve(engine, [
        Workload(kind="rsa", dataset=DatasetSpec(x, f, LAM), y=y, num_classes=C,
                 dissimilarity="contrast", adjust_bias=False)])
    want = _np_reference_rdm(x, y, f, LAM, dissimilarity="contrast",
                             adjust_bias=False)
    np.testing.assert_allclose(np.asarray(resp.rdm), want, atol=1e-5)


def test_serve_rsa_scores_match_scipy(problem, models):
    scipy_stats = pytest.importorskip("scipy.stats")
    x, y, f = problem
    engine = CVEngine()
    responses = serve(engine, [
        Workload(kind="rsa", dataset=DatasetSpec(x, f, LAM), y=y, num_classes=C,
                 model_rdms=models, comparison=method)
        for method in ("spearman", "kendall")])
    ev = np.asarray(rsa.upper_triangle(responses[0].rdm))
    mv = np.asarray(rsa.upper_triangle(models))
    for m in range(models.shape[0]):
        want_s = scipy_stats.spearmanr(ev, mv[m]).statistic
        want_k = scipy_stats.kendalltau(ev, mv[m]).statistic
        assert abs(float(responses[0].model_scores[m]) - want_s) < 1e-5
        assert abs(float(responses[1].model_scores[m]) - want_k) < 1e-5


def test_serve_rsa_multiclass_confusion(problem):
    x, y, f = problem
    engine = CVEngine()
    (resp,) = serve(engine, [
        Workload(kind="rsa", dataset=DatasetSpec(x, f, LAM), y=y, num_classes=C,
                 contrast="multiclass")])
    plan = fastcv.prepare(x, f, LAM, with_train_block=True)
    preds = multiclass.batch_predict(plan, y[None, :], C)[0]
    want = rsa.rdm_from_confusion(preds, y[plan.te_idx], C)
    np.testing.assert_allclose(np.asarray(resp.rdm), np.asarray(want),
                               atol=1e-12)
    r = np.asarray(resp.rdm)
    assert np.allclose(r, r.T) and np.all(np.diag(r) == 0.0)
    assert np.all((r >= 0.0) & (r <= 1.0))


# ---------------------------------------------------------------------------
# No-recompile guarantee for RSA traffic (acceptance criterion)
# ---------------------------------------------------------------------------


def test_warm_rsa_batch_zero_recompiles(problem, models):
    x, y, f = problem
    spec = DatasetSpec(x, f, LAM)
    engine = CVEngine()
    # one warm-up of the batch shape (3 coalesced requests hit a larger
    # contrast-column bucket than a single request would)
    batch = [Workload(kind="rsa", dataset=spec, y=y, num_classes=C,
                      model_rdms=models, n_perm=17, seed=s)
             for s in range(3)]
    serve(engine, batch)
    warm = engine.compile_count()
    # warm replay: same plan, same shape buckets, different seeds
    batch2 = [Workload(kind="rsa", dataset=spec, y=y, num_classes=C,
                       model_rdms=models, n_perm=20, seed=s)
              for s in range(5, 8)]
    responses = serve(engine, batch2)
    assert engine.compile_count() == warm
    assert all(r.null.shape == (2, 20) for r in responses)
    # a second dataset with identical shapes also reuses every program
    x2, y2 = synthetic.make_classification(jax.random.PRNGKey(5), N, P,
                                           num_classes=C, class_sep=2.0)
    spec2 = DatasetSpec(x2, f, LAM)
    serve(engine, [Workload(kind="rsa", dataset=spec2, y=y2, num_classes=C,
                            model_rdms=models, n_perm=20, seed=s)
                   for s in range(3)])
    assert engine.compile_count() == warm
    assert engine.stats()["plans_built"] == 2


def test_rsa_shares_plan_with_cv_requests(problem):
    x, y, f = problem
    spec = DatasetSpec(x, f, LAM)
    engine = CVEngine()
    y_bin = jnp.where(y % 2 == 0, -1.0, 1.0)
    serve(engine, [Workload(kind="rsa", dataset=spec, y=y, num_classes=C),
                   Workload(kind="cv", dataset=spec, y=y_bin, estimator="binary"),
                   Workload(kind="cv", dataset=spec, y=y, estimator="multiclass", num_classes=C)])
    assert engine.stats()["plans_built"] == 1


# ---------------------------------------------------------------------------
# Comparison statistics + permutation nulls
# ---------------------------------------------------------------------------


def test_rankdata_and_correlations_handle_ties():
    scipy_stats = pytest.importorskip("scipy.stats")
    a = jnp.asarray([1.0, 2.0, 2.0, 3.0, 0.5, 2.0])
    b = jnp.asarray([0.1, 0.1, 5.0, 2.0, 2.0, 1.0])
    np.testing.assert_allclose(np.asarray(rsa.rankdata(a)),
                               scipy_stats.rankdata(np.asarray(a)))
    assert abs(float(rsa.spearman(a, b))
               - scipy_stats.spearmanr(np.asarray(a), np.asarray(b)).statistic) < 1e-12
    assert abs(float(rsa.kendall(a, b))
               - scipy_stats.kendalltau(np.asarray(a), np.asarray(b)).statistic) < 1e-12


def test_cosine_and_pearson():
    v = jnp.asarray([1.0, 2.0, 3.0])
    assert abs(float(rsa.cosine(v, 2.0 * v)) - 1.0) < 1e-12
    assert abs(float(rsa.pearson(v, -v)) + 1.0) < 1e-12


def test_permutation_null_engine_matches_library(problem, models):
    """Engine nulls (bucket-rounded T) are prefix-identical to direct
    library calls sharing the key — same contract as CV permutations."""
    x, y, f = problem
    engine = CVEngine()
    (resp,) = serve(engine, [
        Workload(kind="rsa", dataset=DatasetSpec(x, f, LAM), y=y, num_classes=C,
                 model_rdms=models, n_perm=20, seed=7)])
    from repro.serve.batching import bucket_size
    perms = permutation.permutation_indices(jax.random.PRNGKey(7), C,
                                            bucket_size(20))
    want = rsa.permutation_null(resp.rdm, models, perms)[:, :20]
    np.testing.assert_allclose(np.asarray(resp.null), np.asarray(want),
                               atol=1e-12)
    assert resp.p.shape == (2,)
    assert np.all((np.asarray(resp.p) > 0.0) & (np.asarray(resp.p) <= 1.0))
    # a self-model must score (near) perfectly and be significant
    (self_resp,) = serve(engine, [
        Workload(kind="rsa", dataset=DatasetSpec(x, f, LAM), y=y, num_classes=C,
                 model_rdms=resp.rdm[None], n_perm=63, seed=2)])
    assert float(self_resp.model_scores[0]) > 0.999


# ---------------------------------------------------------------------------
# Pattern RDMs (pairdist kernel) + searchlight sharding
# ---------------------------------------------------------------------------


def test_euclidean_rdm_impls_agree(problem):
    x, y, _ = problem
    mu = rsa.condition_means(x, y, C)
    d_xla = rsa.euclidean_rdm(mu, impl="xla")
    d_pal = rsa.euclidean_rdm(mu, impl="pallas")
    np.testing.assert_allclose(np.asarray(d_pal), np.asarray(d_xla),
                               rtol=1e-9, atol=1e-9)
    d = np.asarray(d_xla)
    assert np.allclose(d, d.T) and np.allclose(np.diag(d), 0.0)


def test_condition_means(problem):
    x, y, _ = problem
    mu = np.asarray(rsa.condition_means(x, y, C))
    for c in range(C):
        np.testing.assert_allclose(mu[c],
                                   np.asarray(x)[np.asarray(y) == c].mean(0),
                                   rtol=1e-12)


def test_searchlight_rdm_matches_per_problem(problem):
    x, y, f = problem
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    xs = jax.random.normal(jax.random.PRNGKey(4), (3, N, 48), jnp.float64)
    got = rsa.searchlight_rdm(xs, y, f, LAM, mesh, num_classes=C,
                              problem_axes=("data",))
    assert got.shape == (3, C, C)
    for q in range(3):
        want = rsa.rdm_binary(xs[q], y, f, C, LAM)
        np.testing.assert_allclose(np.asarray(got[q]), np.asarray(want),
                                   rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# Pair-contrast plumbing + threaded server
# ---------------------------------------------------------------------------


def test_pair_contrast_columns(problem):
    _, y, _ = problem
    cols = np.asarray(rsa.pair_contrast_columns(y, C))
    pairs = rsa.condition_pairs(C)
    assert cols.shape == (N, C * (C - 1) // 2)
    y_np = np.asarray(y)
    for j, (a, b) in enumerate(pairs):
        np.testing.assert_array_equal(
            cols[:, j], np.where(y_np == a, 1.0,
                                 np.where(y_np == b, -1.0, 0.0)))


def test_rsa_through_engine_server(problem, models):
    x, y, f = problem
    spec = DatasetSpec(x, f, LAM)
    requests = [Workload(kind="rsa", dataset=spec, y=y, num_classes=C,
                         model_rdms=models, n_perm=10, seed=s)
                for s in range(4)]
    sync = serve(CVEngine(), requests)
    with EngineServer(CVEngine(), max_batch=4, max_wait_ms=5.0) as server:
        futures = [server.submit(r) for r in requests]
        results = [fu.result(timeout=300) for fu in futures]
    for got, want in zip(results, sync):
        np.testing.assert_allclose(np.asarray(got.rdm), np.asarray(want.rdm),
                                   rtol=1e-9, atol=1e-12)
        np.testing.assert_allclose(np.asarray(got.model_scores),
                                   np.asarray(want.model_scores),
                                   rtol=1e-9, atol=1e-12)


def test_oversized_plan_still_serves_rsa(problem):
    """Admission control end-to-end: a budget smaller than one plan serves
    the request un-cached without evicting anything."""
    x, y, f = problem
    engine = CVEngine(EngineConfig(cache_bytes=1024))     # tiny budget
    (resp,) = serve(
        engine, [Workload(kind="rsa", dataset=DatasetSpec(x, f, LAM), y=y, num_classes=C)]
    )
    want = _np_reference_rdm(x, y, f, LAM)
    np.testing.assert_allclose(np.asarray(resp.rdm), want, atol=1e-5)
    stats = engine.stats()
    assert stats["oversized"] >= 1
    assert stats["bytes_in_use"] == 0 and stats["evictions"] == 0
