"""Property-based tests (hypothesis) for the Workload wire schema.

Two invariants back the HTTP edge's claim that Workload JSON is *the*
wire schema:

  * round-trip exactness — for any valid spec (every kind, random
    estimators / handles / options), ``from_dict(to_dict(w))`` through
    real JSON text reproduces ``to_dict`` byte-for-byte;
  * eager rejection — any corrupted dict raises a clear Python
    exception at ``from_dict``/construction time, never a shape failure
    inside jit.

The builders and corruption table live above the hypothesis import on
purpose: they are plain Python, exercised by the deterministic sweep
below on every environment, while hypothesis additionally drives them
across the whole option space in CI (the ``[test]`` extra installs it;
environments without it run only the sweep).
"""

import json

import numpy as np
import pytest

from repro.core import folds as foldlib
from repro.serve import DatasetHandle, DatasetSpec, Workload
from repro.serve.workload import KINDS

N, P, K = 16, 5, 4
_X = np.random.default_rng(0).normal(size=(N, P))
_FOLDS = foldlib.kfold(N, K, seed=0)

_ESTIMATORS = ("binary", "ridge", "multiclass", "ridge_multi")
_MODES = ("auto", "primal", "dual")


def _dataset(use_handle: bool, lam: float, mode: str, with_x: bool = True,
             version: int = 0):
    if use_handle:
        return DatasetHandle(
            key=("fp-x", "fp-te", "fp-tr", float(lam), mode, int(version), True),
            n=N, p=P, lam=float(lam), mode=mode, version=int(version),
        )
    return DatasetSpec(_X if with_x else None, _FOLDS, float(lam), mode)


def _build_workload(kind, *, seed, use_handle, lam, mode, estimator, width,
                    num_classes, n_perm, wseed, metric, contrast,
                    dissimilarity, comparison, with_models, criterion,
                    adjust_bias) -> Workload:
    """One *valid* Workload from drawn primitives (any kind/options)."""
    rng = np.random.default_rng(seed)
    ds = _dataset(use_handle, lam, mode)
    if kind == "cv":
        if estimator == "binary":
            y = rng.choice([-1.0, 1.0], size=(N,) if width == 0 else (N, width))
            return Workload(kind="cv", dataset=ds, y=y, adjust_bias=adjust_bias)
        if estimator == "ridge":
            y = rng.normal(size=(N,) if width == 0 else (N, width))
            return Workload(kind="cv", dataset=ds, y=y, estimator="ridge")
        if estimator == "multiclass":
            y = rng.integers(0, num_classes, size=(N,))
            return Workload(kind="cv", dataset=ds, y=y, estimator="multiclass",
                            num_classes=num_classes)
        y = rng.normal(size=(N, width + 1))  # ridge_multi: (N, Q) targets
        return Workload(kind="cv", dataset=ds, y=y, estimator="ridge_multi")
    if kind == "permutation":
        if estimator in ("multiclass",):
            y = rng.integers(0, num_classes, size=(N,))
            return Workload(kind="permutation", dataset=ds, y=y,
                            estimator="multiclass", num_classes=num_classes,
                            n_perm=n_perm, seed=wseed)
        y = rng.choice([-1.0, 1.0], size=(N,))
        return Workload(kind="permutation", dataset=ds, y=y, n_perm=n_perm,
                        seed=wseed, metric=metric, adjust_bias=adjust_bias)
    if kind == "rsa":
        y = rng.integers(0, num_classes, size=(N,))
        models = rng.normal(size=(2, num_classes, num_classes)) if with_models else None
        return Workload(kind="rsa", dataset=ds, y=y, num_classes=num_classes,
                        contrast=contrast, dissimilarity=dissimilarity,
                        comparison=comparison, model_rdms=models,
                        n_perm=n_perm if with_models else 0, seed=wseed,
                        adjust_bias=adjust_bias)
    if kind == "tune":
        y = rng.normal(size=(N,))
        lambdas = rng.uniform(0.1, 5.0, size=4) if with_models else None
        return Workload(kind="tune", x=_X, y=y, lambdas=lambdas,
                        criterion=criterion)
    if kind == "update":
        # incremental updates act on registry state, so always a handle;
        # draw append-only / retire-only / sliding-window shapes
        ds = _dataset(True, lam, mode, version=width)
        x_new = rng.normal(size=(width + 1, P))
        drop = np.sort(rng.choice(N, size=num_classes, replace=False))
        if not with_models:  # append-only
            return Workload(kind="update", dataset=ds, x=x_new)
        if adjust_bias:  # sliding window: append + retire together
            return Workload(kind="update", dataset=ds, x=x_new, drop_idx=drop)
        return Workload(kind="update", dataset=ds, drop_idx=drop)
    xs = rng.normal(size=(2, N, P))
    y = rng.choice([-1.0, 1.0], size=(N,))
    return Workload(kind="grid", dataset=_dataset(use_handle, lam, mode, with_x=False),
                    y=y, xs=xs, adjust_bias=adjust_bias)


# -- corruptions: each mutation is invalid for EVERY workload kind ----------


def _corrupt_schema(d):
    d["schema"] = d.get("schema", 1) + 41


def _corrupt_drop_schema(d):
    d.pop("schema", None)


def _corrupt_kind(d):
    d["kind"] = "bogus-kind"


def _corrupt_drop_kind(d):
    d.pop("kind", None)


def _corrupt_drop_targets(d):
    if d["kind"] == "update":
        d["x"] = None  # updates need rows to append and/or retire
        d["drop_idx"] = None
    else:
        d["y"] = None  # every other kind requires targets / labels


def _corrupt_drop_dataset(d):
    d["dataset"] = None  # cv/permutation/rsa/grid need it...
    d["x"] = None  # ...and tune needs inline features


def _corrupt_malformed_y(d):
    if d["kind"] == "cv":
        # wrong length for every estimator; also breaks ±1 coding (binary),
        # the integer dtype (multiclass), and the (N, Q) contract (ridge_multi)
        d["y"] = {"__array__": [0.5] * 7, "dtype": "float64"}
    elif d["kind"] == "permutation":
        d["y"] = {"__array__": [[1.0, -1.0]] * 2, "dtype": "float64"}  # 2-D
    elif d["kind"] == "rsa":
        d["y"] = {"__array__": [0.5] * N, "dtype": "float64"}  # non-integer labels
    elif d["kind"] == "tune":
        d["y"] = {"__array__": [1.0] * (N + 3), "dtype": "float64"}  # length != N
    elif d["kind"] == "update":
        d["x"] = {"__array__": [1.0] * P, "dtype": "float64"}  # 1-D, not (k, P)
    else:  # grid
        d["xs"] = {"__array__": [[1.0] * P] * N, "dtype": "float64"}  # not (Q, N, P)


def _corrupt_options(d):
    if d["kind"] == "cv":
        d["estimator"] = "no-such-estimator"
    elif d["kind"] == "permutation":
        d["n_perm"] = 0
    elif d["kind"] == "rsa":
        d["num_classes"] = 0
    elif d["kind"] == "tune":
        d["criterion"] = "nonsense"
    elif d["kind"] == "update":
        d["drop_idx"] = {"__array__": [0.5, 1.5], "dtype": "float64"}  # non-int
    else:  # grid
        d["y"] = None


_CORRUPTIONS = (
    ("wrong-schema-version", _corrupt_schema),
    ("missing-schema", _corrupt_drop_schema),
    ("unknown-kind", _corrupt_kind),
    ("missing-kind", _corrupt_drop_kind),
    ("missing-targets", _corrupt_drop_targets),
    ("missing-dataset", _corrupt_drop_dataset),
    ("malformed-targets", _corrupt_malformed_y),
    ("malformed-options", _corrupt_options),
)

# ---------------------------------------------------------------------------
# deterministic sweep — runs on every environment, hypothesis or not
# ---------------------------------------------------------------------------

_SWEEP = (
    dict(seed=3, use_handle=False, lam=0.7, mode="auto", estimator="binary",
         width=0, num_classes=3, n_perm=8, wseed=11, metric="accuracy",
         contrast="binary", dissimilarity="accuracy", comparison="spearman",
         with_models=False, criterion="mse", adjust_bias=False),
    dict(seed=7, use_handle=True, lam=2.5, mode="dual", estimator="ridge_multi",
         width=2, num_classes=4, n_perm=3, wseed=5, metric="auc",
         contrast="multiclass", dissimilarity="contrast", comparison="kendall",
         with_models=True, criterion="error", adjust_bias=True),
    dict(seed=9, use_handle=True, lam=0.1, mode="primal", estimator="multiclass",
         width=1, num_classes=2, n_perm=1, wseed=0, metric="accuracy",
         contrast="binary", dissimilarity="contrast", comparison="cosine",
         with_models=True, criterion="mse", adjust_bias=False),
)


@pytest.mark.parametrize("opts", range(len(_SWEEP)))
@pytest.mark.parametrize("kind", KINDS)
def test_schema_roundtrips_deterministic_sweep(kind, opts):
    w = _build_workload(kind, **_SWEEP[opts])
    d = w.to_dict()
    back = Workload.from_dict(json.loads(json.dumps(d)))
    assert back.to_dict() == d
    assert back.kind == w.kind
    if isinstance(w.dataset, DatasetHandle):
        assert back.dataset == w.dataset


@pytest.mark.parametrize("name,corrupt", _CORRUPTIONS, ids=[c[0] for c in _CORRUPTIONS])
@pytest.mark.parametrize("kind", KINDS)
def test_corruptions_raise_deterministic_sweep(kind, name, corrupt):
    w = _build_workload(kind, **_SWEEP[1])
    d = json.loads(json.dumps(w.to_dict()))
    corrupt(d)
    with pytest.raises((ValueError, TypeError, KeyError)):
        Workload.from_dict(d)


# ---------------------------------------------------------------------------
# hypothesis drives the builders across the whole option space (when
# installed; the deterministic sweep above runs regardless)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - sweep-only environments
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _SETTINGS = dict(max_examples=30, deadline=None, derandomize=True)

    @st.composite
    def workloads(draw):
        return _build_workload(
            draw(st.sampled_from(KINDS)),
            seed=draw(st.integers(min_value=0, max_value=2**16)),
            use_handle=draw(st.booleans()),
            lam=draw(st.floats(min_value=0.01, max_value=50.0)),
            mode=draw(st.sampled_from(_MODES)),
            estimator=draw(st.sampled_from(_ESTIMATORS)),
            width=draw(st.integers(min_value=0, max_value=3)),
            num_classes=draw(st.integers(min_value=2, max_value=4)),
            n_perm=draw(st.integers(min_value=1, max_value=40)),
            wseed=draw(st.integers(min_value=0, max_value=2**31 - 1)),
            metric=draw(st.sampled_from(("accuracy", "auc"))),
            contrast=draw(st.sampled_from(("binary", "multiclass"))),
            dissimilarity=draw(st.sampled_from(("accuracy", "contrast"))),
            comparison=draw(st.sampled_from(("spearman", "kendall", "pearson", "cosine"))),
            with_models=draw(st.booleans()),
            criterion=draw(st.sampled_from(("mse", "error"))),
            adjust_bias=draw(st.booleans()),
        )

    @given(workloads())
    @settings(**_SETTINGS)
    def test_workload_schema_roundtrips_exactly(w):
        """∀ valid specs: from_dict(to_dict(w)) through real JSON text is a
        byte-exact fixed point of to_dict (and preserves dataset handles)."""
        d = w.to_dict()
        wire = json.loads(json.dumps(d))  # through actual wire bytes
        back = Workload.from_dict(wire)
        assert back.to_dict() == d
        assert back.kind == w.kind and back.estimator == w.estimator
        if isinstance(w.dataset, DatasetHandle):
            assert back.dataset == w.dataset

    @given(workloads(), st.integers(min_value=0, max_value=len(_CORRUPTIONS) - 1))
    @settings(**_SETTINGS)
    def test_fuzzed_invalid_dicts_raise_eager_validation(w, idx):
        """∀ valid specs × corruptions: the mutated dict raises a clear eager
        exception at from_dict — never an in-jit shape failure later."""
        _name, corrupt = _CORRUPTIONS[idx]
        d = json.loads(json.dumps(w.to_dict()))
        corrupt(d)
        with pytest.raises((ValueError, TypeError, KeyError)):
            Workload.from_dict(d)
