"""Tests for the One-API surface: Workload schema + validation, estimator
registry, dataset handles, core parity across all three transports,
compile-count flatness between spec- and handle-addressed traffic, the
0.3 removal of the legacy request shims, RDM memoisation, traffic
record/replay, and mesh-aware streamed nulls."""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastcv, folds as foldlib, multiclass, multidim, regression, tuning
from repro.data import synthetic
from repro.serve import (
    Client,
    CVEngine,
    CVResponse,
    DatasetHandle,
    DatasetSpec,
    EngineConfig,
    GridResponse,
    LeastSquaresSpec,
    TrafficLog,
    Workload,
    as_workload,
    estimators,
    register_estimator,
    serve,
    stream_workload,
)
from repro.serve import workload as workload_mod

N, P, K, LAM = 48, 96, 4, 1.0


@pytest.fixture(scope="module")
def problem():
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(0), N, P, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    f = foldlib.kfold(N, K, seed=1)
    return x, y, yc, f


def _equiv_workloads(problem, dataset, n_perm=12):
    x, y, yc, _ = problem
    return [
        Workload(kind="cv", dataset=dataset, y=y, estimator="binary"),
        Workload(kind="cv", dataset=dataset, y=y, estimator="ridge"),
        Workload(kind="cv", dataset=dataset, y=yc, estimator="multiclass", num_classes=3),
        Workload(kind="permutation", dataset=dataset, y=y, n_perm=n_perm, seed=4),
        Workload(kind="rsa", dataset=dataset, y=yc, num_classes=3,
                 model_rdms=jnp.ones((1, 3, 3)), n_perm=8, seed=2),
        Workload(kind="tune", x=x, y=y),
    ]


def _assert_responses_equal(got, want, exact=True):
    assert type(got) is type(want)
    for field in ("values", "null", "rdm", "model_scores", "p", "score", "accuracies"):
        a, b = getattr(got, field, None), getattr(want, field, None)
        assert (a is None) == (b is None)
        if a is None:
            continue
        if exact:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-9, atol=1e-12)
    if hasattr(want, "result"):
        assert float(got.result.best_lambda) == float(want.result.best_lambda)


# ---------------------------------------------------------------------------
# 0.3: the deprecated request shims are gone
# ---------------------------------------------------------------------------


def test_removed_shims_raise_importerror_with_migration_pointer():
    for name in ("CVRequest", "PermutationRequest", "RSARequest", "TuneRequest", "Request"):
        with pytest.raises(ImportError, match="removed at 0.3"):
            getattr(__import__("repro.serve.api", fromlist=[name]), name)
    # the package namespace no longer advertises them either
    import repro.serve as serve_pkg
    for name in ("CVRequest", "PermutationRequest", "RSARequest", "TuneRequest"):
        with pytest.raises(AttributeError):
            getattr(serve_pkg, name)


def test_as_workload_rejects_foreign_objects_with_migration_pointer(problem):
    x, y, _, f = problem

    class FakeLegacyRequest:
        pass

    with pytest.raises(TypeError, match="README"):
        as_workload(FakeLegacyRequest())


def test_parity_across_all_three_transports(problem):
    """Shim and Workload must be bit-identical through sync, thread, and
    async transports (sequential submission => identical padded shapes)."""
    x, _, _, f = problem
    handle_results = {}
    for transport in ("sync", "thread", "async"):
        engine = CVEngine()
        handle = engine.register(x, f, LAM)
        ws = _equiv_workloads(problem, handle)
        if transport == "async":

            async def drive(ws=ws, engine=engine):
                async with Client(engine, transport="async") as client:
                    return [await client.submit(w) for w in ws]

            handle_results[transport] = asyncio.run(drive())
        elif transport == "thread":
            with Client(engine, transport="thread") as client:
                handle_results[transport] = [client.submit(w).result(timeout=300) for w in ws]
        else:
            client = Client(engine)
            handle_results[transport] = [client.submit(w) for w in ws]
    for transport in ("thread", "async"):
        for got, want in zip(handle_results[transport], handle_results["sync"]):
            _assert_responses_equal(got, want, exact=True)


def test_compile_count_flat_across_spec_and_handle_traffic(problem):
    """Spec-addressed then handle-addressed versions of the same traffic
    must not retrace anything: one program family, not two."""
    x, _, _, f = problem
    engine = CVEngine()
    serve(engine, _equiv_workloads(problem, DatasetSpec(x, f, LAM)))
    warm = engine.compile_count()
    serve(engine, _equiv_workloads(problem, DatasetSpec(x, f, LAM)))
    handle = engine.register(x, f, LAM)
    serve(engine, _equiv_workloads(problem, handle))
    assert engine.compile_count() == warm
    assert engine.stats()["plans_built"] == 1


# ---------------------------------------------------------------------------
# core/ convenience entry points == Workload path
# ---------------------------------------------------------------------------


def test_core_binary_cv_parity(problem):
    x, y, _, f = problem
    dv, y_te = fastcv.binary_cv(x, y, f, lam=LAM)
    resp = Client().submit(Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=y))
    np.testing.assert_array_equal(np.asarray(resp.values), np.asarray(dv))
    np.testing.assert_array_equal(np.asarray(resp.y_te), np.asarray(y_te))


def test_core_analytical_cv_ridge_parity(problem):
    x, y, _, f = problem
    preds, _ = regression.analytical_cv(x, y, f, lam=LAM)
    resp = Client().submit(
        Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=y, estimator="ridge")
    )
    np.testing.assert_array_equal(np.asarray(resp.values), np.asarray(preds))


def test_core_analytical_cv_multiclass_parity(problem):
    x, _, yc, f = problem
    preds, _ = multiclass.analytical_cv_multiclass(x, yc, f, 3, LAM)
    resp = Client().submit(
        Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=yc,
                 estimator="multiclass", num_classes=3)
    )
    np.testing.assert_array_equal(np.asarray(resp.values), np.asarray(preds))


def test_core_tune_ridge_parity(problem):
    x, y, _, _ = problem
    direct = tuning.tune_ridge(x, y)
    resp = Client().submit(Workload(kind="tune", x=x, y=y))
    assert float(resp.result.best_lambda) == float(direct.best_lambda)
    np.testing.assert_array_equal(np.asarray(resp.result.scores), np.asarray(direct.scores))


def test_core_cv_grid_parity(problem):
    x, y, _, f = problem
    xs = jnp.stack([x, x * 1.05, jnp.roll(x, 1, axis=0)])
    direct = multidim.cv_grid(xs, y, f, LAM)
    resp = Client().submit(
        Workload(kind="grid", dataset=DatasetSpec(None, f, LAM), y=y, xs=xs)
    )
    assert isinstance(resp, GridResponse)
    np.testing.assert_array_equal(np.asarray(resp.accuracies), np.asarray(direct))


# ---------------------------------------------------------------------------
# Estimator registry: new least-squares models are registrations
# ---------------------------------------------------------------------------


def test_ridge_multi_registration(problem):
    """Multi-target ridge is served via registration alone — and shares the
    ridge evaluator's compiled programs (eval_key), so zero extra compiles."""
    x, y, _, f = problem
    engine = CVEngine()
    client = Client(engine)
    data = client.register(x, f, LAM)
    q = jnp.stack([y, -y, jnp.roll(y, 5)], axis=1)  # (N, 3) targets
    _, plan = engine.resolve(data)
    ref = engine.eval_ridge(plan, q)
    warm = engine.compile_count()
    resp = client.submit(Workload(kind="cv", dataset=data, y=q, estimator="ridge_multi"))
    assert engine.compile_count() == warm  # shared eval_key="ridge"
    np.testing.assert_array_equal(np.asarray(resp.values), np.asarray(ref))
    # variance-weighted multi-target R², not MSE
    y_te = q[plan.te_idx]
    v = np.asarray(ref).reshape(-1, 3)
    t = np.asarray(y_te).reshape(-1, 3)
    r2 = np.mean(1 - ((t - v) ** 2).sum(0) / ((t - t.mean(0)) ** 2).sum(0))
    assert float(resp.score) == pytest.approx(r2, rel=1e-9)
    with pytest.raises(ValueError, match="needs \\(N, Q\\)"):
        Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=y, estimator="ridge_multi")


def test_third_party_estimator_registration(problem):
    """A model family added by registration alone: demeaned-target ridge.
    No engine, driver, or transport changes — and no new compiled programs
    (it shares the Eq. 14 evaluator via eval_key)."""
    x, y, _, f = problem
    name = "ridge_demeaned"

    def encode(yv, dtype, opts):
        yb = jnp.asarray(yv)
        squeeze = yb.ndim == 1
        yb = (yb[:, None] if squeeze else yb).astype(dtype)
        return yb - jnp.mean(yb, axis=0, keepdims=True), squeeze

    register_estimator(LeastSquaresSpec(
        name=name,
        layout="columns",
        make_eval=lambda opts, donate, fused: fastcv.make_eval_cv(donate=donate, fused=fused),
        encode=encode,
        score=lambda values, y_te, opts: jnp.mean((values - y_te) ** 2),
        eval_key="ridge",
    ))
    try:
        assert name in estimators()
        with pytest.raises(ValueError, match="already registered"):
            register_estimator(LeastSquaresSpec(
                name=name, layout="columns",
                make_eval=lambda opts, donate, fused: fastcv.make_eval_cv(donate=donate, fused=fused),
            ))
        engine = CVEngine()
        client = Client(engine)
        data = client.register(x, f, LAM)
        client.submit(Workload(kind="cv", dataset=data, y=y, estimator="ridge"))
        warm = engine.compile_count()
        resp = client.submit(Workload(kind="cv", dataset=data, y=y, estimator=name))
        assert engine.compile_count() == warm
        _, plan = engine.resolve(data)
        ref = engine.eval_ridge(plan, y - jnp.mean(y))
        np.testing.assert_array_equal(np.asarray(resp.values), np.asarray(ref))
    finally:
        del workload_mod._ESTIMATORS[name]


# ---------------------------------------------------------------------------
# Schema: eager validation + versioned round-trip
# ---------------------------------------------------------------------------


def test_validation_rejects_malformed_workloads(problem):
    x, y, yc, f = problem
    spec = DatasetSpec(x, f, LAM)
    with pytest.raises(ValueError, match="unknown workload kind"):
        Workload(kind="nonsense", dataset=spec, y=y)
    with pytest.raises(ValueError, match="unknown estimator"):
        Workload(kind="cv", dataset=spec, y=y, estimator="nonsense")
    with pytest.raises(ValueError, match="±1"):
        Workload(kind="cv", dataset=spec, y=y * 2.0)
    with pytest.raises(ValueError, match="lie in \\[0, 3\\)"):
        Workload(kind="cv", dataset=spec, y=yc + 5, estimator="multiclass", num_classes=3)
    with pytest.raises(ValueError, match="n_perm > 0"):
        Workload(kind="permutation", dataset=spec, y=y, n_perm=0)
    with pytest.raises(ValueError, match="single \\(N,\\) target"):
        Workload(kind="permutation", dataset=spec, y=jnp.stack([y, -y], 1), n_perm=4)
    with pytest.raises(ValueError, match="metric"):
        Workload(kind="permutation", dataset=spec, y=y, n_perm=4, metric="nonsense")
    with pytest.raises(ValueError, match="num_classes >= 2"):
        Workload(kind="rsa", dataset=spec, y=yc, num_classes=0)
    with pytest.raises(ValueError, match="\\(M, C, C\\)"):
        Workload(kind="rsa", dataset=spec, y=yc, num_classes=3,
                 model_rdms=jnp.ones((2, 4, 4)))
    with pytest.raises(ValueError, match="comparison"):
        Workload(kind="rsa", dataset=spec, y=yc, num_classes=3, comparison="nonsense")
    with pytest.raises(ValueError, match="need a dataset"):
        Workload(kind="cv", y=y)
    with pytest.raises(ValueError, match="criterion"):
        Workload(kind="tune", x=x, y=y, criterion="nonsense")
    with pytest.raises(ValueError, match="\\(Q, N, P\\)"):
        Workload(kind="grid", dataset=spec, y=y, xs=x)


def test_workload_roundtrip_dict(problem):
    """to_dict/from_dict is versioned and result-preserving."""
    x, y, yc, f = problem
    spec = DatasetSpec(x, f, LAM)
    for w in (
        Workload(kind="cv", dataset=spec, y=y),
        Workload(kind="permutation", dataset=spec, y=y, n_perm=6, seed=3),
        Workload(kind="rsa", dataset=spec, y=yc, num_classes=3,
                 model_rdms=jnp.ones((1, 3, 3)), n_perm=4),
        Workload(kind="tune", x=x, y=y),
    ):
        d = w.to_dict()
        assert d["schema"] == 2
        back = Workload.from_dict(d)
        (a,) = serve(CVEngine(), [w])
        (b,) = serve(CVEngine(), [back])
        _assert_responses_equal(b, a, exact=True)
    with pytest.raises(ValueError, match="schema version"):
        Workload.from_dict({"schema": 99, "kind": "cv"})


def test_workload_roundtrip_preserves_handles(problem):
    x, y, _, f = problem
    engine = CVEngine()
    handle = engine.register(x, f, LAM)
    w = Workload(kind="cv", dataset=handle, y=y)
    back = Workload.from_dict(w.to_dict())
    assert isinstance(back.dataset, DatasetHandle)
    assert back.dataset.key == handle.key
    (a,) = serve(engine, [w])
    (b,) = serve(engine, [back])  # resolves through the same registration
    np.testing.assert_array_equal(np.asarray(a.values), np.asarray(b.values))


# ---------------------------------------------------------------------------
# Dataset registry: handles, introspection, handle-scoped ops
# ---------------------------------------------------------------------------


def test_register_is_idempotent_and_introspectable(problem):
    x, y, _, f = problem
    engine = CVEngine()
    h1 = engine.register(x, f, LAM)
    h2 = engine.register(x, f, LAM)
    assert h1 == h2
    assert h1.n == N and h1.p == P
    (info,) = engine.datasets()
    assert info["resident"] is False and info["served"] == 0
    serve(engine, [Workload(kind="cv", dataset=h1, y=y)])
    (info,) = engine.datasets()
    assert info["resident"] is True and info["served"] == 1 and info["nbytes"] > 0


def test_handle_pin_warmup_evict(problem):
    x, y, _, f = problem
    engine = CVEngine()
    h = engine.register(x, f, LAM)
    info = engine.warmup(h, tasks=("binary",), buckets=(1,), pin=True)
    assert info["pinned"]
    assert engine.datasets()[0]["pinned"] is True
    assert engine.unpin(h)
    assert engine.evict(h)
    assert engine.datasets()[0]["resident"] is False
    # a handle workload transparently rebuilds the evicted plan
    built = engine.plans_built
    (resp,) = serve(engine, [Workload(kind="cv", dataset=h, y=y)])
    assert isinstance(resp, CVResponse)
    assert engine.plans_built == built + 1
    engine.evict(h, deregister=True)
    with pytest.raises(KeyError, match="not registered"):
        serve(engine, [Workload(kind="cv", dataset=h, y=y)])


def test_unregistered_handle_fails_clearly(problem):
    x, y, _, f = problem
    other = CVEngine()
    h = other.register(x, f, LAM)
    with pytest.raises(KeyError, match="not registered"):
        serve(CVEngine(), [Workload(kind="cv", dataset=h, y=y)])


# ---------------------------------------------------------------------------
# RDM memoisation
# ---------------------------------------------------------------------------


def test_rdm_memoisation_skips_fold_solves(problem):
    x, _, yc, f = problem
    engine = CVEngine()
    client = Client(engine)
    data = client.register(x, foldlib.stratified_kfold(yc, K, seed=0), LAM)
    models = jnp.ones((2, 3, 3))
    w = Workload(kind="rsa", dataset=data, y=yc, num_classes=3,
                 model_rdms=models, n_perm=8, seed=1)
    r1 = client.submit(w)
    labels_after_first = engine.labels_evaluated
    assert engine.stats()["rdm_hits"] == 0
    r2 = client.submit(w)
    assert engine.stats()["rdm_hits"] == 1
    # the empirical RDM came from the memo: no further fold solves
    assert engine.labels_evaluated == labels_after_first
    np.testing.assert_array_equal(np.asarray(r1.rdm), np.asarray(r2.rdm))
    np.testing.assert_array_equal(np.asarray(r1.model_scores), np.asarray(r2.model_scores))
    # different labels -> different fingerprint -> miss
    client.submit(Workload(kind="rsa", dataset=data, y=(yc + 1) % 3, num_classes=3))
    assert engine.stats()["rdm_hits"] == 1
    assert engine.stats()["rdm_entries"] == 2


def test_rdm_memo_stable_across_plan_variants(problem):
    """The memo must hit even when the same workload is later served from
    the cached *superset* (train-block) plan instead of the train-free one."""
    x, y, yc, f = problem
    engine = CVEngine()
    spec = DatasetSpec(x, foldlib.stratified_kfold(yc, K, seed=0), LAM)
    w = Workload(kind="rsa", dataset=spec, y=yc, num_classes=3, adjust_bias=False)
    serve(engine, [w])  # builds the with_train_block=False plan
    serve(engine, [Workload(kind="cv", dataset=spec, y=y)])  # superset plan now resident
    serve(engine, [w])  # resolves via the superset key; must still hit
    assert engine.stats()["rdm_hits"] == 1
    assert engine.stats()["rdm_entries"] == 1


def test_rdm_memo_streaming_and_batch_share_entries(problem):
    x, _, yc, f = problem
    engine = CVEngine()
    spec = DatasetSpec(x, foldlib.stratified_kfold(yc, K, seed=0), LAM)
    w = Workload(kind="rsa", dataset=spec, y=yc, num_classes=3)
    (batch,) = serve(engine, [w])
    events = list(stream_workload(engine, w))
    assert engine.stats()["rdm_hits"] == 1  # the stream reused the memo
    np.testing.assert_array_equal(
        np.asarray(events[-1].payload.rdm), np.asarray(batch.rdm)
    )


# ---------------------------------------------------------------------------
# Traffic record / replay
# ---------------------------------------------------------------------------


def test_traffic_record_replay_roundtrip(tmp_path, problem):
    x, y, yc, f = problem
    log = TrafficLog()
    client = Client(record=log)
    data = client.register(x, f, LAM)
    client.submit(Workload(kind="cv", dataset=data, y=y))
    client.submit(Workload(kind="cv", dataset=data, y=yc,
                           estimator="multiclass", num_classes=3))
    client.submit(Workload(kind="permutation", dataset=data, y=y, n_perm=12, seed=0))
    client.submit(Workload(kind="tune", x=x, y=y))  # no plan -> not recorded
    assert len(log) == 3
    path = tmp_path / "traffic.json"
    log.save(path)
    loaded = TrafficLog.load(path)
    assert loaded.entries() == log.entries()

    # replay on a fresh engine: the recorded traffic then serves with zero
    # compiles and zero plan builds
    engine = CVEngine()
    h = engine.register(x, f, LAM)
    loaded.replay(engine, h, pin=True)
    warm = engine.compile_count()
    plans = engine.stats()["plans_built"]
    serve(engine, [
        Workload(kind="cv", dataset=h, y=y),
        Workload(kind="cv", dataset=h, y=yc, estimator="multiclass", num_classes=3),
        Workload(kind="permutation", dataset=h, y=y, n_perm=12, seed=0),
    ])
    assert engine.compile_count() == warm
    assert engine.stats()["plans_built"] == plans
    assert engine.stats()["pinned"] == 1


def test_traffic_log_records_static_options(problem):
    """adjust_bias (a static jit option) and the confusion-contrast
    multiclass path must survive record -> replay."""
    x, y, yc, f = problem
    log = TrafficLog()
    client = Client(record=log)
    data = client.register(x, foldlib.stratified_kfold(yc, K, seed=0), LAM)
    client.submit(Workload(kind="cv", dataset=data, y=y, adjust_bias=False))
    client.submit(Workload(kind="rsa", dataset=data, y=yc, num_classes=3,
                           contrast="multiclass"))
    entries = log.entries()
    assert any(e["task"] == "binary" and e["adjust_bias"] is False for e in entries)
    assert any(e["task"] == "multiclass" for e in entries)  # confusion eval path
    engine = CVEngine()
    h = engine.register(x, foldlib.stratified_kfold(yc, K, seed=0), LAM)
    log.replay(engine, h)
    warm = engine.compile_count()
    serve(engine, [
        Workload(kind="cv", dataset=h, y=y, adjust_bias=False),
        Workload(kind="rsa", dataset=h, y=yc, num_classes=3, contrast="multiclass"),
    ])
    assert engine.compile_count() == warm


def test_traffic_log_records_stream_chunk_bucket(problem):
    x, y, _, f = problem
    log = TrafficLog()
    client = Client(record=log, stream_chunk=8)
    data = client.register(x, f, LAM)
    list(client.stream(Workload(kind="permutation", dataset=data, y=y, n_perm=20, seed=0)))
    buckets = sorted(e["bucket"] for e in log.entries())
    assert buckets == [8, 32]  # the chunk program AND the monolithic bucket


def test_permutation_labels_evaluated_counts_requested_draws(problem):
    x, y, _, f = problem
    engine = CVEngine()
    _, plan = engine.plan(x, f, LAM)
    before = engine.labels_evaluated
    engine.permutation_binary(plan, y, 20, jax.random.PRNGKey(0))
    assert engine.labels_evaluated - before == 20  # requested draws, not bucket 32


def test_traffic_log_rejects_unknown_schema():
    with pytest.raises(ValueError, match="schema"):
        TrafficLog.from_json('{"schema": 42, "entries": []}')


# ---------------------------------------------------------------------------
# Streaming: sync generator + mesh-aware chunks
# ---------------------------------------------------------------------------


def test_sync_stream_matches_monolithic(problem):
    x, y, _, f = problem
    engine = CVEngine()
    spec = DatasetSpec(x, f, LAM)
    w = Workload(kind="permutation", dataset=spec, y=y, n_perm=20, seed=4)
    events = list(Client(engine, stream_chunk=8).stream(w))
    kinds = [ev.kind for ev in events]
    assert kinds[:2] == ["plan", "observed"] and kinds[-1] == "done"
    streamed = jnp.concatenate([ev.payload for ev in events if ev.kind == "null"])
    final = events[-1].payload
    np.testing.assert_array_equal(np.asarray(streamed), np.asarray(final.null))
    ref = CVEngine()
    _, plan = ref.plan(x, f, LAM)
    mono = ref.permutation_binary(plan, y, 20, jax.random.PRNGKey(4))
    np.testing.assert_allclose(np.asarray(final.null), np.asarray(mono.null),
                               rtol=1e-9, atol=1e-12)


def test_mesh_engine_streams_sharded_null_chunks(problem, monkeypatch):
    """ROADMAP gap: streamed permutation chunks must route through
    sharded_null_from_plan on a mesh-configured engine, with draws
    identical to the monolithic (and local) paths."""
    from repro.core import distributed

    calls = {"n": 0}
    real = distributed.sharded_null_from_plan

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(distributed, "sharded_null_from_plan", counting)

    x, y, _, f = problem
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    engine = CVEngine(EngineConfig(gram_impl="distributed", mesh=mesh))
    spec = DatasetSpec(x, f, LAM)
    w = Workload(kind="permutation", dataset=spec, y=y, n_perm=20, seed=4)
    events = list(stream_workload(engine, w, chunk=8))
    assert calls["n"] >= 3  # one sharded eval per chunk
    final = events[-1].payload
    streamed = jnp.concatenate([ev.payload for ev in events if ev.kind == "null"])
    np.testing.assert_array_equal(np.asarray(streamed), np.asarray(final.null))
    # identical draws to the mesh engine's monolithic path...
    _, plan = engine.resolve(spec)
    mono = engine.permutation_binary(plan, y, 20, jax.random.PRNGKey(4))
    np.testing.assert_allclose(np.asarray(final.null), np.asarray(mono.null), atol=1e-12)
    # ...and to a plain local engine
    local = CVEngine()
    _, lplan = local.plan(x, f, LAM)
    lmono = local.permutation_binary(lplan, y, 20, jax.random.PRNGKey(4))
    np.testing.assert_allclose(np.asarray(final.null), np.asarray(lmono.null), atol=1e-12)


def test_async_stream_equals_sync_stream(problem):
    x, y, _, f = problem
    spec = DatasetSpec(x, f, LAM)
    w = Workload(kind="permutation", dataset=spec, y=y, n_perm=16, seed=9)
    sync_events = list(stream_workload(CVEngine(), w, chunk=8))

    async def drive():
        async with Client(CVEngine(), transport="async", stream_chunk=8) as client:
            return [ev async for ev in client.stream(w)]

    async_events = asyncio.run(drive())
    assert [e.kind for e in async_events] == [e.kind for e in sync_events]
    np.testing.assert_array_equal(
        np.asarray(async_events[-1].payload.null),
        np.asarray(sync_events[-1].payload.null),
    )


# ---------------------------------------------------------------------------
# Client ergonomics
# ---------------------------------------------------------------------------


def test_client_transport_validation(problem):
    with pytest.raises(ValueError, match="transport"):
        Client(transport="carrier-pigeon")
    c = Client(transport="async")
    with pytest.raises(RuntimeError, match="async with"):
        with c:
            pass
    with pytest.raises(RuntimeError, match="must be entered"):
        c.submit(Workload(kind="tune", x=jnp.ones((4, 2)), y=jnp.ones(4)))


def test_client_gather_coalesces_sync(problem):
    x, y, _, f = problem
    engine = CVEngine()
    client = Client(engine)
    data = client.register(x, f, LAM)
    batch = [Workload(kind="cv", dataset=data, y=jnp.roll(y, i)) for i in range(4)]
    responses = client.gather(batch)
    assert len(responses) == 4
    assert engine.stats()["plans_built"] == 1
    ref = CVEngine()
    _, plan = ref.plan(x, f, LAM)
    for i, resp in enumerate(responses):
        want = ref.eval_binary(plan, jnp.stack([jnp.roll(y, j) for j in range(4)], 1))
        np.testing.assert_allclose(np.asarray(resp.values), np.asarray(want[..., i]),
                                   rtol=1e-9, atol=1e-12)
