"""reprolint (repro.analysis): golden fixtures, suppressions, CLI gating.

The fixtures under tests/fixtures/reprolint/ are the checker's own test
suite in both directions: seeded violations must be reported with the
right rule id and line, clean/suppressed files must pass, and the
shipped tree must be clean end to end (the same assertions the
``reprolint`` CI job makes via the CLI).
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    BAD_SUPPRESSION,
    all_rules,
    load_metrics,
    load_stages,
    run,
)
from repro.analysis.core import check_file

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "reprolint"


def sites(findings):
    return sorted({(f.rule, f.line) for f in findings})


def check(name):
    return check_file(FIXTURES / name, all_rules())


# ---------------------------------------------------------------------------
# Golden fixtures: each seeded violation reported with the right id/line
# ---------------------------------------------------------------------------


def test_rl001_host_jnp_and_wall_clock():
    assert sites(check("rl001_host_jnp.py")) == [
        ("RL001", 13),  # jnp.concatenate
        ("RL001", 14),  # jnp.pad
        ("RL001", 20),  # time.time()
    ]


def test_rl002_stage_vocabulary():
    assert sites(check("rl002_stage_vocab.py")) == [
        ("RL002", 5),  # span("warp_speed")
        ("RL002", 7),  # add("decoed", ...)
        ("RL002", 8),  # observe(..., stage="telemetry")
    ]


def test_rl003_metrics_discipline():
    assert sites(check("rl003_metrics.py")) == [
        ("RL003", 5),  # undeclared metric name
        ("RL003", 6),  # missing label key
        ("RL003", 7),  # f-string label value (cardinality bomb)
        ("RL003", 10),  # .observe() on a counter (+ label-set drift)
        ("RL003", 11),  # registration label drift
    ]


def test_rl004_lock_discipline():
    assert sites(check("rl004_locks.py")) == [
        ("RL004", 16),  # attr assigned without lock
        ("RL004", 17),  # dict item assigned without lock
        ("RL004", 18),  # .pop() without lock
        ("RL004", 24),  # .clear() after the with-block closed
    ]


def test_rl005_host_float64():
    assert sites(check("rl005_dtype.py")) == [
        ("RL005", 10),  # dtype=np.float32
        ("RL005", 11),  # .astype("float16")
    ]


# ---------------------------------------------------------------------------
# Suppression semantics
# ---------------------------------------------------------------------------


def test_suppression_with_reason_is_honored():
    assert check("suppressed_with_reason.py") == []


def test_suppression_without_reason_is_an_error_and_suppresses_nothing():
    got = sites(check("suppressed_no_reason.py"))
    assert (BAD_SUPPRESSION, 9) in got  # the bare ignore is itself reported
    assert ("RL001", 9) in got  # ... and the violation still surfaces


def test_clean_file_has_no_findings():
    assert check("clean.py") == []


def test_suppression_is_rule_scoped(tmp_path):
    # A justification for RL001 must not silence an unrelated rule.
    f = tmp_path / "mod.py"
    f.write_text(
        "# reprolint: host-path\n"
        "import time\n"
        "import jax.numpy as jnp\n"
        "# reprolint: monotonic-time\n"
        "def g(parts):\n"
        "    t = time.time()  # reprolint: ignore[RL005] -- wrong rule id\n"
        "    return jnp.concatenate(parts), t\n"
    )
    got = sites(check_file(f, all_rules()))
    assert ("RL001", 6) in got  # time.time() still reported
    assert ("RL001", 7) in got


# ---------------------------------------------------------------------------
# Vocabulary extraction matches the importable constants
# ---------------------------------------------------------------------------


def test_load_stages_matches_trace_module():
    from repro.serve.trace import STAGES

    assert load_stages() == tuple(STAGES)


def test_load_metrics_matches_obs_module():
    from repro.serve.obs import METRICS

    assert load_metrics() == METRICS
    for name, spec in load_metrics().items():
        assert spec["kind"] in {"counter", "gauge", "histogram"}, name
        assert isinstance(spec["labels"], tuple), name


def test_metrics_table_is_registered_one_to_one():
    # Every declared metric exists on a fresh engine's registry with the
    # declared kind — the engine supplies behavior, never vocabulary.
    from repro.serve.engine import CVEngine
    from repro.serve.obs import METRICS

    engine = CVEngine()
    for name, spec in METRICS.items():
        assert name in engine.metrics, name
        assert engine.metrics.get(name).kind == spec["kind"], name


# ---------------------------------------------------------------------------
# Tree-wide: the shipped tree is clean (same gate as the reprolint CI job)
# ---------------------------------------------------------------------------


def test_shipped_tree_is_clean():
    findings = run([str(REPO / "src"), str(REPO / "benchmarks")])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_every_in_tree_suppression_has_a_reason():
    from repro.analysis.core import iter_py_files, parse_file

    for path in iter_py_files([str(REPO / "src"), str(REPO / "benchmarks")]):
        ctx = parse_file(path)
        assert ctx.bare_suppression_lines == [], path


# ---------------------------------------------------------------------------
# CLI: exit codes + JSON output (what the CI job drives)
# ---------------------------------------------------------------------------


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True,
        text=True,
        env=env,
        cwd=REPO,
    )


def test_cli_exits_zero_on_clean_and_nonzero_on_seeded():
    assert _cli(str(FIXTURES / "clean.py")).returncode == 0
    for seeded in sorted(FIXTURES.glob("rl00*.py")):
        proc = _cli(str(seeded))
        assert proc.returncode == 1, seeded.name
        assert seeded.name.split("_")[0].upper() in proc.stdout


def test_cli_json_output():
    proc = _cli("--json", str(FIXTURES / "rl005_dtype.py"))
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert payload["count"] == 2
    assert {f["rule"] for f in payload["findings"]} == {"RL005"}
    assert all(f["path"].endswith("rl005_dtype.py") for f in payload["findings"])


def test_cli_rule_filter():
    proc = _cli("--rules", "RL005", str(FIXTURES / "rl001_host_jnp.py"))
    assert proc.returncode == 0  # RL001 findings filtered out
    bad = _cli("--rules", "RL999", str(FIXTURES / "clean.py"))
    assert bad.returncode == 2  # argparse error for unknown rule


@pytest.mark.parametrize("rule_id", ["RL001", "RL002", "RL003", "RL004", "RL005"])
def test_rule_table_lists_every_rule(rule_id):
    assert rule_id in {r.id for r in all_rules()}
