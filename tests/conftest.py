"""Shared test config.

x64 is enabled because the paper's statistical workloads (exactness of the
analytical CV identities) are validated to near machine precision.
Rank promotion is set to "raise" as a sanitizer: an implicit
(n,) → (n, 1) broadcast in the solver lineage is almost always a shape
bug that silently evaluates the wrong contraction, so the suite fails
loudly instead. Note: we do NOT touch XLA_FLAGS/device counts here —
smoke tests must see the single real CPU device; multi-device shard_map
tests spawn subprocesses with their own XLA_FLAGS (see
tests/test_distributed.py).
"""

import jax

jax.config.update("jax_enable_x64", True)
jax.config.update("jax_numpy_rank_promotion", "raise")
