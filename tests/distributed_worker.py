"""Multi-device worker script run by tests/test_distributed.py in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.

Each check prints 'PASS <name>' on success; the pytest wrapper asserts on
the output. Separated from the test module so the 8-device XLA flag never
leaks into the main test process (smoke tests must see 1 device).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

jax.config.update("jax_enable_x64", True)


def check(name, cond):
    print(("PASS " if cond else "FAIL ") + name)
    if not cond:
        sys.exit(1)


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    from repro.core import distributed as D
    from repro.core import fastcv, folds as foldlib, permutation
    from repro.data import synthetic

    # ---- feature-sharded Gram == local Gram ------------------------------
    x, yc = synthetic.make_classification(jax.random.PRNGKey(0), 48, 64)
    y = jnp.where(yc == 0, -1.0, 1.0)
    xs = jax.device_put(x, NamedSharding(mesh, P(None, "model")))
    g_dist = D.distributed_gram(xs, mesh)
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    check("distributed_gram",
          np.allclose(np.asarray(g_dist), np.asarray(xc @ xc.T), atol=1e-8))

    # ---- distributed hat matrix == single-device hat matrix --------------
    h_dist = D.distributed_hat_matrix(xs, 1.0, mesh)
    h_ref = fastcv.hat_matrix_dual(x, 1.0)
    check("distributed_hat",
          np.allclose(np.asarray(h_dist), np.asarray(h_ref), atol=1e-8))

    # ---- permutation-sharded null == single-device null ------------------
    f = foldlib.kfold(48, 4, seed=1)
    key = jax.random.PRNGKey(7)
    res_d = D.distributed_permutation_binary(
        xs, y, f, 1.0, n_perm=16, key=key, mesh=mesh)
    res_s = permutation.analytical_permutation_binary(
        x, y, f, 1.0, n_perm=16, key=key, chunk=16)
    check("distributed_permutation_null",
          np.allclose(np.asarray(res_d.null), np.asarray(res_s.null),
                      atol=1e-10))
    check("distributed_permutation_obs",
          abs(float(res_d.observed) - float(res_s.observed)) < 1e-10)

    # ---- searchlight sharding ---------------------------------------------
    keys = jax.random.split(jax.random.PRNGKey(3), 8)
    xs_many = jnp.stack([
        synthetic.make_classification(k, 48, 32, class_sep=3.0)[0]
        for k in keys])
    xs_many = jax.device_put(xs_many, NamedSharding(mesh, P(("data",))))
    acc = D.searchlight_cv(xs_many, y, f, 1.0, mesh,
                           problem_axes=("data",))
    check("searchlight_shape", acc.shape == (8,))
    check("searchlight_finite", bool(np.isfinite(np.asarray(acc)).all()))

    # ---- sharded train step runs and matches unsharded loss ---------------
    from repro.configs.base import get_config
    from repro.launch import sharding as sh
    from repro.optim import optimizer as O
    from repro.train import steps
    from repro.models import model as M

    cfg = get_config("gemma2-2b", smoke=True)
    opt_cfg = O.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=5)
    params, opt_state = steps.init_train_state(jax.random.PRNGKey(0), cfg,
                                               opt_cfg)
    kt = jax.random.PRNGKey(5)
    batch = {"tokens": jax.random.randint(kt, (4, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(kt, (4, 16), 0, cfg.vocab_size)}

    loss_ref = float(M.loss_fn(params, batch, cfg)[0])

    p_sh = sh.param_sharding_tree(params, mesh)
    params_s = jax.device_put(params, p_sh)
    opt_s = jax.device_put(opt_state, jax.tree.map(
        lambda _: NamedSharding(mesh, P()), opt_state))
    batch_s = jax.device_put(batch, NamedSharding(mesh, P("data", None)))

    with sh.axis_ctx(mesh):
        step_fn = jax.jit(steps.make_train_step(cfg, opt_cfg))
        new_p, new_o, metrics = step_fn(params_s, opt_s, batch_s)
    loss_sharded = float(metrics["loss"])
    check("sharded_train_loss_matches",
          abs(loss_sharded - loss_ref) < 1e-3 * max(1.0, abs(loss_ref)))
    check("sharded_train_finite", np.isfinite(loss_sharded))

    # ---- elastic checkpoint: save on (2,4), restore on (4,2) --------------
    from repro.train import checkpoint as ckpt
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        ckpt.save(td, 1, {"params": new_p})
        mesh2 = jax.make_mesh((4, 2), ("data", "model"))
        p_sh2 = sh.param_sharding_tree(params, mesh2)
        restored, _ = ckpt.restore(td, 1, {"params": params},
                                   {"params": p_sh2})
        same = jax.tree.all(jax.tree.map(
            lambda a, b: jnp.allclose(a.astype(jnp.float32),
                                      b.astype(jnp.float32), atol=1e-6),
            restored["params"], new_p))
        check("elastic_restore_values", bool(same))
        one = jax.tree.leaves(restored["params"])[0]
        check("elastic_restore_mesh",
              one.sharding.mesh.shape["data"] == 4)

    print("ALL_OK")


if __name__ == "__main__":
    main()
