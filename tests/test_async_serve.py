"""Tests for repro.serve.aio: concurrent ragged clients against the async
server, streamed permutation/RSA responses, warm-up's zero-recompile
guarantee, and plan pinning under cache pressure.

Like tests/test_serve.py, this suite exercises the *deprecated request
shims* on purpose — the async server must keep accepting them unchanged
while tests/test_workload.py pins their parity with the Workload path
(including async stream == sync stream event-for-event)."""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rsa
from repro.core import fastcv, folds as foldlib
from repro.data import synthetic
from repro.serve import (
    AsyncEngineServer,
    CVEngine,
    DatasetSpec,
    EngineConfig,
    ProgressEvent,
    Workload,
    serve,
)

N, P, K, LAM = 48, 96, 4, 1.0


@pytest.fixture(scope="module")
def problem():
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(0), N, P, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    f = foldlib.kfold(N, K, seed=1)
    return x, y, yc, f


def _spec(problem):
    x, _, _, f = problem
    return DatasetSpec(x, f, LAM)


def _mixed_requests(problem, n_perm=12):
    x, y, yc, f = problem
    spec = DatasetSpec(x, f, LAM)
    return [
        Workload(kind="cv", dataset=spec, y=y, estimator="binary"),
        Workload(kind="cv", dataset=spec, y=-y, estimator="binary"),
        Workload(kind="cv", dataset=spec, y=jnp.stack([y, -y, jnp.roll(y, 3)], axis=1),
                 estimator="binary"),
        Workload(kind="cv", dataset=spec, y=y, estimator="ridge"),
        Workload(kind="cv", dataset=spec, y=yc, estimator="multiclass", num_classes=3),
        Workload(kind="permutation", dataset=spec, y=y, n_perm=n_perm, seed=4),
        Workload(kind="tune", x=x, y=y),
    ]


# ---------------------------------------------------------------------------
# Concurrent submission: correctness per request, one plan, shared batches
# ---------------------------------------------------------------------------


def test_async_server_matches_sync(problem):
    requests = _mixed_requests(problem)
    sync = serve(CVEngine(), requests)
    engine = CVEngine()

    async def main():
        async with AsyncEngineServer(engine, gather_window_ms=5.0) as server:
            return await asyncio.gather(*(server.submit(r) for r in requests))

    results = asyncio.run(main())
    for got, want in zip(results, sync):
        assert type(got) is type(want)
        if hasattr(want, "values"):
            np.testing.assert_allclose(
                np.asarray(got.values), np.asarray(want.values), rtol=1e-9, atol=1e-12
            )
        elif hasattr(want, "null"):
            np.testing.assert_allclose(
                np.asarray(got.null), np.asarray(want.null), rtol=1e-9, atol=1e-12
            )
    # every request shares the one dataset -> one plan build total
    assert engine.stats()["plans_built"] == 1


def test_async_ragged_concurrent_clients(problem):
    """8 clients with ragged mixed-task streams: per-request results must
    match the direct library answers, through shared coalesced batches."""
    x, y, yc, f = problem
    spec = DatasetSpec(x, f, LAM)
    engine = CVEngine()
    dv_direct, _ = fastcv.binary_cv(x, y, f, lam=LAM)

    async def client(server, cid):
        width = 1 + cid % 3
        cols = jnp.stack([jnp.roll(y, cid + j) for j in range(width)], axis=1)
        resp_b = await server.submit(
            Workload(kind="cv", dataset=spec, y=cols, estimator="binary")
        )
        resp_m = await server.submit(
            Workload(kind="cv", dataset=spec, y=yc, estimator="multiclass", num_classes=3)
        )
        return cid, cols, resp_b, resp_m

    async def main():
        async with AsyncEngineServer(engine, gather_window_ms=5.0) as server:
            out = await asyncio.gather(*(client(server, cid) for cid in range(8)))
            return out, server.requests_served, server.batches_served

    out, served, batches = asyncio.run(main())
    assert served == 16
    assert batches < served  # concurrency actually coalesced
    e_ref = CVEngine()
    _, plan = e_ref.plan(x, f, LAM)
    pred_ref = e_ref.eval_multiclass(plan, yc, 3)
    for cid, cols, resp_b, resp_m in out:
        assert resp_b.values.shape[-1] == cols.shape[1]
        want = e_ref.eval_binary(plan, cols)
        np.testing.assert_allclose(
            np.asarray(resp_b.values), np.asarray(want), rtol=1e-9, atol=1e-12
        )
        assert bool(jnp.all(resp_m.values == pred_ref))
    assert engine.stats()["plans_built"] == 1
    # client 0's first column is the unrolled y -> the direct library answer
    np.testing.assert_allclose(
        np.asarray(out[0][2].values[..., 0]), np.asarray(dv_direct), rtol=1e-9, atol=1e-12
    )


def test_async_server_propagates_errors(problem):
    engine = CVEngine()
    # Estimator names are validated eagerly at construction, so smuggle an
    # invalid one past __post_init__ to exercise serve-time propagation.
    bad = Workload(kind="cv", dataset=_spec(problem), y=problem[1])
    object.__setattr__(bad, "estimator", "nonsense")

    async def main():
        async with AsyncEngineServer(engine) as server:
            with pytest.raises(ValueError):
                await server.submit(bad)

    asyncio.run(main())


def test_async_server_rejects_after_stop(problem):
    engine = CVEngine()

    async def main():
        server = AsyncEngineServer(engine)
        await server.start()
        await server.stop()
        with pytest.raises(RuntimeError):
            await server.submit(Workload(kind="cv", dataset=_spec(problem), y=problem[1]))

    asyncio.run(main())


# ---------------------------------------------------------------------------
# Warm-up: compile_count stays flat under concurrent mixed traffic
# ---------------------------------------------------------------------------


def test_warmup_then_zero_recompiles_under_traffic(problem):
    x, y, yc, f = problem
    spec = DatasetSpec(x, f, LAM)
    engine = CVEngine()
    info = engine.warmup(
        spec,
        tasks=("binary", "ridge", "multiclass", "permutation"),
        buckets=(1, 2, 4, 8, 16),
        num_classes=3,
    )
    assert info["buckets"] == (1, 2, 4, 8, 16)
    warm = engine.compile_count()
    assert warm == info["compiles"]

    async def client(server, cid):
        await server.submit(
            Workload(kind="cv", dataset=spec, y=jnp.roll(y, cid), estimator="binary")
        )
        await server.submit(
            Workload(kind="cv", dataset=spec, y=yc, estimator="multiclass", num_classes=3)
        )
        await server.submit(
            Workload(kind="cv", dataset=spec, y=jnp.roll(y, cid + 1), estimator="ridge")
        )
        await server.submit(Workload(kind="permutation", dataset=spec, y=y, n_perm=14, seed=cid))

    async def main():
        async with AsyncEngineServer(engine, gather_window_ms=3.0) as server:
            await asyncio.gather(*(client(server, cid) for cid in range(8)))

    asyncio.run(main())
    assert engine.compile_count() == warm  # zero recompiles after warm-up
    assert engine.stats()["plans_built"] == 1  # warm-up built the only plan


def test_warmup_validates_arguments(problem):
    engine = CVEngine()
    with pytest.raises(ValueError):
        engine.warmup(_spec(problem), tasks=("nonsense",))
    with pytest.raises(ValueError):
        engine.warmup(_spec(problem), tasks=("multiclass",), num_classes=0)


# ---------------------------------------------------------------------------
# Streaming: permutation null chunks and RSA events
# ---------------------------------------------------------------------------


def test_stream_permutation_chunks_match_monolithic(problem):
    x, y, _, f = problem
    spec = DatasetSpec(x, f, LAM)
    engine = CVEngine()

    async def main():
        events = []
        async with AsyncEngineServer(engine, stream_chunk=8) as server:
            w = Workload(kind="permutation", dataset=spec, y=y, n_perm=20, seed=4)
            async for ev in server.stream(w):
                events.append(ev)
        return events

    events = asyncio.run(main())
    kinds = [ev.kind for ev in events]
    assert kinds[:2] == ["plan", "observed"]
    assert kinds[-1] == "done"
    null_events = [ev for ev in events if ev.kind == "null"]
    assert [ev.done for ev in null_events] == [8, 16, 20]
    assert all(isinstance(ev, ProgressEvent) and ev.total == 20 for ev in events)
    streamed_null = jnp.concatenate([ev.payload for ev in null_events])
    final = events[-1].payload
    assert final.null.shape == (20,)
    np.testing.assert_array_equal(np.asarray(streamed_null), np.asarray(final.null))
    # identical draws as the monolithic path (prefix-stable permutations)
    ref = CVEngine()
    _, plan = ref.plan(x, f, LAM)
    mono = ref.permutation_binary(plan, y, 20, jax.random.PRNGKey(4))
    np.testing.assert_allclose(np.asarray(final.null), np.asarray(mono.null), rtol=1e-9, atol=1e-12)
    assert float(final.p) == pytest.approx(float(mono.p), abs=1e-12)


def test_stream_multiclass_permutation(problem):
    x, _, yc, f = problem
    spec = DatasetSpec(x, f, LAM)
    engine = CVEngine()
    req = Workload(kind="permutation", dataset=spec, y=yc, n_perm=10, seed=2,
                   estimator="multiclass", num_classes=3)

    async def main():
        async with AsyncEngineServer(engine, stream_chunk=4) as server:
            return [ev async for ev in server.stream(req)]

    events = asyncio.run(main())
    final = events[-1].payload
    ref = CVEngine()
    _, plan = ref.plan(x, f, LAM)
    mono = ref.permutation_multiclass(plan, yc, 10, jax.random.PRNGKey(2), num_classes=3)
    np.testing.assert_allclose(np.asarray(final.null), np.asarray(mono.null), rtol=1e-9, atol=1e-12)


def test_stream_rsa_events(problem):
    x, _, yc, f = problem
    c = 3
    spec = DatasetSpec(x, foldlib.stratified_kfold(yc, K, seed=0), LAM)
    models = jnp.stack([rsa.ring_rdm(c), rsa.ring_rdm(c) * 0.5 + 0.1])
    engine = CVEngine()
    req = Workload(kind="rsa", dataset=spec, y=yc, num_classes=c,
                   model_rdms=models, n_perm=10, seed=3)

    async def main():
        async with AsyncEngineServer(engine, stream_chunk=4) as server:
            return [ev async for ev in server.stream(req)]

    events = asyncio.run(main())
    kinds = [ev.kind for ev in events]
    assert kinds[0] == "plan" and kinds[1] == "rdm" and kinds[2] == "scores"
    assert kinds[-1] == "done"
    final = events[-1].payload
    (sync,) = serve(CVEngine(), [req])
    np.testing.assert_allclose(np.asarray(final.rdm), np.asarray(sync.rdm), rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(final.model_scores), np.asarray(sync.model_scores), rtol=1e-9, atol=1e-12
    )
    np.testing.assert_allclose(np.asarray(final.null), np.asarray(sync.null), rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(final.p), np.asarray(sync.p), rtol=1e-9, atol=1e-12)


def test_stream_non_streamable_degenerates_to_done(problem):
    x, y, _, f = problem
    engine = CVEngine()
    req = Workload(kind="cv", dataset=DatasetSpec(x, f, LAM), y=y, estimator="binary")

    async def main():
        async with AsyncEngineServer(engine) as server:
            return [ev async for ev in server.stream(req)]

    events = asyncio.run(main())
    assert [ev.kind for ev in events] == ["done"]
    dv, _ = fastcv.binary_cv(x, y, f, lam=LAM)
    np.testing.assert_allclose(
        np.asarray(events[0].payload.values), np.asarray(dv), rtol=1e-9, atol=1e-12
    )


# ---------------------------------------------------------------------------
# Pinning: pinned plans survive cache pressure end to end
# ---------------------------------------------------------------------------


def test_pinned_plan_survives_cache_pressure(problem):
    x, _, _, f = problem
    _, probe = CVEngine().plan(x, f, LAM)
    engine = CVEngine(EngineConfig(cache_bytes=2 * probe.nbytes + 1))
    spec = DatasetSpec(x, f, LAM)
    info = engine.warmup(spec, tasks=("binary",), buckets=(1,), pin=True)
    assert info["pinned"]
    pinned_key = info["plan_key"]
    for lam in (0.5, 2.0, 4.0, 8.0):  # pressure: 4 more plans through a 2-plan budget
        engine.plan(x, f, lam)
    assert pinned_key in engine.cache  # pinned plan never evicted
    stats = engine.stats()
    assert stats["pinned"] == 1
    assert stats["pinned_bytes"] == probe.nbytes
    assert stats["evictions"] >= 2
    # pinned bytes are excluded from pressure: unpinned usage fits the budget
    assert stats["bytes_in_use"] - stats["pinned_bytes"] <= stats["byte_budget"]
    # unpinning re-subjects the plan to LRU pressure
    assert engine.unpin(pinned_key)
    assert engine.stats()["pinned"] == 0
