"""Mutable versioned datasets: incremental plan updates vs full rebuilds.

The tentpole contract of the ``kind="update"`` redesign, from the math
up through the serving stack:

  * ``fastcv.update_plan`` / ``downdate_plan`` / ``sliding_window``
    reproduce the from-scratch ``prepare`` plan (rank-k Woodbury with
    centering corrections, host float64) — checked against rebuilds;
  * an engine handle advanced through ``append``/``retire``/window ops
    serves *predictions* matching a fresh engine registered with the
    final rows, for every registered estimator and both fold shapes
    (k-fold and LOO), within 1e-5;
  * versions are real: old handles stay servable until released,
    in-flight pins defer the purge, and releasing a stale version
    removes its store entry cleanly (never via quarantine);
  * repeated window advances are compile-flat once warm (updates run in
    host numpy — no new XLA programs);
  * schema v1 dicts and v1 traffic logs still load (upgrade hook).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastcv, folds as foldlib
from repro.serve import (Client, CVEngine, DatasetHandle, DatasetSpec,
                         EngineConfig, Workload)
from repro.serve.workload import (WORKLOAD_SCHEMA_VERSION, TrafficLog,
                                  UpdateResponse, _upgrade_v1_to_v2)

N, P, K, LAM = 32, 80, 4, 1.0

ESTIMATORS = ("binary", "ridge", "multiclass", "ridge_multi")


@pytest.fixture(scope="module")
def x_full():
    """More rows than any starting dataset so appends draw fresh ones."""
    return jax.random.normal(jax.random.PRNGKey(7), (N + 3 * K, P),
                             dtype=jnp.float64)


def _make_folds(shape: str):
    return foldlib.kfold(N, K, seed=1) if shape == "kfold" else foldlib.loo(N)


def _workloads(handle, n: int):
    """One workload per registered estimator family, sized for n rows."""
    y_bin = jnp.asarray(np.where(np.arange(n) % 2 == 0, -1.0, 1.0))
    y_int = np.asarray(np.arange(n) % 3, dtype=np.int32)
    y_multi = jnp.stack([y_bin, 2.0 * y_bin], axis=1)
    return {
        "binary": Workload(kind="cv", dataset=handle, y=y_bin),
        "ridge": Workload(kind="cv", dataset=handle, y=y_bin, estimator="ridge"),
        "multiclass": Workload(kind="cv", dataset=handle, y=y_int,
                               estimator="multiclass", num_classes=3),
        "ridge_multi": Workload(kind="cv", dataset=handle, y=y_multi,
                                estimator="ridge_multi"),
    }


# ---------------------------------------------------------------------------
# plan-level parity: incremental corrections == from-scratch prepare
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", ["kfold", "loo"])
def test_incremental_plans_match_rebuild(x_full, shape):
    x0 = np.asarray(x_full[:N], dtype=np.float64)
    folds = _make_folds(shape)
    plan = fastcv.prepare(jnp.asarray(x0), folds, LAM, mode="dual",
                          with_train_block=True)

    if shape == "kfold":
        # append one row per fold, then slide the window
        xa = np.asarray(x_full[N:N + K], dtype=np.float64)
        plan1 = fastcv.update_plan(plan, xa, np.arange(K) % K, x=x0, lam=LAM)
        x1 = np.concatenate([x0, xa])
        drop = np.asarray(jax.device_get(plan1.te_idx))[:, 0].astype(np.int64)
        xb = np.asarray(x_full[N + K:N + 2 * K], dtype=np.float64)
        plan2 = fastcv.sliding_window(plan1, xb, drop, x=x1, lam=LAM)
        x2 = np.concatenate([x1[np.setdiff1d(np.arange(len(x1)), drop)], xb])
    else:
        # LOO folds are width-1: only window moves preserve the shape
        drop = np.array([0, 5], dtype=np.int64)
        xb = np.asarray(x_full[N:N + 2], dtype=np.float64)
        plan2 = fastcv.sliding_window(plan, xb, drop, x=x0, lam=LAM)
        x2 = np.concatenate([x0[np.setdiff1d(np.arange(N), drop)], xb])

    rebuilt = fastcv.prepare(
        jnp.asarray(x2),
        foldlib.Folds.with_indices(plan2.te_idx, plan2.tr_idx),
        LAM, mode="dual", with_train_block=True)
    np.testing.assert_allclose(np.asarray(plan2.h), np.asarray(rebuilt.h),
                               rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(plan2.h_tr_te),
                               np.asarray(rebuilt.h_tr_te),
                               rtol=1e-8, atol=1e-8)


def test_update_plan_requires_folds_delta(x_full):
    x0 = np.asarray(x_full[:N], dtype=np.float64)
    plan = fastcv.prepare(jnp.asarray(x0), _make_folds("kfold"), LAM,
                          mode="dual", with_train_block=True)
    with pytest.raises(ValueError, match="folds_delta"):
        fastcv.update_plan(plan, np.asarray(x_full[N:N + K]), None,
                           x=x0, lam=LAM)


# ---------------------------------------------------------------------------
# served parity: the ISSUE acceptance bar — every estimator, both fold
# shapes, updated-handle predictions vs a fresh from-scratch engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", ["kfold", "loo"])
def test_updated_handle_predictions_match_fresh_rebuild(x_full, shape):
    eng = CVEngine(EngineConfig(cache_bytes=64 << 20))
    h0 = eng.register(x_full[:N], _make_folds(shape), LAM)

    if shape == "kfold":
        h1 = eng.append(h0, x_full[N:N + K])  # round-robin over folds
        drop = np.asarray(
            jax.device_get(eng.dataset_record(h1).folds.te_idx))[:, 0]
        h2 = eng.update_dataset(h1, x_new=x_full[N + K:N + 2 * K],
                                drop_idx=drop)
    else:
        h1 = eng.update_dataset(h0, x_new=x_full[N:N + 2],
                                drop_idx=np.array([0, 5]))
        h2 = eng.update_dataset(h1, x_new=x_full[N + 2:N + 4],
                                drop_idx=np.array([3, 9]))

    assert (h2.version, eng.dataset_record(h2).version) == (2, 2)
    rec = eng.dataset_record(h2)
    n = int(rec.x.shape[0])

    fresh = CVEngine(EngineConfig(cache_bytes=64 << 20))
    fh = fresh.register(rec.x, rec.folds, LAM)

    updated, scratch = Client(eng), Client(fresh)
    for name in ESTIMATORS:
        got = updated.submit(_workloads(h2, n)[name])
        want = scratch.submit(_workloads(fh, n)[name])
        np.testing.assert_allclose(np.asarray(got.values),
                                   np.asarray(want.values),
                                   rtol=1e-5, atol=1e-5, err_msg=name)
        np.testing.assert_allclose(np.asarray(got.score),
                                   np.asarray(want.score),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


# ---------------------------------------------------------------------------
# kind="update" workloads end to end
# ---------------------------------------------------------------------------


def test_update_workload_advances_version_and_counts(x_full):
    eng = CVEngine(EngineConfig(cache_bytes=64 << 20))
    client = Client(eng)
    h0 = eng.register(x_full[:N], _make_folds("kfold"), LAM)

    resp = client.submit(Workload(kind="update", dataset=h0,
                                  x=x_full[N:N + K]))
    assert isinstance(resp, UpdateResponse)
    assert resp.version == 1 and resp.appended == K and resp.dropped == 0
    assert resp.handle.version == 1 and resp.handle.n == N + K
    assert resp.handle.n_appended == K
    assert eng.stats()["plans_updated"] == 1

    # the advanced handle serves; the base version stays servable too
    n1 = resp.handle.n
    got = client.submit(_workloads(resp.handle, n1)["binary"])
    assert np.asarray(got.values).shape[-1] > 0
    base = client.submit(_workloads(h0, N)["binary"])
    assert np.asarray(base.values).shape[-1] > 0

    text = eng.metrics.render_prometheus()
    assert 'plan_updates_total{op="append"} 1' in text
    assert "plan_update_rank" in text


def test_update_workload_rejects_bad_shapes_eagerly(x_full):
    eng = CVEngine(EngineConfig(cache_bytes=64 << 20))
    h0 = eng.register(x_full[:N], _make_folds("kfold"), LAM)
    with pytest.raises(ValueError, match="DatasetHandle"):
        Workload(kind="update",
                 dataset=DatasetSpec(x_full[:N], _make_folds("kfold"), LAM),
                 x=x_full[N:N + K])
    with pytest.raises(ValueError, match="rows to append"):
        Workload(kind="update", dataset=h0)
    with pytest.raises(ValueError, match="features"):
        Workload(kind="update", dataset=h0,
                 x=np.zeros((K, P + 1)))
    with pytest.raises(ValueError, match="duplicate"):
        Workload(kind="update", dataset=h0,
                 drop_idx=np.array([1, 1]))


def test_compile_events_flat_across_repeated_window_updates(x_full):
    eng = CVEngine(EngineConfig(cache_bytes=64 << 20))
    client = Client(eng)
    handle = eng.register(x_full[:N], _make_folds("kfold"), LAM)
    rng = np.random.default_rng(0)

    def advance(h):
        drop = np.asarray(
            jax.device_get(eng.dataset_record(h).folds.te_idx))[:, 0]
        x_new = jnp.asarray(rng.normal(size=(K, P)))
        h2 = client.submit(Workload(kind="update", dataset=h,
                                    x=x_new, drop_idx=drop)).handle
        client.submit(_workloads(h2, h2.n)["binary"])
        return h2

    handle = advance(handle)  # absorb the first-shape compiles
    warm = eng.compile_count()
    for _ in range(3):
        handle = advance(handle)
    assert eng.compile_count() == warm
    assert handle.version == 4


# ---------------------------------------------------------------------------
# version pinning, release, and the clean (no-quarantine) store removal
# ---------------------------------------------------------------------------


def test_release_defers_while_pinned(x_full):
    eng = CVEngine(EngineConfig(cache_bytes=64 << 20))
    h0 = eng.register(x_full[:N], _make_folds("kfold"), LAM)
    h1 = eng.append(h0, x_full[N:N + K])
    assert len(eng.datasets()) == 2

    eng.retain_version(h0.key)
    assert eng.release(h0) is False  # deferred: a workload pins v0
    assert h0.key in {d["handle"].key for d in eng.datasets()}
    eng.release_version(h0.key)  # last pin drops -> purge runs
    assert h0.key not in {d["handle"].key for d in eng.datasets()}
    assert len(eng.datasets()) == 1

    # releasing an unknown handle is a tolerant no-op
    assert eng.release(h0) is False
    # the surviving version still serves
    Client(eng).submit(_workloads(h1, h1.n)["ridge"])


def test_release_drop_store_removes_cleanly(tmp_path, x_full):
    eng = CVEngine(EngineConfig(cache_bytes=64 << 20,
                                plan_store=str(tmp_path), save_plans=True))
    h0 = eng.register(x_full[:N], _make_folds("kfold"), LAM)
    Client(eng).submit(_workloads(h0, N)["binary"])  # build + write-behind
    h1 = eng.append(h0, x_full[N:N + K])
    eng.flush_store()
    assert eng.store.load(h0.key) is not None
    assert eng.store.load(h1.key) is not None

    assert eng.release(h0, drop_store=True) is True
    assert eng.store.load(h0.key) is None  # entry gone...
    assert eng.store.stats.quarantined == 0  # ...but never quarantined
    assert (tmp_path / "quarantine").exists() is False
    assert eng.store.load(h1.key) is not None  # successor untouched


# ---------------------------------------------------------------------------
# schema v1 compatibility: the explicit upgrade hook + old traffic logs
# ---------------------------------------------------------------------------


def test_from_dict_upgrades_schema_v1(x_full):
    w = Workload(kind="cv",
                 dataset=DatasetHandle(key=("a", "b", "c", 1.0, "dual", 0, True),
                                       n=N, p=P, lam=1.0, mode="dual"),
                 y=np.where(np.arange(N) % 2 == 0, -1.0, 1.0))
    d = w.to_dict()
    assert d["schema"] == WORKLOAD_SCHEMA_VERSION == 2
    d["schema"] = 1
    d.pop("drop_idx", None)  # the v2-only field
    up = _upgrade_v1_to_v2(dict(d))
    assert up["schema"] == 2 and up["drop_idx"] is None
    back = Workload.from_dict(dict(d))  # from_dict applies the hook itself
    assert back.kind == "cv" and back.drop_idx is None
    assert back.to_dict()["schema"] == 2


def test_traffic_log_schema_v1_still_replays(tmp_path, x_full):
    """Old recorded logs (schema 1) must keep warming new builds — the
    ``serve_cv --warmup-from`` contract across the version bump."""
    eng = CVEngine(EngineConfig(cache_bytes=64 << 20))
    handle = eng.register(x_full[:N], _make_folds("kfold"), LAM)

    log = TrafficLog()
    log.record(_workloads(handle, N)["binary"], buckets=(1, 8))
    text = log.to_json().replace(
        f'"schema": {WORKLOAD_SCHEMA_VERSION}', '"schema": 1', 1)
    path = tmp_path / "traffic_v1.json"
    path.write_text(text)

    replayed = TrafficLog.load(path)
    assert len(replayed) == len(log)
    summaries = replayed.replay(eng, handle)
    assert summaries and all(s for s in summaries)
    with pytest.raises(ValueError, match="unsupported traffic-log schema"):
        TrafficLog.from_json(text.replace('"schema": 1', '"schema": 99', 1))
