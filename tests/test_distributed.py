"""Multi-device shard_map / pjit tests.

Run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the 8 fake devices never leak into this process (smoke tests and
benchmarks must see the single real CPU device).
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

_WORKER = Path(__file__).parent / "distributed_worker.py"
_SRC = str(Path(__file__).parent.parent / "src")


@pytest.mark.timeout(900)
def test_distributed_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)   # the worker sets its own
    proc = subprocess.run(
        [sys.executable, str(_WORKER)], env=env, capture_output=True,
        text=True, timeout=850)
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-4000:]
    assert "ALL_OK" in proc.stdout, out[-4000:]
    for name in ("distributed_gram", "distributed_hat",
                 "distributed_permutation_null", "searchlight_shape",
                 "sharded_train_loss_matches", "elastic_restore_values",
                 "elastic_restore_mesh"):
        assert f"PASS {name}" in proc.stdout, f"missing PASS {name}\n" + out[-2000:]
