"""EEG/MEG-style permutation testing (paper §2.13 / Fig. 4 workflow).

Simulates a multi-subject 380-channel dataset, then runs per-subject
permutation tests with 10-fold CV — binary (faces vs scrambled) on
windowed features (P = 3800) and 3-class LDA (P = 1900) — using the
analytical engine (Algorithm 1 & 2).

Run:  PYTHONPATH=src python examples/eeg_permutation.py
"""

import time

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import folds, permutation
from repro.data import eeg

N_SUBJECTS = 3
N_TRIALS = 192
N_PERM = 100

for subj in range(N_SUBJECTS):
    key = jax.random.PRNGKey(subj)
    f = folds.kfold(N_TRIALS, 10, seed=subj)

    ds2 = eeg.simulate_subject(key, n_trials=N_TRIALS, num_classes=2)
    x2 = eeg.windowed_features(ds2, 100.0).astype(jnp.float64)   # P = 3800
    y2 = jnp.where(ds2.y == 0, -1.0, 1.0)
    t0 = time.time()
    res2 = permutation.analytical_permutation_binary(
        x2, y2, f, lam=1.0, n_perm=N_PERM, key=key, chunk=50)
    t2 = time.time() - t0

    ds3 = eeg.simulate_subject(jax.random.fold_in(key, 1),
                               n_trials=N_TRIALS, num_classes=3)
    x3 = eeg.windowed_features(ds3, 200.0).astype(jnp.float64)   # P = 1900
    t0 = time.time()
    res3 = permutation.analytical_permutation_multiclass(
        x3, ds3.y, f, num_classes=3, lam=1.0, n_perm=N_PERM, key=key,
        chunk=10)
    t3 = time.time() - t0

    print(f"subject {subj}:")
    print(f"  binary  P=3800: acc={float(res2.observed):.3f} "
          f"p={float(res2.p):.3f}  ({N_PERM} perms in {t2:.1f}s)")
    print(f"  3-class P=1900: acc={float(res3.observed):.3f} "
          f"p={float(res3.p):.3f}  ({N_PERM} perms in {t3:.1f}s)")
