"""Condition-rich RSA with classifier-based dissimilarities (paper §4.2).

Builds a Representational Dissimilarity Matrix over C conditions using
cross-validated LDA accuracy as the dissimilarity — C(C-1)/2 pairwise
cross-validations, each served by the shared analytical machinery (the
hat matrix is rebuilt per pair on the pair's samples; the fold solves are
the cheap part, exactly the regime the paper targets).

Run:  PYTHONPATH=src python examples/rsa_probe.py
"""

import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import fastcv, folds, metrics
from repro.data import synthetic

C = 8                 # conditions -> 28 pairwise CVs
N_PER_COND = 24
P = 1500              # high-dimensional patterns (P >> N)

key = jax.random.PRNGKey(0)
x_all, y_all = synthetic.make_classification(key, C * N_PER_COND, P,
                                             num_classes=C, class_sep=1.5)
x_all = np.asarray(x_all)
y_all = np.asarray(y_all)

rdm = np.zeros((C, C))
f = folds.kfold(2 * N_PER_COND, 6, seed=0)
t0 = time.time()
for a, b in itertools.combinations(range(C), 2):
    sel = np.concatenate([np.flatnonzero(y_all == a)[:N_PER_COND],
                          np.flatnonzero(y_all == b)[:N_PER_COND]])
    x = jnp.asarray(x_all[sel])
    y = jnp.asarray(np.where(y_all[sel] == a, -1.0, 1.0))
    dv, y_te = fastcv.binary_cv(x, y, f, lam=1.0)
    acc = float(metrics.binary_accuracy(dv, y_te))
    rdm[a, b] = rdm[b, a] = acc
elapsed = time.time() - t0

print(f"{C*(C-1)//2} pairwise cross-validations at P={P} in {elapsed:.1f}s")
print("RDM (CV-accuracy dissimilarity):")
with np.printoptions(precision=2, suppress=True):
    print(rdm)
print(f"mean off-diagonal decodability: "
      f"{rdm[np.triu_indices(C, 1)].mean():.3f}")
