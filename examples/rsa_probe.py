"""Condition-rich RSA served end-to-end by the analytical-CV engine.

The paper's §4.2 application: a Representational Dissimilarity Matrix over
C conditions. Where the old version of this example rebuilt a hat matrix
per condition pair (C(C−1)/2 separate cross-validations), `repro.rsa`
treats all pairwise contrasts as ONE label batch against ONE shared
CVPlan — the dataset registers once, every contrast evaluates at O(K·m²),
candidate model RDMs are scored with a condition-permutation null, and a
*repeat* of the same workload is served from the engine's empirical-RDM
memo (zero fold solves — watch `rdm_hits`).

Run:  PYTHONPATH=src python examples/rsa_probe.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro import rsa
from repro.core import folds
from repro.data import synthetic
from repro.serve import Client, Workload

C = 8                 # conditions -> 28 pairwise contrasts, one batch
N_PER_COND = 24
P = 1500              # high-dimensional patterns (P >> N)

key = jax.random.PRNGKey(0)
x, y_cond = synthetic.make_classification(key, C * N_PER_COND, P,
                                          num_classes=C, class_sep=1.5)

# candidate model RDMs: the condition-mean pattern geometry (via the Pallas
# pairdist kernel path), a circular "ring" structure, and a random control
mu = rsa.condition_means(x, y_cond, C)
ring = rsa.ring_rdm(C)
rng = np.random.default_rng(1)
rnd = np.abs(rng.normal(size=(C, C)))
rnd = rnd + rnd.T
np.fill_diagonal(rnd, 0.0)
models = jnp.stack([rsa.euclidean_rdm(mu), ring, jnp.asarray(rnd)])
model_names = ["pattern-euclidean", "ring", "random"]

client = Client()
data = client.register(x, folds.stratified_kfold(y_cond, 6, seed=0), lam=1.0)
workload = Workload(kind="rsa", dataset=data, y=y_cond, num_classes=C,
                    model_rdms=models, n_perm=500, seed=0)

t0 = time.time()
resp = client.submit(workload)
jax.block_until_ready(resp.rdm)
t_cold = time.time() - t0
t0 = time.time()
resp = client.submit(workload)
jax.block_until_ready(resp.rdm)
t_warm = time.time() - t0

print(f"{C * (C - 1) // 2} pairwise contrasts at P={P} in one batched "
      f"workload: cold {t_cold:.2f}s, warm {t_warm:.3f}s "
      f"({t_cold / t_warm:.0f}x)")
print("cross-validated RDM (pairwise decodability):")
with np.printoptions(precision=2, suppress=True):
    print(np.asarray(resp.rdm))
print(f"mean off-diagonal decodability: "
      f"{float(jnp.mean(rsa.upper_triangle(resp.rdm))):.3f}")
print("model-RDM comparison (Spearman, 500-permutation null):")
for name, s, p in zip(model_names, resp.model_scores, resp.p):
    print(f"  {name:18s} rho={float(s):+.3f}  p={float(p):.4f}")

stats = client.stats()
print(f"engine: {stats['plans_built']} plan build(s), "
      f"{stats['hits']} cache hit(s), {stats['compiles']} compiled programs, "
      f"{stats['rdm_hits']} RDM memo hit(s)")
