"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the xlstm-125m architecture at FULL assigned width (768 d_model,
12 layers) but CPU-sized batch/sequence, through the production Trainer
(checkpointing, straggler monitor, WSD-capable optimizer, restart-safe
data cursor). On the CPU container this takes a few minutes; the same
code path drives the 16x16 mesh on real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

from repro.configs.base import get_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.optim import optimizer as O
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=2048,
                    help="reduced vocab keeps the CPU step time sane; "
                    "model width/depth stay at the assigned 125M config")
    args = ap.parse_args()

    cfg = get_config("xlstm-125m")
    cfg = dataclasses.replace(cfg, vocab_size=args.vocab, dtype="float32",
                              param_dtype="float32")
    print(f"[train_lm] {cfg.name}: ~{cfg.param_count():,} params "
          f"(vocab reduced to {args.vocab} for CPU)")

    opt = O.AdamWConfig(lr_peak=3e-3, warmup_steps=20,
                        total_steps=args.steps, schedule="cosine")
    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, seed=0))
    tcfg = TrainerConfig(total_steps=args.steps, log_every=20,
                         checkpoint_every=100,
                         checkpoint_dir="checkpoints/train_lm")
    summary = Trainer(cfg, opt, tcfg, stream).run()
    first, last = summary["log"][0]["loss"], summary["log"][-1]["loss"]
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} over "
          f"{summary['steps']} steps ({summary['wall_s']:.0f}s)")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
