"""Quickstart: exact analytical cross-validation in five lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import fastcv, folds, lda, metrics
from repro.data import synthetic

# a P >> N problem — the paper's home turf
x, yc = synthetic.make_classification(jax.random.PRNGKey(0), n=100, p=2000,
                                      class_sep=2.5)
y = jnp.where(yc == 0, -1.0, 1.0)
f = folds.kfold(100, k=10, seed=0)

# analytical approach: ONE fit, exact CV decision values for every fold
dvals, y_te = fastcv.binary_cv(x, y, f, lam=1.0, adjust_bias=False)
print(f"analytical  acc={float(metrics.binary_accuracy(dvals, y_te)):.3f} "
      f"auc={float(metrics.auc(dvals.ravel(), y_te.ravel())):.3f}")

# standard approach (retrain 10x) — identical predictions, far more work
dv_std, _ = lda.standard_cv_binary(x, y, f, lam=1.0, form="regression")
import numpy as np
np.testing.assert_allclose(np.asarray(dvals), np.asarray(dv_std), rtol=1e-7,
                           atol=1e-8)
print("standard (retrained) decision values match to machine precision ✓")
