"""Async serving quickstart: warm-up, pinned plans, streamed permutations.

    PYTHONPATH=src python examples/async_stream.py

The interactive-analysis story the paper's economics enable (§2.7), on
the One-API surface: a session registers its dataset and warms the engine
once — plan built, pinned, bucketed eval family compiled — then many
concurrent coroutines submit Workloads through one async-transport
Client, coalescing in the server's gather window with zero further
compiles, and a long permutation test *streams* its null distribution
chunk by chunk, so the running p-value is watchable long before the last
permutation lands.
"""

import asyncio

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, CVEngine, Workload


async def main():
    n, p, num_classes = 96, 1536, 3
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(0), n, p, num_classes=num_classes, class_sep=2.5
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)

    engine = CVEngine()
    data = engine.register(x, foldlib.kfold(n, 6, seed=0), lam=1.0)
    info = engine.warmup(
        data,
        tasks=("binary", "ridge", "multiclass", "permutation"),
        buckets=(1, 2, 4, 8, 64),
        num_classes=num_classes,
        pin=True,
    )
    compiles_after_warmup = info["compiles"]
    print(
        f"warmup: plan built + pinned, {compiles_after_warmup} programs "
        f"compiled for buckets {info['buckets']}"
    )

    async with Client(engine, transport="async", gather_window_ms=3.0,
                      stream_chunk=64) as client:
        # Eight concurrent clients; same plan, coalesced padded evals.
        async def one_client(cid):
            r1 = await client.submit(
                Workload(kind="cv", dataset=data, y=jnp.roll(y, cid))
            )
            r2 = await client.submit(
                Workload(kind="cv", dataset=data, y=yc,
                         estimator="multiclass", num_classes=num_classes)
            )
            return float(r1.score), float(r2.score)

        scores = await asyncio.gather(*(one_client(c) for c in range(8)))
        mean_bin = sum(s[0] for s in scores) / len(scores)
        print(
            f"8 async clients: mean binary acc {mean_bin:.3f}, "
            f"{client.server.batches_served} micro-batches, "
            f"recompiles: {engine.compile_count() - compiles_after_warmup}"
        )

        # Stream a 256-draw permutation null in 64-draw chunks: the
        # running p-value converges while the test is still in flight.
        observed = None
        perm = Workload(kind="permutation", dataset=data, y=y, n_perm=256, seed=7)
        async for ev in client.stream(perm):
            if ev.kind == "observed":
                observed = ev.payload
            elif ev.kind == "null":
                null_so_far = float(jnp.sum(ev.payload >= observed))
                print(f"  null {ev.done:3d}/{ev.total}: +{null_so_far:.0f} draws ≥ observed")
            elif ev.kind == "done":
                print(f"streamed permutation test: p = {float(ev.payload.p):.4f}")

    s = engine.stats()
    print(
        f"engine: {s['plans_built']} plan build, {s['pinned']} pinned, "
        f"{s['hits']} cache hits, {s['compiles']} compiled programs"
    )


if __name__ == "__main__":
    asyncio.run(main())
