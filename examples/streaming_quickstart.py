"""Streaming-data quickstart: mutable versioned datasets.

    PYTHONPATH=src python examples/streaming_quickstart.py

An online session: register a dataset once, then keep serving while new
trials arrive and old ones age out. ``client.append`` / ``client.retire``
(and ``Workload(kind="update")``) advance the dataset to version n+1 via
a rank-k correction of the cached CV plan — no Gram rebuild, no new XLA
programs — while version n stays servable until released. The stats at
the end show one plan *build* for the whole session, the rest were
incremental *updates*, and the compile count stays flat once warm.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, Workload


def main():
    n, p, k = 96, 1536, 6
    x, yc = synthetic.make_classification(jax.random.PRNGKey(0), n, p,
                                          num_classes=2, class_sep=2.5)
    y = np.asarray(jnp.where(yc % 2 == 0, -1.0, 1.0))

    client = Client()
    handle = client.register(x, foldlib.kfold(n, k, seed=0), lam=1.0)
    first = client.submit(Workload(kind="cv", dataset=handle, y=y))
    print(f"v0: N={handle.n}, CV accuracy {float(first.score):.3f}")

    # -- new trials arrive: append one row per fold (round-robin) ---------
    rng = np.random.default_rng(1)
    x_new = rng.normal(size=(k, p))
    handle = client.append(handle, x_new)
    y = np.concatenate([y, np.where(np.arange(k) % 2 == 0, -1.0, 1.0)])
    resp = client.submit(Workload(kind="cv", dataset=handle, y=y))
    print(f"v{handle.version}: N={handle.n} (+{handle.n_appended} appended), "
          f"CV accuracy {float(resp.score):.3f}")

    # -- steady state: slide the window (retire oldest, append fresh) -----
    compiles_warm = client.stats()["compiles"]
    for step in range(3):
        rec = client.engine.dataset_record(handle)
        drop = np.asarray(jax.device_get(rec.folds.te_idx))[:, 0]
        keep = np.setdiff1d(np.arange(handle.n), drop)
        x_new = rng.normal(size=(k, p))
        # one kind="update" workload = retire + append in one rank-k move
        upd = client.submit(Workload(kind="update", dataset=handle,
                                     x=x_new, drop_idx=drop))
        handle = upd.handle
        y = np.concatenate([y[keep],
                            np.where(np.arange(k) % 2 == 0, -1.0, 1.0)])
        resp = client.submit(Workload(kind="cv", dataset=handle, y=y))
        print(f"v{handle.version}: window advanced (rank {upd.rank}), "
              f"CV accuracy {float(resp.score):.3f}")

    s = client.stats()
    print(f"engine: {s['plans_built']} plan build, {s['plans_updated']} "
          f"incremental updates, {len(client.datasets())} versions "
          f"registered, {s['compiles'] - compiles_warm} recompiles once warm")
    assert s["plans_built"] == 1 and s["plans_updated"] == 4
    assert s["compiles"] == compiles_warm, "window advances must stay compile-flat"

    # -- old versions are refcounted: release what the window left behind -
    for info in client.datasets():
        h = info["handle"]
        if h.key != handle.key:
            client.engine.release(h)
    print(f"released stale versions; {len(client.datasets())} dataset "
          f"resident (v{handle.version})")


if __name__ == "__main__":
    main()
