"""Serving-engine quickstart: many analyses, one plan.

    PYTHONPATH=src python examples/serve_quickstart.py

A neuroimaging-flavoured session: one dataset, then a stream of questions
against it — binary CV, a permutation test, multi-class CV, ridge-λ
tuning. The engine builds the hat matrix + fold factorisations ONCE
(first request) and serves everything else from the cached plan; the
stats at the end show a single plan build for the whole session.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import (CVEngine, CVRequest, DatasetSpec,
                         PermutationRequest, TuneRequest, serve)


def main():
    n, p, num_classes = 96, 1536, 3
    x, yc = synthetic.make_classification(jax.random.PRNGKey(0), n, p,
                                          num_classes=num_classes,
                                          class_sep=2.5)
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)        # binary contrast
    spec = DatasetSpec(x, foldlib.kfold(n, 6, seed=0), lam=1.0)

    engine = CVEngine()
    responses = serve(engine, [
        CVRequest(spec, y, task="binary"),
        PermutationRequest(spec, y, n_perm=200, seed=1),
        CVRequest(spec, yc, task="multiclass", num_classes=num_classes),
        PermutationRequest(spec, yc, n_perm=200, seed=2, task="multiclass",
                           num_classes=num_classes),
        TuneRequest(x, y),
    ])

    cv_bin, perm_bin, cv_mc, perm_mc, tune = responses
    print(f"binary CV accuracy      : {float(cv_bin.score):.3f} "
          f"(p = {float(perm_bin.p):.4f}, T = {perm_bin.null.shape[0]})")
    print(f"multi-class CV accuracy : {float(cv_mc.score):.3f} "
          f"(p = {float(perm_mc.p):.4f})")
    print(f"tuned ridge λ (exact LOO): {float(tune.result.best_lambda):.3g}")
    s = engine.stats()
    print(f"engine: {s['plans_built']} plan build, {s['hits']} cache hits, "
          f"{s['labels_evaluated']} label vectors evaluated, "
          f"{s['compiles']} compiled programs")


if __name__ == "__main__":
    main()
