"""Serving-engine quickstart: one registered dataset, many workloads.

    PYTHONPATH=src python examples/serve_quickstart.py

A neuroimaging-flavoured session on the One-API surface: register the
dataset once (`client.register` -> DatasetHandle; the feature matrix is
never re-shipped), then a stream of Workload specs against the handle —
binary CV, a permutation test, multi-class CV, ridge-λ tuning. The engine
builds the hat matrix + fold factorisations ONCE (first workload) and
serves everything else from the cached plan; the stats at the end show a
single plan build for the whole session.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, Workload


def main():
    n, p, num_classes = 96, 1536, 3
    x, yc = synthetic.make_classification(jax.random.PRNGKey(0), n, p,
                                          num_classes=num_classes,
                                          class_sep=2.5)
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)        # binary contrast

    client = Client()                             # sync transport, own engine
    data = client.register(x, foldlib.kfold(n, 6, seed=0), lam=1.0)

    responses = client.gather([
        Workload(kind="cv", dataset=data, y=y),
        Workload(kind="permutation", dataset=data, y=y, n_perm=200, seed=1),
        Workload(kind="cv", dataset=data, y=yc, estimator="multiclass",
                 num_classes=num_classes),
        Workload(kind="permutation", dataset=data, y=yc,
                 estimator="multiclass", num_classes=num_classes,
                 n_perm=200, seed=2),
        Workload(kind="tune", x=x, y=y),
    ])

    cv_bin, perm_bin, cv_mc, perm_mc, tune = responses
    print(f"binary CV accuracy      : {float(cv_bin.score):.3f} "
          f"(p = {float(perm_bin.p):.4f}, T = {perm_bin.null.shape[0]})")
    print(f"multi-class CV accuracy : {float(cv_mc.score):.3f} "
          f"(p = {float(perm_mc.p):.4f})")
    print(f"tuned ridge λ (exact LOO): {float(tune.result.best_lambda):.3g}")
    (info,) = client.datasets()
    print(f"dataset: N={info['n']} P={info['p']}, served {info['served']} "
          f"plan resolutions, resident={info['resident']}")
    s = client.stats()
    print(f"engine: {s['plans_built']} plan build, {s['hits']} cache hits, "
          f"{s['labels_evaluated']} label vectors evaluated, "
          f"{s['compiles']} compiled programs")


if __name__ == "__main__":
    main()
