"""Over-the-wire quickstart: the Workload API through the HTTP/SSE edge.

    PYTHONPATH=src python examples/http_quickstart.py

The same session as ``serve_quickstart``, but across a real TCP
boundary: an :class:`repro.serve.HTTPEdge` serves the engine on
loopback (here on a background thread; in production via
``python -m repro.launch.serve_cv --http PORT --warmup --pin``), and an
:class:`repro.serve.HTTPClient` — a constructor-for-constructor mirror
of the in-process ``Client`` — registers the dataset, submits a mixed
Workload batch as JSON, and watches a permutation test stream its null
distribution as Server-Sent Events. Results decode into the same
response dataclasses the in-process path returns, bit-identical to it.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, CVEngine, EdgeThread, HTTPClient, Workload


def main():
    n, p, num_classes = 96, 1536, 3
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(0), n, p, num_classes=num_classes, class_sep=2.5
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    folds = foldlib.kfold(n, 6, seed=0)

    engine = CVEngine()
    with EdgeThread(engine, stream_chunk=64) as edge:
        print(f"edge up at {edge.url}")
        client = HTTPClient(edge.url)

        # register once over the wire; workloads then carry the handle
        data = client.register(np.asarray(x),
                               (np.asarray(folds.te_idx), np.asarray(folds.tr_idx)),
                               lam=1.0)
        print(f"registered dataset: N={data.n}, P={data.p} -> handle {data.key[0][:8]}")

        responses = client.gather([
            Workload(kind="cv", dataset=data, y=y),
            Workload(kind="cv", dataset=data, y=y, estimator="ridge"),
            Workload(kind="cv", dataset=data, y=yc,
                     estimator="multiclass", num_classes=num_classes),
        ])
        for resp in responses:
            print(f"  {resp.task:>10s} CV over the wire: score {float(resp.score):.3f}")

        # the wire is a transport, not a second implementation
        local = Client(engine).submit(Workload(kind="cv", dataset=data, y=y))
        assert np.array_equal(np.asarray(local.values), np.asarray(responses[0].values))
        print("wire result is bit-identical to the in-process Client")

        # SSE: a 256-draw permutation null streams in 64-draw chunks
        observed = None
        perm = Workload(kind="permutation", dataset=data, y=y, n_perm=256, seed=7)
        for ev in client.stream(perm):
            if ev.kind == "observed":
                observed = np.asarray(ev.payload)
            elif ev.kind == "null":
                ge = int(np.sum(np.asarray(ev.payload) >= observed))
                print(f"  null {ev.done:3d}/{ev.total}: +{ge} draws ≥ observed")
            elif ev.kind == "done":
                print(f"streamed permutation test: p = {float(ev.payload.p):.4f}")

        s = client.stats()
        print(f"edge: {s['edge']['http_requests']} http requests, "
              f"{s['edge']['http_streams']} streams, "
              f"{s['engine']['plans_built']} plan build, "
              f"{s['engine']['compiles']} compiled programs")
        client.close()


if __name__ == "__main__":
    main()
