"""HTTP edge benchmark: wire overhead over the in-process transports.

What the wire costs: the edge adds JSON encode/decode and a loopback TCP
round-trip on top of the async server's gather window, so the honest
metrics are per-request latency percentiles against the in-process sync
client on the *same* warmed engine, batched-gather amortisation (one
POST, many workloads), and streamed time-to-first-chunk. Rows
deliberately avoid the "warm" substring — wire latencies swing with
process/socket state far past compare.py's merge gate, which should
gate only the stable compute-bound rows. The HTTP smoke CI job publishes
its own latency JSON next to the bench-smoke artifact
(``benchmarks/http_smoke.py``).
"""

from __future__ import annotations

import time
from statistics import median

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import percentiles, row
from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, CVEngine, EdgeThread, HTTPClient, Workload


def run(fast: bool = False):
    rows = []
    n, p, t_perm, reps = (96, 512, 32, 24) if fast else (192, 2048, 64, 48)
    x, yc = synthetic.make_classification(jax.random.PRNGKey(0), n, p, num_classes=2, class_sep=2.0)
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    folds = foldlib.kfold(n, 6, seed=0)

    engine = CVEngine()
    local = Client(engine)
    with EdgeThread(engine, stream_chunk=t_perm) as edge:
        client = HTTPClient(edge.url)

        t0 = time.perf_counter()
        handle = client.register(
            np.asarray(x), (np.asarray(folds.te_idx), np.asarray(folds.tr_idx)), 1.0
        )
        t_reg = time.perf_counter() - t0
        rows.append(
            row(
                f"http_register_N{n}_P{p}",
                t_reg,
                "wire registration incl. feature upload + fingerprint",
            )
        )
        engine.warmup(handle, tasks=("binary", "permutation"), buckets=(1, t_perm), pin=True)

        ys = [jnp.roll(y, i) for i in range(reps)]
        jax.block_until_ready(ys)

        def one(i):
            return Workload(kind="cv", dataset=handle, y=ys[i % reps])

        # -- single-submit latency: wire vs in-process, same warm engine ---
        # (separate loops: interleaving the transports makes each measure
        # the other's thread contention instead of its own path)
        local.submit(one(0))
        t_local = []
        for i in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(local.submit(one(i)).values)
            t_local.append(time.perf_counter() - t0)
        client.submit(one(0))
        t_wire = []
        for i in range(reps):
            t0 = time.perf_counter()
            client.submit(one(i))  # response is host-side numpy already
            t_wire.append(time.perf_counter() - t0)
        p_local = percentiles(t_local, (50, 95))
        p_wire = percentiles(t_wire, (50, 95))
        rows.append(
            row(
                f"http_submit_N{n}_P{p}",
                p_wire["p50"],
                f"p95={p_wire['p95'] * 1e3:.1f}ms vs in-process "
                f"p50={p_local['p50'] * 1e3:.1f}ms "
                f"({p_wire['p50'] / p_local['p50']:.1f}x wire overhead)",
            )
        )

        # -- batched gather: one POST amortises the round-trip -------------
        batch = [one(i) for i in range(16)]
        client.gather(batch)
        t_batch = median(_timed(client.gather, batch) for _ in range(3))
        rows.append(
            row(
                f"http_gather_16_N{n}_P{p}",
                t_batch,
                f"{16 / t_batch:.0f} req/s through one POST "
                f"({t_batch / 16 * 1e3:.2f}ms/workload amortised)",
            )
        )

        # -- SSE streaming: time-to-first-null-chunk -----------------------
        stream_w = Workload(kind="permutation", dataset=handle, y=y, n_perm=4 * t_perm, seed=5)
        list(client.stream(stream_w))  # prime chunk programs

        def first_chunk():
            t0 = time.perf_counter()
            t_first = t_full = None
            for ev in client.stream(stream_w):
                if ev.kind == "null" and t_first is None:
                    t_first = time.perf_counter() - t0
            t_full = time.perf_counter() - t0
            return t_first, t_full

        runs = [first_chunk() for _ in range(3)]
        t_first = median(r[0] for r in runs)
        t_full = median(r[1] for r in runs)
        rows.append(
            row(
                f"http_stream_first_chunk_T{4 * t_perm}",
                t_first,
                f"first {t_perm}/{4 * t_perm} null draws over SSE; "
                f"full stream {t_full * 1e3:.1f}ms",
            )
        )
        rows.append(
            row(
                "http_stats_roundtrip",
                _timed(client.stats),
                f"ops GET /v1/stats; engine compiles={engine.compile_count()}",
            )
        )
        text = client.metrics_text()
        rows.append(
            row(
                "http_metrics_roundtrip",
                _timed(client.metrics_text),
                f"ops GET /v1/metrics; {len(text.splitlines())} exposition lines",
            )
        )
        client.close()
    return rows


def _timed(fn, *args):
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0
