"""Wire-conformance smoke against a *live* ``serve_cv --http`` server.

    python -m repro.launch.serve_cv --http 8123 --warmup --pin &
    PYTHONPATH=src:. python benchmarks/http_smoke.py --url http://127.0.0.1:8123 \\
        --json http-smoke.json

CI's http-smoke job boots the server with ``--warmup`` and runs this
script against it, which asserts — across a real process boundary —
everything the in-process conformance suite (tests/test_http.py) pins:

  * all five compute workload kinds served over HTTP are **bit-identical** to a
    local in-process Client computing the same workloads;
  * streamed SSE permutation chunks concatenate to the exact monolithic
    null distribution;
  * the warmed eval families (binary/ridge/multiclass CV, permutation
    at the default chunk) serve first wire traffic with **0 compiles**
    (``--expect-warm``; proves ``--warmup`` covered real traffic), and a
    full warm replay of every kind adds 0 compiles;
  * ``POST /v1/datasets/{fp}/append`` advances the dataset version with
    zero compiles, and ``GET /v1/datasets`` + the per-dataset stats
    round-trip ``version``/``n_appended`` across the wire;
  * ``GET /v1/metrics`` renders parseable Prometheus text with every
    stage-latency histogram pre-declared, and ``compile_events`` stays
    flat across a scrape → warm submit → scrape cycle.

Latency percentiles land in a ``run.py --json``-shaped artifact next to
the bench-smoke one. Exit status: 0 conformant, 1 mismatch/regression.
"""

from __future__ import annotations

import argparse
import datetime
import json
import re
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from benchmarks.common import percentiles, row
from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, CVEngine, HTTPClient, Workload
from repro.serve.http import assert_responses_equal
from repro.serve.trace import STAGES

# Prometheus text format 0.0.4: HELP/TYPE comments + `name{labels} value`
# sample lines (same shape tests/test_obs.py pins for the in-process edge).
_PROM_LINE = re.compile(
    r"^(# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]* ?.*"
    r"|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? -?[0-9][0-9eE+.\-]*)$"
)


def _wait_healthy(client: HTTPClient, timeout_s: float) -> None:
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            if client.healthz().get("status") == "ok":
                return
        except Exception:  # noqa: BLE001 - server still booting
            pass
        if time.monotonic() > deadline:
            raise SystemExit(f"server not healthy after {timeout_s:.0f}s")
        time.sleep(0.5)


def _parse_args():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--url", required=True, help="base URL of a serve_cv --http server")
    ap.add_argument("--json", default=None, metavar="PATH", help="latency artifact path")
    ap.add_argument(
        "--n",
        type=int,
        default=96,
        help="samples (match the server's --n so warmed eval shapes cover this traffic)",
    )
    ap.add_argument("--p", type=int, default=256, help="features")
    ap.add_argument("--k", type=int, default=6, help="folds (match server --k)")
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument(
        "--perm",
        type=int,
        default=64,
        help="permutation draws (match server --perm buckets)",
    )
    ap.add_argument("--reps", type=int, default=16, help="warm latency samples")
    ap.add_argument("--boot-timeout", type=float, default=180.0)
    ap.add_argument(
        "--expect-warm",
        action="store_true",
        help="assert the warmed families serve first traffic with zero "
        "compiles (server must run --warmup with matching --n/--k/--perm)",
    )
    return ap.parse_args()


def main() -> int:
    args = _parse_args()
    client = HTTPClient(args.url)
    _wait_healthy(client, args.boot_timeout)
    print(f"[http_smoke] {args.url} healthy")

    # Local reference: the same dataset + workloads through the in-process
    # Client. Bit-identical across the process boundary is the contract.
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(7), args.n, args.p, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    folds = foldlib.kfold(args.n, args.k, seed=3)
    local = Client(CVEngine())
    local_handle = local.register(x, folds, args.lam)

    handle = client.register(
        np.asarray(x), (np.asarray(folds.te_idx), np.asarray(folds.tr_idx)), args.lam
    )
    assert handle.key == local_handle.key, "wire registration changed the fingerprint"

    models = jnp.stack([jnp.ones((3, 3)) - jnp.eye(3), jnp.eye(3) * 0.0 + 0.5])
    mc = Workload(kind="cv", dataset=handle, y=yc, estimator="multiclass", num_classes=3)
    warmed = [
        ("cv/binary", Workload(kind="cv", dataset=handle, y=y)),
        ("cv/ridge", Workload(kind="cv", dataset=handle, y=y, estimator="ridge")),
        ("cv/multiclass", mc),
        (
            "permutation",
            Workload(kind="permutation", dataset=handle, y=y, n_perm=args.perm, seed=11),
        ),
    ]
    cold = [
        (
            "rsa",
            Workload(
                kind="rsa",
                dataset=handle,
                y=yc,
                num_classes=3,
                model_rdms=models,
                n_perm=16,
                seed=5,
            ),
        ),
        ("tune", Workload(kind="tune", x=x, y=y)),
        ("grid", Workload(kind="grid", dataset=handle, y=y, xs=jnp.stack([x, x * 1.05]))),
    ]

    def swap(w, ds):
        d = w.to_dict()
        if isinstance(d.get("dataset"), dict) and "__handle__" in d["dataset"]:
            d["dataset"] = ds.to_dict()
        return Workload.from_dict(d)

    compiles0 = client.stats()["engine"]["compiles"]
    for name, w in warmed:
        assert_responses_equal(client.submit(w), local.submit(swap(w, local_handle)), label=name)
    warm_delta = client.stats()["engine"]["compiles"] - compiles0
    print(f"[http_smoke] warmed families conformant; first-traffic compiles: {warm_delta}")
    if args.expect_warm:
        assert warm_delta == 0, (
            f"--warmup did not cover first wire traffic ({warm_delta} compiles)"
        )

    for name, w in cold:
        assert_responses_equal(client.submit(w), local.submit(swap(w, local_handle)), label=name)
    print("[http_smoke] all five compute kinds bit-identical over the wire")

    # SSE chunks == monolithic null, draw for draw
    stream_w = warmed[3][1]
    events = list(client.stream(stream_w))
    mono = local.submit(swap(stream_w, local_handle))
    streamed = np.concatenate([np.asarray(ev.payload) for ev in events if ev.kind == "null"])
    np.testing.assert_array_equal(streamed, np.asarray(mono.null))
    print(f"[http_smoke] SSE stream conformant ({len(events)} events)")

    # warm replay: every kind again, zero compiles end to end
    before = client.stats()["engine"]["compiles"]
    t_submit = []
    for name, w in warmed + cold:
        t0 = time.perf_counter()
        client.submit(w)
        t_submit.append(time.perf_counter() - t0)
    list(client.stream(stream_w))
    replay_delta = client.stats()["engine"]["compiles"] - before
    assert replay_delta == 0, f"{replay_delta} compiles on warm wire replay"
    print("[http_smoke] warm replay: 0 post-warmup compiles")

    # mutable versioned datasets round-trip the wire: POST .../append
    # advances the version; GET /v1/datasets and the per-dataset stats
    # reflect version/n_appended; plan updates never recompile
    view0 = {d["handle"].key: d for d in client.datasets()}
    assert view0[handle.key]["version"] == 0
    assert view0[handle.key]["n_appended"] == 0
    before_update = client.stats()["engine"]["compiles"]
    x_new = np.asarray(
        jax.random.normal(jax.random.PRNGKey(13), (args.k, args.p)), dtype=np.float64
    )
    h1 = client.append(handle, x_new)
    assert (h1.version, h1.n_appended, h1.n) == (1, args.k, args.n + args.k), (
        f"append returned version={h1.version} n_appended={h1.n_appended} n={h1.n}"
    )
    view1 = {d["handle"].key: d for d in client.datasets()}
    assert view1[handle.key]["version"] == 0, "base version must stay registered"
    assert view1[h1.key]["version"] == 1 and view1[h1.key]["n_appended"] == args.k
    per = client.stats()["engine"]["per_dataset"]
    fp12 = str(h1.key[0])[:12]
    assert per[fp12]["version"] == 1 and per[fp12]["n_appended"] == args.k, (
        f"per_dataset stats missing the appended version: {per.get(fp12)}"
    )
    update_delta = client.stats()["engine"]["compiles"] - before_update
    assert update_delta == 0, f"{update_delta} compiles from a plan update"
    print(
        f"[http_smoke] versioned append conformant (v0 -> v{h1.version}, "
        f"n_appended={h1.n_appended}, 0 compiles)"
    )

    # /v1/metrics: exposition parses line by line, every stage histogram is
    # pre-declared, and compile_events is flat across scrape → submit → scrape
    text = client.metrics_text()
    for line in text.splitlines():
        if not line:
            continue
        assert _PROM_LINE.match(line), f"malformed exposition line: {line!r}"
    for stage in STAGES:
        needle = f'stage_latency_seconds_bucket{{stage="{stage}"'
        assert needle in text, f"stage histogram missing from /v1/metrics: {stage}"
    m = re.search(r"^compile_events (\d+)$", text, re.M)
    assert m, "compile_events missing from /v1/metrics"
    client.submit(warmed[0][1])
    m2 = re.search(r"^compile_events (\d+)$", client.metrics_text(), re.M)
    assert m2 and m2.group(1) == m.group(1), (
        f"compile_events moved on a warm scrape replay: {m.group(1)} -> "
        f"{m2.group(1) if m2 else 'missing'}"
    )
    trace_view = client.trace(n=8)
    assert {"enabled", "ring", "traces", "summary"} <= trace_view.keys()
    print(
        f"[http_smoke] /v1/metrics conformant ({len(text.splitlines())} lines, "
        f"compile_events={m.group(1)} flat); /v1/trace "
        f"{'enabled' if trace_view['enabled'] else 'disabled'}"
    )

    # latency rows (the artifact CI publishes next to bench-smoke)
    lat = []
    cv_w = warmed[0][1]
    for _ in range(args.reps):
        t0 = time.perf_counter()
        client.submit(cv_w)
        lat.append(time.perf_counter() - t0)
    pct = percentiles(lat, (50, 95))
    t0 = time.perf_counter()
    t_first = None
    for ev in client.stream(stream_w):
        if ev.kind == "null" and t_first is None:
            t_first = time.perf_counter() - t0

    def smoke_row(name, seconds, derived):
        return dict(section="http-smoke", **row(name, seconds, derived))

    rows = [
        smoke_row(
            f"http_smoke_submit_N{args.n}_P{args.p}",
            pct["p50"],
            f"p95={pct['p95'] * 1e3:.1f}ms over {args.reps} warm submits",
        ),
        smoke_row(
            f"http_smoke_mixed_kinds_{len(warmed) + len(cold)}req",
            float(np.median(t_submit)),
            "median per-workload submit across all five kinds",
        ),
        smoke_row(
            f"http_smoke_stream_first_chunk_T{args.perm}",
            t_first,
            "SSE time-to-first-null-chunk",
        ),
    ]
    for r in rows:
        print(f"[http_smoke] {r['name']},{r['us_per_call']:.1f},{r['derived']}")

    if args.json:
        meta = {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "url": args.url,
            "expect_warm": bool(args.expect_warm),
            "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(timespec="seconds"),
        }
        with open(args.json, "w") as fh:
            json.dump({"meta": meta, "rows": rows}, fh, indent=2)
        print(f"[http_smoke] wrote {len(rows)} rows to {args.json}")
    print("[http_smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
