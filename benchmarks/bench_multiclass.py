"""Paper Fig. 3b: multi-class LDA CV + permutation relative efficiency.

Standard approach: per-fold scatter matrices + P×P generalised
eigendecomposition. Analytical: one hat matrix + per-fold C×C eigh.
The paper's headline: multi-class gains exceed binary (single inversion
replaces K eigendecompositions), approaching 10^4 at high P.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import folds as foldlib, multiclass, permutation
from repro.data import synthetic
from benchmarks.common import relative_efficiency, row, timeit

CV_CASES = (
    # (N, P, C)
    (100, 64, 5),
    (100, 256, 5),
    (100, 1024, 10),
)

PERM_CASES = (
    # (N, P, C, T_analytical, T_standard_measured)
    (100, 256, 5, 20, 2),
)


def run(fast: bool = False):
    rows = []
    for n, p, c in CV_CASES[:1] if fast else CV_CASES:
        x, y = synthetic.make_classification(jax.random.PRNGKey(p), n, p, c)
        f = foldlib.stratified_kfold(np.asarray(y), 10, seed=0)
        lam = 1.0
        t_std = timeit(lambda: multiclass.standard_cv_multiclass(x, y, f, c, lam), repeats=2)
        t_ana = timeit(lambda: multiclass.analytical_cv_multiclass(x, y, f, c, lam), repeats=2)
        rel = relative_efficiency(t_std, t_ana)
        rows.append(
            row(
                f"cv_multiclass/n{n}_p{p}_c{c}",
                t_ana,
                f"rel_eff={rel:.2f} t_std={t_std*1e3:.1f}ms t_ana={t_ana*1e3:.1f}ms",
            )
        )

    key = jax.random.PRNGKey(1)
    for n, p, c, t_full, t_meas in () if fast else PERM_CASES:
        x, y = synthetic.make_classification(jax.random.PRNGKey(7), n, p, c)
        f = foldlib.stratified_kfold(np.asarray(y), 10, seed=1)
        lam = 1.0
        t_ana = timeit(
            lambda: permutation.analytical_permutation_multiclass(
                x, y, f, c, lam, n_perm=t_full, key=key, chunk=10
            ),
            repeats=2,
        )
        t_std_meas = timeit(
            lambda: permutation.standard_permutation_multiclass(
                x, y, f, c, lam, n_perm=t_meas, key=key
            ),
            repeats=2,
        )
        t_std = t_std_meas * (t_full / t_meas)
        rel = relative_efficiency(t_std, t_ana)
        rows.append(
            row(
                f"perm_multiclass/n{n}_p{p}_c{c}_T{t_full}",
                t_ana,
                f"rel_eff={rel:.2f} t_std_scaled={t_std:.2f}s t_ana={t_ana:.3f}s",
            )
        )
    return rows
