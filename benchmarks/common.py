"""Shared benchmark utilities: timing, relative efficiency."""

from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 1, repeats: int = 3, **kw) -> float:
    """Median wall seconds with block_until_ready (paper used tic/toc)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def relative_efficiency(t_standard: float, t_analytical: float) -> float:
    """log10(time_standard / time_analytical) — paper §2.12."""
    return float(np.log10(t_standard / t_analytical))


def percentiles(samples, qs=(50, 95, 99)) -> dict:
    """{"p50": ..., ...} wall-second percentiles over a latency sample."""
    arr = np.asarray(samples, dtype=float)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


def row(name: str, seconds: float, derived: str = "") -> dict:
    return {"name": name, "us_per_call": seconds * 1e6, "derived": derived}


def print_rows(rows):
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
