"""Gate CI on benchmark regressions against a committed baseline.

    python benchmarks/compare.py benchmarks/baseline.json fresh.json

Both files are ``run.py --json`` artifacts. Rows are matched by
(section, name); only *warm* rows (name contains ``--gate-substring``,
default "warm") gate — cold rows time plan builds **and** jit compiles,
which are too noisy to diff across CI runners.

CI runners and the machine that produced the committed baseline differ in
absolute speed, so raw per-row ratios would gate on hardware, not code.
With ≥ ``--min-rows`` matched rows the gate normalises: each row's ratio
``fresh/baseline`` is divided by the *median* ratio across all gated rows
(the machine-speed factor, clamped at ≥1 so a PR that speeds most rows up
never flags the untouched ones), and a row regresses when its normalised
ratio exceeds ``--tolerance``. Any *single* benchmark regressing (the common
case: one eval path lost its no-recompile guarantee, one batch stopped
coalescing) stands out sharply. Normalisation has a blind spot — a
*correlated* slowdown of half the rows shifts the median and masks
itself — so the median is itself gated by ``--max-median`` (default 4x,
loose enough for honest runner-speed spread): a broad regression fails
the gate even though no individual row does. Below ``--min-rows`` matches
the median is meaningless and raw ratios gate directly.

Exit status: 0 clean, 1 regression(s), 2 usage/structure errors. Rows
missing from either side are reported but do not fail the gate (bench
sets legitimately grow, and full-size sweeps use different row names).
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys


def load_rows(path: str) -> dict:
    """{(section, name): us_per_call} from a run.py --json artifact."""
    with open(path) as fh:
        doc = json.load(fh)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    out = {}
    for r in rows:
        out[(r.get("section", ""), r["name"])] = float(r["us_per_call"])
    return out


def compare(
    baseline: dict,
    fresh: dict,
    tolerance: float = 1.5,
    gate_substring: str = "warm",
    min_rows: int = 3,
    max_median: float = 4.0,
):
    """Return (regressions, checked, missing, median_ratio).

    regressions: [(key, base_us, fresh_us, normalised_ratio), ...] — a
                 median above ``max_median`` adds a synthetic
                 ("<all>", "median") entry (correlated-slowdown backstop)
    checked:     number of gated rows matched in both artifacts
    missing:     gated keys present in exactly one artifact
    """
    gated_base = {k: v for k, v in baseline.items() if gate_substring in k[1]}
    gated_fresh = {k: v for k, v in fresh.items() if gate_substring in k[1]}
    shared = sorted(gated_base.keys() & gated_fresh.keys())
    missing = sorted(gated_base.keys() ^ gated_fresh.keys())
    ratios = {k: gated_fresh[k] / max(gated_base[k], 1e-9) for k in shared}
    if len(shared) >= min_rows:
        median = statistics.median(ratios.values())
    else:
        median = 1.0  # too few rows to estimate machine speed; gate raw ratios
    # Normalise by the median only when it shows a SLOWER machine. A median
    # below 1 means most rows sped up — dividing by it would flag untouched
    # rows as "regressions" for failing to improve, blocking the very PR
    # that made things faster. (Cost: a runner genuinely faster than the
    # baseline host loses some sensitivity until the baseline is refreshed.)
    norm = max(median, 1.0)
    regressions = []
    for k in shared:
        normalised = ratios[k] / norm
        if normalised > tolerance:
            regressions.append((k, gated_base[k], gated_fresh[k], normalised))
    if len(shared) >= min_rows and median > max_median:
        # Correlated-slowdown backstop: enough rows regressed together to
        # drag the median itself past any honest runner-speed spread.
        regressions.append((("<all gated rows>", "median-ratio"), 1.0, median, median))
    return regressions, len(shared), missing, median


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline artifact (run.py --json)")
    ap.add_argument("fresh", help="freshly measured artifact to gate")
    ap.add_argument("--tolerance", type=float, default=1.5, help="max normalised slowdown per row")
    ap.add_argument("--gate-substring", default="warm", help="gate rows whose name contains this")
    ap.add_argument("--min-rows", type=int, default=3, help="min matches for median normalisation")
    ap.add_argument(
        "--max-median",
        type=float,
        default=4.0,
        help="fail when the median ratio itself exceeds this (correlated slowdown)",
    )
    args = ap.parse_args(argv)

    try:
        baseline = load_rows(args.baseline)
        fresh = load_rows(args.fresh)
    except (OSError, KeyError, ValueError, TypeError) as e:
        print(f"[compare] cannot load artifacts: {e!r}", file=sys.stderr)
        return 2

    regressions, checked, missing, median = compare(
        baseline, fresh, args.tolerance, args.gate_substring, args.min_rows, args.max_median
    )
    if checked == 0:
        # A gate with nothing to gate is a broken gate, not a green one —
        # renamed rows or a bench module that stopped emitting must be loud.
        print(
            f"[compare] no '{args.gate_substring}' rows shared between the artifacts; "
            "the gate would be vacuous — refresh benchmarks/baseline.json",
            file=sys.stderr,
        )
        return 2
    print(
        f"[compare] {checked} warm rows gated at {args.tolerance:.2f}x "
        f"(machine-speed median {median:.2f}x)"
    )
    if median < 1.0 / args.tolerance:
        print(
            "[compare] note: most rows are much faster than the baseline — "
            "consider refreshing benchmarks/baseline.json to regain gate sensitivity"
        )
    for key in missing:
        print(f"[compare] warning: row {key} present in only one artifact (not gated)")
    if not regressions:
        print("[compare] OK — no warm-latency regressions")
        return 0
    for (section, name), base_us, fresh_us, ratio in regressions:
        print(
            f"[compare] REGRESSION {section} :: {name}: "
            f"{base_us:.1f}us -> {fresh_us:.1f}us ({ratio:.2f}x normalised)",
            file=sys.stderr,
        )
    print(f"[compare] FAIL — {len(regressions)} row(s) regressed", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
