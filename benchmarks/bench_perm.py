"""Paper Fig. 3a (right): binary permutation-testing relative efficiency.

The analytical engine computes H once and reuses the per-fold Cholesky
factors across all permutations; the standard approach retrains K
classifiers per permutation. Standard timing uses a reduced permutation
count and scales per-permutation cost (documented; the analytical run
uses the full count).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import folds as foldlib, permutation
from repro.data import synthetic
from benchmarks.common import relative_efficiency, row, timeit

CASES = (
    # (N, P, n_perm_analytical, n_perm_standard_measured)
    (64, 64, 100, 10),
    (64, 512, 100, 4),
    (256, 256, 100, 4),
)


def run(fast: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    for n, p, t_full, t_meas in CASES[:1] if fast else CASES:
        x, yc = synthetic.make_classification(jax.random.PRNGKey(n + p), n, p)
        y = jnp.where(yc == 0, -1.0, 1.0)
        f = foldlib.kfold(n, 10, seed=0)
        lam = 1.0

        t_ana = timeit(
            lambda: permutation.analytical_permutation_binary(
                x, y, f, lam, n_perm=t_full, key=key, chunk=min(t_full, 64)
            ),
            repeats=2,
        )
        t_std_meas = timeit(
            lambda: permutation.standard_permutation_binary(x, y, f, lam, n_perm=t_meas, key=key),
            repeats=2,
        )
        t_std = t_std_meas * (t_full / t_meas)  # per-perm cost scales linearly
        rel = relative_efficiency(t_std, t_ana)
        rows.append(
            row(
                f"perm_binary/n{n}_p{p}_T{t_full}",
                t_ana,
                f"rel_eff={rel:.2f} t_std_scaled={t_std:.2f}s t_ana={t_ana:.3f}s",
            )
        )
    return rows
