"""RSA serving benchmark: cold-plan vs warm-cache RDM requests, contrast
throughput, and the Pallas pairdist kernel vs its XLA oracle.

The RSA economics are the paper's §4.2 pitch operationalised: all
C(C−1)/2 pairwise contrasts of an RDM are one label batch against the
cached plan — a cold request pays the O(N²P) Gram + factorisation + jit
compile, a warm one only the O(K·m²·B) fold solves plus tiny model
scoring, through already-compiled programs.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro import rsa
from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import CVEngine, DatasetSpec, Workload, serve


def run(fast: bool = False):
    rows = []
    n, p, c, t_perm = (96, 512, 6, 32) if fast else (240, 4096, 12, 128)
    k = 6
    lam = 1.0

    x, y_cond = synthetic.make_classification(
        jax.random.PRNGKey(0), n, p, num_classes=c, class_sep=2.0
    )
    f = foldlib.stratified_kfold(y_cond, k, seed=0)
    spec = DatasetSpec(x, f, lam)
    mu = rsa.condition_means(x, y_cond, c)
    models = jnp.stack([rsa.euclidean_rdm(mu), rsa.ring_rdm(c)])
    req = Workload(
        kind="rsa", dataset=spec, y=y_cond, num_classes=c, model_rdms=models, n_perm=t_perm, seed=0
    )

    # -- cold: fresh engine; plan build + compile + eval -------------------
    engine = CVEngine()
    t0 = time.perf_counter()
    jax.block_until_ready(serve(engine, [req])[0].rdm)
    t_cold = time.perf_counter() - t0
    rows.append(
        row(f"bench_rsa_cold_N{n}_P{p}_C{c}", t_cold, "plan build + compile + RDM + model scoring")
    )

    # -- warm: cached plan, compiled programs ------------------------------
    compiles_warm = engine.compile_count()

    def warm_once():
        return serve(engine, [req])[0].rdm

    t_warm = timeit(warm_once, warmup=1, repeats=5)
    recompiles = engine.compile_count() - compiles_warm
    rows.append(
        row(
            f"bench_rsa_warm_N{n}_P{p}_C{c}",
            t_warm,
            f"speedup={t_cold / t_warm:.0f}x recompiles={recompiles}",
        )
    )

    # -- coalesced RSA batches: requests/s vs batch size -------------------
    for bs in (1, 4, 16):
        reqs = [
            Workload(
                kind="rsa",
                dataset=spec,
                y=y_cond,
                num_classes=c,
                model_rdms=models,
                n_perm=t_perm,
                seed=s,
            )
            for s in range(bs)
        ]

        def rsa_batch():
            return [r.rdm for r in serve(engine, reqs)]

        secs = timeit(rsa_batch, warmup=1, repeats=5)
        rows.append(row(f"bench_rsa_warm_batch{bs}_N{n}_P{p}_C{c}", secs, f"{bs / secs:.0f} req/s"))

    # -- pairdist kernel (interpret off-TPU) vs the XLA oracle -------------
    cc = 32 if fast else 64
    patterns = jax.random.normal(jax.random.PRNGKey(1), (cc, p), jnp.float64)
    t_xla = timeit(lambda: rsa.euclidean_rdm(patterns, impl="xla"), warmup=1, repeats=5)
    rows.append(row(f"bench_rsa_pairdist_xla_C{cc}_P{p}", t_xla, "jnp oracle"))
    t_pal = timeit(lambda: rsa.euclidean_rdm(patterns, impl="pallas"), warmup=1, repeats=3)
    rows.append(
        row(
            f"bench_rsa_pairdist_pallas_C{cc}_P{p}",
            t_pal,
            "interpret-mode off-TPU; compiled on real TPUs",
        )
    )
    return rows
