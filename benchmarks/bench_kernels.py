"""Kernel-layer benchmark: the CV hot-spots through the jnp (XLA) path.

The Pallas kernels target TPU and are validated in interpret mode (exact
but Python-speed — wall-clock on CPU is meaningless for them), so this
bench times the XLA path the CPU container actually executes and reports
achieved GFLOP/s for the two dominant CV kernels, plus the roofline-model
speedup the Pallas gram kernel's fusion predicts on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fold_eval.ref import fold_eval_ref
from repro.kernels.foldsolve.ref import foldsolve_ref
from repro.kernels.gram.ref import centered_gram_ref
from repro.kernels.hat_apply.ref import hat_apply_ref
from benchmarks.common import row, timeit


def run(fast: bool = False):
    rows = []
    n, p = (512, 2048) if not fast else (256, 512)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, p), jnp.float32)
    g = jax.jit(centered_gram_ref)
    t = timeit(g, x, repeats=3)
    gflops = 2 * n * n * p / t / 1e9
    rows.append(row(f"kernel/gram_xla_n{n}_p{p}", t, f"{gflops:.1f}GFLOP/s"))

    h = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32) / n
    yb = jax.random.normal(jax.random.PRNGKey(2), (n, 256), jnp.float32)
    ha = jax.jit(hat_apply_ref)
    t2 = timeit(ha, h, yb, repeats=3)
    gflops2 = 2 * n * n * 256 / t2 / 1e9
    rows.append(row(f"kernel/hat_apply_xla_n{n}_b256", t2, f"{gflops2:.1f}GFLOP/s"))
    # TPU projection: fusing the subtraction saves one (N,B) round-trip of
    # 3 (write ŷ, read ŷ, write ê -> write ê): at 819GB/s HBM that is
    bytes_saved = 2 * n * 256 * 4
    rows.append(
        row(
            "kernel/hat_apply_pallas_fusion_saving",
            0.0,
            f"{bytes_saved/1e6:.2f}MB/chunk HBM traffic avoided on TPU",
        )
    )
    rows.extend(_fold_eval_rows(fast))
    return rows


def _fold_eval_rows(fast: bool):
    """Fused vs unfused fold-eval at a serving shape, XLA path.

    The Pallas fold_eval kernel only compiles natively on TPU, so on CPU we
    time its XLA-path data flows: *fused* = one jitted program from hat rows
    to ė_Te (no intermediate leaves the program — what the kernel does in
    one grid pass), *unfused* = the two-launch flow (hat_apply-shaped
    contraction materialising the (K, m, B) Ê between two jitted programs —
    today's hat_apply → foldsolve pair). Both rows are warm (compiles
    excluded) and gate against baseline; the dimensionless
    ``fused_vs_unfused`` ratio row makes "fused must not lose to unfused at
    serving shapes" a direct gate rather than a cross-row inference.
    """
    # dispatch overhead swamps sub-100µs kernels on CPU, so even the fast
    # shape keeps the contraction in the hundreds-of-MFLOP range
    k, m, n, b = (8, 64, 512, 256) if fast else (10, 64, 640, 256)
    key = jax.random.PRNGKey(3)
    kk = jax.random.split(key, 3)
    a = jax.random.normal(kk[0], (n, n), jnp.float32) / (3.0 * n**0.5)
    h = a @ a.T
    te = jax.random.permutation(kk[1], n)[: k * m].reshape(k, m)
    h_rows, h_te = h[te], h[te[:, :, None], te[:, None, :]]
    y = jax.random.normal(kk[2], (n, b), jnp.float32)
    y_te = y[te]

    fused = jax.jit(lambda *args: fold_eval_ref(*args)[0])
    t_fused = timeit(fused, h_rows, h_te, y, y_te, repeats=9)

    contract = jax.jit(lambda hr, yy, yt: yt - jnp.einsum("kmn,nb->kmb", hr, yy))
    solve = jax.jit(foldsolve_ref)

    def unfused(hr, ht, yy, yt):
        e = jax.block_until_ready(contract(hr, yy, yt))  # Ê round-trips HBM
        return solve(ht, e)

    t_unfused = timeit(unfused, h_rows, h_te, y, y_te, repeats=9)

    shape = f"k{k}_m{m}_n{n}_b{b}"
    ratio = t_fused / max(t_unfused, 1e-12)
    return [
        row(f"kernel/fold_eval_fused_warm_{shape}", t_fused),
        row(f"kernel/fold_eval_unfused_warm_{shape}", t_unfused),
        # dimensionless: us_per_call field carries the ratio itself (×1e6
        # cancels), so the gate compares ratios, not machine speed
        row("kernel/fold_eval_fused_vs_unfused_warm", ratio / 1e6,
            f"fused/unfused={ratio:.3f} (<1 is a fusion win)"),
    ]
