"""Kernel-layer benchmark: the CV hot-spots through the jnp (XLA) path.

The Pallas kernels target TPU and are validated in interpret mode (exact
but Python-speed — wall-clock on CPU is meaningless for them), so this
bench times the XLA path the CPU container actually executes and reports
achieved GFLOP/s for the two dominant CV kernels, plus the roofline-model
speedup the Pallas gram kernel's fusion predicts on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram.ref import centered_gram_ref
from repro.kernels.hat_apply.ref import hat_apply_ref
from benchmarks.common import row, timeit


def run(fast: bool = False):
    rows = []
    n, p = (512, 2048) if not fast else (256, 512)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, p), jnp.float32)
    g = jax.jit(centered_gram_ref)
    t = timeit(g, x, repeats=3)
    gflops = 2 * n * n * p / t / 1e9
    rows.append(row(f"kernel/gram_xla_n{n}_p{p}", t, f"{gflops:.1f}GFLOP/s"))

    h = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32) / n
    yb = jax.random.normal(jax.random.PRNGKey(2), (n, 256), jnp.float32)
    ha = jax.jit(hat_apply_ref)
    t2 = timeit(ha, h, yb, repeats=3)
    gflops2 = 2 * n * n * 256 / t2 / 1e9
    rows.append(row(f"kernel/hat_apply_xla_n{n}_b256", t2, f"{gflops2:.1f}GFLOP/s"))
    # TPU projection: fusing the subtraction saves one (N,B) round-trip of
    # 3 (write ŷ, read ŷ, write ê -> write ê): at 819GB/s HBM that is
    bytes_saved = 2 * n * 256 * 4
    rows.append(
        row(
            "kernel/hat_apply_pallas_fusion_saving",
            0.0,
            f"{bytes_saved/1e6:.2f}MB/chunk HBM traffic avoided on TPU",
        )
    )
    return rows
