"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a header comment per
section). ``--fast`` runs a reduced sweep (CI-sized); ``--json PATH``
additionally writes the rows (tagged with their section, the git SHA and
a UTC timestamp, so archived artifacts line up into a real trajectory)
as a JSON artifact — CI's bench-smoke job uploads this per PR and gates
warm-row latencies against ``benchmarks/baseline.json`` via
``benchmarks/compare.py``.

  bench_complexity  — paper Table 1 (empirical scaling exponents)
  bench_cv          — paper Fig. 3a left  (binary CV rel. efficiency)
  bench_perm        — paper Fig. 3a right (binary permutations)
  bench_multiclass  — paper Fig. 3b       (multi-class CV + permutations)
  bench_eeg         — paper Fig. 4        (EEG/MEG-style permutation run)
  bench_kernels     — CV hot-spot kernels (XLA path GFLOP/s)
  bench_serve       — serving engine cold/warm + batch throughput
  bench_store       — plan-store write/load + cold-boot-with-store payoff
  bench_update      — incremental plan updates vs rebuild; sliding window
  bench_rsa         — RSA serving cold/warm + pairdist kernel
  bench_async       — async server: concurrent clients, streaming chunks
  bench_http        — HTTP/SSE edge: wire overhead, gather, first chunk
  bench_latency     — warm per-stage latency budget (tracing-derived)
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys

import jax

jax.config.update("jax_enable_x64", True)

from benchmarks import (
    bench_async,
    bench_complexity,
    bench_cv,
    bench_eeg,
    bench_http,
    bench_kernels,
    bench_latency,
    bench_multiclass,
    bench_perm,
    bench_rsa,
    bench_serve,
    bench_store,
    bench_update,
)
from benchmarks.common import print_rows

MODULES = [
    ("complexity(Table1)", bench_complexity),
    ("cv(Fig3a-left)", bench_cv),
    ("perm(Fig3a-right)", bench_perm),
    ("multiclass(Fig3b)", bench_multiclass),
    ("eeg(Fig4)", bench_eeg),
    ("kernels", bench_kernels),
    ("serve(engine)", bench_serve),
    ("store(plan-store)", bench_store),
    ("update(incremental)", bench_update),
    ("rsa(serve+kernel)", bench_rsa),
    ("async(serve.aio)", bench_async),
    ("http(serve.http)", bench_http),
    ("latency(stage-budget)", bench_latency),
]


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True,
            text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10,
        )
        return proc.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced CI sweep")
    ap.add_argument(
        "--only", default=None, help="comma-separated substring filter on section names"
    )
    ap.add_argument(
        "--json", default=None, metavar="PATH", help="also write rows as a JSON artifact"
    )
    args = ap.parse_args()

    sha = _git_sha()
    stamp = datetime.datetime.now(datetime.timezone.utc).isoformat(timespec="seconds")
    all_rows = []
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if args.only and not any(s in name for s in args.only.split(",")):
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        rows = mod.run(fast=args.fast)
        print_rows(rows)
        all_rows.extend(dict(section=name, git_sha=sha, timestamp=stamp, **r) for r in rows)

    if args.json:
        meta = {
            "backend": jax.default_backend(),
            "fast": bool(args.fast),
            "jax": jax.__version__,
            "git_sha": sha,
            "timestamp": stamp,
        }
        with open(args.json, "w") as fh:
            json.dump({"meta": meta, "rows": all_rows}, fh, indent=2)
        print(f"# wrote {len(all_rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
