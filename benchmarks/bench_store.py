"""Plan-store benchmark: checkpoint write/load and the warm-boot payoff.

The plan store turns the paper's §2.7 economics *durable*: the O(N²P)
plan build amortises across process restarts, not just requests. Rows:

  store_write          — atomic serialize + commit of one plan
  store_load_warm      — verified read (manifest + digest check +
                         device_put) of the same plan; this is the cost a
                         rebooted replica pays *instead of* the build, so
                         it is gated like any warm row
  coldboot_with_store  — fresh engine + register + first CV workload
                         against a populated store (0 plan builds)
  coldboot_no_store    — same boot with an empty store dir (full rebuild)

``coldboot_*`` rows are wall-clock context, not gated (they include jit
compile time, which the persistent XLA compilation cache — a separate
process-level mechanism — removes in the real boot sequence).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, CVEngine, EngineConfig, PlanStore, Workload


def _boot_and_serve(store_dir, x, folds, lam, y):
    engine = CVEngine(EngineConfig(plan_store=str(store_dir), save_plans=True))
    client = Client(engine)
    handle = client.register(x, folds, lam)
    resp = client.submit(Workload(kind="cv", dataset=handle, y=y))
    jax.block_until_ready(resp.score)
    engine.flush_store()
    return engine


def run(fast: bool = False):
    import tempfile

    rows = []
    n, p = (96, 512) if fast else (256, 4096)
    k, lam = 8, 1.0

    x, yc = synthetic.make_classification(jax.random.PRNGKey(0), n, p, class_sep=2.0)
    y = jnp.where(yc == 0, -1.0, 1.0)
    folds = foldlib.kfold(n, k, seed=0)

    engine = CVEngine()
    key, plan = engine.resolve(engine.register(x, folds, lam))

    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)

        def write_once():
            store.save(key, plan)
            # content-addressed: remove so every rep pays the full commit
            import shutil

            shutil.rmtree(store.path_for(key))

        secs = timeit(write_once, warmup=1, repeats=5)
        store.save(key, plan)
        mib = store.total_bytes() / 2**20
        rows.append(row(f"store_write_N{n}_P{p}", secs, f"{mib:.1f} MiB entry, atomic commit"))

        def load_once():
            loaded = store.load(key)
            assert loaded is not None
            jax.block_until_ready(loaded.h)

        secs = timeit(load_once, warmup=1, repeats=5)
        build = timeit(
            lambda: jax.block_until_ready(engine._build_plan(x, folds, lam, "auto", True).h),
            warmup=1,
            repeats=3,
        )
        rows.append(
            row(
                f"store_load_warm_N{n}_P{p}",
                secs,
                f"verified read; {build / secs:.1f}x cheaper than rebuild",
            )
        )

    # -- cold boot wall clock, with vs without a populated store -----------
    with tempfile.TemporaryDirectory() as d:
        seeded = _boot_and_serve(d, x, folds, lam, y)  # populates the store
        assert seeded.plans_built == 1

        t0 = time.perf_counter()
        warm = _boot_and_serve(d, x, folds, lam, y)
        t_with = time.perf_counter() - t0
        assert warm.plans_built == 0, "populated store must satisfy the boot"
        rows.append(
            row(
                f"coldboot_with_store_N{n}_P{p}",
                t_with,
                f"0 plan builds, {warm.stats()['store_hits']} store hits",
            )
        )

    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        cold = _boot_and_serve(d, x, folds, lam, y)
        t_without = time.perf_counter() - t0
        assert cold.plans_built == 1
        rows.append(
            row(
                f"coldboot_no_store_N{n}_P{p}",
                t_without,
                f"full rebuild; store saves {t_without - t_with:.3f}s/boot",
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(fast=True))
