"""Serving-engine benchmark: cold-plan vs warm-cache latency, and
request throughput vs coalesced batch size.

The headline number is the paper's §2.7 economics made operational: a cold
permutation workload pays the O(N²P) Gram + O(N³) factorisation + jit
compile; a warm one against the cached plan pays only O(K·m²·T) fold
solves through an already-compiled program. At N=256, P=4096, T=256 the
warm path is expected to be well over 50× faster, with zero recompiles
after the first request per shape bucket. The stream speaks the One-API
surface: a dataset registered once, :class:`~repro.serve.Workload` specs
carrying the handle through a sync-transport :class:`~repro.serve.Client`.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, CVEngine, Workload


def run(fast: bool = False):
    rows = []
    n, p, t_perm = (96, 512, 64) if fast else (256, 4096, 256)
    k = 8
    lam = 1.0

    x, yc = synthetic.make_classification(jax.random.PRNGKey(0), n, p, class_sep=2.0)
    y = jnp.where(yc == 0, -1.0, 1.0)
    folds = foldlib.kfold(n, k, seed=0)

    # -- cold: fresh engine; plan build + compile + eval -------------------
    client = Client(CVEngine())
    data = client.register(x, folds, lam)
    perm = Workload(kind="permutation", dataset=data, y=y, n_perm=t_perm, seed=0)
    t0 = time.perf_counter()
    jax.block_until_ready(client.submit(perm).null)
    t_cold = time.perf_counter() - t0
    rows.append(row(f"serve_perm_cold_N{n}_P{p}_T{t_perm}", t_cold, "plan build + compile + eval"))

    # -- warm: cached plan, compiled program -------------------------------
    engine = client.engine
    compiles_warm = engine.compile_count()

    def warm_once():
        return client.submit(perm).null

    t_warm = timeit(warm_once, warmup=1, repeats=5)
    recompiles = engine.compile_count() - compiles_warm
    rows.append(
        row(
            f"serve_perm_warm_N{n}_P{p}_T{t_perm}",
            t_warm,
            f"speedup={t_cold / t_warm:.0f}x recompiles={recompiles}",
        )
    )

    # -- requests/s vs coalesced batch size --------------------------------
    for bs in (1, 8, 32):
        batch = [
            Workload(kind="cv", dataset=data, y=jnp.roll(y, i), estimator="binary")
            for i in range(bs)
        ]

        def cv_batch():
            return [r.values for r in client.gather(batch)]

        secs = timeit(cv_batch, warmup=1, repeats=5)
        rows.append(row(f"serve_cv_warm_batch{bs}_N{n}_P{p}", secs, f"{bs / secs:.0f} req/s"))

    # -- handle-scoped stats: the per-dataset residency view ---------------
    # (not gated: a dict walk, timed for the record; the derived column
    # documents what the serving session actually held resident)
    t0 = time.perf_counter()
    per = engine.dataset_stats()
    t_stats = time.perf_counter() - t0
    (rec,) = per.values()
    rows.append(
        row(
            "serve_handle_stats",
            t_stats,
            f"1 dataset: served={rec['served']} "
            f"plan_bytes={rec['plan_bytes']} resident={rec['resident']}",
        )
    )
    return rows
