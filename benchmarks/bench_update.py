"""Incremental plan updates: rank-k correction vs from-scratch rebuild.

The ``kind="update"`` economics: appending/retiring a handful of rows
costs a host-numpy rank-k Woodbury correction (O(N²·k)), not the O(N²P)
Gram rebuild — and a steady-state sliding window advances versions with
zero new XLA programs. Rows:

  update_append_warm     — one rank-K append correction of a prepared
                           plan (host float64, no device work); gated
  update_rebuild_cold    — from-scratch ``prepare`` at the appended
                           size: what the correction replaces (includes
                           device transfer; context, not gated)
  update_window_steady_warm — engine-level sliding-window advance
                           (retire oldest test slot per fold + append),
                           steady-state median over several versions;
                           gated. ``derived`` reports the p95 and that
                           the advance stayed compile-flat.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import percentiles, row, timeit
from repro.core import fastcv, folds as foldlib
from repro.serve import CVEngine, EngineConfig


def run(fast: bool = False):
    rows = []
    # the correction is O(N²·k) host work vs the O(N²P) Gram rebuild, so
    # it only pays at serving-sized P — bench at the sizes it targets
    n, p = (128, 4096) if fast else (256, 8192)
    k_folds, lam = 8, 1.0
    steps = 6 if fast else 12

    x_all = jax.random.normal(jax.random.PRNGKey(3), (n + k_folds, p), dtype=jnp.float64)
    x0 = x_all[:n]
    folds = foldlib.kfold(n, k_folds, seed=0)
    plan = fastcv.prepare(x0, folds, lam, mode="dual", with_train_block=True)

    x0_np = np.asarray(x0)
    x_new = np.asarray(x_all[n:])
    assign = np.arange(k_folds) % k_folds

    secs_up = timeit(
        lambda: fastcv.update_plan(plan, x_new, assign, x=x0_np, lam=lam), warmup=1, repeats=5
    )

    folds_after = foldlib.kfold(n + k_folds, k_folds, seed=0)
    secs_rebuild = timeit(
        lambda: fastcv.prepare(x_all, folds_after, lam, mode="dual", with_train_block=True),
        warmup=1,
        repeats=3,
    )

    speedup = secs_rebuild / max(secs_up, 1e-9)
    rows.append(
        row(
            f"update_append_warm_N{n}_P{p}_k{k_folds}",
            secs_up,
            f"rank-{k_folds} correction; {speedup:.1f}x cheaper than rebuild",
        )
    )
    rows.append(
        row(
            f"update_rebuild_cold_N{n + k_folds}_P{p}",
            secs_rebuild,
            "from-scratch prepare the correction replaces",
        )
    )

    # -- engine-level sliding window, steady state -------------------------
    engine = CVEngine(EngineConfig(cache_bytes=256 << 20))
    handle = engine.register(x0, folds, lam)
    rng = np.random.default_rng(0)

    def advance(h):
        te = np.asarray(jax.device_get(engine.dataset_record(h).folds.te_idx))
        fresh = jnp.asarray(rng.normal(size=(k_folds, p)))
        return engine.update_dataset(h, x_new=fresh, drop_idx=te[:, 0])

    handle = advance(handle)  # absorb first-advance overheads
    compiles_warm = engine.compile_count()
    samples = []
    for _ in range(steps):
        t0 = time.perf_counter()
        handle = advance(handle)
        samples.append(time.perf_counter() - t0)
    pct = percentiles(samples)
    flat = engine.compile_count() == compiles_warm
    rows.append(
        row(
            f"update_window_steady_warm_N{n}_P{p}_k{k_folds}",
            pct["p50"],
            f"p95={pct['p95'] * 1e3:.2f}ms over {steps} advances, "
            f"version={handle.version}, compile_flat={flat}",
        )
    )
    return rows


if __name__ == "__main__":
    for r in run(fast=True):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
