"""Restart-durability smoke against a *live* ``serve_cv --http`` server.

Two phases against the same ``--state-dir``, bracketing a ``kill -9``:

    # boot A: --plan-store pstore --compilation-cache xcache --save-plans
    python benchmarks/restart_smoke.py --phase warm --url $URL --state-dir st
    kill -9 $SERVER_PID
    # boot B: same dirs + --warmup-from st/traffic.json
    python benchmarks/restart_smoke.py --phase restart --url $URL \\
        --state-dir st --json restart-smoke.json

``warm`` registers a deterministic dataset, submits one workload per
warmed estimator family, records the traffic client-side (SIGKILL never
reaches the server's ``--record-traffic`` dump) and snapshots every
response bit-exactly. ``restart`` then proves the rebooted process
reached steady state *from disk alone*:

  * ``plans_built == 0`` — every plan (boot warm-up replays and first
    wire traffic) was loaded from the plan store, never rebuilt;
  * ``store_hits > 0`` and zero quarantined entries — the loads were
    verified reads, not silent cache misses;
  * ``compile_events`` stays flat across first wire traffic — the
    ``--warmup-from`` replay plus the persistent XLA compilation cache
    covered every program this traffic needs;
  * every response is **bit-identical** to its pre-kill snapshot.

Exit status: 0 conformant, 1 any restart-durability regression.
"""

from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import re
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import HTTPClient, Workload
from repro.serve.batching import DEFAULT_BUCKETS
from repro.serve.http import response_to_dict
from repro.serve.workload import TrafficLog

EXPECTED = "expected.json"
TRAFFIC = "traffic.json"


def _wait_healthy(client: HTTPClient, timeout_s: float) -> float:
    t0 = time.monotonic()
    deadline = t0 + timeout_s
    while True:
        try:
            if client.healthz().get("status") == "ok":
                return time.monotonic() - t0
        except Exception:  # noqa: BLE001 - server still booting
            pass
        if time.monotonic() > deadline:
            raise SystemExit(f"server not healthy after {timeout_s:.0f}s")
        time.sleep(0.25)


def _compile_events(client: HTTPClient) -> int:
    m = re.search(r"^compile_events (\d+)$", client.metrics_text(), re.M)
    assert m, "compile_events missing from /v1/metrics"
    return int(m.group(1))


def _register(client: HTTPClient, args):
    """Deterministic dataset + the workload set both phases replay."""
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(7), args.n, args.p, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    folds = foldlib.kfold(args.n, args.k, seed=3)
    handle = client.register(
        np.asarray(x), (np.asarray(folds.te_idx), np.asarray(folds.tr_idx)), args.lam
    )
    workloads = [
        ("cv/binary", Workload(kind="cv", dataset=handle, y=y)),
        ("cv/ridge", Workload(kind="cv", dataset=handle, y=y, estimator="ridge")),
        (
            "cv/multiclass",
            Workload(kind="cv", dataset=handle, y=yc, estimator="multiclass", num_classes=3),
        ),
        (
            "permutation",
            Workload(kind="permutation", dataset=handle, y=y, n_perm=args.perm, seed=11),
        ),
    ]
    return handle, workloads


def _canon(d: dict) -> dict:
    """JSON round-trip so in-memory and reloaded snapshots compare equal.

    Drops the tracing-only ``timings`` field (--metrics servers attach
    per-request stage latencies, which legitimately differ across boots);
    every conformance field — values, scores, nulls, plan_key — stays.
    """
    d = dict(d)
    d.pop("timings", None)
    return json.loads(json.dumps(d))


def phase_warm(client: HTTPClient, args, state: pathlib.Path) -> list[dict]:
    _, workloads = _register(client, args)
    log = TrafficLog()
    expected = {}
    for name, w in workloads:
        expected[name] = _canon(response_to_dict(client.submit(w)))
        # Client-side record: SIGKILL kills the server before its own
        # --record-traffic shutdown dump could ever run.
        log.record(w, DEFAULT_BUCKETS)
    (state / EXPECTED).write_text(json.dumps(expected, indent=2))
    log.save(state / TRAFFIC)
    print(
        f"[restart_smoke] warm: {len(expected)} responses snapshotted, "
        f"{len(log)} traffic entries -> {state}"
    )

    # Write-behind is async; poll until the store has absorbed the plan.
    deadline = time.monotonic() + 30.0
    while True:
        eng = client.stats()["engine"]
        if eng["store_writes"] >= 1:
            break
        if time.monotonic() > deadline:
            raise SystemExit("plan store absorbed no writes within 30s of traffic")
        time.sleep(0.25)
    print(
        f"[restart_smoke] warm: {eng['store_writes']} plan(s) persisted, "
        f"{eng['store_bytes'] / 2**20:.1f} MiB on disk — ready for kill -9"
    )
    return []


def phase_restart(client: HTTPClient, args, state: pathlib.Path, t_boot: float) -> list[dict]:
    expected = json.loads((state / EXPECTED).read_text())

    # Registration is content-addressed: the same bytes must resolve to
    # the same handle, or the plan store could never have matched.
    eng0 = client.stats()["engine"]
    compiles0 = _compile_events(client)
    _, workloads = _register(client, args)

    t_first = []
    for name, w in workloads:
        t0 = time.perf_counter()
        got = _canon(response_to_dict(client.submit(w)))
        t_first.append(time.perf_counter() - t0)
        assert got == expected[name], f"{name}: response differs from pre-kill snapshot"
    print(f"[restart_smoke] {len(workloads)} responses bit-identical across kill -9")

    eng = client.stats()["engine"]
    compiles = _compile_events(client)
    assert eng["plans_built"] == 0, (
        f"rebooted server rebuilt {eng['plans_built']} plan(s); "
        f"store: {eng['store_hits']} hits / {eng['store_misses']} misses"
    )
    assert eng["store_hits"] > 0, "restart served traffic without a single store hit"
    assert compiles == compiles0, (
        f"compile_events moved {compiles0} -> {compiles} on first post-restart "
        f"traffic; --warmup-from + compilation cache did not cover it"
    )
    print(
        f"[restart_smoke] steady state from disk: 0 plans built, "
        f"{eng['store_hits']} store hits, compile_events flat at {compiles} "
        f"(boot {eng0['store_hits']} hits before first wire traffic)"
    )

    def smoke_row(name, seconds, derived):
        return dict(section="restart-smoke", **row(name, seconds, derived))

    return [
        smoke_row(
            f"restart_boot_healthy_N{args.n}_P{args.p}",
            t_boot,
            f"kill -9 -> healthy with --warmup-from; {eng0['store_hits']} "
            f"plans from store at boot",
        ),
        smoke_row(
            f"restart_first_traffic_{len(workloads)}req",
            float(np.median(t_first)),
            f"median submit; 0 plan builds, compile_events flat at {compiles}",
        ),
    ]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--phase", required=True, choices=("warm", "restart"))
    ap.add_argument("--url", required=True, help="base URL of a serve_cv --http server")
    ap.add_argument(
        "--state-dir",
        required=True,
        help="directory carrying expected.json + traffic.json across the kill",
    )
    ap.add_argument("--json", default=None, metavar="PATH", help="latency artifact path")
    ap.add_argument("--n", type=int, default=96, help="samples (match server --n)")
    ap.add_argument("--p", type=int, default=256, help="features")
    ap.add_argument("--k", type=int, default=6, help="folds (match server --k)")
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--perm", type=int, default=64, help="permutation draws")
    ap.add_argument("--boot-timeout", type=float, default=180.0)
    args = ap.parse_args()

    state = pathlib.Path(args.state_dir)
    state.mkdir(parents=True, exist_ok=True)
    client = HTTPClient(args.url)
    t_boot = _wait_healthy(client, args.boot_timeout)
    print(f"[restart_smoke] {args.url} healthy after {t_boot:.2f}s ({args.phase} phase)")

    if args.phase == "warm":
        rows = phase_warm(client, args, state)
    else:
        rows = phase_restart(client, args, state, t_boot)

    for r in rows:
        print(f"[restart_smoke] {r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.json and rows:
        meta = {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "url": args.url,
            "phase": args.phase,
            "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(timespec="seconds"),
        }
        with open(args.json, "w") as fh:
            json.dump({"meta": meta, "rows": rows}, fh, indent=2)
        print(f"[restart_smoke] wrote {len(rows)} rows to {args.json}")
    print(f"[restart_smoke] {args.phase} OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
