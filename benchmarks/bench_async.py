"""Async serving benchmark: concurrent mixed-task clients vs sequential serve.

The async front-end's pitch is traffic shaping, not raw speed: N clients
awaiting one Workload at a time coalesce inside the gather window onto
shared plans and shared padded evals, so aggregate throughput beats
serving the same stream sequentially (one eval per request), with zero
recompiles after ``engine.warmup()`` pre-compiled the bucketed eval
family. Streaming turns a monolithic permutation response into
prefix-stable null chunks — time-to-first-chunk is the latency a client
actually waits before it can start updating a running p-value. All
traffic speaks the One-API surface (registered DatasetHandles + Workload
specs through :class:`~repro.serve.Client`).
"""

from __future__ import annotations

import asyncio
import time
from statistics import median

import jax
import jax.numpy as jnp

from benchmarks.common import percentiles, row
from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, CVEngine, Workload

N_CLIENTS = 8


def _datasets(engine, n, p, seed=0):
    datasets = []
    for d in range(2):
        num_classes = 2 if d == 0 else 3
        x, yc = synthetic.make_classification(
            jax.random.PRNGKey(seed + d), n, p, num_classes=num_classes, class_sep=2.0
        )
        handle = engine.register(x, foldlib.kfold(n, 6, seed=d), 1.0)
        y_bin = jnp.where(yc % 2 == 0, -1.0, 1.0)
        datasets.append((handle, y_bin, yc, num_classes))
    return datasets


def _client_workloads(datasets, per_client, t_perm, cid):
    """One client's mixed-task stream: mostly cheap CV queries (the
    coalescable traffic class) plus one permutation test (served as its
    own bucketed eval in both drivers, so it can't coalesce)."""
    work = []
    for i in range(per_client):
        handle, y_bin, yc, c = datasets[(cid + i) % len(datasets)]
        slot = i % 8
        if slot == 7:
            work.append(
                Workload(
                    kind="permutation",
                    dataset=handle,
                    y=y_bin,
                    n_perm=t_perm,
                    seed=cid * 97 + i,
                )
            )
        elif slot in (5, 6) and c > 2:
            work.append(
                Workload(
                    kind="cv",
                    dataset=handle,
                    y=yc,
                    estimator="multiclass",
                    num_classes=c,
                )
            )
        elif slot in (3, 4):
            work.append(
                Workload(
                    kind="cv",
                    dataset=handle,
                    y=jnp.roll(y_bin, i + cid),
                    estimator="ridge",
                )
            )
        else:
            work.append(
                Workload(
                    kind="cv",
                    dataset=handle,
                    y=jnp.roll(y_bin, i + cid),
                    estimator="binary",
                )
            )
    return work


def _ready(resp):
    jax.block_until_ready(resp.values if hasattr(resp, "values") else resp.null)


def run(fast: bool = False):
    rows = []
    n, p, t_perm, per_client = (96, 512, 32, 8) if fast else (192, 2048, 64, 12)
    engine = CVEngine()
    datasets = _datasets(engine, n, p)
    n_req = N_CLIENTS * per_client

    # -- warm-up: pre-build + pin plans, pre-compile the bucketed family ---
    t0 = time.perf_counter()
    for handle, _, _, c in datasets:
        tasks = ("binary", "ridge", "permutation")
        if c > 2:
            tasks = tasks + ("multiclass",)
        engine.warmup(handle, tasks, buckets=(1, 2, 4, 8, 16, t_perm), num_classes=c, pin=True)
    t_warm = time.perf_counter() - t0
    compiles0 = engine.compile_count()
    # NB: named "startup", not "warmup" — this row times plan builds + jit
    # compiles, the noisy class compare.py's "warm"-substring gate must skip.
    rows.append(row(f"async_startup_N{n}_P{p}", t_warm, f"compiles={compiles0} plans pinned"))

    # Medians over REPEATS full runs — a single wall-clock sample of a
    # concurrent workload is scheduling noise. These rows deliberately
    # omit 'warm' from their names: concurrency wall-clock swings 2-4x
    # with process state, far past compare.py's 1.5x merge gate, which
    # should gate only the stable compute-bound warm rows.
    repeats = 3

    # -- sequential baseline: the same stream, one workload at a time ------
    sync_client = Client(engine)
    all_work = []
    for cid in range(N_CLIENTS):
        all_work.extend(_client_workloads(datasets, per_client, t_perm, cid))

    def sequential_once():
        t0 = time.perf_counter()
        for w in all_work:
            _ready(sync_client.submit(w))
        return time.perf_counter() - t0

    t_seq = median(sequential_once() for _ in range(repeats))
    rows.append(
        row(
            f"async_sequential_{n_req}req",
            t_seq,
            f"{n_req / t_seq:.0f} req/s (sync Client one-by-one)",
        )
    )

    # -- async transport: N concurrent clients, gather-window coalescing ---
    latencies = []

    async def timed_submit(client, w):
        t = time.perf_counter()
        _ready(await client.submit(w))
        latencies.append(time.perf_counter() - t)

    async def one_client(client, cid):
        # a client pipelines its whole stream (no await between submits) —
        # that concurrency is what fills the gather window with work
        work = _client_workloads(datasets, per_client, t_perm, cid)
        await asyncio.gather(*(timed_submit(client, w) for w in work))

    async def drive():
        async with Client(engine, transport="async", max_batch=64, gather_window_ms=3.0) as client:
            t = time.perf_counter()
            await asyncio.gather(*(one_client(client, cid) for cid in range(N_CLIENTS)))
            wall = time.perf_counter() - t
            return wall, client.server.batches_served

    runs = [asyncio.run(drive()) for _ in range(repeats)]
    t_async = median(wall for wall, _ in runs)
    batches = runs[0][1]
    recompiles = engine.compile_count() - compiles0
    pct = percentiles(latencies, (50, 95))
    rows.append(
        row(
            f"async_{N_CLIENTS}clients_{n_req}req",
            t_async,
            f"{n_req / t_async:.0f} req/s in {batches} batches recompiles={recompiles} "
            f"p50={pct['p50'] * 1e3:.1f}ms p95={pct['p95'] * 1e3:.1f}ms "
            f"vs sequential {t_seq / t_async:.2f}x",
        )
    )

    # -- streaming: time-to-first-null-chunk vs the monolithic response ----
    handle, y_bin = datasets[0][0], datasets[0][1]
    t_stream = 4 * t_perm  # long-running workload worth streaming
    stream_w = Workload(kind="permutation", dataset=handle, y=y_bin, n_perm=t_stream, seed=5)

    async def drive_stream():
        async with Client(engine, transport="async", stream_chunk=t_perm) as client:
            t = time.perf_counter()
            t_first = None
            async for ev in client.stream(stream_w):
                if ev.kind == "null" and t_first is None:
                    jax.block_until_ready(ev.payload)
                    t_first = time.perf_counter() - t
            return t_first, time.perf_counter() - t

    stream_runs = [asyncio.run(drive_stream()) for _ in range(repeats)]
    t_first = median(first for first, _ in stream_runs)
    t_full = median(full for _, full in stream_runs)
    rows.append(
        row(
            f"async_stream_first_chunk_T{t_stream}",
            t_first,
            f"first {t_perm}/{t_stream} null draws; full stream {t_full * 1e3:.1f}ms "
            f"({t_full / t_first:.1f}x first-chunk latency)",
        )
    )
    return rows
