"""Latency-budget benchmark: warm per-stage stage breakdown via tracing.

The observability layer answers "where do a request's milliseconds go?";
this module turns that answer into benchmark rows. For every workload
kind (cv, permutation, rsa, tune, grid) it submits warm requests with
tracing enabled and reports the end-to-end median plus the median of
every traced stage, so a regression is attributable to *a stage* —
plan_build leaking into the warm path, eval losing its compiled program,
encode suddenly copying — rather than to an opaque total.

Row naming is deliberate: ``latency_{kind}_warm_total`` and
``latency_{kind}_warm_eval`` carry the "warm" tag so compare.py gates
them (stable, compute-bound); the per-stage rows
(``latency_{kind}_stage_{stage}``) and the wire set
(``latency_http_...``) avoid it — micro-stage and socket timings swing
far past the 1.5x gate on shared CI runners and are for attribution,
not gating. The ``latency_tracing_overhead`` row pins the acceptance
claim that tracing-off submissions pay no measurable cost.

Standalone (CI's bench-smoke artifact):

    PYTHONPATH=src:. python benchmarks/bench_latency.py --fast --json out.json
"""

from __future__ import annotations

import time
from statistics import median

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import folds as foldlib
from repro.data import synthetic
from repro.serve import Client, CVEngine, DatasetSpec, Workload
from repro.serve.http import EdgeThread, HTTPClient
from repro.serve.trace import STAGES


def _kind_workloads(handle, f, x, y, yc, t_perm, lam):
    return {
        "cv": Workload(kind="cv", dataset=handle, y=y, estimator="binary"),
        "permutation": Workload(
            kind="permutation", dataset=handle, y=y, n_perm=t_perm, seed=0
        ),
        "rsa": Workload(
            kind="rsa",
            dataset=handle,
            y=yc,
            num_classes=3,
            model_rdms=jnp.ones((1, 3, 3)),
            n_perm=t_perm,
            seed=1,
        ),
        "tune": Workload(kind="tune", x=x, y=y),
        "grid": Workload(
            kind="grid", dataset=DatasetSpec(None, f, lam), y=y, xs=jnp.stack([x])
        ),
    }


def _stage_rows(prefix, reps_timings, totals, rows, gate_total=True):
    """Median total + per-stage medians over a list of timings dicts."""
    t_total = median(totals)
    # Report in the canonical STAGES order (the tracer's vocabulary), so
    # rows line up across runs regardless of which stages actually fired.
    seen = {s for t in reps_timings for s in t}
    stages = [s for s in STAGES if s in seen] + sorted(seen - set(STAGES))
    budget = {s: median(t.get(s, 0.0) for t in reps_timings) for s in stages}
    covered = sum(budget.values()) / t_total if t_total else 0.0
    if gate_total:
        rows.append(
            row(
                f"{prefix}_warm_total",
                t_total,
                f"stage sum covers {covered * 100:.1f}% of end-to-end",
            )
        )
        eval_s = budget.get("eval", 0.0) + budget.get("null_chunk", 0.0)
        rows.append(
            row(
                f"{prefix}_warm_eval",
                eval_s,
                f"eval+null_chunk share {eval_s / t_total * 100:.0f}%",
            )
        )
    else:
        rows.append(
            row(
                f"{prefix}_total",
                t_total,
                f"stage sum covers {covered * 100:.1f}% of end-to-end",
            )
        )
    for stage in stages:
        rows.append(
            row(
                f"{prefix}_stage_{stage}",
                budget[stage],
                f"{budget[stage] / t_total * 100:.1f}% of {prefix} budget",
            )
        )


def run(fast: bool = False):
    rows = []
    n, p, t_perm, reps = (96, 512, 32, 12) if fast else (192, 2048, 128, 32)
    k, lam = 6, 1.0
    x, yc = synthetic.make_classification(
        jax.random.PRNGKey(0), n, p, num_classes=3, class_sep=2.0
    )
    y = jnp.where(yc % 2 == 0, -1.0, 1.0)
    f = foldlib.kfold(n, k, seed=0)

    engine = CVEngine()
    client = Client(engine)
    handle = client.register(x, f, lam)
    kinds = _kind_workloads(handle, f, x, y, yc, t_perm, lam)

    # Warm every plan + program with tracing OFF, then measure the
    # tracing-off warm path as the overhead reference.
    for w in kinds.values():
        client.submit(w)
    t_off = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(client.submit(kinds["cv"]).values)
        t_off.append(time.perf_counter() - t0)

    engine.enable_tracing(ring=max(64, reps * len(kinds)))
    compiles = engine.compile_count()
    for kind, w in kinds.items():
        timings, totals = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            resp = client.submit(w)
            totals.append(time.perf_counter() - t0)
            timings.append(resp.timings)
        _stage_rows(f"latency_{kind}", timings, totals, rows)
    assert engine.compile_count() == compiles, "tracing must not add compiles"

    # Overhead of the *instrumentation points* with tracing back off:
    # the acceptance bar is <2% on warm medians.
    engine.disable_tracing()
    t_off2 = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(client.submit(kinds["cv"]).values)
        t_off2.append(time.perf_counter() - t0)
    rows.append(
        row(
            "latency_tracing_overhead",
            abs(median(t_off2) - median(t_off)),
            f"off-before={median(t_off) * 1e3:.2f}ms "
            f"off-after={median(t_off2) * 1e3:.2f}ms "
            f"(ratio {median(t_off2) / median(t_off):.3f})",
        )
    )

    # -- the same budget over the wire (not gated: socket-noisy) ----------
    http_engine = CVEngine()
    with EdgeThread(http_engine) as edge, HTTPClient(edge.url) as hclient:
        hh = hclient.register(
            np.asarray(x), (np.asarray(f.te_idx), np.asarray(f.tr_idx)), lam
        )
        wcv = Workload(kind="cv", dataset=hh, y=y, estimator="binary")
        hclient.submit(wcv)  # warm
        http_engine.enable_tracing()
        timings, totals = [], []
        for _ in range(max(6, reps // 2)):
            t0 = time.perf_counter()
            resp = hclient.submit(wcv)
            totals.append(time.perf_counter() - t0)
            timings.append(resp.timings)
        _stage_rows("latency_http_cv", timings, totals, rows, gate_total=False)
    return rows


def main() -> None:
    """Standalone entry for CI's bench-smoke artifact (run.py embeds the
    same rows under the ``latency(stage-budget)`` section)."""
    import argparse
    import json as json_mod

    from benchmarks.common import print_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    rows = run(fast=args.fast)
    print("name,us_per_call,derived")
    print_rows(rows)
    if args.json:
        payload = {
            "meta": {"backend": jax.default_backend(), "fast": bool(args.fast)},
            "rows": [dict(section="latency(stage-budget)", **r) for r in rows],
        }
        with open(args.json, "w") as fh:
            json_mod.dump(payload, fh, indent=2)


if __name__ == "__main__":
    main()
