"""Paper Fig. 3a (left): binary LDA cross-validation relative efficiency.

Sweeps features P (log steps), samples N, and folds K (incl. LOO), timing
the standard approach (retrain per fold) against the analytical approach.
Reported value: relative efficiency = log10(t_standard / t_analytical).
Sizes are scaled to the 1-core CPU container (DESIGN.md §8); the paper's
qualitative claims to verify: efficiency grows with P and K, shrinks
with N, and the approaches are at parity when P ≈ N/K.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fastcv, folds as foldlib, lda
from repro.data import synthetic
from benchmarks.common import relative_efficiency, row, timeit

FEATURES = (16, 64, 256, 1024)
CONFIGS = (
    # (N, folds or "loo")
    (64, 5),
    (64, "loo"),
    (256, 5),
    (256, 10),
)


def run(fast: bool = False):
    rows = []
    feats = FEATURES[:3] if fast else FEATURES
    for n, k in CONFIGS[:2] if fast else CONFIGS:
        f = foldlib.loo(n) if k == "loo" else foldlib.kfold(n, k, seed=0)
        kname = "loo" if k == "loo" else f"k{k}"
        for p in feats:
            x, yc = synthetic.make_classification(jax.random.PRNGKey(p), n, p)
            y = jnp.where(yc == 0, -1.0, 1.0)
            lam = 1.0

            t_std = timeit(lambda: lda.standard_cv_binary(x, y, f, lam=lam), repeats=2)
            t_ana = timeit(lambda: fastcv.binary_cv(x, y, f, lam=lam), repeats=2)
            rel = relative_efficiency(t_std, t_ana)
            rows.append(
                row(
                    f"cv_binary/n{n}_{kname}_p{p}",
                    t_ana,
                    f"rel_eff={rel:.2f} t_std={t_std*1e3:.1f}ms t_ana={t_ana*1e3:.1f}ms",
                )
            )
    return rows
