"""Paper Table 1: empirical validation of the complexity claims.

Standard binary CV is O(KNP² + KP³): doubling P at fixed (N, K) should
scale time ~P²..P³. The analytical approach is O(KN³) after the hat
matrix: time should be ~flat in P (the O(N²P) Gram is the only P term).
We fit the log-log slope of time vs P for both and report it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastcv, folds as foldlib, lda
from repro.data import synthetic
from benchmarks.common import row, timeit

N, K = 128, 8
PS = (64, 128, 256, 512, 1024)


def run(fast: bool = False):
    ps = PS[:3] if fast else PS
    f = foldlib.kfold(N, K, seed=0)
    t_std, t_ana = [], []
    for p in ps:
        x, yc = synthetic.make_classification(jax.random.PRNGKey(p), N, p)
        y = jnp.where(yc == 0, -1.0, 1.0)
        t_std.append(timeit(lambda: lda.standard_cv_binary(x, y, f, lam=1.0), repeats=2))
        t_ana.append(timeit(lambda: fastcv.binary_cv(x, y, f, lam=1.0), repeats=2))
    lp = np.log(np.asarray(ps, float))
    slope_std = float(np.polyfit(lp, np.log(t_std), 1)[0])
    slope_ana = float(np.polyfit(lp, np.log(t_ana), 1)[0])
    return [
        row(
            "complexity/standard_scaling_vs_P",
            t_std[-1],
            f"loglog_slope={slope_std:.2f} (theory 2..3, O(KNP^2+KP^3))",
        ),
        row(
            "complexity/analytical_scaling_vs_P",
            t_ana[-1],
            f"loglog_slope={slope_ana:.2f} (theory <=1, O(N^2 P) setup only)",
        ),
    ]
