"""Paper Fig. 4: permutation analysis of the EEG/MEG-style dataset.

Simulated Wakeman-Henson-shaped data (380 channels; DESIGN.md §8 —
the real dataset is not available offline): per-subject permutation
testing with 10-fold CV, binary (faces vs scrambled) and 3-class LDA,
at the paper's two feature scales:

  binary:      380 (one time point)  and 3800 (10 × 100 ms windows)
  multi-class: 380                   and 1900 (5 × 200 ms windows)

Standard-approach cost is measured on a reduced permutation count and
scaled per-permutation (the paper's T=100 at P=3800 would take hours on
this container's single core — which is precisely the paper's point).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import folds as foldlib, permutation
from repro.data import eeg
from benchmarks.common import relative_efficiency, row, timeit

N_TRIALS = 256  # paper: ~787/subject; reduced for the 1-core container
T_FULL = 100  # paper's permutation count
T_MEAS = 2


def run(fast: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    ds2 = eeg.simulate_subject(jax.random.PRNGKey(1), n_trials=N_TRIALS, num_classes=2)
    ds3 = eeg.simulate_subject(jax.random.PRNGKey(2), n_trials=N_TRIALS, num_classes=3)
    f = foldlib.kfold(N_TRIALS, 10, seed=0)
    lam = 1.0

    cases = [("binary_p380", ds2, eeg.timepoint_features(ds2, t_index=135), 2)]
    if not fast:
        cases += [
            ("binary_p3800", ds2, eeg.windowed_features(ds2, 100.0), 2),
            ("multiclass_p1900", ds3, eeg.windowed_features(ds3, 200.0), 3),
        ]

    for name, ds, feats, c in cases:
        x = feats.astype(jnp.float64)
        if c == 2:
            y = jnp.where(ds.y == 0, -1.0, 1.0)
            t_ana = timeit(
                lambda: permutation.analytical_permutation_binary(
                    x, y, f, lam, n_perm=T_FULL, key=key, chunk=50
                ),
                repeats=1,
            )
            t_std_m = timeit(
                lambda: permutation.standard_permutation_binary(
                    x, y, f, lam, n_perm=T_MEAS, key=key
                ),
                repeats=1,
            )
        else:
            t_ana = timeit(
                lambda: permutation.analytical_permutation_multiclass(
                    x, ds.y, f, c, lam, n_perm=T_FULL, key=key, chunk=10
                ),
                repeats=1,
            )
            t_std_m = timeit(
                lambda: permutation.standard_permutation_multiclass(
                    x, ds.y, f, c, lam, n_perm=T_MEAS, key=key
                ),
                repeats=1,
            )
        t_std = t_std_m * (T_FULL / T_MEAS)
        rel = relative_efficiency(t_std, t_ana)
        rows.append(
            row(
                f"eeg/{name}_T{T_FULL}",
                t_ana,
                f"rel_eff={rel:.2f} t_std_scaled={t_std:.1f}s t_ana={t_ana:.2f}s",
            )
        )

    # sanity: the evoked signal is actually decodable (observed > chance).
    # Windowed features average the mixed noise over 20 samples — the same
    # SNR gain the paper's windowed analysis exploits.
    ds_hi = eeg.simulate_subject(jax.random.PRNGKey(9), n_trials=N_TRIALS, num_classes=2, snr=2.0)
    x_win = eeg.windowed_features(ds_hi, 100.0).astype(jnp.float64)
    y = jnp.where(ds_hi.y == 0, -1.0, 1.0)
    res = permutation.analytical_permutation_binary(x_win, y, f, lam, n_perm=50, key=key)
    rows.append(
        row(
            "eeg/decodability_check",
            0.0,
            f"observed_acc={float(res.observed):.3f} p={float(res.p):.3f}",
        )
    )
    return rows
