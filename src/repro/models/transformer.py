"""Generic decoder trunk: pattern superblocks, scan-over-layers, caches.

Architectures are described by ``cfg.layer_pattern`` (e.g. gemma2 =
("local", "attn"), recurrentgemma = ("rglru", "rglru", "local")). Layers
are grouped into *repeats* of the pattern; parameters of each pattern
position are stacked over repeats and the whole trunk runs as one
``lax.scan`` (+ per-repeat ``jax.checkpoint`` in training) — compile time
and HLO size are O(pattern), not O(num_layers). Layers beyond the last
full repeat ("tail") run unscanned.

Block kinds: attn | local | cross | rglru | slstm | mlstm. Every kind is a
pre-norm residual mixer; attention-family blocks are followed by a second
residual MLP/MoE sub-block (xLSTM kinds are self-contained, cfg.d_ff == 0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rg
from repro.models import xlstm as xl

ATTN_KINDS = ("attn", "local", "cross")


# ----------------------------------------------------------------- blocks --

def init_block(key, cfg: ArchConfig, kind: str):
    ks = jax.random.split(key, 4)
    p: dict = {"pre_norm": L.init_norm(cfg)}
    if kind in ATTN_KINDS:
        p["attn"] = L.init_attention(ks[0], cfg, cross=(kind == "cross"))
        if kind == "cross":
            p["gate_attn"] = jnp.zeros((), jnp.float32)
            p["gate_mlp"] = jnp.zeros((), jnp.float32)
    elif kind == "rglru":
        p.update(rg.init_recurrent_block(ks[0], cfg))
    elif kind == "mlstm":
        p["mlstm"] = xl.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = xl.init_slstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        p["post_norm"] = L.init_norm(cfg)
    if kind not in ("mlstm", "slstm"):
        p["pre_mlp_norm"] = L.init_norm(cfg)
        if cfg.moe_experts:
            p["moe"] = moe_lib.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
        if cfg.post_norms:
            p["post_mlp_norm"] = L.init_norm(cfg)
    return p


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, cache_len: int):
    """Static-shape decode cache for one block."""
    dt = jnp.dtype(cfg.dtype)
    if kind in ("attn", "local", "cross"):
        if kind == "cross":
            cap = cfg.vision_tokens
        elif kind == "local":
            cap = min(cfg.local_window or cache_len, cache_len)
        else:
            cap = cache_len
        shape = (batch, cap, cfg.num_kv_heads, cfg.head_dim)
        if cfg.kv_quant and kind != "cross":
            return {"k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "k_scale": jnp.zeros(shape[:3], jnp.float32),
                    "v_scale": jnp.zeros(shape[:3], jnp.float32)}
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if kind == "rglru":
        state = rg.init_recurrent_state(cfg, batch)
        cap = min(cfg.local_window or cache_len, cache_len)
        return state
    if kind == "mlstm":
        return xl.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xl.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def _residual(x, out, p, cfg: ArchConfig, post_key: str):
    if cfg.post_norms and post_key in p:
        out = L.apply_norm(p[post_key], out, cfg)
    if cfg.residual_scale is not None:
        out = out * cfg.residual_scale
    return x + out


def apply_block_full(p, x, kind: str, cfg: ArchConfig, *, positions,
                     vis_kv=None):
    """Train/prefill block. Returns (x, cache_init_or_None, aux_loss)."""
    h = L.apply_norm(p["pre_norm"], x, cfg)
    cache = None
    if kind in ATTN_KINDS:
        window = cfg.local_window if kind == "local" else None
        out, (k, v) = L.attention_full(
            p["attn"], h, cfg, positions=positions, window=window,
            kv_src=vis_kv if kind == "cross" else None)
        if kind == "cross":
            out = out * jnp.tanh(p["gate_attn"]).astype(out.dtype)
            cache = {"k": k, "v": v}
        elif cfg.kv_quant:
            kq, ks = L.quantize_kv(k)
            vq, vs = L.quantize_kv(v)
            cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        else:
            cache = {"k": k, "v": v}
    elif kind == "rglru":
        out, _ = rg.apply_recurrent_block(p, h, cfg)
    elif kind == "mlstm":
        out, _ = xl.apply_mlstm(p["mlstm"], h, cfg)
    elif kind == "slstm":
        out, _ = xl.apply_slstm(p["slstm"], h, cfg)
    x = _residual(x, out, p, cfg, "post_norm")

    aux = jnp.zeros((), jnp.float32)
    if kind not in ("mlstm", "slstm"):
        h2 = L.apply_norm(p["pre_mlp_norm"], x, cfg)
        if cfg.moe_experts:
            out2, aux = moe_lib.apply_moe(p["moe"], h2, cfg)
        else:
            out2 = L.apply_mlp(p["mlp"], h2, cfg)
        if kind == "cross":
            out2 = out2 * jnp.tanh(p["gate_mlp"]).astype(out2.dtype)
        x = _residual(x, out2, p, cfg, "post_mlp_norm")
    return x, cache, aux


def apply_block_decode(p, x, kind: str, cfg: ArchConfig, *, pos, cache):
    """Single-token decode block. Returns (x, new_cache)."""
    h = L.apply_norm(p["pre_norm"], x, cfg)
    if kind in ("attn", "local"):
        window = cfg.local_window if kind == "local" else None
        out, new_cache = L.attention_decode(
            p["attn"], h, cfg, cache_k=cache["k"], cache_v=cache["v"],
            pos=pos, window=window,
            cache_k_scale=cache.get("k_scale"),
            cache_v_scale=cache.get("v_scale"))
    elif kind == "cross":
        out = L.cross_attention_decode(p["attn"], h, cfg, cross_k=cache["k"],
                                       cross_v=cache["v"])
        out = out * jnp.tanh(p["gate_attn"]).astype(out.dtype)
        new_cache = cache
    elif kind == "rglru":
        out, new_cache = rg.apply_recurrent_block(p, h, cfg, state=cache)
    elif kind == "mlstm":
        out, new_cache = xl.apply_mlstm(p["mlstm"], h, cfg, state=cache)
    elif kind == "slstm":
        out, new_cache = xl.apply_slstm(p["slstm"], h, cfg, state=cache)
    else:
        raise ValueError(kind)
    x = _residual(x, out, p, cfg, "post_norm")

    if kind not in ("mlstm", "slstm"):
        h2 = L.apply_norm(p["pre_mlp_norm"], x, cfg)
        if cfg.moe_experts:
            out2, _ = moe_lib.apply_moe(p["moe"], h2, cfg)
        else:
            out2 = L.apply_mlp(p["mlp"], h2, cfg)
        if kind == "cross":
            out2 = out2 * jnp.tanh(p["gate_mlp"]).astype(out2.dtype)
        x = _residual(x, out2, p, cfg, "post_mlp_norm")
    return x, new_cache


# ------------------------------------------------------------------ trunk --

def _pattern_split(cfg: ArchConfig):
    pat = cfg.layer_pattern
    n_rep = cfg.num_layers // len(pat)
    tail = cfg.layer_kinds[n_rep * len(pat):]
    return pat, n_rep, tail


def init_trunk(key, cfg: ArchConfig):
    pat, n_rep, tail = _pattern_split(cfg)
    keys = jax.random.split(key, cfg.num_layers + 1)
    stack = []
    for pos, kind in enumerate(pat):
        per_rep = [init_block(keys[r * len(pat) + pos], cfg, kind)
                   for r in range(n_rep)]
        stack.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep)
                     if n_rep > 1 else jax.tree.map(lambda t: t[None], per_rep[0]))
    tail_p = [init_block(keys[n_rep * len(pat) + i], cfg, kind)
              for i, kind in enumerate(tail)]
    return {"stack": stack, "tail": tail_p}


def init_trunk_cache(cfg: ArchConfig, batch: int, cache_len: int):
    pat, n_rep, tail = _pattern_split(cfg)
    stack = []
    for kind in pat:
        one = init_block_cache(cfg, kind, batch, cache_len)
        stack.append(jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (n_rep,) + t.shape), one))
    tail_c = [init_block_cache(cfg, kind, batch, cache_len) for kind in tail]
    return {"stack": stack, "tail": tail_c}


def apply_trunk_full(trunk, x, cfg: ArchConfig, *, positions, vis_kv=None,
                     collect_cache: bool = False):
    """Returns (x, caches_or_None, aux_loss_sum)."""
    pat, n_rep, tail = _pattern_split(cfg)

    def repeat_body(carry, rep_params):
        h, aux = carry
        caches = []
        for pos, kind in enumerate(pat):
            h, cache, a = apply_block_full(rep_params[pos], h, kind, cfg,
                                           positions=positions, vis_kv=vis_kv)
            aux = aux + a
            if collect_cache:
                caches.append(cache)
        return (h, aux), caches if collect_cache else None

    body = repeat_body
    if cfg.remat:
        body = jax.checkpoint(repeat_body, prevent_cse=False)

    (x, aux), stack_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), tuple(trunk["stack"]))

    tail_caches = []
    for i, kind in enumerate(tail):
        x, cache, a = apply_block_full(trunk["tail"][i], x, kind, cfg,
                                       positions=positions, vis_kv=vis_kv)
        aux = aux + a
        if collect_cache:
            tail_caches.append(cache)
    caches = ({"stack": stack_caches, "tail": tail_caches}
              if collect_cache else None)
    return x, caches, aux


def apply_trunk_decode(trunk, x, cfg: ArchConfig, *, pos, caches):
    """Caches ride in the scan CARRY (updated in place with a one-hot-slot
    dynamic_update_slice per repeat) rather than as xs→ys: while-loop
    carries alias their buffers, so the multi-GB KV cache is single-
    buffered instead of holding separate input and output copies."""
    pat, n_rep, tail = _pattern_split(cfg)
    rep_idx = jnp.arange(n_rep)

    def repeat_body(carry, rep_in):
        h, all_caches = carry
        rep_params, r = rep_in
        rep_cache = jax.tree.map(lambda c: c[r], all_caches)
        new_caches = []
        for i, kind in enumerate(pat):
            h, nc = apply_block_decode(rep_params[i], h, kind, cfg, pos=pos,
                                       cache=rep_cache[i])
            new_caches.append(nc)
        all_caches = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), r, 0),
            all_caches, new_caches)
        return (h, all_caches), None

    (x, new_stack), _ = jax.lax.scan(
        repeat_body, (x, caches["stack"]), (tuple(trunk["stack"]), rep_idx))

    new_tail = []
    for i, kind in enumerate(tail):
        x, nc = apply_block_decode(trunk["tail"][i], x, kind, cfg, pos=pos,
                                   cache=caches["tail"][i])
        new_tail.append(nc)
    return x, {"stack": new_stack, "tail": new_tail}
