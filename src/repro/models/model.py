"""Model assembly: embeddings → trunk → head; train / prefill / decode steps.

``init_params(key, cfg)`` builds the full parameter pytree (layout matches
the sharding rules); ``train_step`` / ``prefill_step`` / ``decode_step``
are the three programs the launcher jits and the dry-run lowers.

Modality stubs (per assignment): [vlm] takes precomputed patch embeddings
(B, vision_tokens, vision_dim) through a linear projector feeding the
cross-attention layers; [audio] sums ``num_codebooks`` token embeddings and
predicts each codebook with its own head.

Cross-entropy is computed in the sharded-vocab-friendly masked-reduce form
(no (B,S,V) one-hot materialisation, exact under a "model"-sharded vocab).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.launch.sharding import constrain


def init_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p: dict = {}
    if cfg.num_codebooks:
        p["embed"] = {f"codebook_{i}": L.dense_init(k, (cfg.vocab_size, cfg.d_model), dt)
                      for i, k in enumerate(jax.random.split(ks[0], cfg.num_codebooks))}
    else:
        p["embed"] = {"tokens": L.dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dt)}
    if cfg.vision_tokens:
        p["vision_proj"] = {"w": L.dense_init(ks[1], (cfg.vision_dim, cfg.d_model), dt)}
    p["blocks"] = T.init_trunk(ks[2], cfg)
    p["final_norm"] = L.init_norm(cfg)
    if cfg.num_codebooks:
        for i, k in enumerate(jax.random.split(ks[3], cfg.num_codebooks)):
            p[f"lm_head_{i}"] = L.dense_init(k, (cfg.d_model, cfg.vocab_size), dt)
    elif not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[3], (cfg.d_model, cfg.vocab_size), dt)
    return p


def _embed(params, tokens, cfg: ArchConfig, positions):
    from repro.launch import sharding as _sh
    dt = jnp.dtype(cfg.dtype)

    def lookup(table, ids):
        if _sh.GATHERED_EMBED:
            # force one (V, D) table all-gather instead of letting GSPMD
            # mask-and-psum a (B, S, D) activation per lookup (§Perf)
            table = constrain(table, (None, None))
        return jnp.take(table, ids, axis=0)

    if cfg.num_codebooks:
        # tokens: (B, K, S) — sum codebook embeddings
        parts = [lookup(params["embed"][f"codebook_{i}"], tokens[:, i])
                 for i in range(cfg.num_codebooks)]
        h = sum(parts).astype(dt)
    else:
        h = lookup(params["embed"]["tokens"], tokens).astype(dt)
    # keep the lookup's batch sharding aligned with the DP axes so the
    # backward scatter into the (vocab-sharded) table stays shard-local
    h = constrain(h, ("batch_unembed", "seq", "embed"))
    if cfg.emb_scale is not None:
        h = h * jnp.asarray(cfg.emb_scale, dt)
    if cfg.pos_embedding == "sinusoidal":
        h = h + L.sinusoidal(positions, cfg.d_model).astype(dt)
    return constrain(h, ("batch", "seq", "embed"))


def _unembed(params, h, cfg: ArchConfig):
    """h: (B, S, D) -> logits f32 (B, S, V) or (B, S, K, V) for [audio]."""
    h = L.apply_norm(params["final_norm"], h, cfg)
    # align the unembed batch axes with the vocab-sharded logits: without
    # this the tied-embedding weight gradient all-gathers global (B,S,V)
    hf = constrain(h.astype(jnp.float32), ("batch_unembed", "seq", "embed"))
    if cfg.num_codebooks:
        logits = jnp.stack(
            [hf @ params[f"lm_head_{i}"].astype(jnp.float32)
             for i in range(cfg.num_codebooks)], axis=2)  # (B,S,K,V)
    elif cfg.tie_embeddings:
        logits = hf @ params["embed"]["tokens"].astype(jnp.float32).T
    else:
        logits = hf @ params["lm_head"].astype(jnp.float32)
    if cfg.logit_scale is not None:
        logits = logits * cfg.logit_scale
    if cfg.final_logit_softcap is not None:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return constrain(logits, ("batch", "seq", "vocab")) if not cfg.num_codebooks \
        else logits


def _vision_kv(params, vision_embeds, cfg: ArchConfig):
    if vision_embeds is None:
        return None
    w = params["vision_proj"]["w"].astype(jnp.dtype(cfg.dtype))
    return vision_embeds.astype(w.dtype) @ w      # (B, n_vis, D)


def forward(params, tokens, cfg: ArchConfig, *, vision_embeds=None,
            collect_cache: bool = False):
    """Full-sequence forward. Returns (logits, caches|None, aux_loss)."""
    seq_axis = -1
    s = tokens.shape[seq_axis]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    h = _embed(params, tokens, cfg, positions)
    vis_kv = _vision_kv(params, vision_embeds, cfg)
    h, caches, aux = T.apply_trunk_full(params["blocks"], h, cfg,
                                        positions=positions, vis_kv=vis_kv,
                                        collect_cache=collect_cache)
    return _unembed(params, h, cfg), caches, aux


def cross_entropy(logits, labels):
    """Sharded-vocab-safe CE. logits f32 (..., V); labels int (...,)."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    idx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    label_logit = jnp.sum(jnp.where(idx == labels[..., None], logits, 0.0),
                          axis=-1)
    return lse - label_logit


def loss_fn(params, batch, cfg: ArchConfig):
    """Mean next-token CE (+ MoE aux). batch: tokens/labels (+vision)."""
    logits, _, aux = forward(params, batch["tokens"], cfg,
                             vision_embeds=batch.get("vision_embeds"))
    if cfg.num_codebooks:
        # logits (B,S,K,V); labels (B,K,S)
        labels = batch["labels"].transpose(0, 2, 1)     # (B,S,K)
        ce = cross_entropy(logits, labels)
        loss = jnp.mean(ce)
    else:
        loss = jnp.mean(cross_entropy(logits, batch["labels"]))
    return loss + aux, {"ce": loss, "aux": aux}


def prefill_step(params, batch, cfg: ArchConfig):
    """Prefill: full forward returning last-position logits + KV caches."""
    logits, caches, _ = forward(params, batch["tokens"], cfg,
                                vision_embeds=batch.get("vision_embeds"),
                                collect_cache=True)
    if cfg.num_codebooks:
        last = logits[:, -1]                            # (B,K,V)
    else:
        last = logits[:, -1]                            # (B,V)
    return last, caches


def decode_step(params, tokens, pos, caches, cfg: ArchConfig, *,
                vision_embeds=None):
    """One-token decode. tokens: (B, 1) or (B, K, 1) [audio].

    pos: () int32 — absolute position of the new token. Returns
    (logits (B, 1, V|K,V), new_caches).
    """
    positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)
    h = _embed(params, tokens, cfg, positions)
    h, new_caches = T.apply_trunk_decode(params["blocks"], h, cfg, pos=pos,
                                         caches=caches)
    logits = _unembed(params, h, cfg)
    return logits, new_caches


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
