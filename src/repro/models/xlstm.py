"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with new
memory mixing), after Beck et al., arXiv:2405.04517.

mLSTM is evaluated in its *parallel* (masked quadratic, like attention)
form for train/prefill, and in its *recurrent* form (state: C (H,dh,dh),
n (H,dh), m (H)) for decode — constant-size state is what qualifies the
arch for long_500k. sLSTM is strictly sequential (hidden-to-hidden memory
mixing) and is evaluated with ``lax.scan``; decode carries (h, c, n, m).

Both use exponential gating with the paper's max-stabiliser state m.
Blocks are self-contained (cfg.d_ff == 0): the mLSTM block wraps its cell
in an up(2×)/down projection pair with a SiLU output gate; the sLSTM block
is followed by a gated 4/3-factor FFN, per the paper's block diagrams.
Deviation noted in DESIGN.md: q/k/v projections are full (not 4-block
block-diagonal) and the mLSTM causal conv feeds q/k only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, _pdt
from repro.models.rglru import _causal_conv

_MIN_NORM = 1e-6


def _heads(cfg: ArchConfig):
    return cfg.num_heads


# ================================================================== mLSTM ==

def init_mlstm(key, cfg: ArchConfig):
    d = cfg.d_model
    e = 2 * d                       # proj factor 2
    h = _heads(cfg)
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], (d, 2 * e), _pdt(cfg)),      # x_m | z
        "conv_w": dense_init(ks[1], (cfg.conv_width, e), _pdt(cfg)),
        "conv_b": jnp.zeros((e,), jnp.float32),
        "w_q": dense_init(ks[2], (e, e), _pdt(cfg)),
        "w_k": dense_init(ks[3], (e, e), _pdt(cfg)),
        "w_v": dense_init(ks[4], (e, e), _pdt(cfg)),
        "w_i": dense_init(ks[5], (e, h), jnp.float32),
        "w_f": dense_init(ks[6], (e, h), jnp.float32),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.linspace(3.0, 6.0, h).astype(jnp.float32),  # long-memory init
        "gn": jnp.ones((e,), jnp.float32),
        "skip_scale": jnp.zeros((e,), jnp.float32),
        "w_down": dense_init(ks[7], (e, d), _pdt(cfg)),
    }


def _headwise_norm(scale, x, eps=1e-6):
    """Per-head group norm. x: (B, S, H, dh); scale: (H*dh,)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    b, s, h, dh = x.shape
    return (out.reshape(b, s, h * dh) * scale[None, None, :]).astype(x.dtype)


def _mlstm_qkv(p, x, cfg, conv_state=None):
    b, s, d = x.shape
    e = 2 * d
    h = _heads(cfg)
    dh = e // h
    u = x @ p["w_up"].astype(x.dtype)
    x_m, z = u[..., :e], u[..., e:]
    c, new_conv = _causal_conv(x_m, p["conv_w"].astype(x.dtype), p["conv_b"],
                               conv_state)
    c = jax.nn.silu(c)
    q = (c @ p["w_q"].astype(x.dtype)).reshape(b, s, h, dh)
    k = (c @ p["w_k"].astype(x.dtype)).reshape(b, s, h, dh) * dh**-0.5
    v = (x_m @ p["w_v"].astype(x.dtype)).reshape(b, s, h, dh)
    i_pre = c.astype(jnp.float32) @ p["w_i"] + p["b_i"][None, None, :]  # (B,S,H)
    f_pre = c.astype(jnp.float32) @ p["w_f"] + p["b_f"][None, None, :]
    return q, k, v, i_pre, f_pre, c, z, new_conv


MLSTM_CHUNK = 256


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, cfg: ArchConfig):
    """Chunkwise-parallel mLSTM: O(S·L) memory instead of O(S²).

    Within a chunk of length L the stabilised masked-quadratic form is
    used; across chunks the (C, n, m) recurrent state is carried by a
    scan. Exact (up to float assoc.) equal to the full quadratic form.
    q,k,v: (B, S, H, dh); i_pre, f_pre: (B, S, H). Returns (B, S, H, dh).
    """
    b, s, h, dh = q.shape
    chunk = MLSTM_CHUNK if s % MLSTM_CHUNK == 0 else s
    n_chunks = s // chunk

    def reshape_c(t):
        return t.reshape(b, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = reshape_c(q.astype(jnp.float32)), reshape_c(
        k.astype(jnp.float32)), reshape_c(v.astype(jnp.float32))
    is_, fs = reshape_c(i_pre), reshape_c(jax.nn.log_sigmoid(f_pre))

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)

    def one_chunk(carry, inp):
        c_prev, n_prev, m_prev = carry
        qc, kc, vc, ic, fc = inp                     # (B,L,H,*)
        f_cum = jnp.cumsum(fc, axis=1)               # (B,L,H) inclusive
        # intra-chunk log weights D_ij = F_i − F_j + i_j (j <= i)
        dmat = f_cum[:, :, None, :] - f_cum[:, None, :, :] + ic[:, None, :, :]
        mask = jnp.tril(jnp.ones((qc.shape[1], qc.shape[1]), bool))
        dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)              # (B,L,H)
        m_inter = f_cum + m_prev[:, None, :]         # decay of previous state
        m_i = jnp.maximum(m_inter, m_intra)          # (B,L,H)

        w_intra = jnp.exp(dmat - m_i[:, :, None, :])
        scores = jnp.einsum("bihd,bjhd->bijh", qc, kc) * w_intra
        num = jnp.einsum("bijh,bjhd->bihd", scores, vc)
        den = jnp.sum(scores, axis=2)                # (B,L,H)

        w_inter = jnp.exp(m_inter - m_i)             # (B,L,H)
        num = num + w_inter[..., None] * jnp.einsum("bhde,bihd->bihe",
                                                    c_prev, qc)
        den = den + w_inter * jnp.einsum("bhd,bihd->bih", n_prev, qc)
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
        h_c = num / (denom[..., None] + _MIN_NORM)

        # end-of-chunk state
        f_tot = f_cum[:, -1, :]                      # (B,H)
        m_new = jnp.maximum(f_tot + m_prev, jnp.max(
            f_tot[:, None, :] - f_cum + ic, axis=1))
        w_old = jnp.exp(f_tot + m_prev - m_new)      # (B,H)
        w_tok = jnp.exp(f_tot[:, None, :] - f_cum + ic - m_new[:, None, :])
        c_new = (w_old[:, :, None, None] * c_prev
                 + jnp.einsum("bih,bihd,bihe->bhde", w_tok, kc, vc))
        n_new = (w_old[:, :, None] * n_prev
                 + jnp.einsum("bih,bihd->bhd", w_tok, kc))
        return (c_new, n_new, m_new), h_c

    _, hs = jax.lax.scan(one_chunk, (c0, n0, m0), (qs, ks, vs, is_, fs))
    return hs.swapaxes(0, 1).reshape(b, s, h, dh)


def apply_mlstm(p, x, cfg: ArchConfig, state=None):
    """x: (B, S, D). state None (parallel) or decode dict. Returns (out, st)."""
    b, s, d = x.shape
    e = 2 * d
    h = _heads(cfg)
    dh = e // h

    if state is None:
        q, k, v, i_pre, f_pre, c, z, _ = _mlstm_qkv(p, x, cfg)
        h_out = _mlstm_chunkwise(q, k, v, i_pre, f_pre, cfg).astype(x.dtype)
        new_state = None
    else:
        q, k, v, i_pre, f_pre, c, z, new_conv = _mlstm_qkv(
            p, x, cfg, conv_state=state["conv"])
        log_f = jax.nn.log_sigmoid(f_pre[:, 0])                # (B,H)
        i_t = i_pre[:, 0]
        m_prev, c_prev, n_prev = state["m"], state["C"], state["n"]
        m_new = jnp.maximum(log_f + m_prev, i_t)
        f_sc = jnp.exp(log_f + m_prev - m_new)                 # (B,H)
        i_sc = jnp.exp(i_t - m_new)
        kv = jnp.einsum("bhd,bhe->bhde", k[:, 0].astype(jnp.float32),
                        v[:, 0].astype(jnp.float32))
        c_new = f_sc[..., None, None] * c_prev + i_sc[..., None, None] * kv
        n_new = f_sc[..., None] * n_prev + i_sc[..., None] * k[:, 0].astype(jnp.float32)
        qf = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhde,bhd->bhe", c_new, qf)
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, qf)),
                            jnp.exp(-m_new))
        h_out = (num / (denom[..., None] + _MIN_NORM))[:, None].astype(x.dtype)
        new_state = {"C": c_new, "n": n_new, "m": m_new, "conv": new_conv}

    h_n = _headwise_norm(p["gn"], h_out.reshape(b, -1, h, dh))
    h_n = h_n + p["skip_scale"].astype(x.dtype)[None, None, :] * c
    h_n = h_n * jax.nn.silu(z)
    return h_n @ p["w_down"].astype(x.dtype), new_state


def init_mlstm_state(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    e = 2 * d
    h = _heads(cfg)
    dh = e // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, e), _pdt(cfg)),
    }


# ================================================================== sLSTM ==

def init_slstm(key, cfg: ArchConfig):
    d = cfg.d_model
    h = _heads(cfg)
    dh = d // h
    ff = (4 * d // 3 + 63) // 64 * 64       # gated FFN, proj factor 4/3
    ks = jax.random.split(key, 12)
    p = {"gn": jnp.ones((d,), jnp.float32),
         "w_up": dense_init(ks[8], (d, ff), _pdt(cfg)),
         "w_ffgate": dense_init(ks[9], (d, ff), _pdt(cfg)),
         "w_down": dense_init(ks[10], (ff, d), _pdt(cfg))}
    for n, kk in zip(("i", "f", "z", "o"), ks[:4]):
        p[f"w_{n}"] = dense_init(kk, (d, d), _pdt(cfg))
    for n, kk in zip(("i", "f", "z", "o"), ks[4:8]):
        p[f"r_{n}"] = dense_init(kk, (h, dh, dh), jnp.float32) * 0.5
    p["b_i"] = jnp.zeros((d,), jnp.float32)
    p["b_f"] = jnp.ones((d,), jnp.float32) * 3.0
    p["b_z"] = jnp.zeros((d,), jnp.float32)
    p["b_o"] = jnp.zeros((d,), jnp.float32)
    return p


def _rec(r, h_vec, num_heads):
    """Block-diagonal recurrent matmul. h_vec: (B, D), r: (H, dh, dh)."""
    b, d = h_vec.shape
    hs = h_vec.reshape(b, num_heads, d // num_heads)
    return jnp.einsum("bhd,hdq->bhq", hs, r).reshape(b, d)


def _slstm_cell(p, xi, xf, xz, xo, carry, num_heads):
    h_prev, c_prev, n_prev, m_prev = carry
    i_pre = xi + _rec(p["r_i"], h_prev, num_heads)
    f_pre = xf + _rec(p["r_f"], h_prev, num_heads)
    z = jnp.tanh(xz + _rec(p["r_z"], h_prev, num_heads))
    o = jax.nn.sigmoid(xo + _rec(p["r_o"], h_prev, num_heads))
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m_prev, i_pre)
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(log_f + m_prev - m_new)
    c_new = f_sc * c_prev + i_sc * z
    n_new = jnp.maximum(f_sc * n_prev + i_sc, _MIN_NORM)
    h_new = o * (c_new / n_new)
    return h_new, c_new, n_new, m_new


def apply_slstm(p, x, cfg: ArchConfig, state=None):
    """x: (B, S, D). Sequential scan over S (decode: single step)."""
    b, s, d = x.shape
    nh = _heads(cfg)
    xf32 = x.astype(jnp.float32)
    xi = xf32 @ p["w_i"].astype(jnp.float32) + p["b_i"][None, None, :]
    xf = xf32 @ p["w_f"].astype(jnp.float32) + p["b_f"][None, None, :]
    xz = xf32 @ p["w_z"].astype(jnp.float32) + p["b_z"][None, None, :]
    xo = xf32 @ p["w_o"].astype(jnp.float32) + p["b_o"][None, None, :]

    if state is None:
        carry = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
                 jnp.zeros((b, d), jnp.float32), jnp.full((b, d), -1e30, jnp.float32))

        def step(carry, inp):
            new = _slstm_cell(p, *inp, carry, nh)
            return new, new[0]

        carry, hs = jax.lax.scan(step, carry,
                                 (xi.transpose(1, 0, 2), xf.transpose(1, 0, 2),
                                  xz.transpose(1, 0, 2), xo.transpose(1, 0, 2)))
        h_seq = hs.transpose(1, 0, 2)                        # (B,S,D)
        new_state = None
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])
        new = _slstm_cell(p, xi[:, 0], xf[:, 0], xz[:, 0], xo[:, 0], carry, nh)
        h_seq = new[0][:, None]
        new_state = {"h": new[0], "c": new[1], "n": new[2], "m": new[3]}

    dh = d // nh
    h_n = _headwise_norm(p["gn"], h_seq.reshape(b, -1, nh, dh)).astype(x.dtype)
    # gated FFN (PF 4/3)
    up = h_n @ p["w_up"].astype(x.dtype)
    gate = jax.nn.gelu(h_n @ p["w_ffgate"].astype(x.dtype), approximate=True)
    out = (up * gate) @ p["w_down"].astype(x.dtype)
    return out, new_state


def init_slstm_state(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"h": z(), "c": z(), "n": z(),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}
