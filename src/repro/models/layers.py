"""Core transformer layers: norms, RoPE, GQA attention (+cache), MLPs.

Functional style: ``init_*`` builds nested param dicts (named to match the
sharding rules in ``repro.launch.sharding``); ``apply`` functions are pure.
Attention weights are stored 3D — wq (D, H, Dh) etc. — so tensor-parallel
sharding of the head axis is expressed directly in the param layout.

Compute dtype is bf16 with f32 norms/softmax/logits (TPU-native mix);
smoke tests may run everything f32 via the config dtype fields.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.flash_attention.ref import attention_ref
from repro.launch.sharding import constrain

Init = jax.nn.initializers


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, dtype, in_axis=0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


# ------------------------------------------------------------------ norms --

def init_norm(cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def _over_last(v, ndim):
    """Broadcast a (D,) param over the last axis of a rank-``ndim`` input
    explicitly (the suite runs with rank promotion set to raise)."""
    return v.reshape((1,) * (ndim - 1) + (-1,))


def apply_norm(p, x, cfg: ArchConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * _over_last(p["scale"], out.ndim) + _over_last(p["bias"], out.ndim)
    else:
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * _over_last(p["scale"], xf.ndim)
    return out.astype(x.dtype)


def rms_head_norm(scale, x, eps):
    """Per-head RMS norm (qk-norm, Qwen3-style); x: (..., Dh), f32 math."""
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (out * _over_last(scale, out.ndim)).astype(x.dtype)


# ------------------------------------------------------------------- rope --

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh), positions: (B, S) or (S,). Pairwise rotation."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)                  # (B, S, half)
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """(B, S) or (S,) -> (B, S, D) sinusoidal embeddings (MusicGen-style)."""
    if positions.ndim == 1:
        positions = positions[None, :]
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs[None, None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -------------------------------------------------------------- attention --

def init_attention(key, cfg: ArchConfig, cross: bool = False):
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    kv_in = cfg.d_model  # cross-attn keys come from projected vision embeds (D)
    p = {
        "wq": dense_init(kq, (d, h, dh), _pdt(cfg)),
        "wk": dense_init(kk, (kv_in, hkv, dh), _pdt(cfg)),
        "wv": dense_init(kv, (kv_in, hkv, dh), _pdt(cfg)),
        "wo": dense_init(ko, (h, dh, d), _pdt(cfg), in_axis=0),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _project_qkv(p, x, kv_src, cfg: ArchConfig, positions, rope_on: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if rope_on and cfg.pos_embedding == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


ATTN_CHUNK_THRESHOLD = 8192
ATTN_CHUNK = 1024


def _attention_xla_chunked(q, k, v, *, scale, causal, window, softcap):
    """Query-chunked masked attention: O(S·chunk) logits memory.

    XLA analogue of the flash kernel's memory behaviour for the dry-run /
    non-TPU backends: a scan over query blocks keeps per-step logits at
    (B, H, chunk, S) instead of (B, H, S, S).
    """
    b, hq, s, d = q.shape
    hkv, s_kv = k.shape[1], k.shape[2]
    group = hq // hkv
    chunk = ATTN_CHUNK if s % ATTN_CHUNK == 0 else s
    n_chunks = s // chunk
    qc = q.reshape(b, hkv, group, n_chunks, chunk, d).transpose(3, 0, 1, 2, 4, 5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    k_idx = jnp.arange(s_kv)[None, :]

    def one_chunk(ci, qi):
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                            kf) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        q_idx = (ci * chunk + jnp.arange(chunk))[:, None] + (s_kv - s)
        mask = jnp.ones((chunk, s_kv), bool)
        if causal:
            mask &= q_idx >= k_idx
        if window is not None:
            mask &= (q_idx - k_idx) < window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)

    outs = jax.lax.map(lambda args: one_chunk(*args),
                       (jnp.arange(n_chunks), qc))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, s, d)
    return out.astype(q.dtype)


def attention_full(p, x, cfg: ArchConfig, *, positions, window=None,
                   kv_src=None, causal=True):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    k/v returned in (B, S, Hkv, Dh) layout for cache initialisation.
    Long sequences take the query-chunked path (flash-like memory).
    """
    cross = kv_src is not None
    src = kv_src if cross else x
    q, k, v = _project_qkv(p, x, src, cfg, positions, rope_on=not cross)
    scale = cfg.query_scale if cfg.query_scale is not None else cfg.head_dim ** -0.5
    qT = constrain(q.transpose(0, 2, 1, 3), ("batch", "heads", "seq", None))
    # Broadcast KV to the full query-head count: under TP the head axis is
    # sharded 16-way while Hkv (1-36 on this pool) rarely divides the mesh;
    # repeating keeps every attention tensor cleanly "heads"-sharded.
    group = cfg.num_heads // cfg.num_kv_heads
    k_rep = jnp.repeat(k, group, axis=2) if group > 1 else k
    v_rep = jnp.repeat(v, group, axis=2) if group > 1 else v
    kT = constrain(k_rep.transpose(0, 2, 1, 3), ("batch", "heads", None, None))
    vT = constrain(v_rep.transpose(0, 2, 1, 3), ("batch", "heads", None, None))
    is_causal = causal and not cross
    if x.shape[1] >= ATTN_CHUNK_THRESHOLD and not cross:
        out = _attention_xla_chunked(qT, kT, vT, scale=scale, causal=is_causal,
                                     window=window,
                                     softcap=cfg.attn_logit_softcap)
    else:
        out = attention_ref(qT, kT, vT, scale=scale, causal=is_causal,
                            window=window, softcap=cfg.attn_logit_softcap)
    out = out.transpose(0, 2, 1, 3)                     # (B, S, H, Dh)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return constrain(out, ("batch", "seq", "embed")), (k, v)


def quantize_kv(t):
    """Per-(token, head) int8 KV quantisation. t: (B, S, H, Dh) ->
    (int8 values, f32 scales (B, S, H))."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_decode(p, x, cfg: ArchConfig, *, cache_k, cache_v, pos,
                     window=None, cache_k_scale=None, cache_v_scale=None):
    """Single-token decode against a static-shape KV cache.

    x: (B, 1, D). cache_k/v: (B, C, Hkv, Dh) where C = cache capacity
    (full context, or the ring-buffer window for local layers); int8 with
    per-(slot, head) f32 scales when cfg.kv_quant (serving memory lever).
    pos: () int32 absolute position of the new token.
    Returns (out (B,1,D), new caches dict).
    """
    b, _, d = x.shape
    cap = cache_k.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, x, cfg, positions, rope_on=True)
    slot = pos % cap if window is not None else jnp.minimum(pos, cap - 1)
    quant = cache_k_scale is not None
    if quant:
        k_q, k_s = quantize_kv(k_new)
        v_q, v_s = quantize_kv(v_new)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_q, slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_q, slot, axis=1)
        cache_k_scale = jax.lax.dynamic_update_slice_in_dim(
            cache_k_scale, k_s, slot, axis=1)
        cache_v_scale = jax.lax.dynamic_update_slice_in_dim(
            cache_v_scale, v_s, slot, axis=1)
        k_eff = dequantize_kv(cache_k, cache_k_scale, x.dtype)
        v_eff = dequantize_kv(cache_v, cache_v_scale, x.dtype)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, axis=1)
        k_eff, v_eff = cache_k, cache_v

    scale = cfg.query_scale if cfg.query_scale is not None else cfg.head_dim ** -0.5
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    group = hq // hkv
    qg = q.reshape(b, 1, hkv, group, cfg.head_dim)
    logits = jnp.einsum("bqhgk,bchk->bhgqc", qg.astype(jnp.float32),
                        k_eff.astype(jnp.float32)) * scale
    if cfg.attn_logit_softcap is not None:
        logits = cfg.attn_logit_softcap * jnp.tanh(logits / cfg.attn_logit_softcap)
    idx = jnp.arange(cap)
    if window is not None:
        # ring buffer: slot c holds absolute position pos - ((slot - c) % cap)
        age = (slot - idx) % cap
        abs_pos = pos - age
        valid = (abs_pos >= 0) & (age < cap)
    else:
        valid = idx <= pos
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqc,bchk->bqhgk", probs, v_eff.astype(jnp.float32))
    out = out.reshape(b, 1, hq, cfg.head_dim).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    new_cache = {"k": cache_k, "v": cache_v}
    if quant:
        new_cache["k_scale"] = cache_k_scale
        new_cache["v_scale"] = cache_v_scale
    return out, new_cache


def cross_attention_decode(p, x, cfg: ArchConfig, *, cross_k, cross_v):
    """Decode-time cross attention against fixed (cached) vision K/V."""
    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
    scale = cfg.query_scale if cfg.query_scale is not None else cfg.head_dim ** -0.5
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    group = hq // hkv
    qg = q.reshape(b, 1, hkv, group, cfg.head_dim)
    logits = jnp.einsum("bqhgk,bchk->bhgqc", qg.astype(jnp.float32),
                        cross_k.astype(jnp.float32)) * scale
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqc,bchk->bqhgk", probs, cross_v.astype(jnp.float32))
    out = out.reshape(b, 1, hq, cfg.head_dim).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ------------------------------------------------------------------- mlps --

def init_mlp(key, cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.mlp in ("swiglu", "geglu")
    p = {"w_up": dense_init(k1, (d, f), _pdt(cfg)),
         "w_down": dense_init(k2, (f, d), _pdt(cfg))}
    if gated:
        p["w_gate"] = dense_init(k3, (d, f), _pdt(cfg))
    return p


def apply_mlp(p, x, cfg: ArchConfig):
    up = constrain(x @ p["w_up"].astype(x.dtype), ("batch", "seq", "ffn"))
    if cfg.mlp == "swiglu":
        gate = x @ p["w_gate"].astype(x.dtype)
        h = jax.nn.silu(gate) * up
    elif cfg.mlp == "geglu":
        gate = x @ p["w_gate"].astype(x.dtype)
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    out = h @ p["w_down"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "embed"))
