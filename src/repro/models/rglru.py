"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = σ(W_a x_t + b_a)            recurrence gate (block-diag linear)
    i_t = σ(W_x x_t + b_x)            input gate      (block-diag linear)
    a_t = exp(c · softplus(Λ) · (−r_t))   with c = 8, Λ learnable
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

The linear recurrence is evaluated with ``jax.lax.associative_scan`` over
the sequence axis — TPU-parallel, O(S log S) depth — which is the
hardware adaptation of Griffin's custom linear-scan kernel (DESIGN.md §2).
Decode carries h as O(1) state: this is what makes the arch long_500k-able.

Block structure (Griffin recurrent block): norm → {linear → conv1d(4) →
RG-LRU} ⊙ gelu(linear) → linear out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, _pdt
from repro.launch.sharding import constrain

_C = 8.0


def init_rglru(key, cfg: ArchConfig):
    d = cfg.d_model
    r = cfg.rnn_width or d
    nb = cfg.num_heads                       # block-diagonal gate blocks
    rb = r // nb
    ks = jax.random.split(key, 7)
    return {
        "w_x": dense_init(ks[0], (d, r), _pdt(cfg)),
        "w_gate": dense_init(ks[1], (d, r), _pdt(cfg)),
        "w_out": dense_init(ks[2], (r, d), _pdt(cfg)),
        "conv_w": dense_init(ks[3], (cfg.conv_width, r), _pdt(cfg)),
        "conv_b": jnp.zeros((r,), jnp.float32),
        "w_a": dense_init(ks[4], (nb, rb, rb), jnp.float32),
        "w_input_gate": dense_init(ks[5], (nb, rb, rb), jnp.float32),
        "b_a": jnp.zeros((r,), jnp.float32),
        "b_input_gate": jnp.zeros((r,), jnp.float32),
        # Λ init so a ≈ uniform(0.9, 0.999)^c at r=0.5 (Griffin appendix)
        "a_param": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, r)) / _C)).astype(jnp.float32),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv, width W. x: (B, S, R), w: (W, R).

    state: (B, W-1, R) trailing inputs from the previous segment (decode).
    Returns (y, new_state).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)             # (B, S+W-1, R)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    new_state = xp[:, -(width - 1):, :]
    return y + b.astype(y.dtype)[None, None, :], new_state


def _block_linear(x, w, b):
    """Block-diagonal linear: x (..., R) with blocks (NB, RB, RB)."""
    nb, rb, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, rb)
    y = jnp.einsum("...nr,nrq->...nq", xs.astype(jnp.float32), w)
    y = y.reshape(*x.shape)
    return y + b.reshape((1,) * (y.ndim - 1) + (-1,))


def _gates(p, x):
    """log a_t (f32) and gated input; x: (B, S, R)."""
    r_t = jax.nn.sigmoid(_block_linear(x, p["w_a"], p["b_a"]))
    i_t = jax.nn.sigmoid(_block_linear(x, p["w_input_gate"], p["b_input_gate"]))
    log_a = -_C * jax.nn.softplus(p["a_param"])[None, None, :] * r_t  # (B,S,R), <= 0
    a2 = jnp.exp(2.0 * log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i_t * x.astype(jnp.float32)
    return log_a, gated_x


def rglru_scan(p, x):
    """Full-sequence RG-LRU via associative scan. x: (B, S, R) -> (B, S, R)."""
    log_a, gx = _gates(p, x)
    a = jnp.exp(log_a)

    def combine(c1, c2):
        a1, b1 = c1
        a2_, b2 = c2
        return a1 * a2_, a2_ * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h.astype(x.dtype)


def rglru_step(p, x, h_prev):
    """Single decode step. x: (B, 1, R), h_prev: (B, R) -> (y, h)."""
    log_a, gx = _gates(p, x)
    a = jnp.exp(log_a[:, 0])
    h = a * h_prev + gx[:, 0]
    return h[:, None, :].astype(x.dtype), h


def init_recurrent_block(key, cfg: ArchConfig):
    return {"rglru": init_rglru(key, cfg)}


def apply_recurrent_block(p, x, cfg: ArchConfig, state=None):
    """Griffin recurrent mixer. x: (B, S, D).

    state: None (train/prefill) or {"h": (B,R) f32, "conv": (B,W-1,R)}.
    Returns (out, new_state).
    """
    q = p["rglru"]
    branch = x @ q["w_x"].astype(x.dtype)                    # (B, S, R)
    branch = constrain(branch, ("batch", "seq", "rnn"))
    gate = jax.nn.gelu(x @ q["w_gate"].astype(x.dtype), approximate=True)
    if state is None:
        conv_out, _ = _causal_conv(branch, q["conv_w"].astype(x.dtype),
                                   q["conv_b"])
        h = rglru_scan(q, conv_out)
        new_state = None
    else:
        conv_out, conv_state = _causal_conv(
            branch, q["conv_w"].astype(x.dtype), q["conv_b"], state["conv"])
        y, h_new = rglru_step(q, conv_out, state["h"])
        h = y
        new_state = {"h": h_new, "conv": conv_state}
    out = (h * gate) @ q["w_out"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "embed")), new_state


def init_recurrent_state(cfg: ArchConfig, batch: int):
    r = cfg.rnn_width or cfg.d_model
    return {"h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, r), _pdt(cfg))}
