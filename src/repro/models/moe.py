"""Mixture-of-Experts layer: top-k routing, capacity dispatch, EP sharding.

GShard-style dense dispatch: tokens are grouped per sequence, each group
dispatches into (E, C) capacity slots with two one-hot factors, experts run
as a single batched einsum over the expert-stacked weights (sharded over
the "model"/EP axis), and results are combined with the routing gates.
Over-capacity tokens are dropped (residual passes through) — the standard
trade for static shapes on TPU. An auxiliary load-balancing loss (Switch
Transformer form) is returned for the trainer.

Routing follows OLMoE/Qwen3-MoE: softmax over experts, top-k, gate values
renormalised over the selected k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, _pdt
from repro.launch.sharding import constrain


def init_moe(key, cfg: ArchConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (d, e), jnp.float32),
        "w_gate": dense_init(kg, (e, d, f), _pdt(cfg)),
        "w_up": dense_init(ku, (e, d, f), _pdt(cfg)),
        "w_down": dense_init(kd, (e, f, d), _pdt(cfg), in_axis=1),
    }


MOE_GROUP = 4096


def apply_moe(p, x, cfg: ArchConfig):
    """x: (B, S, D) -> (out (B, S, D), aux_loss ()).

    Long sequences are regrouped to (B·S/4096, 4096, D): dispatch groups
    (and hence capacity) are per-4096-token blocks, keeping the staged
    (group, L, E, C) dispatch tensor bounded — at S=32k the ungrouped
    tensor is ~17 GB/chip (EXPERIMENTS §Dry-run). The leading (sharded)
    batch dim stays leading, so the reshape is shard-local under GSPMD.
    """
    b, s, d = x.shape
    if s > MOE_GROUP and s % MOE_GROUP == 0:
        nc = s // MOE_GROUP
        out, aux = apply_moe(p, x.reshape(b * nc, MOE_GROUP, d), cfg)
        return out.reshape(b, s, d), aux
    e, k = cfg.moe_experts, cfg.moe_top_k
    cap = int(s * k * cfg.moe_capacity_factor / e) or 1

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                     # (B,S,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balancing aux loss (fraction routed × router prob).
    me = jnp.mean(probs, axis=(0, 1))                                   # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32),
                  axis=(0, 1))
    aux = e * jnp.sum(me * ce) * cfg.moe_aux_loss_coef

    # positions within each expert's capacity, per group (= per sequence)
    onehot_e = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)         # (B,S,k,E)
    flat = onehot_e.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1.0                                # (B,S*k,E)
    pos = pos.reshape(b, s, k, e)
    in_cap = (pos < cap) & (onehot_e > 0)
    slot = jnp.sum(pos * onehot_e, axis=-1)                             # (B,S,k)

    onehot_c = jax.nn.one_hot(slot.astype(jnp.int32), cap,
                              dtype=x.dtype) * in_cap.any(-1, keepdims=False
                              ).astype(x.dtype)[..., None]              # (B,S,k,C)
    disp_e = (onehot_e * in_cap).astype(x.dtype)                        # (B,S,k,E)

    # dispatch, staged so GSPMD lowers the resharding as an expert-parallel
    # all-to-all instead of all-gathering the one-hot masks (§Perf H3):
    # (B,S,k,E)×(B,S,k,C) -> (B,S,E,C), then ×(B,S,D) -> (B,E,C,D)
    disp = jnp.einsum("bske,bskc->bsec", disp_e, onehot_c)
    x_disp = jnp.einsum("bsec,bsd->becd", disp, x)
    x_disp = constrain(x_disp, ("batch_dp", "experts", None, "embed"))

    wg = p["w_gate"].astype(x.dtype)
    wu = p["w_up"].astype(x.dtype)
    wd = p["w_down"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", x_disp, wg)) * jnp.einsum(
        "becd,edf->becf", x_disp, wu)
    y = jnp.einsum("becf,efd->becd", h, wd)                             # (B,E,C,D)
    y = constrain(y, ("batch_dp", "experts", None, "embed"))

    # combine with gates: weight (B,S,k) on the (E,C) slot of each choice
    combine = disp * jnp.einsum("bske,bsk->bse", disp_e,
                                gate_vals.astype(x.dtype))[..., None]
    out = jnp.einsum("bsec,becd->bsd", combine, y)
    return constrain(out, ("batch", "seq", "embed")), aux
