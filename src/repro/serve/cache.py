"""LRU plan cache with a byte-budget eviction policy and pinning.

The :class:`~repro.core.fastcv.CVPlan` is the expensive, label-invariant
half of the paper's economics (§2.7): O(N²P + N³ + K·m³) to build, O(K·m²)
to use. The cache keys plans by the content fingerprint of
(X, folds, λ, mode, train-block) — see :func:`repro.core.fastcv.plan_key` —
so any number of tenants asking about the same dataset share one build.

Eviction is least-recently-used under a *byte* budget (plans from different
datasets differ wildly in size: N=64 LOO vs N=4096 10-fold is a ~4000×
spread, so an entry-count LRU would be meaningless). Admission control: a
single plan larger than the whole budget is *not* admitted — it is served
un-cached (``get_or_build`` still returns it) and counted in
``stats.oversized``, rather than evicting every resident plan to make room
for an entry that can never fit.

Pinning: :meth:`PlanCache.pin` marks a resident plan as a first-class,
pre-warmed resource (the warm-up workflow of the serving engine). Pinned
plans are never LRU-evicted and their bytes are *excluded* from the
byte-budget pressure calculation — pinning is an operator statement that
the plan's memory is budgeted elsewhere — with counts in ``stats.pinned``
/ ``stats.pinned_bytes``. :meth:`PlanCache.unpin` re-subjects the entry to
ordinary LRU pressure.

Thread safety: one coarse lock around all operations. ``get_or_build``
holds it across the build, which doubles as single-flight semantics —
concurrent requests for the same missing plan trigger exactly one build.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Hashable, Optional

from repro.core.fastcv import CVPlan

__all__ = ["CacheStats", "PlanCache"]


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0  # builds (cached inserts + oversized un-cached)
    evictions: int = 0
    oversized: int = 0  # builds served un-cached (nbytes > byte_budget)
    pinned: int = 0  # entries currently pinned (never evicted)
    pinned_bytes: int = 0  # bytes held by pinned entries (outside pressure)
    bytes_in_use: int = 0
    byte_budget: int = 0

    @property
    def entries_alive(self) -> int:
        # inserts (misses minus un-cached builds) minus removals
        return self.misses - self.oversized - self.evictions

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlanCache:
    """LRU ``plan_key -> CVPlan`` map bounded by device bytes."""

    # Concurrency contract, machine-checked by reprolint RL004: every
    # mutation of the entry map, pin set or stats happens under _lock.
    _GUARDED_BY = {"_entries": "_lock", "_pinned": "_lock", "stats": "_lock"}
    # _evict_over_budget is only reached from put() with _lock held.
    _LOCKED_HELPERS = ("_evict_over_budget",)

    def __init__(self, byte_budget: int = 512 << 20):
        if byte_budget <= 0:
            raise ValueError("byte_budget must be positive")
        self._lock = threading.RLock()
        self._entries: "OrderedDict[Hashable, CVPlan]" = OrderedDict()
        self._pinned: set = set()
        self.stats = CacheStats(byte_budget=byte_budget)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def peek(self, key: Hashable) -> Optional[CVPlan]:
        """Locked lookup without recency refresh or stats — introspection
        (e.g. the engine's ``datasets()`` residency view), not serving."""
        with self._lock:
            return self._entries.get(key)

    def get(self, key: Hashable) -> Optional[CVPlan]:
        """Return the cached plan (refreshing recency) or None on miss.

        Only ``get_or_build`` counts misses: a bare failed probe is not a
        build, and counting it would let lookups double-count with the
        subsequent ``put``.
        """
        with self._lock:
            plan = self._entries.get(key)
            if plan is None:
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return plan

    def put(self, key: Hashable, plan: CVPlan) -> bool:
        """Insert (counted as a miss) and evict LRU entries over budget.

        Admission control: a plan that could never fit (``nbytes`` above
        the whole budget) is rejected — counted as a miss (it was a build)
        *and* in ``stats.oversized``, resident entries untouched. Returns
        whether the plan was admitted.
        """
        with self._lock:
            if plan.nbytes > self.stats.byte_budget:
                self.stats.misses += 1
                self.stats.oversized += 1
                return False
            if key in self._entries:  # replace without re-counting
                old = self._entries.pop(key)
                self.stats.bytes_in_use -= old.nbytes
                self.stats.misses -= 1
                if key in self._pinned:
                    self.stats.pinned_bytes += plan.nbytes - old.nbytes
            self._entries[key] = plan
            self.stats.misses += 1
            self.stats.bytes_in_use += plan.nbytes
            self._evict_over_budget()
            return True

    # -- pinning -----------------------------------------------------------

    def pin(self, key: Hashable) -> bool:
        """Exempt a resident plan from LRU eviction and budget pressure.

        Returns False (no-op) when the key is absent; idempotent when it
        is already pinned.
        """
        with self._lock:
            plan = self._entries.get(key)
            if plan is None:
                return False
            if key not in self._pinned:
                self._pinned.add(key)
                self.stats.pinned += 1
                self.stats.pinned_bytes += plan.nbytes
            return True

    def unpin(self, key: Hashable) -> bool:
        """Re-subject a pinned plan to ordinary LRU pressure.

        The entry stays resident (freshly most-recent) but its bytes count
        against the budget again, so eviction may immediately reclaim
        colder entries. Returns False when the key was not pinned.
        """
        with self._lock:
            if key not in self._pinned:
                return False
            self._pinned.discard(key)
            self.stats.pinned -= 1
            self.stats.pinned_bytes -= self._entries[key].nbytes
            self._entries.move_to_end(key)
            self._evict_over_budget()
            return True

    def pinned_keys(self) -> tuple:
        with self._lock:
            return tuple(self._pinned)

    def remove(self, key: Hashable) -> bool:
        """Explicitly drop one entry (handle-scoped eviction).

        Unpins first if needed; counted as an eviction. Returns whether the
        key was resident.
        """
        with self._lock:
            plan = self._entries.pop(key, None)
            if plan is None:
                return False
            if key in self._pinned:
                self._pinned.discard(key)
                self.stats.pinned -= 1
                self.stats.pinned_bytes -= plan.nbytes
            self.stats.bytes_in_use -= plan.nbytes
            self.stats.evictions += 1
            return True

    def _evict_over_budget(self) -> None:
        # Pressure counts unpinned bytes only; victims are the LRU
        # *unpinned* entries (pinned plans are exempt by contract).
        while self.stats.bytes_in_use - self.stats.pinned_bytes > self.stats.byte_budget:
            victim = next((k for k in self._entries if k not in self._pinned), None)
            if victim is None:
                break
            evicted = self._entries.pop(victim)
            self.stats.bytes_in_use -= evicted.nbytes
            self.stats.evictions += 1

    def get_or_build(
        self,
        key: Hashable,
        build: Callable[[], CVPlan],
        fetch: Optional[Callable[[], Optional[CVPlan]]] = None,
    ) -> tuple[CVPlan, bool]:
        """Return ``(plan, was_hit)``; builds (single-flight) on miss.

        ``fetch`` is the optional second tier between memory and build —
        the engine passes the disk-backed plan store's verified ``load``.
        A fetched plan is admitted like a fresh build (it *was* a cache
        miss, just resolved cheaply) and returned with ``was_hit=False``,
        so cache hit/miss stats keep meaning "resident in memory".

        An oversized build is still returned to the caller — the engine
        must serve it — it just never enters the cache (see ``put``).
        """
        with self._lock:
            plan = self.get(key)
            if plan is not None:
                return plan, True
            if fetch is not None:
                plan = fetch()
                if plan is not None:
                    self.put(key, plan)
                    return plan, False
            plan = build()
            self.put(key, plan)
            return plan, False

    def clear(self) -> None:
        """Drop every entry, pinned ones included (counted as evictions)."""
        with self._lock:
            for plan in self._entries.values():
                self.stats.bytes_in_use -= plan.nbytes
                self.stats.evictions += 1
            self._entries.clear()
            self._pinned.clear()
            self.stats.pinned = 0
            self.stats.pinned_bytes = 0
