"""Durable CVPlan checkpoint store — the warm-boot tier under the cache.

The :class:`~repro.serve.cache.PlanCache` makes plan builds amortise
*within* a process; this module makes them amortise *across* processes.
A :class:`CVPlan` is the paper's expensive label-invariant artifact
(§2.7) — O(N²P + N³ + K·m³) to build, pure data thereafter — so a
restarted or autoscaled replica that can read yesterday's plans from
disk skips straight to the O(K·m²) serving regime. :class:`PlanStore`
is that disk tier: a content-addressed directory of serialized plans,
wired into :class:`~repro.serve.engine.CVEngine` as read-through (a
cache miss tries disk before building) and write-behind (fresh builds
are persisted off the request path).

Durability properties (the commit protocol is the one proven out by
:mod:`repro.train.checkpoint`):

* **atomic** — entries are written to ``<id>.tmp-<pid>-<seq>/`` with the
  manifest last, then renamed into place; a crash mid-write can never
  produce a readable-but-wrong entry, and concurrent writers (two
  engines, one dir) race benignly: entries are content-addressed by
  ``plan_key``, so whichever rename lands first wins and the loser's
  identical bytes are discarded.
* **self-verifying** — the manifest records a schema version, the full
  plan key, and per-leaf shape/dtype/blake2b digests; ``load`` re-hashes
  what it read and rejects any mismatch.
* **fail-soft** — a corrupt, truncated, or version-skewed entry is moved
  to ``quarantine/`` (keeping the bytes for a post-mortem) and reported
  as a miss, never an exception: a damaged store degrades to cold-boot
  behaviour instead of taking the server down.
* **bounded** — ``gc`` evicts oldest-written entries while the store
  exceeds its byte budget, skipping any key in ``protect`` (the engine
  passes its pinned plan keys, so operator-pinned plans survive on disk
  as long as they are pinned in memory).

Layout::

    root/
      <entry id>/              # blake2b(plan_key) hex
        manifest.json          # schema, plan_key, per-leaf integrity
        h.npy  te_idx.npy  tr_idx.npy  chol_ih.npy  [h_tr_te.npy]
      quarantine/
        <entry id>.<n>/        # damaged entries, moved not deleted
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Iterable, Optional

import numpy as np

from repro.core.fastcv import CVPlan, plan_from_arrays, plan_to_arrays

__all__ = ["SCHEMA_VERSION", "StoreStats", "PlanStore"]

#: Bumped whenever the on-disk layout or manifest contract changes; a
#: mismatched entry is quarantined (it may belong to a newer binary), not
#: reinterpreted.
SCHEMA_VERSION = 1

_MANIFEST = "manifest.json"
_QUARANTINE = "quarantine"


def _entry_id(key: tuple) -> str:
    """Stable directory name for a plan key.

    ``plan_key`` tuples contain only str/float/bool, all of which
    round-trip JSON exactly (Python float repr is shortest-round-trip),
    so hashing the JSON encoding is deterministic across processes.
    """
    return hashlib.blake2b(json.dumps(list(key)).encode(), digest_size=16).hexdigest()


def _digest(arr: np.ndarray) -> str:
    """Full-content integrity hash (unlike ``fingerprint``, never sampled:
    the array was just read off disk, hashing it is already the cheap
    part of the I/O)."""
    return hashlib.blake2b(np.ascontiguousarray(arr).tobytes(), digest_size=16).hexdigest()


class StoreCorruption(Exception):
    """Internal: an entry failed an integrity check (caught by ``load``)."""


@dataclasses.dataclass
class StoreStats:
    hits: int = 0  # loads that returned a verified plan
    misses: int = 0  # loads that found nothing usable
    writes: int = 0  # entries committed (renamed into place)
    quarantined: int = 0  # damaged entries moved aside by load
    evictions: int = 0  # entries removed by byte-budget GC
    bytes_in_store: int = 0  # committed entry bytes on disk
    byte_budget: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlanStore:
    """Content-addressed ``plan_key -> CVPlan`` directory with integrity
    checks, quarantine, and byte-budget GC.

    Thread-safe: one lock serialises commits/GC/stat updates inside a
    process; *cross*-process safety needs no locking because every
    mutation is an atomic rename and entries are content-addressed.
    """

    # Concurrency contract, machine-checked by reprolint RL004
    # (write-behind threads and the request path share these).
    _GUARDED_BY = {"_pending": "_lock", "stats": "_lock"}

    def __init__(self, root, byte_budget: int = 4 << 30):
        if byte_budget <= 0:
            raise ValueError("byte_budget must be positive")
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = threading.RLock()
        self._tmp_seq = itertools.count()
        self._pending: list[threading.Thread] = []
        self.stats = StoreStats(byte_budget=byte_budget)
        self.stats.bytes_in_store = sum(self._entry_bytes(d) for d in self._entry_dirs())

    # -- layout helpers ----------------------------------------------------

    def _entry_dirs(self) -> list[Path]:
        return sorted(
            d
            for d in self.root.iterdir()
            if d.is_dir()
            and d.name != _QUARANTINE
            and ".tmp-" not in d.name
            and (d / _MANIFEST).exists()
        )

    @staticmethod
    def _entry_bytes(entry: Path) -> int:
        return sum(f.stat().st_size for f in entry.iterdir() if f.is_file())

    def path_for(self, key: tuple) -> Path:
        return self.root / _entry_id(key)

    def __contains__(self, key: tuple) -> bool:
        return (self.path_for(key) / _MANIFEST).exists()

    def __len__(self) -> int:
        return len(self._entry_dirs())

    def keys(self) -> list[tuple]:
        """Plan keys of every committed entry (read from manifests)."""
        out = []
        for d in self._entry_dirs():
            try:
                out.append(tuple(json.loads((d / _MANIFEST).read_text())["plan_key"]))
            except (OSError, ValueError, KeyError):
                continue  # unreadable manifest: load() will quarantine it
        return out

    def total_bytes(self) -> int:
        return sum(self._entry_bytes(d) for d in self._entry_dirs())

    # -- write path --------------------------------------------------------

    def save(self, key: tuple, plan: CVPlan, *, protect: Iterable[tuple] = ()) -> bool:
        """Persist ``plan`` under ``key`` atomically; returns whether this
        call committed a new entry (False when one already exists — the
        store is content-addressed, identical keys mean identical bytes).
        Runs :meth:`gc` with ``protect`` after a commit."""
        final = self.path_for(key)
        if (final / _MANIFEST).exists():
            return False
        arrays = plan_to_arrays(plan)
        return self._commit(key, final, arrays, protect)

    def save_async(
        self, key: tuple, plan: CVPlan, *, protect: Iterable[tuple] = ()
    ) -> Optional[threading.Thread]:
        """Write-behind :meth:`save`: snapshot to host now (the only
        synchronous part), commit on a background thread. ``flush`` joins
        outstanding writes (engine/server shutdown)."""
        final = self.path_for(key)
        if (final / _MANIFEST).exists():
            return None
        arrays = plan_to_arrays(plan)  # host snapshot before returning
        protect = tuple(tuple(k) for k in protect)

        def _write():
            self._commit(key, final, arrays, protect)

        t = threading.Thread(target=_write, daemon=True, name="plan-store-write")
        t.start()
        with self._lock:
            self._pending.append(t)
        return t

    def flush(self) -> None:
        """Block until every outstanding :meth:`save_async` committed."""
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()

    def _commit(self, key: tuple, final: Path, arrays: dict, protect) -> bool:
        tmp = self.root / f"{final.name}.tmp-{os.getpid()}-{next(self._tmp_seq)}"
        tmp.mkdir(parents=True)
        try:
            leaves = []
            for name, arr in arrays.items():
                np.save(tmp / f"{name}.npy", arr)
                leaves.append(
                    {
                        "name": name,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "digest": _digest(arr),
                        "nbytes": int(arr.nbytes),
                    }
                )
            manifest = {
                "schema": SCHEMA_VERSION,
                "plan_key": list(key),
                "leaves": leaves,
            }
            # manifest last: its presence IS the entry's commit marker
            (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=2))
            with self._lock:
                if (final / _MANIFEST).exists():
                    shutil.rmtree(tmp, ignore_errors=True)
                    return False
                try:
                    tmp.rename(final)
                except OSError:
                    # cross-process race: someone else committed this key
                    shutil.rmtree(tmp, ignore_errors=True)
                    return False
                self.stats.writes += 1
                self.stats.bytes_in_store += self._entry_bytes(final)
            self.gc(protect=protect)
            return True
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    # -- read path ---------------------------------------------------------

    def load(self, key: tuple) -> Optional[CVPlan]:
        """Verified read of ``key``; None on miss *or* damage.

        Every failure mode — unreadable/garbled manifest, schema skew,
        plan-key mismatch (hash collision or tampering), missing leaf
        file, shape/dtype/digest mismatch — quarantines the entry and
        reports a miss. The engine then rebuilds exactly as if the entry
        had never existed.
        """
        entry = self.path_for(key)
        if not (entry / _MANIFEST).exists():
            with self._lock:
                self.stats.misses += 1
            return None
        try:
            plan = self._load_verified(entry, key)
        except (StoreCorruption, OSError, ValueError, KeyError, TypeError) as e:
            self._quarantine(entry, reason=str(e))
            with self._lock:
                self.stats.misses += 1
            return None
        with self._lock:
            self.stats.hits += 1
        return plan

    def _load_verified(self, entry: Path, key: tuple) -> CVPlan:
        manifest = json.loads((entry / _MANIFEST).read_text())
        if manifest.get("schema") != SCHEMA_VERSION:
            raise StoreCorruption(f"schema {manifest.get('schema')!r} != {SCHEMA_VERSION}")
        if tuple(manifest.get("plan_key", ())) != tuple(key):
            raise StoreCorruption("manifest plan_key does not match requested key")
        arrays = {}
        for leaf in manifest["leaves"]:
            path = entry / f"{leaf['name']}.npy"
            if not path.exists():
                raise StoreCorruption(f"missing leaf file {leaf['name']}.npy")
            arr = np.load(path)
            if list(arr.shape) != leaf["shape"] or str(arr.dtype) != leaf["dtype"]:
                raise StoreCorruption(
                    f"leaf {leaf['name']}: shape/dtype mismatch "
                    f"({arr.shape}/{arr.dtype} vs manifest)"
                )
            if _digest(arr) != leaf["digest"]:
                raise StoreCorruption(f"leaf {leaf['name']}: content digest mismatch")
            arrays[leaf["name"]] = arr
        return plan_from_arrays(arrays)

    def _quarantine(self, entry: Path, reason: str = "") -> None:
        qdir = self.root / _QUARANTINE
        qdir.mkdir(exist_ok=True)
        with self._lock:
            size = self._entry_bytes(entry) if entry.exists() else 0
            for n in itertools.count():
                dest = qdir / f"{entry.name}.{n}"
                if not dest.exists():
                    break
            try:
                entry.rename(dest)
            except OSError:
                return  # raced with another quarantine/GC: entry is gone
            self.stats.quarantined += 1
            self.stats.bytes_in_store -= size
            if reason:
                try:
                    (dest / "quarantine-reason.txt").write_text(reason + "\n")
                except OSError:
                    pass

    def remove(self, key: tuple) -> bool:
        """Cleanly delete one entry (released dataset versions).

        Unlike :meth:`_quarantine` this is an intentional removal — the
        bytes are gone, nothing lands in ``quarantine/`` and the
        ``quarantined`` counter does not move. Returns True if an entry
        existed. Tolerant no-op for absent keys.
        """
        entry = self.path_for(key)
        with self._lock:
            if not entry.exists():
                return False
            size = self._entry_bytes(entry)
            shutil.rmtree(entry, ignore_errors=True)
            self.stats.bytes_in_store -= size
        return True

    # -- GC ----------------------------------------------------------------

    def gc(self, protect: Iterable[tuple] = ()) -> int:
        """Evict oldest-written entries while over ``byte_budget``.

        ``protect`` lists plan keys that must survive (the engine passes
        its in-memory pinned set). Returns the number evicted. Protected
        entries never count as victims, so a store whose protected bytes
        alone exceed the budget simply stays over it.
        """
        shielded = {_entry_id(tuple(k)) for k in protect}
        evicted = 0
        with self._lock:
            entries = [(d.stat().st_mtime, d, self._entry_bytes(d)) for d in self._entry_dirs()]
            total = sum(b for _, _, b in entries)
            self.stats.bytes_in_store = total
            for _, d, size in sorted(entries, key=lambda e: e[0]):
                if total <= self.stats.byte_budget:
                    break
                if d.name in shielded:
                    continue
                shutil.rmtree(d, ignore_errors=True)
                total -= size
                evicted += 1
                self.stats.evictions += 1
                self.stats.bytes_in_store -= size
        return evicted
