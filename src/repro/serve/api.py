"""Request/response API for the serving engine, plus drivers.

Clients speak in terms of datasets and label queries:

  * :class:`CVRequest` — one cross-validation run (binary LDA, multi-class
    LDA, or ridge regression) against a dataset.
  * :class:`PermutationRequest` — a full permutation test (observed + null
    + p-value); the expensive part is label-batched through the plan.
  * :class:`RSARequest` — a cross-validated RDM over conditions (pairwise
    contrasts or multi-class confusion), optionally scored against model
    RDMs with a condition-permutation null. Contrast columns are just
    label columns, so RSA requests coalesce through the same
    :class:`~repro.serve.batching.MicroBatcher` paths as CV requests.
  * :class:`TuneRequest` — ridge-λ selection, routed to the
    eigendecomposition-based exact-LOO machinery (`tuning.tune_ridge`).

:func:`serve` is the synchronous driver: it groups requests by plan
identity, coalesces same-plan label queries through the
:class:`~repro.serve.batching.MicroBatcher` (one padded jitted eval per
group), and un-pads per-request results. :class:`EngineServer` wraps the
same driver in a thread-backed queue so concurrent submitters get futures
while their queries ride shared micro-batches; the asyncio counterpart
(with streamed responses) lives in :mod:`repro.serve.aio`.
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from concurrent.futures import Future
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core import metrics, tuning
from repro.rsa import rdm as rsa_rdm
from repro.serve.batching import MicroBatcher, as_folds
from repro.serve.engine import CVEngine

__all__ = [
    "DatasetSpec",
    "CVRequest",
    "PermutationRequest",
    "RSARequest",
    "TuneRequest",
    "Request",
    "CVResponse",
    "PermutationResponse",
    "RSAResponse",
    "TuneResponse",
    "serve",
    "EngineServer",
]


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DatasetSpec:
    """The label-invariant half of a request: features, folds, λ.

    ``folds`` is a :class:`~repro.core.folds.Folds` or a raw
    ``(te_idx, tr_idx)`` index pair (normalised via ``Folds.with_indices``).
    """

    x: jax.Array
    folds: object
    lam: float
    mode: str = "auto"


@dataclasses.dataclass
class CVRequest:
    data: DatasetSpec
    y: jax.Array  # binary/ridge: (N,) or (N, B); mc: (N,)/(B, N)
    task: str = "binary"  # "binary" | "multiclass" | "ridge"
    num_classes: int = 0  # required for task="multiclass"
    adjust_bias: bool = True  # binary only (paper §2.5)


@dataclasses.dataclass
class PermutationRequest:
    data: DatasetSpec
    y: jax.Array
    n_perm: int
    seed: int = 0
    task: str = "binary"  # "binary" | "multiclass"
    num_classes: int = 0
    metric: str = "accuracy"  # binary only: "accuracy" | "auc"
    adjust_bias: bool = True


@dataclasses.dataclass
class RSARequest:
    """Cross-validated RDM over conditions, optionally scored vs models.

    ``y`` holds integer condition labels in [0, num_classes). With
    ``contrast="binary"`` the RDM comes from C(C−1)/2 pairwise ±1/0
    contrast columns through the plan's fold solves (dissimilarity
    "accuracy" or "contrast"); with ``contrast="multiclass"`` it is the
    symmetrised confusion dissimilarity of one Algorithm-2 CV run.
    ``model_rdms`` (M, C, C), when given, are scored against the empirical
    RDM (``comparison``: spearman/kendall/pearson/cosine) with an
    ``n_perm``-draw condition-permutation null.
    """

    data: DatasetSpec
    y: jax.Array  # int (N,) condition labels
    num_classes: int
    contrast: str = "binary"  # "binary" | "multiclass"
    dissimilarity: str = "accuracy"  # binary only: "accuracy" | "contrast"
    adjust_bias: bool = True  # binary only (paper §2.5)
    model_rdms: Optional[jax.Array] = None  # (M, C, C)
    comparison: str = "spearman"
    n_perm: int = 0
    seed: int = 0


@dataclasses.dataclass
class TuneRequest:
    x: jax.Array
    y: jax.Array
    lambdas: Optional[jax.Array] = None
    criterion: str = "mse"


Request = Union[CVRequest, PermutationRequest, RSARequest, TuneRequest]


# ---------------------------------------------------------------------------
# Responses
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CVResponse:
    task: str
    values: object  # dvals / ẏ_Te (K, m[, B]) or preds — host np.ndarray
    #                 from the batched driver (MicroBatcher un-pads on the
    #                 host), jax.Array from direct engine calls
    y_te: jax.Array  # matching test labels/responses
    score: jax.Array  # accuracy (classification) or mse (ridge)
    plan_key: tuple


@dataclasses.dataclass
class PermutationResponse:
    observed: jax.Array
    null: jax.Array
    p: jax.Array
    plan_key: tuple


@dataclasses.dataclass
class RSAResponse:
    rdm: jax.Array  # (C, C) empirical RDM
    pair_values: Optional[object]  # (B,) pair dissimilarities (binary);
    #                                np.ndarray from the batched driver
    model_scores: Optional[jax.Array]  # (M,) or None
    null: Optional[jax.Array]  # (M, n_perm) or None
    p: Optional[jax.Array]  # (M,) or None
    plan_key: tuple


@dataclasses.dataclass
class TuneResponse:
    result: tuning.RidgeTuneResult


# ---------------------------------------------------------------------------
# Synchronous driver
# ---------------------------------------------------------------------------


def _score(task: str, values, y_te):
    if task == "binary":
        return metrics.binary_accuracy(values, y_te)
    if task == "multiclass":
        return metrics.multiclass_accuracy(values, y_te)
    return metrics.mse(values, y_te)


def serve(engine: CVEngine, requests: Sequence[Request]) -> list:
    """Serve a batch of requests; responses align with ``requests``.

    Same-plan CV label queries are coalesced into one padded jitted eval
    per (plan, task) group; plans are fetched once per distinct dataset
    (fingerprints memoised per driver call, keyed by object identity).
    """
    responses: list = [None] * len(requests)
    plan_memo: dict = {}

    def plan_for(data: DatasetSpec, with_train_block: bool):
        memo_key = (id(data.x), id(data.folds), float(data.lam), data.mode, with_train_block)
        hit = plan_memo.get(memo_key)
        if hit is None:
            folds = as_folds(data.folds)
            hit = engine.plan(
                data.x, folds, data.lam, mode=data.mode, with_train_block=with_train_block
            )
            plan_memo[memo_key] = hit
        return hit

    # -- group CV requests by (plan, eval path) ----------------------------
    groups: dict = {}
    rsa_groups: dict = {}
    for i, req in enumerate(requests):
        if isinstance(req, RSARequest):
            if req.contrast not in ("binary", "multiclass"):
                raise ValueError(f"unknown RSA contrast {req.contrast!r}")
            needs_train = req.contrast == "multiclass" or req.adjust_bias
            key, plan = plan_for(req.data, needs_train)
            if req.contrast == "binary":
                gkey = (key, "binary", req.dissimilarity, req.adjust_bias, req.num_classes)
            else:
                gkey = (key, "multiclass", None, None, req.num_classes)
            rsa_groups.setdefault(gkey, (plan, []))[1].append((i, req))
        elif isinstance(req, TuneRequest):
            responses[i] = TuneResponse(
                engine.tune(req.x, req.y, lambdas=req.lambdas, criterion=req.criterion)
            )
        elif isinstance(req, PermutationRequest):
            needs_train = req.task == "multiclass" or req.adjust_bias
            key, plan = plan_for(req.data, needs_train)
            if req.task == "multiclass":
                res = engine.permutation_multiclass(
                    plan,
                    jnp.asarray(req.y),
                    req.n_perm,
                    jax.random.PRNGKey(req.seed),
                    num_classes=req.num_classes,
                )
            else:
                res = engine.permutation_binary(
                    plan,
                    jnp.asarray(req.y),
                    req.n_perm,
                    jax.random.PRNGKey(req.seed),
                    metric=req.metric,
                    adjust_bias=req.adjust_bias,
                )
            responses[i] = PermutationResponse(res.observed, res.null, res.p, key)
        elif isinstance(req, CVRequest):
            needs_train = req.task == "multiclass" or (req.task == "binary" and req.adjust_bias)
            key, plan = plan_for(req.data, needs_train)
            gkey = (key, req.task, req.adjust_bias, req.num_classes)
            groups.setdefault(gkey, (plan, []))[1].append((i, req))
        else:
            raise TypeError(f"unknown request type {type(req).__name__}")

    # -- one coalesced eval per group --------------------------------------
    batcher: MicroBatcher = engine.batcher
    for (key, task, adjust_bias, num_classes), (plan, members) in groups.items():
        ys = [jnp.asarray(req.y) for _, req in members]
        if task == "binary":
            outs = batcher.run_columns(ys, lambda b: engine.eval_binary(plan, b, adjust_bias))
        elif task == "ridge":
            outs = batcher.run_columns(ys, lambda b: engine.eval_ridge(plan, b))
        elif task == "multiclass":
            outs = batcher.run_rows(ys, lambda b: engine.eval_multiclass(plan, b, num_classes))
        else:
            raise ValueError(f"unknown task {task!r}")
        for (i, req), values in zip(members, outs):
            y = jnp.asarray(req.y)
            if task == "multiclass":
                y_te = y[plan.te_idx] if y.ndim == 1 else y[:, plan.te_idx]
            else:
                y_te = y[plan.te_idx]  # (K, m[, B]) via trailing dims
            responses[i] = CVResponse(task, values, y_te, _score(task, values, y_te), key)

    # -- RSA: contrast columns ride the same coalesced label-batch path ----
    for (key, contrast, diss, adj, c), (plan, members) in rsa_groups.items():
        if contrast == "binary":
            cols = [
                rsa_rdm.pair_contrast_columns(jnp.asarray(req.y), c, plan.h.dtype)
                for _, req in members
            ]
            outs = batcher.run_columns(cols, lambda b: engine.eval_rsa_pairs(plan, b, diss, adj))
            rdms = [(rsa_rdm.rdm_from_pair_values(vals, c), vals) for vals in outs]
        else:
            ys = [jnp.asarray(req.y) for _, req in members]
            preds = batcher.run_rows(ys, lambda b: engine.eval_multiclass(plan, b, c))
            rdms = [
                (rsa_rdm.rdm_from_confusion(pred, y[plan.te_idx], c), None)
                for pred, y in zip(preds, ys)
            ]
        for (i, req), (rdm, vals) in zip(members, rdms):
            scores = null = p = None
            if req.model_rdms is not None:
                scores, null, p = engine.compare_rdms(
                    rdm,
                    jnp.asarray(req.model_rdms),
                    req.comparison,
                    req.n_perm,
                    jax.random.PRNGKey(req.seed),
                )
            responses[i] = RSAResponse(rdm, vals, scores, null, p, key)
    return responses


# ---------------------------------------------------------------------------
# Thread-backed queue for concurrent submitters
# ---------------------------------------------------------------------------


class EngineServer:
    """Background worker that drains a request queue into micro-batches.

    Submitters (any thread) get a Future per request; the worker collects
    whatever is queued — up to ``max_batch`` requests, waiting at most
    ``max_wait_ms`` after the first — and serves the whole batch through
    :func:`serve`, so concurrent clients' queries coalesce onto shared
    plans and shared padded evals.
    """

    def __init__(self, engine: CVEngine, max_batch: int = 64, max_wait_ms: float = 2.0):
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self._queue: "queue_mod.Queue" = queue_mod.Queue()
        self._stop = threading.Event()
        self._submit_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self.batches_served = 0
        self.requests_served = 0

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "EngineServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True, name="cv-engine-server")
        self._thread.start()
        return self

    def stop(self) -> None:
        # The lock orders every in-flight submit() before the stop flag:
        # anything enqueued before the flag is visible to the worker's
        # exit condition (stop AND queue-empty), so it gets served; any
        # later submit raises instead of landing on a dead queue.
        with self._submit_lock:
            self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        while True:  # belt-and-braces: never strand a future
            try:
                _, fut = self._queue.get_nowait()
            except queue_mod.Empty:
                break
            fut.set_exception(RuntimeError("server stopped before serving"))

    def __enter__(self) -> "EngineServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client side -------------------------------------------------------

    def submit(self, request: Request) -> Future:
        with self._submit_lock:
            if self._stop.is_set() or self._thread is None:
                raise RuntimeError("server is not running")
            fut: Future = Future()
            self._queue.put((request, fut))
            return fut

    # -- worker side -------------------------------------------------------

    def _drain_batch(self):
        try:
            first = self._queue.get(timeout=0.05)
        except queue_mod.Empty:
            return []
        batch = [first]
        t_end = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = t_end - time.monotonic()
            if remaining <= 0:
                break
            try:
                batch.append(self._queue.get(timeout=remaining))
            except queue_mod.Empty:
                break
        return batch

    def _run(self) -> None:
        while not (self._stop.is_set() and self._queue.empty()):
            batch = self._drain_batch()
            if not batch:
                continue
            requests = [req for req, _ in batch]
            futures = [fut for _, fut in batch]
            try:
                responses = serve(self.engine, requests)
            except Exception as e:  # noqa: BLE001 - fanned out
                for fut in futures:
                    fut.set_exception(e)
                continue
            for fut, resp in zip(futures, responses):
                fut.set_result(resp)
            self.batches_served += 1
            self.requests_served += len(batch)
