"""Sync drivers over the one-Workload API.

The serving surface is :class:`repro.serve.workload.Workload` — one
versioned, eagerly-validated spec (``kind``: ``cv | permutation | rsa |
tune | grid | update``) against a registered dataset handle or an inline
:class:`~repro.serve.workload.DatasetSpec`, executed by
:func:`repro.serve.workload.run_workloads` and fronted by
:class:`repro.serve.client.Client` (which picks the sync, thread-queue,
or asyncio transport by construction).

The pre-0.1 request vocabulary (``CVRequest``, ``PermutationRequest``,
``RSARequest``, ``TuneRequest`` and their ``to_workload()`` shims) was
**removed at 0.3** per the deprecation timeline announced in README "One
API"; importing any of those names raises :class:`ImportError` with a
pointer at the README migration table ("Migration from the request
classes").

:func:`serve` is the synchronous batch driver: it groups workloads by
plan identity, coalesces same-plan label queries through the
:class:`~repro.serve.batching.MicroBatcher` (one padded jitted eval per
(plan, estimator, static-options) group), and un-pads per-request
results. :class:`EngineServer` wraps the same driver in a thread-backed
queue so concurrent submitters get futures while their queries ride
shared micro-batches; the asyncio counterpart (with streamed responses)
lives in :mod:`repro.serve.aio`.
"""

from __future__ import annotations

import queue as queue_mod
import threading
import time
from concurrent.futures import Future
from typing import Optional, Sequence

# reprolint: monotonic-time
# (Gather deadlines / batch_wait stamps — the PR 6 bug class.)

from repro.serve.engine import CVEngine
from repro.serve.trace import attach_trace, trace_of
from repro.serve.workload import (  # noqa: F401  (re-exported compat surface)
    CVResponse,
    DatasetSpec,
    GridResponse,
    PermutationResponse,
    RSAResponse,
    TuneResponse,
    Workload,
    as_workload,
    run_workloads,
)

__all__ = [
    "DatasetSpec",
    "CVResponse",
    "PermutationResponse",
    "RSAResponse",
    "TuneResponse",
    "GridResponse",
    "serve",
    "EngineServer",
]

#: Names removed at 0.3 (the deprecated request shims). Kept here only so
#: the ImportError can say where the replacement lives.
_REMOVED_AT_0_3 = ("CVRequest", "PermutationRequest", "RSARequest", "TuneRequest", "Request")


def __getattr__(name: str):
    if name in _REMOVED_AT_0_3:
        raise ImportError(
            f"{name} was removed at 0.3 — construct a repro.serve.Workload "
            "(or use repro.serve.Client) instead; the field-by-field mapping "
            "is in the README migration table ('Migration from the request "
            "classes')."
        )
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# Synchronous driver
# ---------------------------------------------------------------------------


def serve(engine: CVEngine, requests: Sequence[Workload]) -> list:
    """Serve a batch of Workloads; responses align with ``requests``.

    Thin alias of :func:`repro.serve.workload.run_workloads`: same-plan CV
    label queries are coalesced into one padded jitted eval per (plan,
    estimator, static-options) group; plans are fetched once per distinct
    dataset; ``kind="update"`` workloads against the same handle coalesce
    into one rank-k plan correction.
    """
    return run_workloads(engine, requests)


# ---------------------------------------------------------------------------
# Thread-backed queue for concurrent submitters
# ---------------------------------------------------------------------------


class EngineServer:
    """Background worker that drains a request queue into micro-batches.

    Submitters (any thread) get a Future per Workload; the worker
    collects whatever is queued — up to
    ``max_batch`` requests, waiting at most ``max_wait_ms`` after the
    first — and serves the whole batch through :func:`serve`, so
    concurrent clients' queries coalesce onto shared plans and shared
    padded evals.
    """

    def __init__(self, engine: CVEngine, max_batch: int = 64, max_wait_ms: float = 2.0):
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self._queue: "queue_mod.Queue" = queue_mod.Queue()
        self._stop = threading.Event()
        self._submit_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self.batches_served = 0
        self.requests_served = 0

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "EngineServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True, name="cv-engine-server")
        self._thread.start()
        return self

    def stop(self) -> None:
        # The lock orders every in-flight submit() before the stop flag:
        # anything enqueued before the flag is visible to the worker's
        # exit condition (stop AND queue-empty), so it gets served; any
        # later submit raises instead of landing on a dead queue.
        with self._submit_lock:
            self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        while True:  # belt-and-braces: never strand a future
            try:
                _, fut = self._queue.get_nowait()
            except queue_mod.Empty:
                break
            fut.set_exception(RuntimeError("server stopped before serving"))

    def __enter__(self) -> "EngineServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client side -------------------------------------------------------

    def submit(self, request: Workload) -> Future:
        with self._submit_lock:
            if self._stop.is_set() or self._thread is None:
                raise RuntimeError("server is not running")
            # Tracing starts on the *submit* side so queue time is a real,
            # measured stage (batch_wait) instead of silently inflating
            # eval time. The trace rides the workload object across the
            # thread boundary (context vars do not).
            tracer = self.engine.tracer
            if tracer.enabled and trace_of(request) is None:
                trace = tracer.trace()
                attach_trace(request, trace)
            trace = trace_of(request)
            if trace is not None:
                trace.mark_enqueue()
            fut: Future = Future()
            self._queue.put((request, fut))
            return fut

    # -- worker side -------------------------------------------------------

    def _drain_batch(self):
        try:
            first = self._queue.get(timeout=0.05)
        except queue_mod.Empty:
            return []
        batch = [first]
        t_end = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = t_end - time.monotonic()
            if remaining <= 0:
                break
            try:
                batch.append(self._queue.get(timeout=remaining))
            except queue_mod.Empty:
                break
        return batch

    def _run(self) -> None:
        while not (self._stop.is_set() and self._queue.empty()):
            batch = self._drain_batch()
            if not batch:
                continue
            requests = [req for req, _ in batch]
            futures = [fut for _, fut in batch]
            # One dequeue timestamp for the whole batch: every member's
            # submit->here latency is its batch_wait stage.
            now = time.perf_counter()
            for req in requests:
                trace = trace_of(req)
                if trace is not None:
                    trace.note_dequeue(now)
            self.engine.metrics.observe("gather_window_occupancy", len(batch))
            try:
                # Per-entry result-or-error: one bad workload must not abort
                # sibling submitters coalesced into the same batch.
                responses = run_workloads(self.engine, requests, return_errors=True)
            except Exception as e:  # noqa: BLE001 - fanned out
                for fut in futures:
                    fut.set_exception(e)
                continue
            for fut, resp in zip(futures, responses):
                if isinstance(resp, Exception):
                    fut.set_exception(resp)
                else:
                    fut.set_result(resp)
            self.batches_served += 1
            self.requests_served += len(batch)
