"""repro.serve — plan-cached analytical-CV serving engine.

The paper's economics (§2.7: the hat matrix and fold factorisations depend
on features only) have the exact shape of a serving workload — expensive
label-invariant state, cheap per-request evaluation. This package
productises that:

  cache     PlanCache — LRU CVPlan store under a byte budget, with
            admission control for plans larger than the whole budget and
            pin/unpin for warm, never-evicted plans.
  engine    CVEngine — cached plans + shape-bucketed jitted eval paths
            (CV, permutation, and RSA workload families), plus an
            explicit warmup() readiness API.
  batching  MicroBatcher — coalesce ragged same-plan label queries.
  api       Request/response types, sync driver, threaded queue server.
  aio       AsyncEngineServer — asyncio front-end with gather-window
            micro-batching and streamed permutation/RSA responses.

Entry point: ``python -m repro.launch.serve_cv``.
"""

from repro.serve.aio import AsyncEngineServer, ProgressEvent  # noqa: F401
from repro.serve.api import (  # noqa: F401
    CVRequest,
    CVResponse,
    DatasetSpec,
    EngineServer,
    PermutationRequest,
    PermutationResponse,
    RSARequest,
    RSAResponse,
    TuneRequest,
    TuneResponse,
    serve,
)
from repro.serve.batching import MicroBatcher, bucket_size  # noqa: F401
from repro.serve.cache import CacheStats, PlanCache  # noqa: F401
from repro.serve.engine import CVEngine, EngineConfig  # noqa: F401
