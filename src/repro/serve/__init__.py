"""repro.serve — plan-cached analytical-CV serving engine, one workload API.

The paper's economics (§2.7: the hat matrix and fold factorisations depend
on features only) have the exact shape of a serving workload — expensive
label-invariant state, cheap per-request evaluation. This package
productises that behind a single declarative surface:

  workload  Workload — one versioned, eagerly-validated spec (kind:
            cv | permutation | rsa | tune | grid | update) against a
            registered DatasetHandle or inline DatasetSpec;
            LeastSquaresSpec — the estimator registry under which binary
            LDA, multi-class LDA, ridge, and multi-target ridge are
            registrations, not engine forks; run_workloads /
            stream_workload drivers; TrafficLog.
  client    Client — submit/stream/gather over a transport chosen by
            construction (sync, thread-queue, or asyncio).
  cache     PlanCache — LRU CVPlan store under a byte budget, with
            admission control for plans larger than the whole budget and
            pin/unpin for warm, never-evicted plans.
  store     PlanStore — durable disk tier under the cache: atomic
            content-addressed plan checkpoints with integrity-verified
            loads, corrupt-entry quarantine, and byte-budget GC, so a
            restarted replica warm-boots with zero plan builds.
  engine    CVEngine — mutable versioned dataset registry (register →
            version-0 handle; append/retire/update_dataset → version n+1
            by rank-k plan correction, old versions pinned by in-flight
            workloads until release), cached plans + shape-bucketed
            jitted eval paths from the estimator registry, RDM
            memoisation, and an explicit warmup() readiness API
            (replayable from recorded traffic).
  batching  MicroBatcher — coalesce ragged same-plan label queries.
  api       Sync driver + threaded queue server (the pre-0.1 request
            shims were removed at 0.3; see the README migration table).
  aio       AsyncEngineServer — asyncio front-end with gather-window
            micro-batching and streamed permutation/RSA responses.
  http      HTTPEdge — the HTTP/SSE wire over the async server (Workload
            JSON in, result-or-error batches and SSE ProgressEvent
            streams out), plus the HTTPClient transport mirror — and the
            ``GET /v1/metrics`` (Prometheus text) / ``GET /v1/trace``
            exposition routes.
  obs       MetricsRegistry — zero-dependency counters, gauges, and
            fixed-bucket histograms over the whole request path, rendered
            in Prometheus text format.
  trace     Tracer / Trace / Span — request-scoped stage timing
            (decode → validate → plan_build → cache_lookup → store_load →
            batch_wait → eval → null_chunk → encode) attached to responses
            as an optional ``timings`` dict; off by default, zero overhead
            when disabled (``engine.enable_tracing()``).

Entry point: ``python -m repro.launch.serve_cv`` (``--http PORT`` for the
network edge).
"""

from repro.serve.aio import AsyncEngineServer, ProgressEvent  # noqa: F401
from repro.serve.api import (  # noqa: F401
    CVResponse,
    DatasetSpec,
    EngineServer,
    GridResponse,
    PermutationResponse,
    RSAResponse,
    TuneResponse,
    serve,
)
from repro.serve.batching import MicroBatcher, bucket_size  # noqa: F401
from repro.serve.cache import CacheStats, PlanCache  # noqa: F401
from repro.serve.client import Client  # noqa: F401
from repro.serve.engine import CVEngine, EngineConfig  # noqa: F401
from repro.serve.http import (  # noqa: F401
    EdgeThread,
    HTTPClient,
    HTTPEdge,
    WireError,
)
from repro.serve.obs import MetricsRegistry  # noqa: F401
from repro.serve.store import PlanStore, StoreStats  # noqa: F401
from repro.serve.trace import STAGES, Span, Trace, Tracer  # noqa: F401
from repro.serve.workload import (  # noqa: F401
    WORKLOAD_SCHEMA_VERSION,
    DatasetHandle,
    LeastSquaresSpec,
    TrafficLog,
    UpdateResponse,
    Workload,
    as_workload,
    estimators,
    get_estimator,
    register_estimator,
    run_workloads,
    stream_workload,
)
