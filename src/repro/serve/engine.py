"""CVEngine: plan-cached, shape-bucketed analytical-CV evaluation.

The engine is the multi-tenant core of ``repro.serve``. It owns

  * a :class:`~repro.serve.cache.PlanCache` — one
    :class:`~repro.core.fastcv.CVPlan` per (dataset × folds × λ × mode),
    LRU-evicted under a byte budget, so repeated requests against the same
    features never re-factorise;
  * a fixed family of *jitted evaluators* (binary LDA, multi-class LDA,
    ridge regression, permutation-null metrics, RSA pairwise-contrast
    dissimilarities and model-RDM scoring), created once per engine so
    their jit caches — and hence compile counts — are observable;
  * *shape buckets* for the label-batch dimension: every batch is padded up
    to a static bucket size before hitting jit, so an engine serving ragged
    traffic compiles at most ``len(buckets)`` programs per eval path and
    zero after warm-up.

Plan builds route the O(N²P) centered-Gram hot-spot through the Pallas
``gram`` kernel on TPU (``gram_impl="auto"``/"pallas") or through
``distributed_gram`` when a mesh is configured (``gram_impl="distributed"``,
which also shards permutation batches over the mesh's data axes).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import fastcv, metrics, multiclass, permutation as perm_lib
from repro.core import tuning
from repro.core.folds import Folds
from repro.rsa import compare as rsa_compare
from repro.rsa import rdm as rsa_rdm
from repro.serve.batching import DEFAULT_BUCKETS, MicroBatcher, bucket_size
from repro.serve.cache import PlanCache

__all__ = ["EngineConfig", "CVEngine"]

_GRAM_IMPLS = ("auto", "xla", "pallas", "distributed")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine knobs.

    cache_bytes: PlanCache byte budget.
    gram_impl:   "auto" (Pallas kernel on TPU, plain XLA elsewhere),
                 "xla", "pallas", or "distributed" (requires ``mesh``).
    mesh:        optional jax Mesh; enables distributed plan builds and
                 mesh-sharded permutation batches.
    feature_axis / perm_axes: mesh axis names for the feature-sharded Gram
                 reduction and the permutation fan-out respectively.
    donate:      donate label-batch buffers to the jitted evals. Off by
                 default (None/False): when a batch needs no padding or
                 dtype cast, jax aliases the *caller's* array straight
                 into the eval, and donating it would invalidate the
                 caller's buffer. Set True only when every submitted
                 label array is single-use (and on TPU/GPU, where
                 donation is actually implemented).
    buckets:     static label-batch sizes; ragged batches pad up to these.
    """

    cache_bytes: int = 512 << 20
    gram_impl: str = "auto"
    mesh: Optional[object] = None
    feature_axis: str = "model"
    perm_axes: tuple = ("data",)
    donate: Optional[bool] = None
    buckets: Sequence[int] = DEFAULT_BUCKETS

    def __post_init__(self):
        if self.gram_impl not in _GRAM_IMPLS:
            raise ValueError(f"gram_impl must be one of {_GRAM_IMPLS}")
        if self.gram_impl == "distributed" and self.mesh is None:
            raise ValueError("gram_impl='distributed' requires a mesh")


class CVEngine:
    """Multi-tenant analytical-CV evaluation engine."""

    def __init__(self, config: Optional[EngineConfig] = None):
        self.config = config or EngineConfig()
        self.cache = PlanCache(self.config.cache_bytes)
        self.batcher = MicroBatcher(self.config.buckets)
        self._donate = bool(self.config.donate)
        # Eval paths are created lazily but exactly once per static
        # signature and held forever: the dict entry IS the jit cache the
        # no-recompile guarantee rests on.
        self._eval_binary = {}      # adjust_bias -> jit[(plan, y(N,B)) -> (K,m,B)]
        self._eval_ridge = fastcv.make_eval_cv(donate=self._donate)
        self._eval_multiclass = {}  # num_classes -> jit[(plan, y(B,N)) -> (B,K,m)]
        self._perm_binary = {}      # (metric, adjust_bias) -> jit -> (B,)
        self._perm_multiclass = {}  # num_classes -> jit -> (B,)
        self._rsa_pairs = {}        # (dissimilarity, adjust_bias) -> jit -> (B,)
        self._rsa_score = {}        # method -> jit[(emp, models) -> (M,)]
        self._rsa_null = {}         # method -> jit[(emp, models, perms) -> (M,T)]
        self.plans_built = 0
        self.labels_evaluated = 0

    # ------------------------------------------------------------------
    # Plans
    # ------------------------------------------------------------------

    def plan(self, x: jax.Array, folds: Folds, lam: float,
             mode: str = "auto", with_train_block: bool = True):
        """Fetch-or-build the plan for (x, folds, λ). Returns (key, plan).

        A plan *with* the train block is a superset of the one without
        (same H, same factors, extra H_{Tr,Te}), so a ridge request is
        happily served from a cached bias-adjust plan."""
        key = fastcv.plan_key(x, folds, lam, mode, with_train_block)
        if not with_train_block:
            superset = key[:-1] + (True,)
            plan = self.cache.get(superset)
            if plan is not None:
                return superset, plan
        plan, _ = self.cache.get_or_build(
            key, lambda: self._build_plan(x, folds, lam, mode,
                                          with_train_block))
        return key, plan

    def _build_plan(self, x, folds, lam, mode, with_train_block):
        n, p = x.shape
        resolved = ("dual" if p >= n else "primal") if mode == "auto" else mode
        gram = self._build_gram(x) if resolved == "dual" else None
        plan = fastcv.prepare(x, folds, lam, mode=resolved,
                              with_train_block=with_train_block, gram=gram)
        self.plans_built += 1
        return plan

    def _build_gram(self, x):
        impl = self.config.gram_impl
        if impl == "auto":
            impl = "pallas" if jax.default_backend() == "tpu" else "xla"
        if impl == "xla":
            return None                      # prepare() computes it inline
        if impl == "pallas":
            from repro.kernels.gram.ops import centered_gram
            return centered_gram(x)
        from repro.core.distributed import distributed_gram
        return distributed_gram(x, self.config.mesh,
                                feature_axis=self.config.feature_axis)

    # ------------------------------------------------------------------
    # Shape-bucketed jitted evaluation
    # ------------------------------------------------------------------

    @staticmethod
    def _strip_train(plan: fastcv.CVPlan) -> fastcv.CVPlan:
        """Canonicalise a plan for train-block-free eval paths.

        A no-train-block request may be served from the cached *superset*
        plan (see :meth:`plan`), whose ``h_tr_te`` is an array instead of
        None — a different pytree structure, which would retrace the jitted
        eval and recompute the unused Eq. 15 train solves. Stripping the
        block restores one structure (and one compiled program) per shape.
        """
        if plan.h_tr_te is None:
            return plan
        return dataclasses.replace(plan, h_tr_te=None)

    def _pad_cols(self, y: jax.Array) -> tuple[jax.Array, int]:
        b = y.shape[1]
        padded = bucket_size(b, self.config.buckets)
        if padded > b:
            y = jnp.pad(y, ((0, 0), (0, padded - b)))
        return y, b

    def _pad_rows(self, y: jax.Array) -> tuple[jax.Array, int]:
        b = y.shape[0]
        padded = bucket_size(b, self.config.buckets)
        if padded > b:
            y = jnp.concatenate(
                [y, jnp.broadcast_to(y[:1], (padded - b,) + y.shape[1:])], 0)
        return y, b

    def eval_binary(self, plan: fastcv.CVPlan, y: jax.Array,
                    adjust_bias: bool = True) -> jax.Array:
        """Binary-LDA decision values. y: (N,) or (N, B) ±1 labels."""
        squeeze = y.ndim == 1
        yb = y[:, None] if squeeze else y
        fn = self._eval_binary.get(adjust_bias)
        if fn is None:
            fn = self._eval_binary[adjust_bias] = fastcv.make_eval_binary(
                adjust_bias=adjust_bias, donate=self._donate)
        if not adjust_bias:
            plan = self._strip_train(plan)
        yb = yb.astype(plan.h.dtype)
        padded, b = self._pad_cols(yb)
        out = fn(plan, padded)[..., :b]
        self.labels_evaluated += b
        return out[..., 0] if squeeze else out

    def eval_ridge(self, plan: fastcv.CVPlan, y: jax.Array) -> jax.Array:
        """Exact CV ridge predictions ẏ_Te. y: (N,) or (N, B) responses."""
        plan = self._strip_train(plan)
        squeeze = y.ndim == 1
        yb = (y[:, None] if squeeze else y).astype(plan.h.dtype)
        padded, b = self._pad_cols(yb)
        out = self._eval_ridge(plan, padded)[..., :b]
        self.labels_evaluated += b
        return out[..., 0] if squeeze else out

    def eval_multiclass(self, plan: fastcv.CVPlan, y: jax.Array,
                        num_classes: int) -> jax.Array:
        """Multi-class LDA CV predictions. y: int (N,) or (B, N)."""
        squeeze = y.ndim == 1
        yb = y[None, :] if squeeze else y
        fn = self._eval_multiclass.get(num_classes)
        if fn is None:
            fn = self._eval_multiclass[num_classes] = \
                multiclass.make_eval_multiclass(num_classes,
                                                donate=self._donate)
        padded, b = self._pad_rows(yb)
        out = fn(plan, padded)[:b]
        self.labels_evaluated += b
        return out[0] if squeeze else out

    # ------------------------------------------------------------------
    # RSA serving (pairwise-contrast RDMs + model scoring, §4.2)
    # ------------------------------------------------------------------

    def eval_rsa_pairs(self, plan: fastcv.CVPlan, cols: jax.Array,
                       dissimilarity: str = "accuracy",
                       adjust_bias: bool = True) -> jax.Array:
        """Pairwise-contrast dissimilarities. cols: (N, B) ±1/0 columns.

        Contrast columns are just label columns, so they ride the same
        bucketed column path as binary/ridge evals: padded (all-zero)
        columns score to a harmless constant and are sliced away.
        """
        fn = self._rsa_pairs.get((dissimilarity, adjust_bias))
        if fn is None:
            fn = self._rsa_pairs[(dissimilarity, adjust_bias)] = \
                rsa_rdm.make_eval_pairs(dissimilarity, adjust_bias,
                                        donate=self._donate)
        if not adjust_bias:
            plan = self._strip_train(plan)
        cols = cols.astype(plan.h.dtype)
        padded, b = self._pad_cols(cols)
        out = fn(plan, padded)[:b]
        self.labels_evaluated += b
        return out

    def compare_rdms(self, empirical: jax.Array, model_rdms: jax.Array,
                     method: str = "spearman", n_perm: int = 0,
                     key: Optional[jax.Array] = None):
        """Score model RDMs against an empirical RDM; optional null.

        Returns (scores (M,), null (M, n_perm) | None, p (M,) | None).
        Null permutations are generated at the bucketed size (like the CV
        permutation path), so arbitrary client-chosen n_perm never
        compiles a fresh program after one warm-up per shape bucket.
        """
        fn = self._rsa_score.get(method)
        if fn is None:
            fn = self._rsa_score[method] = rsa_compare.make_compare(method)
        scores = fn(empirical, model_rdms)
        if n_perm <= 0:
            return scores, None, None
        nfn = self._rsa_null.get(method)
        if nfn is None:
            nfn = self._rsa_null[method] = rsa_compare.make_compare_null(method)
        t_gen = bucket_size(n_perm, self.config.buckets)
        if key is None:
            key = jax.random.PRNGKey(0)
        perms = perm_lib.permutation_indices(key, empirical.shape[0], t_gen)
        null = nfn(empirical, model_rdms, perms)[:, :n_perm]
        p = ((1.0 + jnp.sum(null >= scores[:, None], axis=1))
             / (1.0 + n_perm))
        return scores, null, p

    # ------------------------------------------------------------------
    # Permutation serving (Algorithms 1 & 2 against a cached plan)
    # ------------------------------------------------------------------

    def _perm_binary_fn(self, metric: str, adjust_bias: bool):
        """jit[(plan, y (N,), perms (B, N)) -> (B,) metrics].

        The label gather lives *inside* the jit so the permuted (N, B)
        label matrix is fused away rather than materialised per request."""
        fn = self._perm_binary.get((metric, adjust_bias))
        if fn is None:
            def _eval(plan, y, perms):
                yp = y[perms].T                            # (N, B)
                dv = fastcv.binary_dvals(plan, yp, adjust_bias=adjust_bias)
                return perm_lib._fold_metric_binary(dv, yp[plan.te_idx],
                                                    metric)
            fn = self._perm_binary[(metric, adjust_bias)] = jax.jit(_eval)
        return fn

    def _perm_multiclass_fn(self, num_classes: int):
        fn = self._perm_multiclass.get(num_classes)
        if fn is None:
            def _eval(plan, y, perms):
                y_rows = y[perms]                          # (B, N)
                preds = multiclass.batch_predict(plan, y_rows, num_classes)
                y_te = y_rows[:, plan.te_idx]              # (B, K, m)
                return jax.vmap(metrics.multiclass_accuracy)(preds, y_te)
            fn = self._perm_multiclass[num_classes] = jax.jit(_eval)
        return fn

    def permutation_binary(self, plan: fastcv.CVPlan, y: jax.Array,
                           n_perm: int, key: jax.Array, *,
                           metric: str = "accuracy",
                           adjust_bias: bool = True) -> perm_lib.PermutationResult:
        """Algorithm 1 against a cached plan: observed + null + p-value.

        With a mesh configured, the permutation batch shards over the
        mesh's ``perm_axes``; otherwise it runs through the bucketed local
        eval path (padded to a static shape, so repeats never recompile).
        """
        if not adjust_bias:
            plan = self._strip_train(plan)
        y = y.astype(plan.h.dtype)
        n = y.shape[0]
        fn = self._perm_binary_fn(metric, adjust_bias)
        identity = jnp.arange(n, dtype=jnp.int32)[None]    # unpermuted row
        observed = fn(plan, y, self._pad_rows(identity)[0])[0]
        # Generate directly at the bucket size: permutation_indices jits on
        # static (n, T), so bucketing T here is what keeps arbitrary
        # client-chosen n_perm from compiling a fresh generator each time.
        t_gen = bucket_size(n_perm, self.config.buckets)
        perms = perm_lib.permutation_indices(key, n, t_gen)
        if self.config.mesh is not None:
            from repro.core.distributed import sharded_null_from_plan
            n_shards = 1
            for a in self.config.perm_axes:
                n_shards *= self.config.mesh.shape[a]
            t_pad = -(-t_gen // n_shards) * n_shards
            perms = jnp.pad(perms, ((0, t_pad - t_gen), (0, 0)), mode="edge")
            null = sharded_null_from_plan(
                plan, y, perms, self.config.mesh, metric=metric,
                perm_axes=self.config.perm_axes,
                adjust_bias=adjust_bias)[:n_perm]
        else:
            null = fn(plan, y, self._pad_rows(perms)[0])[:n_perm]
        self.labels_evaluated += n_perm
        return perm_lib.PermutationResult(observed, null,
                                          perm_lib.p_value(observed, null))

    def permutation_multiclass(self, plan: fastcv.CVPlan, y: jax.Array,
                               n_perm: int, key: jax.Array, *,
                               num_classes: int) -> perm_lib.PermutationResult:
        """Algorithm 2 under permutations against a cached plan."""
        fn = self._perm_multiclass_fn(num_classes)
        n = y.shape[0]
        identity = jnp.arange(n, dtype=jnp.int32)[None]
        observed = fn(plan, y, self._pad_rows(identity)[0])[0]
        t_gen = bucket_size(n_perm, self.config.buckets)
        perms = perm_lib.permutation_indices(key, n, t_gen)
        null = fn(plan, y, self._pad_rows(perms)[0])[:n_perm]
        self.labels_evaluated += n_perm
        return perm_lib.PermutationResult(observed, null,
                                          perm_lib.p_value(observed, null))

    # ------------------------------------------------------------------
    # Tuning (routed to the eigendecomposition-based LOO machinery)
    # ------------------------------------------------------------------

    def tune(self, x: jax.Array, y: jax.Array, lambdas=None,
             criterion: str = "mse") -> tuning.RidgeTuneResult:
        return tuning.tune_ridge(x, y, lambdas=lambdas, criterion=criterion)

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def compile_count(self) -> int:
        """Total jit cache entries across every eval path this engine owns.

        Stable compile_count across requests == zero recompiles."""
        fns = ([self._eval_ridge] + list(self._eval_binary.values())
               + list(self._eval_multiclass.values())
               + list(self._perm_binary.values())
               + list(self._perm_multiclass.values())
               + list(self._rsa_pairs.values())
               + list(self._rsa_score.values())
               + list(self._rsa_null.values()))
        return int(sum(f._cache_size() for f in fns))

    def stats(self) -> dict:
        s = self.cache.stats.as_dict()
        s.update(plans_built=self.plans_built,
                 labels_evaluated=self.labels_evaluated,
                 compiles=self.compile_count())
        return s
