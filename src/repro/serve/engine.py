"""CVEngine: plan-cached, shape-bucketed analytical-CV evaluation.

The engine is the multi-tenant core of ``repro.serve``. It owns

  * a :class:`~repro.serve.cache.PlanCache` — one
    :class:`~repro.core.fastcv.CVPlan` per (dataset × folds × λ × mode),
    LRU-evicted under a byte budget, so repeated requests against the same
    features never re-factorise — optionally backed by a durable
    :class:`~repro.serve.store.PlanStore` tier (``plan_store`` config):
    cache misses read-through from disk before rebuilding, fresh builds
    persist write-behind (``save_plans``), so a restarted replica
    warm-boots with zero plan builds;
  * a **dataset registry** — :meth:`CVEngine.register` fingerprints a
    dataset once and returns a
    :class:`~repro.serve.workload.DatasetHandle`; workloads carry the
    handle instead of re-shipping the feature matrix, evicted plans
    rebuild transparently, and :meth:`datasets` exposes residency /
    pinning / traffic per registration. The registry is *mutable and
    versioned*: :meth:`append` / :meth:`retire` /
    :meth:`update_dataset` advance a dataset to a version n+1 handle by
    rank-k plan correction (:func:`repro.core.fastcv.update_plan`),
    while version n stays servable — in-flight workloads pin it
    (:meth:`retain_version`) — until :meth:`release`;
  * the CV *jitted evaluators*, drawn from the least-squares **estimator
    registry** (:mod:`repro.serve.workload`): one compiled program per
    (eval family × static options × shape bucket), created lazily but
    exactly once per engine so jit caches — and hence compile counts —
    are observable. Binary LDA, multi-class LDA, ridge, and multi-target
    ridge are registrations; :meth:`eval_estimator` serves any newly
    registered model with zero engine changes. Permutation-null metrics
    and RSA scoring keep their own jit families;
  * an **RDM memo** (:class:`repro.rsa.rdm.RDMCache`): empirical RDMs
    keyed by (plan, labels fingerprint), so repeat model scoring against
    the same data skips the fold solves (``stats()["rdm_hits"]``);
  * *shape buckets* for the label-batch dimension: every batch is padded up
    to a static bucket size before hitting jit, so an engine serving ragged
    traffic compiles at most ``len(buckets)`` programs per eval path and
    zero after warm-up.

Plan builds route the O(N²P) centered-Gram hot-spot through the Pallas
``gram`` kernel on TPU (``gram_impl="auto"``/"pallas") or through
``distributed_gram`` when a mesh is configured (``gram_impl="distributed"``,
which also shards permutation batches over the mesh's data axes).

:meth:`CVEngine.warmup` turns the lazy caches into an explicit readiness
API: it pre-builds (and optionally pins) the plan for a dataset spec and
pre-compiles the bucketed eval family for a set of tasks, so first real
traffic hits zero plan builds and zero compiles. The chunk-level
``observed_*`` / ``null_*`` methods expose the permutation machinery at
sub-request granularity — the streaming front-end
(:mod:`repro.serve.aio`) drives them to emit incremental null chunks.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastcv, metrics, multiclass, tuning
from repro.core import permutation as perm_lib
from repro.core.folds import Folds
from repro.kernels.common import default_fused
from repro.rsa import compare as rsa_compare
from repro.rsa import rdm as rsa_rdm
from repro.serve.batching import DEFAULT_BUCKETS, MicroBatcher, as_folds, bucket_size
from repro.serve.cache import PlanCache
from repro.serve.obs import BUCKET_FAMILIES, METRICS, MetricsRegistry
from repro.serve.store import PlanStore
from repro.serve.trace import STAGES, Tracer
from repro.serve.workload import DatasetHandle, get_estimator

__all__ = ["EngineConfig", "CVEngine", "DatasetHandle"]

_GRAM_IMPLS = ("auto", "xla", "pallas", "distributed")
_PRECISIONS = ("fp32", "bf16_gram")  # mirrors repro.kernels.gram.ops.PRECISIONS
_WARMUP_TASKS = ("binary", "ridge", "multiclass", "permutation", "rsa")


@dataclasses.dataclass
class _DatasetRecord:
    """Registry entry behind a :class:`DatasetHandle`.

    Keeps the actual feature matrix and folds so plans evicted under cache
    pressure can be rebuilt from the handle alone — clients never re-ship
    the bytes.

    ``version``/``n_appended`` mirror the handle (the registry is the
    source of truth for the mutable-dataset lineage). ``refs`` counts
    in-flight workload batches pinning this version
    (:meth:`CVEngine.retain_version`); ``retired`` marks a version whose
    :meth:`CVEngine.release` was deferred until those refs drain.
    """

    handle: DatasetHandle
    x: jax.Array
    folds: Folds
    lam: float
    mode: str
    served: int = 0
    last_used: float = 0.0  # wall-clock (time.time) — display only, never a deadline
    version: int = 0
    n_appended: int = 0
    refs: int = 0
    retired: bool = False
    drop_store: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine knobs.

    cache_bytes: PlanCache byte budget.
    gram_impl:   "auto" (Pallas kernel on TPU, plain XLA elsewhere),
                 "xla", "pallas", or "distributed" (requires ``mesh``).
    mesh:        optional jax Mesh; enables distributed plan builds and
                 mesh-sharded permutation batches.
    feature_axis / perm_axes: mesh axis names for the feature-sharded Gram
                 reduction and the permutation fan-out respectively.
    donate:      donate label-batch buffers to the jitted evals. Off by
                 default (None/False): donation lets XLA alias the batch
                 into the eval's output (single-use permutation chunks
                 never round-trip), meaningful on TPU/GPU. With donate on,
                 batches the engine doesn't own are defensively copied
                 before hitting an exact shape bucket (no padding = no
                 implicit copy), so a caller's array is never invalidated
                 behind its back; internal paths pass ``owned=True`` and
                 donate end-to-end.
    fused:       route CV evals through the fused Pallas fold-eval
                 kernels instead of the XLA reference composite. None
                 (default) = auto: on where Pallas compiles natively
                 (TPU), off elsewhere (interpret mode is Python-slow).
                 Plans without train blocks get the fully fused
                 ``fold_eval`` kernel (no (N, B) Ê materialisation);
                 train-block paths fuse the fold-solve stage.
    precision:   Gram/hat build precision: "fp32" (default; the working
                 dtype end-to-end) or "bf16_gram" (dual-mode Gram built
                 from bf16 inputs with f32 accumulation, all solves full
                 precision — see :mod:`repro.kernels.gram.ops` for the
                 error bound). Part of the plan key: the two precisions
                 never share cached plans.
    buckets:     static label-batch sizes; ragged batches pad up to these.
    plan_store:  optional directory for the durable plan tier
                 (:class:`repro.serve.store.PlanStore`): cache misses try
                 a verified disk read before the O(N²P) rebuild.
    save_plans:  with ``plan_store``: write-behind every freshly built
                 plan to the store (off = read-only warm-boot tier).
    store_bytes: plan-store byte budget (GC evicts oldest entries over
                 it, never those pinned in the in-memory cache).
    """

    cache_bytes: int = 512 << 20
    gram_impl: str = "auto"
    mesh: Optional[object] = None
    feature_axis: str = "model"
    perm_axes: tuple = ("data",)
    donate: Optional[bool] = None
    fused: Optional[bool] = None
    precision: str = "fp32"
    buckets: Sequence[int] = DEFAULT_BUCKETS
    plan_store: Optional[str] = None
    save_plans: bool = False
    store_bytes: int = 4 << 30

    def __post_init__(self):
        if self.gram_impl not in _GRAM_IMPLS:
            raise ValueError(f"gram_impl must be one of {_GRAM_IMPLS}")
        if self.gram_impl == "distributed" and self.mesh is None:
            raise ValueError("gram_impl='distributed' requires a mesh")
        if self.save_plans and not self.plan_store:
            raise ValueError("save_plans=True requires a plan_store directory")
        if self.precision not in _PRECISIONS:
            raise ValueError(f"precision must be one of {_PRECISIONS}")
        if self.precision != "fp32" and self.gram_impl == "distributed":
            raise ValueError(
                "precision='bf16_gram' is not supported with "
                "gram_impl='distributed' (the feature-sharded reduction "
                "has no mixed-precision path yet)")


class CVEngine:
    """Multi-tenant analytical-CV evaluation engine."""

    # Concurrency contract, machine-checked by reprolint RL004: the
    # thread server (EngineServer) and the asyncio gather loop both drive
    # one engine, so the lifetime stat counters increment under _lock —
    # a lost `+= b` here silently skews capacity accounting.
    _GUARDED_BY = {
        "plans_built": "_lock",
        "plans_updated": "_lock",
        "labels_evaluated": "_lock",
    }

    def __init__(self, config: Optional[EngineConfig] = None):
        self.config = config or EngineConfig()
        self.cache = PlanCache(self.config.cache_bytes)
        self.store = (
            PlanStore(self.config.plan_store, byte_budget=self.config.store_bytes)
            if self.config.plan_store
            else None
        )
        self.rdm_cache = rsa_rdm.RDMCache()
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(registry=self.metrics)
        self._declare_metrics()
        self.batcher = MicroBatcher(self.config.buckets, metrics=self.metrics)
        self._donate = bool(self.config.donate)
        self._fused = default_fused() if self.config.fused is None else bool(self.config.fused)
        # Eval paths are created lazily but exactly once per static
        # signature and held forever: the dict entry IS the jit cache the
        # no-recompile guarantee rests on. CV evals come from the
        # least-squares estimator registry (repro.serve.workload): one
        # jitted program per (eval_key, static options, donate, fused) —
        # registered estimators sharing an eval_key (ridge / ridge_multi)
        # share it. donate/fused sit in the key so flipping either
        # (set_donate, a reconfigured engine) can never serve a stale
        # program with the wrong aliasing or kernel route.
        self._evals = {}  # (eval_key, static opts, donate, fused) -> jit
        self._perm_binary = {}  # (metric, adjust_bias) -> jit -> (B,)
        self._perm_multiclass = {}  # num_classes -> jit -> (B,)
        self._rsa_pairs = {}  # (dissim, adjust_bias, donate, fused) -> jit
        self._rsa_score = {}  # method -> jit[(emp, models) -> (M,)]
        self._rsa_null = {}  # method -> jit[(emp, models, perms) -> (M,T)]
        self._datasets = {}  # handle key -> _DatasetRecord
        self._lock = threading.Lock()  # guards the stat counters below
        self.plans_built = 0
        self.plans_updated = 0
        self.labels_evaluated = 0

    def _declare_metrics(self) -> None:
        """Register the central :data:`repro.serve.obs.METRICS` table.

        The table is the single declaration of every metric name, kind
        and label-key set (reprolint RL003 checks call sites against it);
        this method contributes only *behavior*: the callback behind each
        gauge. Cache / jit / memo health is exported through callback
        gauges over the existing counters — the registry is a view, never
        a second copy, which is what keeps ``stats()`` bit-for-bit
        identical to its pre-observability schema. Stage histograms get
        every stage label pre-declared so the ``/v1/metrics`` exposition
        lists the full vocabulary before any traffic.
        """
        m = self.metrics
        gauge_sources = {
            "plan_cache_hits": lambda: self.cache.stats.hits,
            "plan_cache_misses": lambda: self.cache.stats.misses,
            "plan_cache_evictions": lambda: self.cache.stats.evictions,
            "plan_cache_oversized": lambda: self.cache.stats.oversized,
            "plan_cache_bytes_in_use": lambda: self.cache.stats.bytes_in_use,
            "plan_store_hits": lambda: self.store.stats.hits if self.store else 0,
            "plan_store_misses": lambda: self.store.stats.misses if self.store else 0,
            "plan_store_writes": lambda: self.store.stats.writes if self.store else 0,
            "plan_store_bytes": lambda: self.store.stats.bytes_in_store if self.store else 0,
            "compile_events": self.compile_count,
            "rdm_hits": lambda: self.rdm_cache.hits,
            "plans_built": lambda: self.plans_built,
            "plans_updated": lambda: self.plans_updated,
            "labels_evaluated": lambda: self.labels_evaluated,
            "datasets_registered": lambda: len(self._datasets),
        }
        for name, spec in METRICS.items():
            kind = spec["kind"]
            if kind == "counter":
                m.counter(name, spec["help"], labels=spec["labels"])
            elif kind == "histogram":
                m.histogram(
                    name,
                    spec["help"],
                    buckets=BUCKET_FAMILIES[spec["buckets"]],
                    labels=spec["labels"],
                )
            else:
                # KeyError here means METRICS declares a gauge this engine
                # supplies no callback for — fail at construction, loudly.
                m.gauge(name, spec["help"], fn=gauge_sources.pop(name))
        if gauge_sources:
            raise RuntimeError(
                f"gauge callbacks without a METRICS declaration: {sorted(gauge_sources)}"
            )
        stage_hist = m.get("stage_latency_seconds")
        for stage in STAGES:
            stage_hist.declare(stage=stage)

    def enable_tracing(self, ring: int = 256) -> None:
        """Turn on request-scoped span tracing (``serve_cv --metrics``).

        Every subsequent workload gets a span tree (decode → encode),
        attached to its response as ``timings`` and kept in a bounded ring
        of ``ring`` traces (``GET /v1/trace``, :meth:`Tracer.summary`).
        Tracing adds per-stage clock reads and a ``block_until_ready``
        per span — leave it off for peak-throughput serving.
        """
        self.tracer.enable(ring=ring)

    def disable_tracing(self) -> None:
        """Back to zero-overhead mode (finished traces stay in the ring)."""
        self.tracer.disable()

    def set_donate(self, donate: bool) -> None:
        """Flip label-batch donation at runtime.

        Safe mid-traffic: donate is part of every eval-cache key, so a
        non-donating program compiled before the flip can never be served
        for a donating request (or vice versa) — the regression that
        motivated keying the caches on it.
        """
        self._donate = bool(donate)

    # ------------------------------------------------------------------
    # Plans
    # ------------------------------------------------------------------

    def plan(
        self,
        x: jax.Array,
        folds: Folds,
        lam: float,
        mode: str = "auto",
        with_train_block: bool = True,
        version: int = 0,
    ):
        """Fetch-or-build the plan for (x, folds, λ). Returns (key, plan).

        Lookup order: memory (PlanCache) → disk (PlanStore, when
        configured) → build. A plan *with* the train block is a superset
        of the one without (same H, same factors, extra H_{Tr,Te}), so a
        ridge request is happily served from a cached bias-adjust plan.
        ``version`` is the dataset-registry version the key is minted
        under (0 for unregistered / freshly registered data)."""
        with self.tracer.span("cache_lookup"):
            key = fastcv.plan_key(x, folds, lam, mode, with_train_block,
                                  version=version, precision=self.config.precision)
            if not with_train_block:
                superset = key[:-1] + (True,)
                plan = self.cache.get(superset)
                if plan is not None:
                    return superset, plan
        plan, _ = self.cache.get_or_build(
            key,
            lambda: self._build_plan(x, folds, lam, mode, with_train_block, key=key),
            fetch=self._store_fetch(key),
        )
        return key, plan

    def _store_fetch(self, key):
        """Read-through closure for the disk tier (None when no store).

        ``store_load`` is its own trace stage: warm-boot budgets care
        whether a miss cost a disk read or an O(N²P) rebuild.
        """
        if self.store is None:
            return None

        def fetch():
            with self.tracer.span("store_load"):
                return self.tracer.sync(self.store.load(key))

        return fetch

    def _build_plan(self, x, folds, lam, mode, with_train_block, key=None):
        # Top-level span (not nested under cache_lookup) so the build cost
        # lands in its own stage_latency_seconds series — plan_build is the
        # budget the next perf PR (kernel fusion) is judged against.
        with self.tracer.span("plan_build"):
            n, p = x.shape
            resolved = ("dual" if p >= n else "primal") if mode == "auto" else mode
            gram = self._build_gram(x) if resolved == "dual" else None
            plan = self.tracer.sync(
                fastcv.prepare(
                    x, folds, lam, mode=resolved, with_train_block=with_train_block,
                    gram=gram, precision=self.config.precision
                )
            )
        with self._lock:
            self.plans_built += 1
        if key is not None and self.store is not None and self.config.save_plans:
            # Write-behind: snapshot now, commit off the request path. The
            # current pin set shields those entries from this write's GC.
            self.store.save_async(key, plan, protect=self.cache.pinned_keys())
        return plan

    def flush_store(self) -> None:
        """Join outstanding write-behind plan saves (shutdown path);
        no-op without a configured store."""
        if self.store is not None:
            self.store.flush()

    def _build_gram(self, x):
        impl = self.config.gram_impl
        if impl == "auto":
            impl = "pallas" if jax.default_backend() == "tpu" else "xla"
        if impl == "xla":
            return None  # prepare() computes it inline (honouring precision)
        if impl == "pallas":
            from repro.kernels.gram.ops import centered_gram

            return centered_gram(x, precision=self.config.precision)
        from repro.core.distributed import distributed_gram

        return distributed_gram(x, self.config.mesh, feature_axis=self.config.feature_axis)

    # ------------------------------------------------------------------
    # Dataset registry: register once, serve by handle
    # ------------------------------------------------------------------

    def register(self, x: jax.Array, folds, lam: float, mode: str = "auto") -> DatasetHandle:
        """Register a dataset; returns a :class:`DatasetHandle`.

        The handle is keyed by the same content fingerprint the plan cache
        uses (``fastcv.plan_key``), so registering identical bytes twice
        yields the same handle. Workloads carry the handle instead of
        re-shipping the feature matrix; the engine keeps the features so a
        plan evicted under byte pressure rebuilds transparently on next
        use. Handle-scoped operations: :meth:`warmup` (accepts a handle),
        :meth:`pin`/:meth:`unpin` (via ``handle.key``), :meth:`evict`, and
        the :meth:`datasets` introspection view.
        """
        folds = as_folds(folds)
        key = fastcv.plan_key(x, folds, lam, mode, True, version=0,
                              precision=self.config.precision)
        rec = self._datasets.get(key)
        if rec is None:
            handle = DatasetHandle(
                key=key, n=int(x.shape[0]), p=int(x.shape[1]), lam=float(lam), mode=mode
            )
            rec = self._datasets[key] = _DatasetRecord(handle, x, folds, float(lam), mode)
        return rec.handle

    def dataset_record(self, handle: DatasetHandle) -> _DatasetRecord:
        rec = self._datasets.get(handle.key)
        if rec is None:
            raise KeyError(f"dataset handle {handle.key[0][:8]} is not registered on this engine")
        return rec

    def resolve(self, dataset, with_train_block: bool = True):
        """(key, plan) for a :class:`DatasetHandle` or inline spec.

        Handles resolve through the registry (rebuilding the plan if it
        was evicted); anything with ``x`` / ``folds`` / ``lam`` attributes
        — e.g. :class:`repro.serve.workload.DatasetSpec` — is planned
        directly.
        """
        if isinstance(dataset, DatasetHandle):
            rec = self.dataset_record(dataset)
            rec.served += 1
            rec.last_used = time.time()
            return self.plan(
                rec.x,
                rec.folds,
                rec.lam,
                mode=rec.mode,
                with_train_block=with_train_block,
                version=rec.version,
            )
        folds = as_folds(dataset.folds)
        mode = getattr(dataset, "mode", "auto")
        return self.plan(
            dataset.x,
            folds,
            dataset.lam,
            mode=mode,
            with_train_block=with_train_block,
            version=getattr(dataset, "version", 0),
        )

    def evict(self, handle: DatasetHandle, *, deregister: bool = False) -> bool:
        """Drop a registered dataset's cached plans (both train-block
        variants); with ``deregister`` also forget the registration."""
        rec = self._datasets.get(handle.key)
        removed = self.cache.remove(handle.key)
        no_train = handle.key[:-1] + (False,)
        removed = self.cache.remove(no_train) or removed
        if deregister and rec is not None:
            del self._datasets[handle.key]
        return removed

    # ------------------------------------------------------------------
    # Mutable versioned datasets: append / retire / sliding window
    # ------------------------------------------------------------------

    def update_dataset(
        self,
        handle: DatasetHandle,
        *,
        x_new=None,
        drop_idx=None,
        folds_delta=None,
    ) -> DatasetHandle:
        """Advance a registered dataset to version n+1 and return its handle.

        Exactly one logical operation per call, picked by the arguments:
        ``x_new`` alone appends rows (round-robin over folds by default —
        requires ``len(x_new) % K == 0`` — or per ``folds_delta``),
        ``drop_idx`` alone retires rows, both together slide the window
        (appended rows inherit the dropped rows' fold slots unless
        ``folds_delta`` says otherwise). Dual-mode plans advance by the
        rank-k correction in :func:`repro.core.fastcv.update_plan` — no
        Gram rebuild, no XLA entry; primal plans fall back to a from-scratch
        rebuild with the same fold evolution.

        The previous version stays registered and servable (in-flight
        workloads pin it via :meth:`retain_version`) until
        :meth:`release` — the two versions have distinct plan keys, so the
        PlanCache/PlanStore never conflate them.
        """
        # reprolint: host-path
        # (Update-group coalescing: everything until the plan correction
        # runs on host; jnp is only entered through asarray/device slices.)
        rec = self.dataset_record(handle)
        if x_new is None and drop_idx is None:
            raise ValueError(
                "update_dataset needs x_new (append), drop_idx (retire), or both (window)"
            )
        n, p = int(rec.x.shape[0]), int(rec.x.shape[1])
        k = 0 if x_new is None else int(x_new.shape[0])
        drop = None
        if drop_idx is not None:
            drop = np.asarray(jax.device_get(drop_idx)).reshape(-1).astype(np.int64)
        d = 0 if drop is None else int(drop.size)
        if k and not d and folds_delta is None:
            n_folds = rec.folds.k
            if k % n_folds:
                raise ValueError(
                    f"appending {k} rows to a {n_folds}-fold dataset without "
                    "folds_delta would leave ragged folds; pass a per-row fold "
                    f"assignment or append a multiple of {n_folds} rows"
                )
            folds_delta = np.arange(k, dtype=np.int64) % n_folds
        op = "window" if (k and d) else ("append" if k else "retire")
        resolved = rec.mode
        if resolved == "auto":
            resolved = "dual" if p >= n else "primal"
        _, plan = self.plan(
            rec.x, rec.folds, rec.lam, mode=rec.mode, with_train_block=True, version=rec.version
        )
        with self.tracer.span("plan_update"):
            if resolved == "dual":
                if op == "window":
                    plan2 = fastcv.sliding_window(
                        plan,
                        x_new,
                        drop,
                        x=rec.x,
                        lam=rec.lam,
                        mode="dual",
                        folds_delta=folds_delta,
                    )
                elif op == "append":
                    plan2 = fastcv.update_plan(
                        plan, x_new, folds_delta, x=rec.x, lam=rec.lam, mode="dual"
                    )
                else:
                    plan2 = fastcv.downdate_plan(plan, drop, x=rec.x, lam=rec.lam, mode="dual")
                folds2 = Folds.with_indices(plan2.te_idx, plan2.tr_idx, n=n - d + k)
            else:
                folds2 = self._updated_folds(rec, k, drop, folds_delta)
                plan2 = None
            x2 = rec.x
            if d:
                keep = np.setdiff1d(np.arange(n), drop)
                x2 = x2[jnp.asarray(keep)]
            if k:
                # Grows the registered device copy in place of a host
                # round-trip of the full X: window traffic repeats the
                # same (n, p) signature, so this concatenate is a
                # steady-state jit-cache hit, not per-call churn.
                x2 = jnp.concatenate(  # reprolint: ignore[RL001] -- steady-state shapes repeat
                    [x2, jnp.asarray(x_new, dtype=x2.dtype)]
                )
            new_version = rec.version + 1
            new_key = fastcv.plan_key(x2, folds2, rec.lam, resolved, True,
                                      version=new_version,
                                      precision=self.config.precision)
            if plan2 is None:
                plan2 = self._build_plan(x2, folds2, rec.lam, resolved, True, key=new_key)
            else:
                self.cache.get_or_build(new_key, lambda: plan2)
                if self.store is not None and self.config.save_plans:
                    self.store.save_async(new_key, plan2, protect=self.cache.pinned_keys())
        new_handle = DatasetHandle(
            key=new_key,
            n=int(x2.shape[0]),
            p=p,
            lam=rec.lam,
            mode=resolved,
            version=new_version,
            n_appended=rec.n_appended + k,
        )
        rec2 = self._datasets.get(new_key)
        if rec2 is None:
            rec2 = self._datasets[new_key] = _DatasetRecord(
                new_handle,
                x2,
                folds2,
                rec.lam,
                resolved,
                version=new_version,
                n_appended=rec.n_appended + k,
            )
        with self._lock:
            self.plans_updated += 1
        self.metrics.inc("plan_updates_total", op=op)
        self.metrics.observe("plan_update_rank", float(k + d))
        return rec2.handle

    def _updated_folds(self, rec: _DatasetRecord, k: int, drop, folds_delta) -> Folds:
        """Fold evolution for the primal (full-rebuild) fallback — the same
        geometry the dual fast path derives from the corrected plan."""
        if isinstance(folds_delta, Folds):
            return folds_delta
        te = np.asarray(jax.device_get(rec.folds.te_idx)).astype(np.int64)
        n = int(rec.x.shape[0])
        d = 0 if drop is None else int(drop.size)
        if k and d:
            if folds_delta is None:
                if k != d:
                    raise ValueError(
                        "sliding-window update without folds_delta requires "
                        "len(x_new) == len(drop_idx) so appended rows can "
                        f"inherit fold slots (got {k} new vs {d} dropped)"
                    )
                assign = fastcv._fold_of(te, np.sort(drop))
            else:
                assign = np.asarray(jax.device_get(folds_delta)).reshape(-1).astype(np.int64)
            te2 = fastcv._window_folds(te, n, drop, assign)
        elif k:
            assign = np.asarray(jax.device_get(folds_delta)).reshape(-1).astype(np.int64)
            te2 = fastcv._extend_folds(te, n, assign)
        else:
            te2 = fastcv._drop_folds(te, n, drop)
        tr2 = fastcv._complement_folds(te2, n - d + k)
        return Folds.with_indices(
            jnp.asarray(te2, dtype=jnp.int32), jnp.asarray(tr2, dtype=jnp.int32), n=n - d + k
        )

    def append(self, handle: DatasetHandle, x_new, folds_delta=None) -> DatasetHandle:
        """Append rows to a registered dataset → version n+1 handle.

        Sugar for :meth:`update_dataset`; see it for fold-assignment rules
        and version-pinning semantics.
        """
        return self.update_dataset(handle, x_new=x_new, folds_delta=folds_delta)

    def retire(self, handle: DatasetHandle, idx) -> DatasetHandle:
        """Retire rows of a registered dataset → version n+1 handle."""
        return self.update_dataset(handle, drop_idx=idx)

    def release(self, handle: DatasetHandle, *, drop_store: bool = False) -> bool:
        """Release a dataset version: deregister it and drop its cached
        plans once no in-flight workload pins it.

        With refs outstanding the version is only marked ``retired`` and
        the purge happens on the last :meth:`release_version`. With
        ``drop_store`` the durable :class:`PlanStore` entry is removed too
        (a clean removal — stale versions are *not* quarantined); without
        it the store entry stays for forensic warm-boots. Returns True if
        the purge ran now, False if deferred (or unknown handle).
        """
        rec = self._datasets.get(handle.key)
        if rec is None:
            return False
        rec.retired = True
        rec.drop_store = drop_store
        if rec.refs > 0:
            return False
        self._purge(handle.key, drop_store)
        return True

    def retain_version(self, key) -> None:
        """Pin a dataset version for an in-flight workload batch.

        Tolerant no-op for keys that are not registered versions (inline
        specs, raw plan keys)."""
        rec = self._datasets.get(key)
        if rec is not None:
            rec.refs += 1

    def release_version(self, key) -> None:
        """Drop an in-flight pin; purges the version if it was released
        (retired) while pinned. Tolerant no-op on unknown keys."""
        rec = self._datasets.get(key)
        if rec is None:
            return
        rec.refs = max(0, rec.refs - 1)
        if rec.retired and rec.refs == 0:
            self._purge(key, rec.drop_store)

    def _purge(self, key, drop_store: bool) -> None:
        """Forget a dataset version: registry entry, both cached plan
        variants, and (optionally) the durable store entry — cleanly, so
        eviction of a stale version never quarantines its checkpoint."""
        self._datasets.pop(key, None)
        self.cache.unpin(key)
        self.cache.remove(key)
        no_train = key[:-1] + (False,)
        self.cache.unpin(no_train)
        self.cache.remove(no_train)
        if drop_store and self.store is not None:
            self.store.remove(key)
            self.store.remove(no_train)

    def datasets(self) -> tuple:
        """Introspection view: one dict per registered dataset."""
        out = []
        for key, rec in self._datasets.items():
            plan = self.cache.peek(key) or self.cache.peek(key[:-1] + (False,))
            out.append(
                {
                    "handle": rec.handle,
                    "n": rec.handle.n,
                    "p": rec.handle.p,
                    "lam": rec.lam,
                    "mode": rec.mode,
                    "version": rec.version,
                    "n_appended": rec.n_appended,
                    "served": rec.served,
                    "resident": plan is not None,
                    "pinned": key in self.cache.pinned_keys(),
                    "nbytes": plan.nbytes if plan is not None else 0,
                }
            )
        return tuple(out)

    # -- pinning (PlanCache passthrough) -------------------------------

    def pin(self, key) -> bool:
        """Exempt a cached plan from eviction; see :meth:`PlanCache.pin`.

        Accepts a raw plan key or a :class:`DatasetHandle`.
        """
        return self.cache.pin(key.key if isinstance(key, DatasetHandle) else key)

    def unpin(self, key) -> bool:
        return self.cache.unpin(key.key if isinstance(key, DatasetHandle) else key)

    # ------------------------------------------------------------------
    # Warm-up: pre-build plans, pre-compile the bucketed eval family
    # ------------------------------------------------------------------

    def warmup(
        self,
        spec,
        tasks: Sequence[str] = ("binary",),
        buckets: Optional[Sequence[int]] = None,
        *,
        num_classes: int = 0,
        metric: str = "accuracy",
        adjust_bias: bool = True,
        dissimilarity: str = "accuracy",
        comparison: str = "spearman",
        num_model_rdms: int = 0,
        pin: bool = False,
    ) -> dict:
        """Pre-build the plan for ``spec`` and pre-compile eval programs.

        ``spec`` is anything with ``x`` / ``folds`` / ``lam`` (and
        optionally ``mode``) attributes — e.g. :class:`repro.serve.api
        .DatasetSpec`. ``tasks`` selects eval families from
        {"binary", "ridge", "multiclass", "permutation", "rsa"};
        ``buckets`` the label-batch sizes to compile (default: every
        configured bucket; values are canonicalised via ``bucket_size``).
        After a warm-up covering the shapes traffic will hit,
        ``compile_count()`` stays flat — first real requests pay only the
        O(K·m²) fold solves.

        The "rsa" task compiles the pairwise-contrast path for
        (``dissimilarity``, ``adjust_bias``); with ``num_model_rdms`` > 0
        it also compiles the model-scoring + permutation-null programs for
        ``comparison`` at every null bucket (the model count M is a static
        shape, so pass the M real traffic will carry).

        With ``pin=True`` the built plan is pinned in the cache (never
        LRU-evicted, excluded from budget pressure) until ``unpin``.
        Returns a summary dict (plan_key, buckets, compiles, pinned).
        """
        unknown = [t for t in tasks if t not in _WARMUP_TASKS]
        if unknown:
            raise ValueError(f"unknown warmup tasks {unknown}; expected {_WARMUP_TASKS}")
        if "multiclass" in tasks and num_classes < 2:
            raise ValueError("warmup of 'multiclass' needs num_classes >= 2")
        if isinstance(spec, DatasetHandle):
            spec = self.dataset_record(spec)
        key, plan = self.resolve(spec, with_train_block=True)
        wanted = sorted(
            {bucket_size(b, self.config.buckets) for b in (buckets or self.config.buckets)}
        )
        n = int(spec.x.shape[0])
        y_bin = jnp.where(jnp.arange(n) % 2 == 0, -1.0, 1.0).astype(plan.h.dtype)
        y_mc = (jnp.arange(n, dtype=jnp.int32) % max(num_classes, 2)).astype(jnp.int32)
        outs = []
        if "permutation" in tasks:
            outs.append(self.observed_binary(plan, y_bin, metric=metric, adjust_bias=adjust_bias))
            if num_classes >= 2:
                outs.append(self.observed_multiclass(plan, y_mc, num_classes=num_classes))
        for b in wanted:
            if "binary" in tasks:
                cols = jnp.tile(y_bin[:, None], (1, b))
                outs.append(self.eval_binary(plan, cols, adjust_bias))
            if "ridge" in tasks:
                outs.append(self.eval_ridge(plan, jnp.tile(y_bin[:, None], (1, b))))
            if "multiclass" in tasks:
                rows = jnp.tile(y_mc[None, :], (b, 1))
                outs.append(self.eval_multiclass(plan, rows, num_classes))
            if "permutation" in tasks:
                perms = perm_lib.permutation_indices(jax.random.PRNGKey(0), n, b)
                outs.append(
                    self.null_binary(plan, y_bin, perms, metric=metric, adjust_bias=adjust_bias)
                )
                if num_classes >= 2:  # mirrors the observed_multiclass gate above
                    outs.append(self.null_multiclass(plan, y_mc, perms, num_classes=num_classes))
            if "rsa" in tasks:
                cols = jnp.tile(y_bin[:, None], (1, b))
                outs.append(self.eval_rsa_pairs(plan, cols, dissimilarity, adjust_bias))
        if "rsa" in tasks and num_model_rdms > 0:
            if num_classes < 2:
                raise ValueError("rsa model-scoring warmup needs num_classes >= 2")
            rdm0 = jnp.zeros((num_classes, num_classes), plan.h.dtype)
            models0 = jnp.zeros((num_model_rdms,) + rdm0.shape, plan.h.dtype)
            outs.append(self.score_rdms(rdm0, models0, comparison))
            for b in wanted:
                perms0 = perm_lib.permutation_indices(jax.random.PRNGKey(0), num_classes, b)
                outs.append(self.null_rdm_scores(rdm0, models0, perms0, comparison))
        jax.block_until_ready(outs)
        pinned = self.cache.pin(key) if pin else False
        return {
            "plan_key": key,
            "buckets": tuple(wanted),
            "compiles": self.compile_count(),
            "pinned": pinned,
        }

    # ------------------------------------------------------------------
    # Shape-bucketed jitted evaluation
    # ------------------------------------------------------------------

    @staticmethod
    def _strip_train(plan: fastcv.CVPlan) -> fastcv.CVPlan:
        """Canonicalise a plan for train-block-free eval paths.

        A no-train-block request may be served from the cached *superset*
        plan (see :meth:`plan`), whose ``h_tr_te`` is an array instead of
        None — a different pytree structure, which would retrace the jitted
        eval and recompute the unused Eq. 15 train solves. Stripping the
        block restores one structure (and one compiled program) per shape.
        """
        if plan.h_tr_te is None:
            return plan
        return dataclasses.replace(plan, h_tr_te=None)

    def _pad_cols(self, y: jax.Array, *, owned: bool = False) -> tuple[jax.Array, int]:
        b = y.shape[1]
        padded = bucket_size(b, self.config.buckets)
        if padded > b:
            y = jnp.pad(y, ((0, 0), (0, padded - b)))
        elif self._donate and not owned:
            # Exact-bucket batches pass through without the implicit copy
            # padding provides; a donating eval would invalidate the
            # caller's array behind its back. Copy defensively — internal
            # single-use batches (MicroBatcher groups, permutation chunks)
            # declare owned=True and donate end-to-end instead.
            y = jnp.copy(y)
        return y, b

    def _pad_rows(self, y: jax.Array, *, owned: bool = False) -> tuple[jax.Array, int]:
        b = y.shape[0]
        padded = bucket_size(b, self.config.buckets)
        if padded > b:
            y = jnp.concatenate([y, jnp.broadcast_to(y[:1], (padded - b,) + y.shape[1:])], 0)
        elif self._donate and not owned:
            y = jnp.copy(y)  # same exact-bucket aliasing hazard as _pad_cols
        return y, b

    def eval_estimator(self, plan: fastcv.CVPlan, y: jax.Array, estimator: str,
                       owned: bool = False, **opts):
        """Shape-bucketed eval through the least-squares estimator registry.

        ``estimator`` names a registered
        :class:`~repro.serve.workload.LeastSquaresSpec`; the spec supplies
        the targets encoding, batch layout, jitted-eval factory, and
        train-block requirement — this one method is the engine's entire
        CV eval surface, so a newly registered estimator (multi-target
        ridge, optimal-scoring variants, …) is served, bucketed, and
        compile-counted with zero engine changes.

        ``owned=True`` declares the batch single-use engine property (the
        MicroBatcher's coalesced groups): with donation on it skips the
        exact-bucket defensive copy and lets the eval consume the buffer.
        """
        spec = get_estimator(estimator)
        opts = spec.resolve_opts(opts)
        if not spec.needs_train(opts):
            plan = self._strip_train(plan)
        batch, squeeze = spec.encode(y, plan.h.dtype, opts)
        owned = owned or batch is not y  # encode copied -> engine owns it
        key = (spec.eval_key, spec.static_key(opts), self._donate, self._fused)
        fn = self._evals.get(key)
        if fn is None:
            fn = self._evals[key] = spec.make_eval(opts, self._donate, self._fused)
        if spec.layout == "columns":
            padded, b = self._pad_cols(batch, owned=owned)
            with self.tracer.span("eval"):
                out = self.tracer.sync(fn(plan, padded)[..., :b])
            with self._lock:
                self.labels_evaluated += b
            return out[..., 0] if squeeze else out
        padded, b = self._pad_rows(batch, owned=owned)
        with self.tracer.span("eval"):
            out = self.tracer.sync(fn(plan, padded)[:b])
        with self._lock:
            self.labels_evaluated += b
        return out[0] if squeeze else out

    def eval_binary(self, plan: fastcv.CVPlan, y: jax.Array, adjust_bias: bool = True) -> jax.Array:
        """Binary-LDA decision values. y: (N,) or (N, B) ±1 labels."""
        return self.eval_estimator(plan, y, "binary", adjust_bias=adjust_bias)

    def eval_ridge(self, plan: fastcv.CVPlan, y: jax.Array) -> jax.Array:
        """Exact CV ridge predictions ẏ_Te. y: (N,) or (N, B) responses."""
        return self.eval_estimator(plan, y, "ridge")

    def eval_multiclass(
        self, plan: fastcv.CVPlan, y: jax.Array, num_classes: int, owned: bool = False
    ) -> jax.Array:
        """Multi-class LDA CV predictions. y: int (N,) or (B, N)."""
        return self.eval_estimator(plan, y, "multiclass", owned=owned, num_classes=num_classes)

    # ------------------------------------------------------------------
    # RSA serving (pairwise-contrast RDMs + model scoring, §4.2)
    # ------------------------------------------------------------------

    def eval_rsa_pairs(
        self,
        plan: fastcv.CVPlan,
        cols: jax.Array,
        dissimilarity: str = "accuracy",
        adjust_bias: bool = True,
        owned: bool = False,
    ) -> jax.Array:
        """Pairwise-contrast dissimilarities. cols: (N, B) ±1/0 columns.

        Contrast columns are just label columns, so they ride the same
        bucketed column path as binary/ridge evals: padded (all-zero)
        columns score to a harmless constant and are sliced away.
        ``owned`` as in :meth:`eval_estimator`.
        """
        cache_key = (dissimilarity, adjust_bias, self._donate, self._fused)
        fn = self._rsa_pairs.get(cache_key)
        if fn is None:
            fn = self._rsa_pairs[cache_key] = rsa_rdm.make_eval_pairs(
                dissimilarity, adjust_bias, donate=self._donate, fused=self._fused
            )
        if not adjust_bias:
            plan = self._strip_train(plan)
        cast = cols.astype(plan.h.dtype)
        owned = owned or cast is not cols  # dtype cast copied -> engine owns it
        cols = cast
        padded, b = self._pad_cols(cols, owned=owned)
        with self.tracer.span("eval"):
            out = self.tracer.sync(fn(plan, padded)[:b])
        with self._lock:
            self.labels_evaluated += b
        return out

    def score_rdms(
        self, empirical: jax.Array, model_rdms: jax.Array, method: str = "spearman"
    ) -> jax.Array:
        """(M,) model-RDM scores through the engine's jitted scorer."""
        fn = self._rsa_score.get(method)
        if fn is None:
            fn = self._rsa_score[method] = rsa_compare.make_compare(method)
        with self.tracer.span("eval"):
            return self.tracer.sync(fn(empirical, model_rdms))

    def null_rdm_scores(
        self,
        empirical: jax.Array,
        model_rdms: jax.Array,
        perms: jax.Array,
        method: str = "spearman",
    ) -> jax.Array:
        """(M, B) null scores for explicit condition permutations (B, C).

        The permutation batch pads up to a shape bucket like every other
        batched path, so chunked (streaming) nulls never recompile after
        one warm-up per chunk bucket.
        """
        with self.tracer.span("null_chunk"):
            fn = self._rsa_null.get(method)
            if fn is None:
                fn = self._rsa_null[method] = rsa_compare.make_compare_null(method)
            padded, b = self._pad_rows(perms, owned=True)
            return self.tracer.sync(fn(empirical, model_rdms, padded)[:, :b])

    def compare_rdms(
        self,
        empirical: jax.Array,
        model_rdms: jax.Array,
        method: str = "spearman",
        n_perm: int = 0,
        key: Optional[jax.Array] = None,
    ):
        """Score model RDMs against an empirical RDM; optional null.

        Returns (scores (M,), null (M, n_perm) | None, p (M,) | None).
        Null permutations are generated at the bucketed size (like the CV
        permutation path), so arbitrary client-chosen n_perm never
        compiles a fresh program after one warm-up per shape bucket.
        """
        scores = self.score_rdms(empirical, model_rdms, method)
        if n_perm <= 0:
            return scores, None, None
        t_gen = bucket_size(n_perm, self.config.buckets)
        if key is None:
            key = jax.random.PRNGKey(0)
        # Draw generation and the p-value are null-distribution work: they
        # count toward the null_chunk stage like the CV permutation path.
        with self.tracer.span("null_chunk"):
            perms = self.tracer.sync(
                perm_lib.permutation_indices(key, empirical.shape[0], t_gen)
            )
        null = self.null_rdm_scores(empirical, model_rdms, perms, method)
        with self.tracer.span("null_chunk"):
            null = null[:, :n_perm]
            p = self.tracer.sync(
                (1.0 + jnp.sum(null >= scores[:, None], axis=1)) / (1.0 + n_perm)
            )
        return scores, null, p

    # ------------------------------------------------------------------
    # Permutation serving (Algorithms 1 & 2 against a cached plan)
    # ------------------------------------------------------------------

    def _perm_binary_fn(self, metric: str, adjust_bias: bool):
        """jit[(plan, y (N,), perms (B, N)) -> (B,) metrics].

        The label gather lives *inside* the jit so the permuted (N, B)
        label matrix is fused away rather than materialised per request."""
        fn = self._perm_binary.get((metric, adjust_bias))
        if fn is None:

            def _eval(plan, y, perms):
                yp = y[perms].T  # (N, B)
                dv = fastcv.binary_dvals(plan, yp, adjust_bias=adjust_bias)
                return perm_lib._fold_metric_binary(dv, yp[plan.te_idx], metric)

            fn = self._perm_binary[(metric, adjust_bias)] = jax.jit(_eval)
        return fn

    def _perm_multiclass_fn(self, num_classes: int):
        fn = self._perm_multiclass.get(num_classes)
        if fn is None:

            def _eval(plan, y, perms):
                y_rows = y[perms]  # (B, N)
                preds = multiclass.batch_predict(plan, y_rows, num_classes)
                y_te = y_rows[:, plan.te_idx]  # (B, K, m)
                return jax.vmap(metrics.multiclass_accuracy)(preds, y_te)

            fn = self._perm_multiclass[num_classes] = jax.jit(_eval)
        return fn

    def observed_binary(
        self,
        plan: fastcv.CVPlan,
        y: jax.Array,
        *,
        metric: str = "accuracy",
        adjust_bias: bool = True,
    ) -> jax.Array:
        """Observed (unpermuted) binary metric through the permutation path."""
        # The span covers the dispatch preamble (dtype cast, identity
        # batch, padding) too — each is a device dispatch that would
        # otherwise show up as an untraced gap in the span timeline.
        with self.tracer.span("eval"):
            if not adjust_bias:
                plan = self._strip_train(plan)
            y = y.astype(plan.h.dtype)
            fn = self._perm_binary_fn(metric, adjust_bias)
            identity = jnp.arange(y.shape[0], dtype=jnp.int32)[None]
            return self.tracer.sync(fn(plan, y, self._pad_rows(identity, owned=True)[0])[0])

    def null_binary(
        self,
        plan: fastcv.CVPlan,
        y: jax.Array,
        perms: jax.Array,
        *,
        metric: str = "accuracy",
        adjust_bias: bool = True,
    ) -> jax.Array:
        """Null metrics for an explicit (B, N) permutation batch → (B,).

        The chunk-level building block under both :meth:`permutation_binary`
        and the streaming front-end. On a mesh-configured engine the batch
        shards over ``perm_axes`` via ``sharded_null_from_plan`` (padded up
        to a whole number of shards, trimmed back) — so *streamed* null
        chunks use the mesh exactly like monolithic requests, with
        identical draws. Locally, the batch pads up to a shape bucket and
        repeats never recompile.
        """
        b = perms.shape[0]
        with self.tracer.span("null_chunk"):
            if not adjust_bias:
                plan = self._strip_train(plan)
            y = y.astype(plan.h.dtype)
            if self.config.mesh is not None:
                from repro.core.distributed import sharded_null_from_plan

                n_shards = 1
                for a in self.config.perm_axes:
                    n_shards *= self.config.mesh.shape[a]
                t_pad = -(-b // n_shards) * n_shards
                if t_pad > b:
                    perms = jnp.pad(perms, ((0, t_pad - b), (0, 0)), mode="edge")
                out = sharded_null_from_plan(
                    plan,
                    y,
                    perms,
                    self.config.mesh,
                    metric=metric,
                    perm_axes=self.config.perm_axes,
                    adjust_bias=adjust_bias,
                )[:b]
            else:
                fn = self._perm_binary_fn(metric, adjust_bias)
                out = fn(plan, y, self._pad_rows(perms, owned=True)[0])[:b]
            self.tracer.sync(out)
        with self._lock:
            self.labels_evaluated += b
        return out

    def observed_multiclass(
        self, plan: fastcv.CVPlan, y: jax.Array, *, num_classes: int
    ) -> jax.Array:
        with self.tracer.span("eval"):
            fn = self._perm_multiclass_fn(num_classes)
            identity = jnp.arange(y.shape[0], dtype=jnp.int32)[None]
            return self.tracer.sync(fn(plan, y, self._pad_rows(identity, owned=True)[0])[0])

    def null_multiclass(
        self, plan: fastcv.CVPlan, y: jax.Array, perms: jax.Array, *, num_classes: int
    ) -> jax.Array:
        """Multi-class analogue of :meth:`null_binary` → (B,) accuracies."""
        with self.tracer.span("null_chunk"):
            fn = self._perm_multiclass_fn(num_classes)
            padded, b = self._pad_rows(perms, owned=True)
            out = self.tracer.sync(fn(plan, y, padded)[:b])
        with self._lock:
            self.labels_evaluated += b
        return out

    def permutation_binary(
        self,
        plan: fastcv.CVPlan,
        y: jax.Array,
        n_perm: int,
        key: jax.Array,
        *,
        metric: str = "accuracy",
        adjust_bias: bool = True,
    ) -> perm_lib.PermutationResult:
        """Algorithm 1 against a cached plan: observed + null + p-value.

        With a mesh configured, the permutation batch shards over the
        mesh's ``perm_axes``; otherwise it runs through the bucketed local
        eval path (padded to a static shape, so repeats never recompile).
        """
        n = y.shape[0]
        observed = self.observed_binary(plan, y, metric=metric, adjust_bias=adjust_bias)
        # Generate directly at the bucket size: permutation_indices jits on
        # static (n, T), so bucketing T here is what keeps arbitrary
        # client-chosen n_perm from compiling a fresh generator each time.
        # Draw generation and the p-value are null-distribution work, so
        # they count toward the null_chunk stage (timings() sums same-name
        # top-level spans) — leaving them untraced would break the
        # stage-sum ≈ end-to-end acceptance invariant.
        t_gen = bucket_size(n_perm, self.config.buckets)
        with self.tracer.span("null_chunk"):
            perms = self.tracer.sync(perm_lib.permutation_indices(key, n, t_gen))
        null = self.null_binary(plan, y, perms, metric=metric, adjust_bias=adjust_bias)[:n_perm]
        # null_binary counted the bucketed batch; this API's contract (and
        # the multiclass path) counts the *requested* draws only.
        with self._lock:
            self.labels_evaluated -= t_gen - n_perm
        with self.tracer.span("null_chunk"):
            p = self.tracer.sync(perm_lib.p_value(observed, null))
        return perm_lib.PermutationResult(observed, null, p)

    def permutation_multiclass(
        self,
        plan: fastcv.CVPlan,
        y: jax.Array,
        n_perm: int,
        key: jax.Array,
        *,
        num_classes: int,
    ) -> perm_lib.PermutationResult:
        """Algorithm 2 under permutations against a cached plan."""
        fn = self._perm_multiclass_fn(num_classes)
        n = y.shape[0]
        observed = self.observed_multiclass(plan, y, num_classes=num_classes)
        t_gen = bucket_size(n_perm, self.config.buckets)
        with self.tracer.span("null_chunk"):
            perms = self.tracer.sync(perm_lib.permutation_indices(key, n, t_gen))
            null = self.tracer.sync(fn(plan, y, self._pad_rows(perms, owned=True)[0])[:n_perm])
        with self._lock:
            self.labels_evaluated += n_perm
        with self.tracer.span("null_chunk"):
            p = self.tracer.sync(perm_lib.p_value(observed, null))
        return perm_lib.PermutationResult(observed, null, p)

    # ------------------------------------------------------------------
    # Tuning (routed to the eigendecomposition-based LOO machinery)
    # ------------------------------------------------------------------

    def tune(self, x: jax.Array, y: jax.Array, lambdas=None, criterion: str = "mse"):
        with self.tracer.span("eval"):
            # RidgeTuneResult is a NamedTuple, i.e. a pytree — sync whole.
            return self.tracer.sync(tuning.tune_ridge(x, y, lambdas=lambdas, criterion=criterion))

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def compile_count(self) -> int:
        """Total jit cache entries across every eval path this engine owns.

        Stable compile_count across requests == zero recompiles."""
        fns = (
            list(self._evals.values())
            + list(self._perm_binary.values())
            + list(self._perm_multiclass.values())
            + list(self._rsa_pairs.values())
            + list(self._rsa_score.values())
            + list(self._rsa_null.values())
        )
        return int(sum(f._cache_size() for f in fns))

    def dataset_stats(self) -> dict:
        """JSON-safe per-registered-dataset breakdown.

        Keyed by the first 12 hex chars of the content fingerprint (the
        same prefix ``/v1/datasets`` shows). ``plan_bytes`` counts the
        resident plan (either train-block variant), 0 when evicted;
        ``last_used`` is a wall-clock timestamp (0.0 = never served by
        handle). This is the handle-scoped view behind
        ``stats()["per_dataset"]`` and the bench_serve residency row.
        """
        out = {}
        for key, rec in self._datasets.items():
            plan = self.cache.peek(key) or self.cache.peek(key[:-1] + (False,))
            out[str(key[0])[:12]] = {
                "n": rec.handle.n,
                "p": rec.handle.p,
                "version": rec.version,
                "n_appended": rec.n_appended,
                "served": rec.served,
                "plan_bytes": plan.nbytes if plan is not None else 0,
                "resident": plan is not None,
                "pinned": key in self.cache.pinned_keys(),
                "last_used": rec.last_used,
            }
        return out

    def stats(self) -> dict:
        """Flat engine/cache counters plus a ``per_dataset`` breakdown.

        The pre-observability keys (cache stats, plans_built,
        labels_evaluated, compiles, datasets_registered, rdm_hits,
        rdm_entries) are preserved bit-for-bit — the metrics registry
        reads *these* counters through callback gauges, never the other
        way round. The ``store_*`` keys are always present (zero without
        a configured plan store) so dashboards and the restart-smoke
        assertions never branch on configuration. ``per_dataset`` is
        :meth:`dataset_stats`.
        """
        s = self.cache.stats.as_dict()
        st = self.store.stats if self.store is not None else None
        s.update(
            plans_built=self.plans_built,
            plans_updated=self.plans_updated,
            labels_evaluated=self.labels_evaluated,
            compiles=self.compile_count(),
            datasets_registered=len(self._datasets),
            rdm_hits=self.rdm_cache.hits,
            rdm_entries=len(self.rdm_cache),
            store_hits=st.hits if st else 0,
            store_misses=st.misses if st else 0,
            store_writes=st.writes if st else 0,
            store_bytes=st.bytes_in_store if st else 0,
        )
        s["per_dataset"] = self.dataset_stats()
        return s
