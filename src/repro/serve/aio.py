"""repro.serve.aio — asyncio front-end over the CV engine.

The sync drivers in :mod:`repro.serve.api` make one of two trades: the
blocking :func:`~repro.serve.api.serve` wants the whole batch up front,
and the thread-queue :class:`~repro.serve.api.EngineServer` gives each
submitter a `concurrent.futures.Future` but keeps long work monolithic —
one slow permutation workload head-of-line-blocks every cheap binary
query behind it. This module turns the engine into a traffic-shaped
service:

* :class:`AsyncEngineServer` — submitters ``await server.submit(w)``
  (a :class:`~repro.serve.workload.Workload` or a legacy request shim)
  from any coroutine; the worker gathers whatever arrives inside a
  deadline-bounded window (``gather_window_ms`` after the first request,
  up to ``max_batch``) and serves the whole group through the sync
  driver, so same-plan traffic still coalesces through the engine's
  :class:`~repro.serve.batching.MicroBatcher` into one padded jitted
  eval per flush group. Engine compute runs on a single executor thread;
  the event loop never blocks on XLA.
* **Streaming** — ``server.stream(w)`` returns an async iterator of
  :class:`~repro.serve.workload.ProgressEvent`\\ s for long-running work:
  permutation workloads emit their null distribution in prefix-stable
  chunks (running p-values for free), RSA workloads emit the empirical
  RDM, then model scores, then permutation-null chunks. The event
  sequence is produced by the *one* streaming implementation —
  :func:`repro.serve.workload.stream_workload` — driven chunk by chunk
  on the engine's executor thread, so a stream interleaves with batch
  traffic at chunk granularity and never recompiles after warm-up. On a
  mesh-configured engine, streamed null chunks shard over ``perm_axes``
  exactly like monolithic permutation requests
  (``engine.null_binary`` routes through ``sharded_null_from_plan``).

The streamed permutations are the same draws the monolithic path uses
(``permutation_indices`` is prefix-stable under bucket rounding), so a
stream's final ``done`` payload matches the one-shot response up to
padded-shape rounding.
"""

from __future__ import annotations

import asyncio
import functools
import time
from concurrent.futures import ThreadPoolExecutor
from typing import AsyncIterator, Optional

# reprolint: monotonic-time
# (Gather-window deadlines and batch_wait stamps must not jump with the
# wall clock — loop.time()/perf_counter only in this module.)

from repro.serve.engine import CVEngine
from repro.serve.trace import attach_trace, trace_of
from repro.serve.workload import ProgressEvent, as_workload, run_workloads, stream_workload

__all__ = ["ProgressEvent", "AsyncEngineServer"]

_STOP = object()
_STREAM_END = object()


class AsyncEngineServer:
    """Asyncio server: gather-window micro-batching + streaming workloads.

    Submitters get one coroutine per workload (``await submit(w)``);
    concurrent submissions landing within ``gather_window_ms`` of each
    other coalesce onto shared plans and shared padded evals exactly like
    the sync driver. ``stream(w)`` yields
    :class:`~repro.serve.workload.ProgressEvent`\\ s for permutation/RSA
    workloads instead of one monolithic response, chunked by
    ``stream_chunk`` (canonicalised to an engine shape bucket).
    """

    # Concurrency contract, machine-checked by reprolint RL004. The map
    # is deliberately empty: every mutable attribute here is confined to
    # the event loop (submit/stream/worker are coroutines; engine calls
    # hop to the executor but mutate only engine state, which carries its
    # own _GUARDED_BY). Listing an attr here is how a future fleet-mode
    # change would opt it into lock checking.
    _GUARDED_BY = {}

    def __init__(
        self,
        engine: CVEngine,
        max_batch: int = 64,
        gather_window_ms: float = 2.0,
        stream_chunk: int = 64,
    ):
        self.engine = engine
        self.max_batch = max_batch
        self.gather_window_s = gather_window_ms / 1e3
        self.stream_chunk = stream_chunk
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._queue: Optional[asyncio.Queue] = None
        self._executor: Optional[ThreadPoolExecutor] = None
        self._worker_task: Optional[asyncio.Task] = None
        self._stopping = False
        self.batches_served = 0
        self.requests_served = 0
        self.streams_served = 0

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> "AsyncEngineServer":
        if self._worker_task is not None:
            raise RuntimeError("server already started")
        self._loop = asyncio.get_running_loop()
        self._queue = asyncio.Queue()
        # One engine thread: jax compute never blocks the event loop, and
        # batch evals / stream chunks interleave fairly at task granularity.
        self._executor = ThreadPoolExecutor(max_workers=1, thread_name_prefix="cv-engine-aio")
        self._stopping = False
        self._worker_task = self._loop.create_task(self._worker())
        return self

    async def stop(self) -> None:
        if self._worker_task is None:
            return
        self._stopping = True
        self._queue.put_nowait(_STOP)
        await self._worker_task
        self._worker_task = None
        while not self._queue.empty():  # belt-and-braces: never strand a future
            item = self._queue.get_nowait()
            if item is not _STOP:
                _, fut = item
                if not fut.done():
                    fut.set_exception(RuntimeError("server stopped before serving"))
        self._executor.shutdown(wait=True)
        self._executor = None
        # Write-behind plan saves must land before the process can exit —
        # a SIGTERM'd replica's last builds are next boot's store hits.
        self.engine.flush_store()

    async def __aenter__(self) -> "AsyncEngineServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def _check_running(self) -> None:
        if self._worker_task is None or self._stopping:
            raise RuntimeError("server is not running")

    def _run(self, fn, *args, **kw):
        """Run one engine call on the executor thread; await the result.

        Guarded so a stream outliving :meth:`stop` fails fast instead of
        silently falling back to the loop's default (multi-thread)
        executor — which would break the single-engine-thread invariant.
        """
        if self._executor is None:
            raise RuntimeError("server is not running")
        return self._loop.run_in_executor(self._executor, functools.partial(fn, *args, **kw))

    # -- client side -------------------------------------------------------

    async def submit(self, request):
        """Submit one workload; awaits its response."""
        self._check_running()
        # Trace from the submit side so gather-window queue time is a
        # measured batch_wait stage; the trace rides the workload object
        # onto the engine thread (run_in_executor does not copy context).
        tracer = self.engine.tracer
        if tracer.enabled and trace_of(request) is None:
            attach_trace(request, tracer.trace())
        trace = trace_of(request)
        if trace is not None:
            trace.mark_enqueue()
        fut = self._loop.create_future()
        await self._queue.put((request, fut))
        return await fut

    async def register(self, x, folds, lam: float, mode: str = "auto"):
        """Register a dataset on the engine thread; returns its handle.

        Fingerprinting hashes the feature bytes, so it runs on the
        executor like every other engine touch — the event loop never
        blocks on a large registration (the HTTP edge's ``POST
        /v1/datasets`` route lands here).
        """
        self._check_running()
        return await self._run(self.engine.register, x, folds, lam, mode=mode)

    async def append(self, handle, x_new=None, *, drop_idx=None, folds_delta=None):
        """Advance a registered dataset on the engine thread; returns the
        version n+1 handle (the ``POST /v1/datasets/{fp}/append`` route
        lands here). Append, retire, or slide per the arguments — thin
        passthrough to :meth:`CVEngine.update_dataset`."""
        self._check_running()
        return await self._run(
            self.engine.update_dataset,
            handle,
            x_new=x_new,
            drop_idx=drop_idx,
            folds_delta=folds_delta,
        )

    async def stream(self, request) -> AsyncIterator[ProgressEvent]:
        """Async iterator of :class:`ProgressEvent`\\ s for one workload.

        Permutation, RSA, and update workloads stream incrementally by
        driving :func:`~repro.serve.workload.stream_workload` on the
        engine thread (updates emit one event per applied increment); any
        other kind degenerates to a single "done" event wrapping the
        batched response (counted in ``streams_served`` either way —
        streams count when they start, so abandoned iterators count too).
        """
        self._check_running()
        self.streams_served += 1
        w = as_workload(request)
        if w.kind not in ("permutation", "rsa", "update"):
            yield ProgressEvent("done", 1, 1, await self.submit(w))
            return
        gen = stream_workload(self.engine, w, chunk=self.stream_chunk)
        while True:
            event = await self._run(next, gen, _STREAM_END)
            if event is _STREAM_END:
                return
            yield event

    # -- worker side -------------------------------------------------------

    async def _worker(self) -> None:
        while True:
            item = await self._queue.get()
            if item is _STOP:
                # Serve anything that raced in behind the sentinel, then exit.
                leftovers = []
                while not self._queue.empty():
                    nxt = self._queue.get_nowait()
                    if nxt is not _STOP:
                        leftovers.append(nxt)
                if leftovers:
                    await self._serve_batch(leftovers)
                return
            batch = [item]
            deadline = self._loop.time() + self.gather_window_s
            while len(batch) < self.max_batch:
                remaining = deadline - self._loop.time()
                if remaining <= 0:
                    break
                try:
                    nxt = await asyncio.wait_for(self._queue.get(), remaining)
                except asyncio.TimeoutError:
                    break
                if nxt is _STOP:
                    self._queue.put_nowait(_STOP)  # re-post; exit after this batch
                    break
                batch.append(nxt)
            await self._serve_batch(batch)

    async def _serve_batch(self, batch) -> None:
        requests = [req for req, _ in batch]
        futures = [fut for _, fut in batch]
        # One dequeue timestamp for the whole gather window: each member's
        # submit->here latency becomes its batch_wait stage.
        now = time.perf_counter()
        for req in requests:
            trace = trace_of(req)
            if trace is not None:
                trace.note_dequeue(now)
        self.engine.metrics.observe("gather_window_occupancy", len(batch))
        try:
            # Per-entry result-or-error: a malformed workload (or an
            # unknown/evicted dataset handle) fails only its own future,
            # never sibling submitters sharing the gather window.
            responses = await self._run(run_workloads, self.engine, requests, return_errors=True)
        except Exception as e:  # noqa: BLE001 - fanned out to submitters
            for fut in futures:
                if not fut.done():
                    fut.set_exception(e)
            return
        for fut, resp in zip(futures, responses):
            if not fut.done():
                if isinstance(resp, Exception):
                    fut.set_exception(resp)
                else:
                    fut.set_result(resp)
        self.batches_served += 1
        self.requests_served += len(batch)
