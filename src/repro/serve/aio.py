"""repro.serve.aio — asyncio front-end over the CV engine.

The sync drivers in :mod:`repro.serve.api` make one of two trades: the
blocking :func:`~repro.serve.api.serve` wants the whole batch up front,
and the thread-queue :class:`~repro.serve.api.EngineServer` gives each
submitter a `concurrent.futures.Future` but keeps long work monolithic —
one slow permutation request head-of-line-blocks every cheap binary query
behind it. This module turns the engine into a traffic-shaped service:

* :class:`AsyncEngineServer` — submitters ``await server.submit(req)``
  from any coroutine; the worker gathers whatever arrives inside a
  deadline-bounded window (``gather_window_ms`` after the first request,
  up to ``max_batch``) and serves the whole group through the sync
  driver, so same-plan traffic still coalesces through the engine's
  :class:`~repro.serve.batching.MicroBatcher` into one padded jitted
  eval per flush group. Engine compute runs on a single executor thread;
  the event loop never blocks on XLA.
* **Streaming** — ``server.stream(req)`` returns an async iterator of
  :class:`ProgressEvent`\\ s for long-running work: permutation requests
  emit their null distribution in prefix-stable chunks (running p-values
  for free), RSA requests emit the empirical RDM, then model scores,
  then permutation-null chunks. Because chunks run through the engine's
  bucketed ``null_*`` paths at a fixed chunk size, a stream interleaves
  with batch traffic at chunk granularity and never recompiles after
  warm-up.

The streamed permutations are the same draws the monolithic path uses
(``permutation_indices`` is prefix-stable under bucket rounding), so a
stream's final ``done`` payload matches the one-shot response up to
padded-shape rounding.

Known limitation: streamed nulls always run the *local* bucketed chunk
path (``engine.null_binary`` / ``null_multiclass``). On a mesh-configured
engine, ``submit()`` shards permutation nulls over ``perm_axes`` while
``stream()`` does not (and compiles the unsharded program) — mesh-sharded
streaming is a ROADMAP item, not a silent behaviour of this class.
"""

from __future__ import annotations

import asyncio
import dataclasses
import functools
from concurrent.futures import ThreadPoolExecutor
from typing import AsyncIterator, Optional

import jax
import jax.numpy as jnp

from repro.core import permutation as perm_lib
from repro.rsa import rdm as rsa_rdm
from repro.serve.api import (
    PermutationRequest,
    PermutationResponse,
    Request,
    RSARequest,
    RSAResponse,
    serve,
)
from repro.serve.batching import as_folds, bucket_size
from repro.serve.engine import CVEngine

__all__ = ["ProgressEvent", "AsyncEngineServer"]

_STOP = object()


@dataclasses.dataclass
class ProgressEvent:
    """One step of a streamed request.

    kind:    "plan" (payload: plan key), "observed" (payload: observed
             metric), "rdm" (payload: empirical RDM), "scores" (payload:
             model scores), "null" (payload: the new null chunk), or
             "done" (payload: the final response object).
    done:    permutations finished so far (0 for pre-null events).
    total:   total permutations the stream will produce.
    payload: kind-specific value; always the full response on "done".
    """

    kind: str
    done: int
    total: int
    payload: object


class AsyncEngineServer:
    """Asyncio server: gather-window micro-batching + streaming requests.

    Submitters get one coroutine per request (``await submit(req)``);
    concurrent submissions landing within ``gather_window_ms`` of each
    other coalesce onto shared plans and shared padded evals exactly like
    the sync driver. ``stream(req)`` yields :class:`ProgressEvent`\\ s for
    permutation/RSA requests instead of one monolithic response, chunked
    by ``stream_chunk`` (canonicalised to an engine shape bucket).
    """

    def __init__(
        self,
        engine: CVEngine,
        max_batch: int = 64,
        gather_window_ms: float = 2.0,
        stream_chunk: int = 64,
    ):
        self.engine = engine
        self.max_batch = max_batch
        self.gather_window_s = gather_window_ms / 1e3
        self.stream_chunk = stream_chunk
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._queue: Optional[asyncio.Queue] = None
        self._executor: Optional[ThreadPoolExecutor] = None
        self._worker_task: Optional[asyncio.Task] = None
        self._stopping = False
        self.batches_served = 0
        self.requests_served = 0
        self.streams_served = 0

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> "AsyncEngineServer":
        if self._worker_task is not None:
            raise RuntimeError("server already started")
        self._loop = asyncio.get_running_loop()
        self._queue = asyncio.Queue()
        # One engine thread: jax compute never blocks the event loop, and
        # batch evals / stream chunks interleave fairly at task granularity.
        self._executor = ThreadPoolExecutor(max_workers=1, thread_name_prefix="cv-engine-aio")
        self._stopping = False
        self._worker_task = self._loop.create_task(self._worker())
        return self

    async def stop(self) -> None:
        if self._worker_task is None:
            return
        self._stopping = True
        self._queue.put_nowait(_STOP)
        await self._worker_task
        self._worker_task = None
        while not self._queue.empty():  # belt-and-braces: never strand a future
            item = self._queue.get_nowait()
            if item is not _STOP:
                _, fut = item
                if not fut.done():
                    fut.set_exception(RuntimeError("server stopped before serving"))
        self._executor.shutdown(wait=True)
        self._executor = None

    async def __aenter__(self) -> "AsyncEngineServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def _check_running(self) -> None:
        if self._worker_task is None or self._stopping:
            raise RuntimeError("server is not running")

    def _run(self, fn, *args, **kw):
        """Run one engine call on the executor thread; await the result.

        Guarded so a stream outliving :meth:`stop` fails fast instead of
        silently falling back to the loop's default (multi-thread)
        executor — which would break the single-engine-thread invariant.
        """
        if self._executor is None:
            raise RuntimeError("server is not running")
        return self._loop.run_in_executor(self._executor, functools.partial(fn, *args, **kw))

    # -- client side -------------------------------------------------------

    async def submit(self, request: Request):
        """Submit one request; awaits (and returns) its response."""
        self._check_running()
        fut = self._loop.create_future()
        await self._queue.put((request, fut))
        return await fut

    async def stream(self, request: Request) -> AsyncIterator[ProgressEvent]:
        """Async iterator of :class:`ProgressEvent`\\ s for one request.

        Permutation and RSA requests stream incrementally; any other
        request type degenerates to a single "done" event wrapping the
        batched response (counted in ``streams_served`` either way —
        streams count when they start, so abandoned iterators count too).
        """
        self._check_running()
        self.streams_served += 1
        if isinstance(request, PermutationRequest):
            agen = self._stream_permutation(request)
        elif isinstance(request, RSARequest):
            agen = self._stream_rsa(request)
        else:
            yield ProgressEvent("done", 1, 1, await self.submit(request))
            return
        async for event in agen:
            yield event

    # -- worker side -------------------------------------------------------

    async def _worker(self) -> None:
        while True:
            item = await self._queue.get()
            if item is _STOP:
                # Serve anything that raced in behind the sentinel, then exit.
                leftovers = []
                while not self._queue.empty():
                    nxt = self._queue.get_nowait()
                    if nxt is not _STOP:
                        leftovers.append(nxt)
                if leftovers:
                    await self._serve_batch(leftovers)
                return
            batch = [item]
            deadline = self._loop.time() + self.gather_window_s
            while len(batch) < self.max_batch:
                remaining = deadline - self._loop.time()
                if remaining <= 0:
                    break
                try:
                    nxt = await asyncio.wait_for(self._queue.get(), remaining)
                except asyncio.TimeoutError:
                    break
                if nxt is _STOP:
                    self._queue.put_nowait(_STOP)  # re-post; exit after this batch
                    break
                batch.append(nxt)
            await self._serve_batch(batch)

    async def _serve_batch(self, batch) -> None:
        requests = [req for req, _ in batch]
        futures = [fut for _, fut in batch]
        try:
            responses = await self._run(serve, self.engine, requests)
        except Exception as e:  # noqa: BLE001 - fanned out to submitters
            for fut in futures:
                if not fut.done():
                    fut.set_exception(e)
            return
        for fut, resp in zip(futures, responses):
            if not fut.done():
                fut.set_result(resp)
        self.batches_served += 1
        self.requests_served += len(batch)

    # -- streaming ---------------------------------------------------------

    async def _plan_for(self, data, needs_train: bool):
        folds = as_folds(data.folds)
        return await self._run(self.engine.plan, data.x, folds, data.lam, data.mode, needs_train)

    def _chunking(self, total: int) -> tuple[int, int]:
        buckets = self.engine.config.buckets
        t_gen = bucket_size(total, buckets)
        return t_gen, min(bucket_size(self.stream_chunk, buckets), t_gen)

    async def _null_chunks(self, total: int, n_items: int, seed: int, eval_chunk):
        """Shared streaming loop: yield (done, null_block) chunk by chunk.

        Permutations of ``n_items`` are generated once at the bucketed
        ``t_gen`` — rounded up to a whole number of chunks, so every slice
        is a full chunk with one static shape even under non-nested custom
        buckets — and evaluated ``chunk`` rows at a time; repeats never
        recompile, and the rounding preserves the prefix
        (``permutation_indices`` is prefix-stable), so the stream's first
        ``total`` draws match the monolithic path exactly.
        ``eval_chunk(block, keep)`` trims its own output to ``keep``.
        """
        t_gen, chunk = self._chunking(total)
        t_gen = -(-t_gen // chunk) * chunk  # whole chunks, same prefix
        perms = await self._run(
            perm_lib.permutation_indices, jax.random.PRNGKey(seed), n_items, t_gen
        )
        for lo in range(0, total, chunk):
            hi = min(lo + chunk, total)
            block = perms[lo : min(lo + chunk, t_gen)]
            yield hi, await eval_chunk(block, hi - lo)

    async def _stream_permutation(self, req: PermutationRequest):
        if req.n_perm <= 0:
            raise ValueError("streaming a permutation request needs n_perm > 0")
        engine = self.engine
        total = req.n_perm
        needs_train = req.task == "multiclass" or req.adjust_bias
        key, plan = await self._plan_for(req.data, needs_train)
        yield ProgressEvent("plan", 0, total, key)
        y = jnp.asarray(req.y)
        if req.task == "multiclass":
            observed = await self._run(
                engine.observed_multiclass, plan, y, num_classes=req.num_classes
            )
        else:
            observed = await self._run(
                engine.observed_binary, plan, y, metric=req.metric, adjust_bias=req.adjust_bias
            )
        yield ProgressEvent("observed", 0, total, observed)

        if req.task == "multiclass":

            async def eval_chunk(block, keep):
                out = await self._run(
                    engine.null_multiclass, plan, y, block, num_classes=req.num_classes
                )
                return out[:keep]

        else:

            async def eval_chunk(block, keep):
                out = await self._run(
                    engine.null_binary,
                    plan,
                    y,
                    block,
                    metric=req.metric,
                    adjust_bias=req.adjust_bias,
                )
                return out[:keep]

        chunks = []
        async for hi, null_block in self._null_chunks(total, int(y.shape[0]), req.seed, eval_chunk):
            chunks.append(null_block)
            yield ProgressEvent("null", hi, total, null_block)

        def finish():  # keep even the cheap eager tail off the loop thread
            null = jnp.concatenate(chunks)
            return null, perm_lib.p_value(observed, null)

        null, p = await self._run(finish)
        yield ProgressEvent("done", total, total, PermutationResponse(observed, null, p, key))

    async def _stream_rsa(self, req: RSARequest):
        if req.contrast not in ("binary", "multiclass"):
            raise ValueError(f"unknown RSA contrast {req.contrast!r}")
        engine = self.engine
        c = req.num_classes
        total = req.n_perm if req.model_rdms is not None else 0
        needs_train = req.contrast == "multiclass" or req.adjust_bias
        key, plan = await self._plan_for(req.data, needs_train)
        yield ProgressEvent("plan", 0, total, key)
        y = jnp.asarray(req.y)
        if req.contrast == "binary":

            def build_rdm():  # contrast columns + eval + scatter, one engine-thread hop
                cols = rsa_rdm.pair_contrast_columns(y, c, plan.h.dtype)
                vals = engine.eval_rsa_pairs(plan, cols, req.dissimilarity, req.adjust_bias)
                return rsa_rdm.rdm_from_pair_values(vals, c), vals

        else:

            def build_rdm():
                preds = engine.eval_multiclass(plan, y, c)
                return rsa_rdm.rdm_from_confusion(preds, y[plan.te_idx], c), None

        rdm, vals = await self._run(build_rdm)
        yield ProgressEvent("rdm", 0, total, rdm)
        if req.model_rdms is None:
            yield ProgressEvent("done", 0, 0, RSAResponse(rdm, vals, None, None, None, key))
            return
        models = jnp.asarray(req.model_rdms)
        scores = await self._run(engine.score_rdms, rdm, models, req.comparison)
        yield ProgressEvent("scores", 0, total, scores)
        if total <= 0:
            yield ProgressEvent("done", 0, 0, RSAResponse(rdm, vals, scores, None, None, key))
            return

        async def eval_chunk(block, keep):
            out = await self._run(engine.null_rdm_scores, rdm, models, block, req.comparison)
            return out[:, :keep]

        chunks = []
        async for hi, null_block in self._null_chunks(total, c, req.seed, eval_chunk):
            chunks.append(null_block)
            yield ProgressEvent("null", hi, total, null_block)

        def finish():
            null = jnp.concatenate(chunks, axis=1)
            p = (1.0 + jnp.sum(null >= scores[:, None], axis=1)) / (1.0 + total)
            return null, p

        null, p = await self._run(finish)
        yield ProgressEvent("done", total, total, RSAResponse(rdm, vals, scores, null, p, key))
