"""repro.serve.trace — request-scoped span trees for the serving stack.

The *tracing* half of the observability layer (the metrics half lives in
:mod:`repro.serve.obs`). A :class:`Trace` is one workload's span tree:
stage-named, monotonic-clock (``time.perf_counter``) intervals that cover
the request path decode → validate → plan_build/cache_lookup →
batch_wait → eval/null_chunk → encode. Finished traces land in a bounded
ring buffer on the :class:`Tracer` (exposed as ``GET /v1/trace``) and
their per-stage durations feed the registry's ``stage_latency_seconds``
histogram, from which :meth:`Tracer.summary` derives per-stage p50/p95.

Propagation model
-----------------
A ``contextvars.ContextVar`` carries the *active* trace so engine-internal
instrumentation (``tracer.span("plan_build")`` deep inside
``CVEngine._build_plan``) finds the right trace without threading it
through every signature — and does so correctly under asyncio, where many
logical requests interleave on one thread.

Context vars do **not** cross thread/queue boundaries on their own
(``loop.run_in_executor`` does not copy context into the engine thread),
so cross-thread hand-off is explicit: the submit side *attaches* the
trace to the workload object (:func:`attach_trace`), and the serving side
picks it up (:func:`trace_of`) and re-activates it
(``with tracer.activate(trace):``) on whichever thread actually runs the
engine. Workload objects are frozen dataclasses, so attachment uses
``object.__setattr__``; a workload object resubmitted after its trace
finished (bench loops re-send the same objects) gets a *fresh* trace —
finished traces are never reused.

Cost model: when tracing is disabled (the default), every hook degenerates
to a shared null context manager / ``None`` checks — no clock reads, no
allocation, and crucially no extra ``block_until_ready`` (``Tracer.sync``
is a no-op without an active trace), so jax's async dispatch pipeline is
untouched. The ISSUE's overhead guard (disabled ⇒ zero extra compiles,
``timings`` absent) is enforced by ``tests/test_obs.py``.
"""

from __future__ import annotations

import contextlib
import contextvars
import threading
import time
from collections import deque
from typing import Optional

# reprolint: monotonic-time
# (Span intervals and batch deadlines must survive wall-clock jumps —
# the PR 6 bug class; RL001 flags any time.time() in this module.)

__all__ = [
    "STAGES",
    "Span",
    "Trace",
    "Tracer",
    "NULL_TRACER",
    "attach_trace",
    "trace_of",
]

#: Fixed stage vocabulary — every span name must be one of these, so the
#: per-stage histogram's label set is closed (and CI can assert all of
#: them are declared in the exposition).
STAGES = (
    "decode",  # wire JSON -> workload dataclass (HTTP edge only)
    "validate",  # as_workload normalisation + workload validation
    "plan_build",  # O(N^2 P) Gram + factorisations (cache miss only)
    "plan_update",  # rank-k append/retire/window correction (kind="update")
    "cache_lookup",  # plan_key fingerprint + cache probe
    "store_load",  # disk plan-store read + integrity check (miss path)
    "batch_wait",  # submit -> dequeue latency (thread/async servers)
    "eval",  # bucketed jitted eval (scores, RDMs, tune sweeps)
    "null_chunk",  # permutation-null chunks (monolithic or streamed)
    "encode",  # response assembly (+ wire JSON on the HTTP edge)
)

_CURRENT: "contextvars.ContextVar[Optional[Trace]]" = contextvars.ContextVar(
    "repro_serve_trace", default=None
)

_ATTR = "_obs_trace"


def attach_trace(obj, trace: "Optional[Trace]") -> None:
    """Pin a trace onto a (possibly frozen) workload object for explicit
    cross-thread hand-off. Silently a no-op for objects that reject
    attribute creation (``__slots__`` without a dict)."""
    if trace is None:
        return
    try:
        object.__setattr__(obj, _ATTR, trace)
    except (AttributeError, TypeError):
        pass


def trace_of(obj) -> "Optional[Trace]":
    """Return the live trace attached to ``obj``, or None.

    A *finished* trace is treated as absent: bench loops resubmit the
    same workload objects, and reopening a closed trace would corrupt
    both its ring entry and its histogram contribution.
    """
    trace = getattr(obj, _ATTR, None)
    if trace is not None and trace.finished:
        return None
    return trace


class Span:
    """One timed stage: offset from trace start, duration, children."""

    __slots__ = ("name", "start", "duration", "children")

    def __init__(self, name: str, start: float):
        self.name = name
        self.start = start  # seconds since trace start
        self.duration = 0.0
        self.children: list = []

    def to_dict(self) -> dict:
        d = {"name": self.name, "start_s": self.start, "duration_s": self.duration}
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d


class Trace:
    """One workload's span tree, built incrementally as stages run.

    ``timings()`` sums **top-level** spans only — a ``plan_build`` nested
    under another stage contributes to its parent's wall time already, and
    double-counting would break the "stage sum ≈ end-to-end duration"
    invariant the acceptance criteria (and ``tests/test_obs.py``) assert.
    """

    __slots__ = (
        "kind",
        "estimator",
        "spans",
        "duration",
        "finished",
        "_stack",
        "_t0",
        "_t_enqueue",
    )

    def __init__(self, kind: str = "", estimator: str = ""):
        self.kind = kind
        self.estimator = estimator
        self.spans: list = []  # top-level spans
        self.duration = 0.0
        self.finished = False
        self._stack: list = []  # open spans (innermost last)
        self._t0 = time.perf_counter()
        self._t_enqueue: Optional[float] = None

    # -- span construction -------------------------------------------------

    def span(self, name: str) -> "_SpanCtx":
        """Context manager timing one stage; nests under any open span."""
        return _SpanCtx(self, name)

    def add(self, name: str, seconds: float) -> Span:
        """Append an already-measured stage (e.g. a shared coalesced eval
        timed once for the whole flush group, attributed to each member)."""
        now = time.perf_counter() - self._t0
        span = Span(name, max(0.0, now - seconds))
        span.duration = seconds
        self._sink().append(span)
        return span

    def mark_enqueue(self) -> None:
        """Submit side of the batch_wait stage (thread/async servers)."""
        self._t_enqueue = time.perf_counter()

    def note_dequeue(self, now: Optional[float] = None) -> None:
        """Serving side: record submit->dequeue latency as ``batch_wait``.

        ``now`` lets a server timestamp the batch *once* and attribute the
        identical dequeue instant to every member.
        """
        if self._t_enqueue is None:
            return
        t = time.perf_counter() if now is None else now
        self.add("batch_wait", max(0.0, t - self._t_enqueue))
        self._t_enqueue = None

    def _sink(self) -> list:
        return self._stack[-1].children if self._stack else self.spans

    # -- completion --------------------------------------------------------

    def finish(self) -> None:
        if self.finished:
            return
        self.duration = time.perf_counter() - self._t0
        self.finished = True

    def timings(self) -> dict:
        """Per-stage duration sums over top-level spans, in STAGES order."""
        sums: dict = {}
        for span in self.spans:
            sums[span.name] = sums.get(span.name, 0.0) + span.duration
        return {name: sums[name] for name in STAGES if name in sums}

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "estimator": self.estimator,
            "duration_s": self.duration,
            "timings": self.timings(),
            "spans": [s.to_dict() for s in self.spans],
        }


class _SpanCtx:
    __slots__ = ("trace", "name", "_span", "_start")

    def __init__(self, trace: Trace, name: str):
        self.trace = trace
        self.name = name

    def __enter__(self) -> Span:
        self._start = time.perf_counter()
        self._span = Span(self.name, self._start - self.trace._t0)
        self.trace._sink().append(self._span)
        self.trace._stack.append(self._span)
        return self._span

    def __exit__(self, *exc) -> None:
        self._span.duration = time.perf_counter() - self._start
        if self.trace._stack and self.trace._stack[-1] is self._span:
            self.trace._stack.pop()


_NULL_CM = contextlib.nullcontext()


class _Activation:
    """Sets/resets the active-trace context var around a with-block."""

    __slots__ = ("trace", "_token")

    def __init__(self, trace: Trace):
        self.trace = trace

    def __enter__(self) -> Trace:
        self._token = _CURRENT.set(self.trace)
        return self.trace

    def __exit__(self, *exc) -> None:
        _CURRENT.reset(self._token)


class Tracer:
    """Trace factory + bounded ring of finished traces.

    Disabled by default: ``trace()`` returns None, ``span()`` returns a
    shared null context manager, ``sync()`` is a no-op — the instrumented
    request path pays only a handful of attribute checks. ``enable()``
    flips all of that on and (re)sizes the ring.
    """

    # Concurrency contract, machine-checked by reprolint RL004.
    _GUARDED_BY = {"_ring": "_lock"}

    def __init__(self, registry=None, ring: int = 256, enabled: bool = False):
        self.registry = registry
        self.enabled = enabled
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=max(1, int(ring)))

    # -- lifecycle ---------------------------------------------------------

    def enable(self, ring: Optional[int] = None) -> None:
        if ring is not None and ring != self._ring.maxlen:
            with self._lock:
                self._ring = deque(self._ring, maxlen=max(1, int(ring)))
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    @property
    def ring_size(self) -> int:
        return self._ring.maxlen

    # -- request-path hooks ------------------------------------------------

    def trace(self, kind: str = "", estimator: str = "") -> Optional[Trace]:
        """New trace when enabled, else None (callers pass it straight to
        :meth:`activate` / :func:`attach_trace`, both None-tolerant)."""
        return Trace(kind, estimator) if self.enabled else None

    def activate(self, trace: Optional[Trace]):
        """Context manager making ``trace`` the active trace; no-op CM for
        None so call sites never branch."""
        return _Activation(trace) if trace is not None else _NULL_CM

    def current(self) -> Optional[Trace]:
        return _CURRENT.get()

    def span(self, name: str):
        """Time one stage on the *active* trace (null CM when none)."""
        trace = _CURRENT.get()
        return trace.span(name) if trace is not None else _NULL_CM

    def sync(self, value):
        """``jax.block_until_ready`` **only when a trace is active** — span
        durations must measure compute, not async-dispatch enqueue time;
        without a trace the dispatch pipeline stays untouched."""
        if _CURRENT.get() is not None and value is not None:
            import jax

            jax.block_until_ready(value)
        return value

    # -- completion / exposition -------------------------------------------

    def finish(self, trace: Optional[Trace]) -> None:
        """Close a trace: stamp duration, ring-append, feed histograms."""
        if trace is None or trace.finished:
            return
        trace.finish()
        with self._lock:
            self._ring.append(trace)
        if self.registry is not None and "stage_latency_seconds" in self.registry:
            for stage, seconds in trace.timings().items():
                self.registry.observe("stage_latency_seconds", seconds, stage=stage)

    def last(self, n: int = 32) -> list:
        """Newest-first dicts of the last ``n`` finished traces."""
        with self._lock:
            traces = list(self._ring)
        return [t.to_dict() for t in reversed(traces[-max(0, int(n)) :])]

    def summary(self) -> dict:
        """Per-stage ``{count, p50_s, p95_s}`` over the current ring."""
        with self._lock:
            traces = list(self._ring)
        by_stage: dict = {}
        for t in traces:
            for stage, seconds in t.timings().items():
                by_stage.setdefault(stage, []).append(seconds)
        out = {}
        for stage in STAGES:
            vals = by_stage.get(stage)
            if not vals:
                continue
            vals.sort()
            out[stage] = {
                "count": len(vals),
                "p50_s": vals[len(vals) // 2],
                "p95_s": vals[min(len(vals) - 1, int(len(vals) * 0.95))],
            }
        return out


#: Shared fallback so call sites can write
#: ``tracer = getattr(engine, "tracer", None) or NULL_TRACER`` and never
#: branch again — a disabled Tracer's hooks are all no-ops.
NULL_TRACER = Tracer()
