"""One workload API: registered datasets, Workload specs, estimator registry.

The paper's central claim (Treder 2018, §2) is that the analytical-CV
identity holds for *every* ridge-regularised least-squares model. This
module makes the public surface say the same thing: instead of one request
class and one engine code path per model, there is

  * a **least-squares estimator registry** — :class:`LeastSquaresSpec`
    describes a model family by its targets encoding, batch layout,
    jitted-eval factory, and metric family. Binary LDA, multi-class LDA,
    ridge regression, and multi-target ridge are *registrations*, not
    engine forks; adding e.g. optimal-scoring LDA is one
    :func:`register_estimator` call away.
  * a **unified, versioned** :class:`Workload` spec — one dataclass schema
    (``kind``: ``cv | permutation | rsa | tune | grid | update``) that
    normalises and validates eagerly at construction, so malformed traffic
    fails with a clear message instead of a shape error deep inside jit.
    ``to_dict``/``from_dict`` round-trip the schema (version-stamped; the
    previous schema version is accepted through an explicit upgrade hook)
    for logging, replay, and cross-process submission.
  * **dataset handles** — :meth:`repro.serve.engine.CVEngine.register`
    fingerprints a dataset once and returns a :class:`DatasetHandle` at
    version 0; workloads carry the handle instead of re-shipping the
    feature matrix. ``kind="update"`` workloads append/retire rows through
    the engine's incremental plan math and yield the version n+1 handle.
  * the **unified driver** :func:`run_workloads` — same-plan CV label
    queries coalesce through the engine's
    :class:`~repro.serve.batching.MicroBatcher` (one padded jitted eval
    per group), RSA contrast columns ride the identical column path with
    empirical-RDM memoisation, and permutation / tune / grid workloads
    route to their engine entry points.
  * a **synchronous streaming generator** :func:`stream_workload` — the
    single implementation of chunked permutation/RSA progress events; the
    asyncio front-end (:mod:`repro.serve.aio`) drives the same generator
    on its executor thread.
  * a :class:`TrafficLog` — records the (task, bucket) set a serving
    session actually hit, serialisable to JSON, replayable at boot through
    :meth:`~repro.serve.engine.CVEngine.warmup`.

The legacy request classes (``CVRequest``/``PermutationRequest``/
``RSARequest``/``TuneRequest``) were removed at 0.3 per the README
deprecation timeline — importing them raises with a pointer at the
migration table. The ``core/`` convenience functions (``binary_cv``,
``analytical_cv``, ``analytical_cv_multiclass``, ``tune_ridge``,
``cv_grid``) remain the library-level reference implementations, with
parity tests pinning them to this path.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastcv, metrics, multidim, tuning
from repro.core import permutation as perm_lib
from repro.rsa import rdm as rsa_rdm
from repro.serve.batching import as_folds, bucket_size
from repro.serve.trace import NULL_TRACER, attach_trace, trace_of

__all__ = [
    "WORKLOAD_SCHEMA_VERSION",
    "KINDS",
    "DatasetSpec",
    "DatasetHandle",
    "LeastSquaresSpec",
    "register_estimator",
    "get_estimator",
    "estimators",
    "Workload",
    "as_workload",
    "CVResponse",
    "PermutationResponse",
    "RSAResponse",
    "TuneResponse",
    "GridResponse",
    "UpdateResponse",
    "run_workloads",
    "ProgressEvent",
    "stream_workload",
    "TrafficLog",
]

#: Version 2 added ``kind="update"`` and the ``drop_idx`` field; version 1
#: dicts are upgraded transparently by :func:`_upgrade_v1_to_v2`.
WORKLOAD_SCHEMA_VERSION = 2
KINDS = ("cv", "permutation", "rsa", "tune", "grid", "update")

_PERM_ESTIMATORS = ("binary", "multiclass")
_BINARY_METRICS = ("accuracy", "auc")
_CONTRASTS = ("binary", "multiclass")
_DISSIMILARITIES = ("accuracy", "contrast")
_COMPARISONS = ("spearman", "kendall", "pearson", "cosine")
_CRITERIA = ("mse", "error")


# ---------------------------------------------------------------------------
# Datasets: inline specs and registered handles
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DatasetSpec:
    """The label-invariant half of a workload: features, folds, λ.

    ``folds`` is a :class:`~repro.core.folds.Folds` or a raw
    ``(te_idx, tr_idx)`` index pair (normalised via ``Folds.with_indices``).
    ``x`` may be None for ``kind="grid"`` workloads, which carry their own
    feature grid and only borrow the spec's folds and λ.
    """

    x: Optional[jax.Array]
    folds: object
    lam: float
    mode: str = "auto"


@dataclasses.dataclass(frozen=True)
class DatasetHandle:
    """Opaque reference to a dataset registered on a :class:`CVEngine`.

    ``key`` is the content fingerprint ``plan_key(x, folds, λ, mode,
    with_train_block=True, version=version)`` — the same identity the
    :class:`~repro.serve.cache.PlanCache` uses — so a handle survives
    serialisation (:meth:`Workload.to_dict` emits the key) and resolves on
    any engine that registered the same bytes. Workloads carry the handle
    instead of re-shipping the feature matrix.

    ``version`` is 0 for a freshly registered dataset and increments each
    time the engine applies an incremental update (``append``/``retire``/
    a ``kind="update"`` workload); ``n_appended`` counts the rows appended
    over the handle's whole lineage. Old versions remain servable until
    released — in-flight workloads pin the version they were built
    against.
    """

    key: tuple
    n: int = 0
    p: int = 0
    lam: float = 0.0
    mode: str = "auto"
    version: int = 0
    n_appended: int = 0

    def to_dict(self) -> dict:
        return {
            "__handle__": list(self.key),
            "n": self.n,
            "p": self.p,
            "lam": self.lam,
            "mode": self.mode,
            "version": self.version,
            "n_appended": self.n_appended,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DatasetHandle":
        return cls(
            key=tuple(d["__handle__"]),
            n=int(d.get("n", 0)),
            p=int(d.get("p", 0)),
            lam=float(d.get("lam", 0.0)),
            mode=d.get("mode", "auto"),
            version=int(d.get("version", 0)),
            n_appended=int(d.get("n_appended", 0)),
        )


# ---------------------------------------------------------------------------
# Least-squares estimator registry
# ---------------------------------------------------------------------------


def _columns_encode(y, dtype, opts):
    yb = jnp.asarray(y)
    squeeze = yb.ndim == 1
    yb = yb[:, None] if squeeze else yb
    return yb.astype(dtype), squeeze


def _columns_test_targets(y, plan, opts):
    return y[plan.te_idx]


def _rows_encode(y, dtype, opts):
    yb = jnp.asarray(y)
    squeeze = yb.ndim == 1
    return (yb[None, :] if squeeze else yb), squeeze


def _rows_test_targets(y, plan, opts):
    return y[plan.te_idx] if y.ndim == 1 else y[:, plan.te_idx]


@dataclasses.dataclass(frozen=True)
class LeastSquaresSpec:
    """One registered least-squares model family.

    The registry turns "add a model" from an engine fork into a data
    declaration: how targets are encoded into the shared label-batch
    layout, which jitted evaluator serves it, whether the plan's Eq. 15
    train block is needed, and which metric family scores it.

    Attributes:
      name:         registry key; ``Workload.estimator`` refers to it.
      layout:       "columns" (targets stack along a trailing batch dim,
                    binary/ridge style) or "rows" (label vectors stack
                    along a leading batch dim, multi-class style).
      make_eval:    ``(opts, donate, fused) -> jit[(plan, batch) -> out]``
                    — a fresh, independently-cached jitted evaluator (the
                    engine memoises one per (eval_key, static opts,
                    donate, fused) and counts its compiles). ``fused``
                    asks for the Pallas fold-eval kernels instead of the
                    XLA reference composite.
      encode:       ``(y, dtype, opts) -> (batch2d, squeeze)`` target
                    normalisation into the layout.
      test_targets: ``(y, plan, opts) -> y_te`` matching test targets.
      score:        ``(values, y_te, opts) -> scalar`` metric family.
      needs_train:  ``(opts) -> bool`` — True if the eval consumes the
                    plan's H_{Tr,Te} block (paper Eq. 15).
      validate:     ``(y, n, opts) -> None``, raising ValueError with a
                    clear message on malformed targets (eager, pre-jit).
      static_opts:  Workload option names that are static to the jitted
                    program (part of the eval-cache identity).
      defaults:     default option values.
      eval_key:     jit-cache identity; estimators sharing an evaluator
                    (e.g. ridge and multi-target ridge both run Eq. 14)
                    share one compiled program by sharing this key.
    """

    name: str
    layout: str
    make_eval: Callable
    encode: Callable = _columns_encode
    test_targets: Callable = _columns_test_targets
    score: Callable = None
    needs_train: Callable = lambda opts: False
    validate: Callable = lambda y, n, opts: None
    static_opts: tuple = ()
    defaults: dict = dataclasses.field(default_factory=dict)
    eval_key: str = ""

    def __post_init__(self):
        if self.layout not in ("columns", "rows"):
            raise ValueError(f"layout must be 'columns' or 'rows', got {self.layout!r}")
        if not self.eval_key:
            object.__setattr__(self, "eval_key", self.name)

    def resolve_opts(self, opts: dict) -> dict:
        merged = dict(self.defaults)
        merged.update({k: v for k, v in opts.items() if k in self.defaults})
        return merged

    def static_key(self, opts: dict) -> tuple:
        return tuple((k, opts[k]) for k in self.static_opts)


_ESTIMATORS: dict = {}


def register_estimator(spec: LeastSquaresSpec, *, overwrite: bool = False) -> LeastSquaresSpec:
    """Register a least-squares model family under ``spec.name``.

    Registration is the *entire* integration surface: every driver
    (sync/thread/async), the micro-batcher, the shape-bucketed eval cache,
    and the warm-up API pick the new estimator up from here.
    """
    if spec.name in _ESTIMATORS and not overwrite:
        raise ValueError(f"estimator {spec.name!r} already registered (pass overwrite=True)")
    _ESTIMATORS[spec.name] = spec
    return spec


def get_estimator(name: str) -> LeastSquaresSpec:
    spec = _ESTIMATORS.get(name)
    if spec is None:
        known = tuple(sorted(_ESTIMATORS))
        raise ValueError(f"unknown estimator {name!r}; registered: {known}")
    return spec


def estimators() -> tuple:
    """Names of all registered least-squares estimators."""
    return tuple(sorted(_ESTIMATORS))


# -- built-in registrations: the paper's three models + multi-target ridge --


def _validate_binary(y, n, opts):
    arr = np.asarray(y)
    if arr.ndim not in (1, 2) or arr.shape[0] != n:
        raise ValueError(f"binary targets must be (N,) or (N, B) with N={n}, got {arr.shape}")
    if not np.all((arr == 1) | (arr == -1)):
        raise ValueError(
            "binary targets must be coded ±1 (paper §2.2); "
            "use estimator='ridge' for continuous responses"
        )


def _validate_ridge(y, n, opts):
    arr = np.asarray(y)
    if arr.ndim not in (1, 2) or arr.shape[0] != n:
        raise ValueError(f"ridge responses must be (N,) or (N, B) with N={n}, got {arr.shape}")


def _validate_multiclass(y, n, opts):
    arr = np.asarray(y)
    c = opts.get("num_classes", 0)
    if c < 2:
        raise ValueError("multiclass workloads need num_classes >= 2")
    if arr.ndim not in (1, 2) or arr.shape[-1] != n:
        raise ValueError(f"multiclass labels must be (N,) or (B, N) with N={n}, got {arr.shape}")
    if not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(f"multiclass labels must be integers, got dtype {arr.dtype}")
    if arr.size and (arr.min() < 0 or arr.max() >= c):
        raise ValueError(
            f"multiclass labels must lie in [0, {c}), got range [{arr.min()}, {arr.max()}]"
        )


def _validate_ridge_multi(y, n, opts):
    arr = np.asarray(y)
    if arr.ndim != 2 or arr.shape[0] != n:
        raise ValueError(f"multi-target ridge needs (N, Q) targets with N={n}, got {arr.shape}")


def _score_ridge_multi(values, y_te, opts):
    # Variance-weighted multi-target R² — a genuinely different metric
    # family from single-target MSE, which is the point of the registry.
    v = jnp.reshape(values, (-1, values.shape[-1]))
    t = jnp.reshape(y_te, (-1, y_te.shape[-1]))
    ss_res = jnp.sum((t - v) ** 2, axis=0)
    ss_tot = jnp.sum((t - jnp.mean(t, axis=0, keepdims=True)) ** 2, axis=0)
    return jnp.mean(1.0 - ss_res / jnp.maximum(ss_tot, jnp.finfo(t.dtype).tiny))


def _make_eval_binary(opts, donate, fused):
    return fastcv.make_eval_binary(adjust_bias=opts["adjust_bias"],
                                   donate=donate, fused=fused)


def _make_eval_ridge(opts, donate, fused):
    return fastcv.make_eval_cv(donate=donate, fused=fused)


def _make_eval_multiclass(opts, donate, fused):
    from repro.core import multiclass

    return multiclass.make_eval_multiclass(opts["num_classes"], donate=donate,
                                           fused=fused)


def _score_binary(values, y_te, opts):
    return metrics.binary_accuracy(values, y_te)


def _score_ridge(values, y_te, opts):
    return metrics.mse(values, y_te)


def _score_multiclass(values, y_te, opts):
    return metrics.multiclass_accuracy(values, y_te)


def _needs_train_binary(opts):
    return bool(opts["adjust_bias"])


def _needs_train_always(opts):
    return True


register_estimator(
    LeastSquaresSpec(
        name="binary",
        layout="columns",
        make_eval=_make_eval_binary,
        score=_score_binary,
        needs_train=_needs_train_binary,
        validate=_validate_binary,
        static_opts=("adjust_bias",),
        defaults={"adjust_bias": True},
    )
)

register_estimator(
    LeastSquaresSpec(
        name="ridge",
        layout="columns",
        make_eval=_make_eval_ridge,
        score=_score_ridge,
        validate=_validate_ridge,
    )
)

register_estimator(
    LeastSquaresSpec(
        name="multiclass",
        layout="rows",
        make_eval=_make_eval_multiclass,
        encode=_rows_encode,
        test_targets=_rows_test_targets,
        score=_score_multiclass,
        needs_train=_needs_train_always,
        validate=_validate_multiclass,
        static_opts=("num_classes",),
        defaults={"num_classes": 0},
    )
)

# Multi-target ridge shares the ridge evaluator (Eq. 14 over trailing
# columns) — and hence its compiled programs — via eval_key; only the
# targets contract and the metric family differ.
register_estimator(
    LeastSquaresSpec(
        name="ridge_multi",
        layout="columns",
        make_eval=_make_eval_ridge,
        score=_score_ridge_multi,
        validate=_validate_ridge_multi,
        eval_key="ridge",
    )
)


# ---------------------------------------------------------------------------
# Responses
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CVResponse:
    task: str  # estimator name
    values: object  # dvals / ẏ_Te (K, m[, B]) or preds — host np.ndarray
    #                 from the batched driver (MicroBatcher un-pads on the
    #                 host), jax.Array from direct engine calls
    y_te: jax.Array  # matching test labels/responses
    score: jax.Array  # the estimator's metric family (accuracy / mse / R²)
    plan_key: tuple
    timings: Optional[dict] = None  # stage -> seconds, tracing only


@dataclasses.dataclass
class PermutationResponse:
    observed: jax.Array
    null: jax.Array
    p: jax.Array
    plan_key: tuple
    timings: Optional[dict] = None  # stage -> seconds, tracing only


@dataclasses.dataclass
class RSAResponse:
    rdm: jax.Array  # (C, C) empirical RDM
    pair_values: Optional[object]  # (B,) pair dissimilarities (binary);
    #                                np.ndarray from the batched driver
    model_scores: Optional[jax.Array]  # (M,) or None
    null: Optional[jax.Array]  # (M, n_perm) or None
    p: Optional[jax.Array]  # (M,) or None
    plan_key: tuple
    timings: Optional[dict] = None  # stage -> seconds, tracing only


@dataclasses.dataclass
class TuneResponse:
    result: tuning.RidgeTuneResult
    timings: Optional[dict] = None  # stage -> seconds, tracing only


@dataclasses.dataclass
class GridResponse:
    accuracies: jax.Array  # (Q,) per-grid-point CV accuracy
    timings: Optional[dict] = None  # stage -> seconds, tracing only


@dataclasses.dataclass
class UpdateResponse:
    """Result of a ``kind="update"`` workload: the advanced dataset.

    ``handle`` is the version n+1 :class:`DatasetHandle`; subsequent
    workloads should carry it. ``appended``/``dropped`` count this
    workload's own contribution (coalesced updates share one correction
    but report per-member counts); ``rank`` = appended + dropped is the
    correction rank the engine applied for this member.
    """

    handle: DatasetHandle
    version: int
    appended: int
    dropped: int
    rank: int
    plan_key: tuple
    timings: Optional[dict] = None  # stage -> seconds, tracing only


# ---------------------------------------------------------------------------
# The Workload spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Workload:
    """One versioned, eagerly-validated unit of work against the engine.

    ``kind`` selects the workload family; the remaining fields are that
    family's sub-spec (unused fields are ignored by the driver but still
    validated for coherence):

      cv           dataset + y + estimator (+ estimator options)
      permutation  dataset + y + estimator (binary|multiclass) + null spec
                   (n_perm, seed, metric)
      rsa          dataset + y (condition labels) + contrast spec
                   (num_classes, contrast, dissimilarity, adjust_bias) +
                   optional model spec (model_rdms, comparison, n_perm, seed)
      tune         x + y + lambdas/criterion (exact-LOO ridge tuning; no
                   plan, so no dataset)
      grid         xs (Q, N, P) + y + dataset for folds/λ (the spec's own
                   ``x`` may be None)
      update       dataset (a registered DatasetHandle) + x (rows to
                   append) and/or drop_idx (base-version rows to retire);
                   the engine advances the cached plan by a rank-k
                   correction and returns the version n+1 handle

    ``dataset`` is a :class:`DatasetHandle` (registered; carries no
    feature bytes) or an inline :class:`DatasetSpec` (``kind="update"``
    requires a handle — incremental updates act on registry state).
    Validation runs at construction: shape/coding errors surface here with
    a clear message, never as a jit shape failure mid-serve.
    """

    kind: str
    dataset: object = None  # DatasetHandle | DatasetSpec | None
    y: object = None
    estimator: str = "binary"
    num_classes: int = 0
    adjust_bias: bool = True
    # null / permutation spec
    n_perm: int = 0
    seed: int = 0
    metric: str = "accuracy"
    # rsa contrast + model spec
    contrast: str = "binary"
    dissimilarity: str = "accuracy"
    model_rdms: object = None
    comparison: str = "spearman"
    # tune spec
    lambdas: object = None
    criterion: str = "mse"
    x: object = None  # tune-kind features / update-kind appended rows
    xs: object = None  # grid-kind (Q, N, P) feature grid
    drop_idx: object = None  # update-kind base-version rows to retire

    def __post_init__(self):
        self.validate()

    # -- validation --------------------------------------------------------

    def _dataset_n(self) -> Optional[int]:
        if isinstance(self.dataset, DatasetHandle):
            return self.dataset.n or None
        if self.dataset is not None and getattr(self.dataset, "x", None) is not None:
            return int(self.dataset.x.shape[0])
        return None

    def estimator_opts(self) -> dict:
        spec = get_estimator(self.estimator)
        opts = {"adjust_bias": self.adjust_bias, "num_classes": self.num_classes}
        return spec.resolve_opts(opts)

    def validate(self) -> "Workload":
        if self.kind not in KINDS:
            raise ValueError(f"unknown workload kind {self.kind!r}; expected one of {KINDS}")
        getattr(self, f"_validate_{self.kind}")()
        return self

    def _require_dataset(self):
        if self.dataset is None:
            raise ValueError(
                f"kind={self.kind!r} workloads need a dataset (DatasetHandle or DatasetSpec)"
            )
        if not isinstance(self.dataset, DatasetHandle) and not hasattr(self.dataset, "folds"):
            raise TypeError(
                f"dataset must be a DatasetHandle or DatasetSpec-like, "
                f"got {type(self.dataset).__name__}"
            )

    def _validate_cv(self):
        self._require_dataset()
        if self.y is None:
            raise ValueError("cv workloads need targets y")
        spec = get_estimator(self.estimator)
        n = self._dataset_n()
        if n is not None:
            spec.validate(self.y, n, self.estimator_opts())

    def _validate_permutation(self):
        self._require_dataset()
        if self.y is None:
            raise ValueError("permutation workloads need targets y")
        if self.estimator not in _PERM_ESTIMATORS:
            raise ValueError(
                f"permutation workloads support estimators {_PERM_ESTIMATORS}, "
                f"got {self.estimator!r}"
            )
        if self.n_perm <= 0:
            raise ValueError("permutation workloads need n_perm > 0")
        if np.ndim(self.y) != 1:
            raise ValueError("permutation workloads need a single (N,) target vector y")
        if self.estimator == "binary" and self.metric not in _BINARY_METRICS:
            raise ValueError(
                f"binary permutation metric must be one of {_BINARY_METRICS}, "
                f"got {self.metric!r}"
            )
        n = self._dataset_n()
        if n is not None:
            spec = get_estimator(self.estimator)
            spec.validate(self.y, n, self.estimator_opts())

    def _validate_rsa(self):
        self._require_dataset()
        if self.y is None:
            raise ValueError("rsa workloads need condition labels y")
        if self.num_classes < 2:
            raise ValueError("rsa workloads need num_classes >= 2")
        if self.contrast not in _CONTRASTS:
            raise ValueError(f"unknown RSA contrast {self.contrast!r}; expected {_CONTRASTS}")
        if self.dissimilarity not in _DISSIMILARITIES:
            raise ValueError(
                f"unknown RSA dissimilarity {self.dissimilarity!r}; "
                f"expected one of {_DISSIMILARITIES}"
            )
        if self.comparison not in _COMPARISONS:
            raise ValueError(
                f"unknown RDM comparison {self.comparison!r}; expected one of {_COMPARISONS}"
            )
        arr = np.asarray(self.y)
        if arr.ndim != 1:
            raise ValueError(f"rsa condition labels must be (N,), got shape {arr.shape}")
        if not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(f"rsa condition labels must be integers, got {arr.dtype}")
        if arr.size and (arr.min() < 0 or arr.max() >= self.num_classes):
            raise ValueError(f"rsa condition labels must lie in [0, {self.num_classes})")
        if self.model_rdms is not None:
            m = np.shape(self.model_rdms)
            if len(m) != 3 or m[1] != self.num_classes or m[2] != self.num_classes:
                raise ValueError(
                    f"model_rdms must be (M, C, C) with C={self.num_classes}, got shape {m}"
                )

    def _validate_tune(self):
        x = self.x if self.x is not None else getattr(self.dataset, "x", None)
        if x is None:
            raise ValueError("tune workloads need features (x=... or a dataset with x)")
        if self.y is None:
            raise ValueError("tune workloads need targets y")
        if self.criterion not in _CRITERIA:
            raise ValueError(f"tune criterion must be one of {_CRITERIA}, got {self.criterion!r}")
        if np.shape(self.y)[0] != np.shape(x)[0]:
            raise ValueError(f"tune targets length {np.shape(self.y)[0]} != N={np.shape(x)[0]}")

    def _validate_grid(self):
        self._require_dataset()
        if self.xs is None or self.y is None:
            raise ValueError("grid workloads need xs (Q, N, P) and y")
        shape = np.shape(self.xs)
        if len(shape) != 3:
            raise ValueError(f"grid xs must be (Q, N, P), got shape {shape}")
        if shape[1] != np.shape(self.y)[0]:
            raise ValueError(f"grid xs second dim {shape[1]} != len(y) {np.shape(self.y)[0]}")

    def _validate_update(self):
        self._require_dataset()
        if not isinstance(self.dataset, DatasetHandle):
            raise ValueError(
                "update workloads need a registered DatasetHandle — "
                "incremental updates advance registry state, so register() "
                "the dataset first"
            )
        if self.x is None and self.drop_idx is None:
            raise ValueError(
                "update workloads need rows to append (x), rows to retire "
                "(drop_idx), or both"
            )
        if self.x is not None:
            shape = np.shape(self.x)
            if len(shape) != 2:
                raise ValueError(
                    f"update x must be a (k, P) block of appended rows, "
                    f"got shape {shape}"
                )
            if self.dataset.p and shape[1] != self.dataset.p:
                raise ValueError(
                    f"update x has {shape[1]} features but the dataset has "
                    f"P={self.dataset.p}"
                )
        if self.drop_idx is not None:
            arr = np.asarray(self.drop_idx)
            if arr.ndim != 1 or arr.size == 0:
                raise ValueError(
                    f"update drop_idx must be a non-empty 1-D index array, "
                    f"got shape {arr.shape}"
                )
            if not np.issubdtype(arr.dtype, np.integer):
                raise ValueError(
                    f"update drop_idx must be integer row indices, got "
                    f"dtype {arr.dtype}"
                )
            if arr.min() < 0 or (self.dataset.n and arr.max() >= self.dataset.n):
                raise ValueError(
                    f"update drop_idx out of range for the dataset's "
                    f"N={self.dataset.n}"
                )
            if np.unique(arr).size != arr.size:
                raise ValueError("update drop_idx contains duplicate rows")

    # -- versioned serialisation -------------------------------------------

    def to_dict(self) -> dict:
        """Versioned plain-dict form (JSON-serialisable)."""
        d = {
            "schema": WORKLOAD_SCHEMA_VERSION,
            "kind": self.kind,
            "estimator": self.estimator,
            "num_classes": self.num_classes,
            "adjust_bias": self.adjust_bias,
            "n_perm": self.n_perm,
            "seed": self.seed,
            "metric": self.metric,
            "contrast": self.contrast,
            "dissimilarity": self.dissimilarity,
            "comparison": self.comparison,
            "criterion": self.criterion,
        }
        for field in ("y", "model_rdms", "lambdas", "x", "xs", "drop_idx"):
            d[field] = _encode_array(getattr(self, field))
        d["dataset"] = _encode_dataset(self.dataset)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Workload":
        schema = d.get("schema")
        while schema in _SCHEMA_UPGRADES and schema != WORKLOAD_SCHEMA_VERSION:
            d = _SCHEMA_UPGRADES[schema](d)
            schema = d.get("schema")
        if schema != WORKLOAD_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported workload schema version {schema!r} "
                f"(this build speaks {WORKLOAD_SCHEMA_VERSION})"
            )
        return cls(
            kind=d["kind"],
            dataset=_decode_dataset(d.get("dataset")),
            y=_decode_array(d.get("y")),
            estimator=d.get("estimator", "binary"),
            num_classes=int(d.get("num_classes", 0)),
            adjust_bias=bool(d.get("adjust_bias", True)),
            n_perm=int(d.get("n_perm", 0)),
            seed=int(d.get("seed", 0)),
            metric=d.get("metric", "accuracy"),
            contrast=d.get("contrast", "binary"),
            dissimilarity=d.get("dissimilarity", "accuracy"),
            model_rdms=_decode_array(d.get("model_rdms")),
            comparison=d.get("comparison", "spearman"),
            lambdas=_decode_array(d.get("lambdas")),
            criterion=d.get("criterion", "mse"),
            x=_decode_array(d.get("x")),
            xs=_decode_array(d.get("xs")),
            drop_idx=_decode_array(d.get("drop_idx")),
        )


def _upgrade_v1_to_v2(d: dict) -> dict:
    """Schema 1 → 2: ``kind="update"`` and ``drop_idx`` were added; every
    v1 field kept its meaning, so the upgrade just fills the v2 defaults."""
    out = dict(d)
    out["schema"] = 2
    out.setdefault("drop_idx", None)
    return out


_SCHEMA_UPGRADES = {1: _upgrade_v1_to_v2}


def _encode_array(a):
    if a is None:
        return None
    arr = np.asarray(a)
    return {"__array__": arr.tolist(), "dtype": str(arr.dtype)}


def _decode_array(d):
    if d is None:
        return None
    return jnp.asarray(np.asarray(d["__array__"], dtype=np.dtype(d["dtype"])))


def _encode_dataset(ds):
    if ds is None:
        return None
    if isinstance(ds, DatasetHandle):
        return ds.to_dict()
    folds = ds.folds
    if folds is not None:
        folds = as_folds(folds)
        folds = {
            "te_idx": np.asarray(folds.te_idx).tolist(),
            "tr_idx": np.asarray(folds.tr_idx).tolist(),
        }
    return {
        "__dataset__": {
            "x": _encode_array(ds.x),
            "folds": folds,
            "lam": float(ds.lam),
            "mode": getattr(ds, "mode", "auto"),
        }
    }


def _decode_dataset(d):
    if d is None:
        return None
    if "__handle__" in d:
        return DatasetHandle.from_dict(d)
    spec = d["__dataset__"]
    folds = spec["folds"]
    if folds is not None:
        folds = (np.asarray(folds["te_idx"], np.int32), np.asarray(folds["tr_idx"], np.int32))
        folds = as_folds(folds)
    return DatasetSpec(_decode_array(spec["x"]), folds, spec["lam"], spec.get("mode", "auto"))


def as_workload(obj) -> Workload:
    """Normalise to a :class:`Workload` (the deprecated request-shim
    conversion hook was removed at 0.3 — see the README migration table)."""
    if isinstance(obj, Workload):
        return obj
    raise TypeError(
        f"cannot interpret {type(obj).__name__} as a Workload; the legacy "
        "request classes were removed at 0.3 — construct a repro.serve."
        "Workload (README: 'Migration from the request classes')"
    )


# ---------------------------------------------------------------------------
# Unified driver
# ---------------------------------------------------------------------------


def _rdm_memo_key(plan_key, w: Workload):
    diss = w.dissimilarity if w.contrast == "binary" else None
    adj = w.adjust_bias if w.contrast == "binary" else None
    # Drop the trailing with-train-block flag: the same workload may be
    # served from either plan variant (the superset plan satisfies
    # train-block-free requests once resident) with identical RDMs.
    base = plan_key[:-1]
    return (base, fastcv.fingerprint(jnp.asarray(w.y)), w.contrast, diss, adj, w.num_classes)


def run_workloads(engine, workloads: Sequence, *, return_errors: bool = False) -> list:
    """Serve a batch of workloads; responses align with ``workloads``.

    Same-plan CV label queries coalesce into one padded jitted eval per
    (plan, estimator, static-options) group; RSA contrast columns ride the
    same column path with empirical-RDM memoisation (repeat scoring of the
    same (plan, labels) skips the fold solves entirely); permutation, tune,
    and grid workloads route to their engine entry points. ``update``
    workloads against the same base version coalesce into one rank-k
    correction (appends stack in submission order, drop sets union); every
    member receives the same version n+1 handle with its own
    appended/dropped contribution in the :class:`UpdateResponse`.

    With ``return_errors=True`` a failing workload (conversion error,
    unknown/evicted dataset handle, eval failure) yields its *exception
    object* in the corresponding slot instead of aborting the batch, so
    sibling workloads — including other clients' traffic coalesced into
    the same gather window — still get served. The batch transports
    (:class:`~repro.serve.api.EngineServer`,
    :class:`~repro.serve.aio.AsyncEngineServer`, and the HTTP edge) run in
    this mode and fan each entry's result-or-error back to its own
    submitter.

    Observability: when the engine's tracer is enabled, every workload
    carries (or gets) a :class:`~repro.serve.trace.Trace`; engine-internal
    spans (cache_lookup, plan_build, eval, null_chunk) fire while that
    trace is *activated* around the calls below, the shared coalesced
    group eval is timed once and attributed to every member as an ``eval``
    span, and the finished trace's per-stage sums attach to the response
    as ``timings``. Tracing off ⇒ all hooks are no-ops and ``timings``
    stays None.
    """
    # reprolint: host-path
    # (Batch grouping/coalescing is host work: eager jnp assembly here
    # would recompile per traffic mix — PR 3's bug class, now RL001.)
    raw = list(workloads)
    responses: list = [None] * len(raw)
    tracer = getattr(engine, "tracer", None) or NULL_TRACER
    metrics_reg = getattr(engine, "metrics", None)
    traces: list = [None] * len(raw)
    plan_memo: dict = {}
    # In-flight version pinning: every handle this batch resolves is
    # retained on the engine for the batch's duration, so a concurrent
    # release() of a stale version cannot pull the plan out from under a
    # workload that was built against it.
    retain = getattr(engine, "retain_version", None)
    release = getattr(engine, "release_version", None)
    retained: set = set()

    def fail(i, e: Exception):
        if not return_errors:
            # Propagating aborts the batch: drop the version pins first so
            # a failed batch can't wedge deferred releases forever.
            if release is not None:
                for key in retained:
                    release(key)
                retained.clear()
            raise e
        responses[i] = e

    def plan_for(dataset, with_train_block: bool):
        if isinstance(dataset, DatasetHandle):
            if retain is not None and dataset.key not in retained:
                retain(dataset.key)
                retained.add(dataset.key)
            memo_key = (dataset.key, with_train_block)
        else:
            memo_key = (
                id(dataset.x),
                id(dataset.folds),
                float(dataset.lam),
                dataset.mode,
                with_train_block,
            )
        hit = plan_memo.get(memo_key)
        if hit is None:
            hit = plan_memo[memo_key] = engine.resolve(dataset, with_train_block)
        return hit

    # -- group CV workloads by (plan, estimator, static opts) --------------
    groups: dict = {}
    rsa_groups: dict = {}
    update_groups: dict = {}
    for i, obj in enumerate(raw):
        tr = trace_of(obj)
        if tr is None and tracer.enabled:
            tr = tracer.trace()
        traces[i] = tr
        try:
            with tracer.activate(tr):
                with tracer.span("validate"):
                    w = as_workload(obj)
                    est = w.estimator if w.kind in ("cv", "permutation") else ""
                    if tr is not None:
                        tr.kind, tr.estimator = w.kind, est
                    if metrics_reg is not None:
                        metrics_reg.inc("requests_total", kind=w.kind, estimator=est)
                if w.kind == "cv":
                    with tracer.span("validate"):
                        spec = get_estimator(w.estimator)
                        opts = w.estimator_opts()
                    key, plan = plan_for(w.dataset, spec.needs_train(opts))
                    gkey = (key, w.estimator, spec.static_key(opts))
                    groups.setdefault(gkey, (plan, spec, opts, []))[3].append((i, w))
                elif w.kind == "rsa":
                    needs_train = w.contrast == "multiclass" or w.adjust_bias
                    key, plan = plan_for(w.dataset, needs_train)
                    if w.contrast == "binary":
                        gkey = (key, "binary", w.dissimilarity, w.adjust_bias, w.num_classes)
                    else:
                        gkey = (key, "multiclass", None, None, w.num_classes)
                    rsa_groups.setdefault(gkey, (plan, []))[1].append((i, w))
                elif w.kind == "permutation":
                    needs_train = w.estimator == "multiclass" or w.adjust_bias
                    key, plan = plan_for(w.dataset, needs_train)
                    # Input normalisation (labels -> device array, seed ->
                    # PRNG key) is validate-stage work; leaving it untraced
                    # breaks the stage-sum ≈ end-to-end invariant.
                    with tracer.span("validate"):
                        yv = tracer.sync(jnp.asarray(w.y))
                        pkey = tracer.sync(jax.random.PRNGKey(w.seed))
                    if w.estimator == "multiclass":
                        res = engine.permutation_multiclass(
                            plan, yv, w.n_perm, pkey, num_classes=w.num_classes
                        )
                    else:
                        res = engine.permutation_binary(
                            plan,
                            yv,
                            w.n_perm,
                            pkey,
                            metric=w.metric,
                            adjust_bias=w.adjust_bias,
                        )
                    with tracer.span("encode"):
                        responses[i] = PermutationResponse(
                            res.observed, res.null, tracer.sync(res.p), key
                        )
                elif w.kind == "tune":
                    x = w.x if w.x is not None else w.dataset.x
                    res = engine.tune(x, w.y, lambdas=w.lambdas, criterion=w.criterion)
                    with tracer.span("encode"):
                        responses[i] = TuneResponse(res)
                elif w.kind == "grid":
                    folds, lam = _grid_folds_lam(engine, w.dataset)
                    xs, yv = jnp.asarray(w.xs), jnp.asarray(w.y)
                    with tracer.span("eval"):
                        grid = tracer.sync(
                            multidim.cv_grid(xs, yv, folds, lam, adjust_bias=w.adjust_bias)
                        )
                    with tracer.span("encode"):
                        responses[i] = GridResponse(grid)
                elif w.kind == "update":
                    # Same-dataset updates coalesce into one rank-k
                    # correction per base version (appends stack, drops
                    # union) — processed after grouping, below.
                    update_groups.setdefault(w.dataset.key, []).append((i, w))
                else:  # unreachable: validate() gates kinds
                    raise ValueError(f"unknown workload kind {w.kind!r}")
        except Exception as e:  # noqa: BLE001 - isolated per workload
            fail(i, e)

    # -- one coalesced eval per CV group -----------------------------------
    batcher = engine.batcher
    for (key, estimator, _static), (plan, spec, opts, members) in groups.items():
        try:
            # The coalesced eval is shared work: time it once — including
            # the label device transfer, since that dispatch is part of the
            # shared prep (the batcher un-pads through host numpy, which is
            # the device sync) — and attribute the whole cost to every
            # member's trace. No trace is active here, so the
            # engine-internal eval span is a no-op — the cost is counted
            # exactly once per trace.
            t0 = time.perf_counter() if tracer.enabled else 0.0
            ys = [jnp.asarray(w.y) for _, w in members]
            run = batcher.run_columns if spec.layout == "columns" else batcher.run_rows
            outs = run(ys, lambda b: engine.eval_estimator(plan, b, estimator, owned=True, **opts))
            if tracer.enabled:
                dt = time.perf_counter() - t0
                for i, _w in members:
                    if traces[i] is not None:
                        traces[i].add("eval", dt)
        except Exception as e:  # noqa: BLE001 - the whole group shares the eval
            for i, _w in members:
                fail(i, e)
            continue
        for (i, w), values in zip(members, outs):
            try:
                with tracer.activate(traces[i]), tracer.span("encode"):
                    y = jnp.asarray(w.y)
                    y_te = spec.test_targets(y, plan, opts)
                    score = tracer.sync(spec.score(values, y_te, opts))
                    responses[i] = CVResponse(estimator, values, y_te, score, key)
            except Exception as e:  # noqa: BLE001 - per-member post-processing
                fail(i, e)

    # -- RSA: contrast columns ride the same coalesced label-batch path ----
    for (key, contrast, diss, adj, c), (plan, members) in rsa_groups.items():
        try:
            t0 = time.perf_counter() if tracer.enabled else 0.0
            rdms = _rsa_empirical(engine, key, plan, contrast, diss, adj, c, members)
            if tracer.enabled:
                dt = time.perf_counter() - t0
                for i, _w in members:
                    if traces[i] is not None:
                        traces[i].add("eval", dt)
        except Exception as e:  # noqa: BLE001 - the whole group shares the eval
            for i, _w in members:
                fail(i, e)
            continue
        for (i, w), (rdm, vals) in zip(members, rdms):
            try:
                with tracer.activate(traces[i]):
                    scores = null = p = None
                    if w.model_rdms is not None:
                        with tracer.span("validate"):
                            models = tracer.sync(jnp.asarray(w.model_rdms))
                            pkey = tracer.sync(jax.random.PRNGKey(w.seed))
                        scores, null, p = engine.compare_rdms(
                            rdm, models, w.comparison, w.n_perm, pkey
                        )
                    with tracer.span("encode"):
                        responses[i] = RSAResponse(rdm, vals, tracer.sync(scores), null, p, key)
            except Exception as e:  # noqa: BLE001 - per-member model scoring
                fail(i, e)

    # -- one coalesced rank-k correction per updated base version ----------
    for base_key, members in update_groups.items():
        try:
            update_dataset = getattr(engine, "update_dataset", None)
            if update_dataset is None:
                raise TypeError(
                    "this engine does not support kind='update' workloads "
                    "(no update_dataset method)")
            x_blocks = [w.x for _, w in members if w.x is not None]
            drops = [np.asarray(w.drop_idx) for _, w in members if w.drop_idx is not None]
            # Host-side coalescing (RL001): appended blocks arrive as wire
            # arrays with arbitrary ragged row counts, so stacking them
            # with eager jnp would compile per group mix. The update path
            # consumes x_new on host (float64 Woodbury correction) anyway.
            x_new = np.concatenate([np.asarray(b) for b in x_blocks]) if x_blocks else None
            drop_idx = np.concatenate(drops) if drops else None
            t0 = time.perf_counter() if tracer.enabled else 0.0
            handle = update_dataset(members[0][1].dataset, x_new=x_new, drop_idx=drop_idx)
            if tracer.enabled:
                dt = time.perf_counter() - t0
                for i, _w in members:
                    if traces[i] is not None:
                        traces[i].add("plan_update", dt)
        except Exception as e:  # noqa: BLE001 - the group shares the update
            for i, _w in members:
                fail(i, e)
            continue
        for i, w in members:
            try:
                with tracer.activate(traces[i]), tracer.span("encode"):
                    appended = 0 if w.x is None else int(np.shape(w.x)[0])
                    dropped = 0 if w.drop_idx is None else int(np.shape(w.drop_idx)[0])
                    responses[i] = UpdateResponse(
                        handle=handle,
                        version=handle.version,
                        appended=appended,
                        dropped=dropped,
                        rank=appended + dropped,
                        plan_key=handle.key,
                    )
            except Exception as e:  # noqa: BLE001 - per-member encode
                fail(i, e)

    # -- close traces; attach per-stage sums to the responses --------------
    for i, resp in enumerate(responses):
        tr = traces[i]
        if tr is None:
            continue
        tracer.finish(tr)
        if resp is not None and not isinstance(resp, Exception):
            resp.timings = tr.timings()
    if release is not None:
        for key in retained:
            release(key)
    return responses


def _grid_folds_lam(engine, dataset):
    if isinstance(dataset, DatasetHandle):
        rec = engine.dataset_record(dataset)
        return rec.folds, rec.lam
    return as_folds(dataset.folds), float(dataset.lam)


def _rsa_empirical(engine, key, plan, contrast, diss, adj, c, members):
    """(rdm, pair_values) per member, with engine-level RDM memoisation.

    Only cache misses pay fold solves — and they still coalesce into one
    padded batch; hits are filled from
    :attr:`~repro.serve.engine.CVEngine.rdm_cache` (ROADMAP "RDM caching").
    """
    out: list = [None] * len(members)
    misses = []
    for j, (_i, w) in enumerate(members):
        memo_key = _rdm_memo_key(key, w)
        hit = engine.rdm_cache.get(memo_key)
        if hit is not None:
            out[j] = hit
        else:
            misses.append((j, w, memo_key))
    if misses:
        batcher = engine.batcher
        if contrast == "binary":
            cols = [
                rsa_rdm.pair_contrast_columns(jnp.asarray(w.y), c, plan.h.dtype)
                for _, w, _ in misses
            ]
            vals_list = batcher.run_columns(
                cols, lambda b: engine.eval_rsa_pairs(plan, b, diss, adj, owned=True)
            )
            built = [(rsa_rdm.rdm_from_pair_values(vals, c), vals) for vals in vals_list]
        else:
            ys = [jnp.asarray(w.y) for _, w, _ in misses]
            preds = batcher.run_rows(ys, lambda b: engine.eval_multiclass(plan, b, c, owned=True))
            built = [
                (rsa_rdm.rdm_from_confusion(pred, jnp.asarray(w.y)[plan.te_idx], c), None)
                for pred, (_, w, _) in zip(preds, misses)
            ]
        for (j, _w, memo_key), value in zip(misses, built):
            engine.rdm_cache.put(memo_key, value)
            out[j] = value
    return out


# ---------------------------------------------------------------------------
# Streaming (synchronous generator; repro.serve.aio drives it async)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ProgressEvent:
    """One step of a streamed workload.

    kind:    "plan" (payload: plan key), "observed" (payload: observed
             metric), "rdm" (payload: empirical RDM), "scores" (payload:
             model scores), "null" (payload: the new null chunk),
             "update" (payload: per-increment metrics delta dict — rows
             applied, correction rank, new version, seconds), or "done"
             (payload: the final response object).
    done:    permutations finished so far (0 for pre-null events); rows
             applied so far for streamed updates.
    total:   total permutations (or update rows) the stream will produce.
    payload: kind-specific value; always the full response on "done".
    """

    kind: str
    done: int
    total: int
    payload: object


def _chunk_plan(engine, total: int, chunk: int) -> tuple[int, int]:
    buckets = engine.config.buckets
    t_gen = bucket_size(total, buckets)
    chunk = min(bucket_size(chunk, buckets), t_gen)
    # whole chunks, same prefix (permutation_indices is prefix-stable)
    return -(-t_gen // chunk) * chunk, chunk


def _null_chunks(engine, total: int, n_items: int, seed: int, chunk: int, eval_chunk):
    """Shared streaming loop: yield (done, null_block) chunk by chunk.

    Permutations of ``n_items`` are generated once at the bucketed total —
    rounded up to a whole number of chunks, so every slice is a full chunk
    with one static shape even under non-nested custom buckets — and
    evaluated ``chunk`` rows at a time; repeats never recompile, and the
    rounding preserves the prefix, so the stream's first ``total`` draws
    match the monolithic path exactly. ``eval_chunk(block, keep)`` trims
    its own output to ``keep``.
    """
    t_gen, chunk = _chunk_plan(engine, total, chunk)
    perms = perm_lib.permutation_indices(jax.random.PRNGKey(seed), n_items, t_gen)
    for lo in range(0, total, chunk):
        hi = min(lo + chunk, total)
        yield hi, eval_chunk(perms[lo : min(lo + chunk, t_gen)], hi - lo)


def stream_workload(engine, workload, chunk: int = 64) -> Iterator[ProgressEvent]:
    """Generator of :class:`ProgressEvent`\\ s for one workload.

    Permutation workloads emit their null distribution in prefix-stable
    bucket-sized chunks (identical draws to the monolithic path — on a
    mesh-configured engine the chunks shard over ``perm_axes`` exactly
    like :meth:`~repro.serve.engine.CVEngine.permutation_binary`); RSA
    workloads emit the empirical RDM, then model scores, then null chunks.
    Any other kind degenerates to a single "done" event wrapping the
    batched response.

    Tracing: the workload's attached trace (or a fresh one when the
    engine's tracer is enabled) is *activated only around engine calls*,
    never across a ``yield`` — a generator suspending inside an activation
    would leak the context var into whatever its driver thread runs next.
    The final "done" response carries ``timings`` like the batched path.
    """
    tracer = getattr(engine, "tracer", None) or NULL_TRACER
    tr = trace_of(workload)
    if tr is None and tracer.enabled:
        tr = tracer.trace()
    with tracer.activate(tr):
        with tracer.span("validate"):
            w = as_workload(workload)
    if w.kind == "permutation":
        if tr is not None:
            tr.kind, tr.estimator = w.kind, w.estimator
        _count_request(engine, w.kind, w.estimator)
        yield from _stream_permutation(engine, w, chunk, tracer, tr)
    elif w.kind == "rsa":
        if tr is not None:
            tr.kind = w.kind
        _count_request(engine, w.kind, "")
        yield from _stream_rsa(engine, w, chunk, tracer, tr)
    elif w.kind == "update":
        if tr is not None:
            tr.kind = w.kind
        _count_request(engine, w.kind, "")
        yield from _stream_update(engine, w, chunk, tracer, tr)
    else:
        # run_workloads counts the request, picks the trace up from the
        # workload object, and attaches timings itself.
        attach_trace(w, tr)
        (resp,) = run_workloads(engine, [w])
        yield ProgressEvent("done", 1, 1, resp)


def _count_request(engine, kind: str, estimator: str) -> None:
    metrics_reg = getattr(engine, "metrics", None)
    if metrics_reg is not None:
        metrics_reg.inc("requests_total", kind=kind, estimator=estimator)


def _finish_stream(tracer, tr, build_response):
    """Final-event helper: build the response under an ``encode`` span,
    close the trace, and attach its per-stage sums."""
    if tr is None:
        return build_response()
    with tracer.activate(tr), tracer.span("encode"):
        resp = build_response()
    tracer.finish(tr)
    resp.timings = tr.timings()
    return resp


def _stream_permutation(engine, w: Workload, chunk: int, tracer=NULL_TRACER, tr=None):
    # reprolint: host-path
    # (Chunk assembly is host work: a stream's chunk count varies with
    # n_perm, so eager jnp concatenation would compile per stream shape.)
    total = w.n_perm
    needs_train = w.estimator == "multiclass" or w.adjust_bias
    with tracer.activate(tr):
        key, plan = engine.resolve(w.dataset, needs_train)
    yield ProgressEvent("plan", 0, total, key)
    y = jnp.asarray(w.y)
    if w.estimator == "multiclass":
        with tracer.activate(tr):
            observed = engine.observed_multiclass(plan, y, num_classes=w.num_classes)

        def eval_chunk(block, keep):
            with tracer.activate(tr):
                return engine.null_multiclass(plan, y, block, num_classes=w.num_classes)[:keep]

    else:
        with tracer.activate(tr):
            observed = engine.observed_binary(
                plan, y, metric=w.metric, adjust_bias=w.adjust_bias
            )

        def eval_chunk(block, keep):
            with tracer.activate(tr):
                return engine.null_binary(
                    plan, y, block, metric=w.metric, adjust_bias=w.adjust_bias
                )[:keep]

    yield ProgressEvent("observed", 0, total, observed)
    chunks = []
    for hi, null_block in _null_chunks(engine, total, int(y.shape[0]), w.seed, chunk, eval_chunk):
        chunks.append(null_block)
        yield ProgressEvent("null", hi, total, null_block)

    def build():
        # Host concatenation: chunk boundaries vary per stream, and the
        # float64 draws are bit-identical either side of the transfer.
        null = np.concatenate([np.asarray(c) for c in chunks])
        p = perm_lib.p_value(observed, null)
        return PermutationResponse(observed, null, p, key)

    yield ProgressEvent("done", total, total, _finish_stream(tracer, tr, build))


def _stream_update(engine, w: Workload, chunk: int, tracer=NULL_TRACER, tr=None):
    """Chunked incremental updates: apply the correction in increments.

    The drop set (plus an equal number of appended rows when both are
    present — the sliding-window move) lands as the first increment; any
    remaining appended rows follow in chunks rounded to a whole number of
    folds so every increment keeps per-fold test sizes rectangular. Each
    increment is a real engine update (counters and histograms move per
    increment — the emitted "update" events are metrics deltas), and the
    superseded intermediate versions are released as soon as the next one
    lands; only the base version and the final version survive the stream.
    """
    # reprolint: host-path
    # (Increment slicing/grouping is host work; device entry is asarray.)
    handle = w.dataset
    k_total = 0 if w.x is None else int(np.shape(w.x)[0])
    d_total = 0 if w.drop_idx is None else int(np.shape(w.drop_idx)[0])
    total = k_total + d_total
    yield ProgressEvent("plan", 0, total, handle.key)
    x = None if w.x is None else jnp.asarray(w.x)
    increments = []
    lo = 0
    if d_total:
        take = min(k_total, d_total)
        increments.append((None if not take else x[:take], w.drop_idx))
        lo = take
    if lo < k_total:
        rec = getattr(engine, "dataset_record", None)
        n_folds = rec(handle).folds.k if rec is not None else 1
        step = max(n_folds, chunk - chunk % n_folds)
        for start in range(lo, k_total, step):
            increments.append((x[start : start + step], None))
    release = getattr(engine, "release", None)
    cur, prev = handle, None
    applied = 0
    for x_inc, drop_inc in increments:
        k_inc = 0 if x_inc is None else int(x_inc.shape[0])
        d_inc = 0 if drop_inc is None else int(np.shape(drop_inc)[0])
        t0 = time.perf_counter()
        with tracer.activate(tr):
            cur = engine.update_dataset(cur, x_new=x_inc, drop_idx=drop_inc)
        dt = time.perf_counter() - t0
        if prev is not None and release is not None:
            release(prev, drop_store=True)
        prev = cur
        applied += k_inc + d_inc
        yield ProgressEvent(
            "update",
            applied,
            total,
            {
                "appended": k_inc,
                "dropped": d_inc,
                "rank": k_inc + d_inc,
                "version": cur.version,
                "seconds": dt,
            },
        )

    def build():
        return UpdateResponse(
            handle=cur,
            version=cur.version,
            appended=k_total,
            dropped=d_total,
            rank=total,
            plan_key=cur.key,
        )

    yield ProgressEvent("done", total, total, _finish_stream(tracer, tr, build))


def _stream_rsa(engine, w: Workload, chunk: int, tracer=NULL_TRACER, tr=None):
    # reprolint: host-path
    # (Null-chunk assembly and the final p-value are host work — chunk
    # counts vary per stream, so eager jnp here is the recompile class.)
    c = w.num_classes
    total = w.n_perm if w.model_rdms is not None else 0
    needs_train = w.contrast == "multiclass" or w.adjust_bias
    with tracer.activate(tr):
        key, plan = engine.resolve(w.dataset, needs_train)
    yield ProgressEvent("plan", 0, total, key)
    y = jnp.asarray(w.y)
    memo_key = _rdm_memo_key(key, w)
    hit = engine.rdm_cache.get(memo_key)
    if hit is not None:
        rdm, vals = hit
    elif w.contrast == "binary":
        with tracer.activate(tr):
            cols = rsa_rdm.pair_contrast_columns(y, c, plan.h.dtype)
            vals = engine.eval_rsa_pairs(plan, cols, w.dissimilarity, w.adjust_bias)
            rdm = rsa_rdm.rdm_from_pair_values(vals, c)
        engine.rdm_cache.put(memo_key, (rdm, vals))
    else:
        with tracer.activate(tr):
            preds = engine.eval_multiclass(plan, y, c)
            rdm, vals = rsa_rdm.rdm_from_confusion(preds, y[plan.te_idx], c), None
        engine.rdm_cache.put(memo_key, (rdm, vals))
    yield ProgressEvent("rdm", 0, total, rdm)
    if w.model_rdms is None:
        resp = _finish_stream(
            tracer, tr, lambda: RSAResponse(rdm, vals, None, None, None, key)
        )
        yield ProgressEvent("done", 0, 0, resp)
        return
    models = jnp.asarray(w.model_rdms)
    with tracer.activate(tr):
        scores = engine.score_rdms(rdm, models, w.comparison)
    yield ProgressEvent("scores", 0, total, scores)
    if total <= 0:
        resp = _finish_stream(
            tracer, tr, lambda: RSAResponse(rdm, vals, scores, None, None, key)
        )
        yield ProgressEvent("done", 0, 0, resp)
        return

    def eval_chunk(block, keep):
        with tracer.activate(tr):
            return engine.null_rdm_scores(rdm, models, block, w.comparison)[:, :keep]

    chunks = []
    for hi, null_block in _null_chunks(engine, total, c, w.seed, chunk, eval_chunk):
        chunks.append(null_block)
        yield ProgressEvent("null", hi, total, null_block)

    def build():
        # Host concatenation + counting: comparisons of float64 values
        # are exact, so the integer exceedance counts (and hence p) are
        # bit-identical to the previous on-device reduction.
        null = np.concatenate([np.asarray(c) for c in chunks], axis=1)
        p = (1.0 + np.sum(null >= np.asarray(scores)[:, None], axis=1)) / (1.0 + total)
        return RSAResponse(rdm, vals, scores, null, p, key)

    yield ProgressEvent("done", total, total, _finish_stream(tracer, tr, build))


# ---------------------------------------------------------------------------
# Traffic recording: the observed (task, bucket) set, replayable at boot
# ---------------------------------------------------------------------------


class TrafficLog:
    """The (task, bucket) set a serving session actually hit.

    The :class:`~repro.serve.client.Client` records every submitted
    workload's warm-up coordinates — eval task, label-batch bucket, and
    the static options the compiled program depends on — as a dedup'd
    set. ``save``/``load`` round-trip it as JSON (``serve_cv
    --record-traffic`` / ``--warmup-from``), and :meth:`replay` feeds it
    back through :meth:`~repro.serve.engine.CVEngine.warmup`, so a boot
    sequence pre-compiles what yesterday's traffic needed.

    Buckets are recorded *per workload*. Batch paths that coalesce many
    workloads into one padded eval (sync ``gather``, the thread/async
    gather windows) compile at the coalesced width, which depends on
    traffic timing — replaying a per-workload log warms every individual
    shape (and the deterministic permutation/RSA buckets) but may still
    leave a first compile for a novel coalesced batch composition.
    """

    _TASKS = {
        "binary": "binary",
        "ridge": "ridge",
        "ridge_multi": "ridge",
        "multiclass": "multiclass",
    }

    def __init__(self, entries: Optional[Sequence[dict]] = None):
        self._entries: set = set()
        for e in entries or ():
            self._entries.add(tuple(sorted(e.items())))

    def __len__(self) -> int:
        return len(self._entries)

    def entries(self) -> list[dict]:
        return sorted((dict(e) for e in self._entries), key=lambda d: (d["task"], d["bucket"]))

    def _add(self, **fields) -> None:
        self._entries.add(tuple(sorted(fields.items())))

    def record(
        self, workload: Workload, buckets: Sequence[int], stream_chunk: Optional[int] = None
    ) -> None:
        """Record one workload's warm-up coordinates.

        ``stream_chunk`` (set by ``Client.stream``) additionally records
        the chunk-sized null bucket a *streamed* permutation/RSA workload
        evaluates at, so replay also warms the chunk program.
        """
        w = as_workload(workload)
        chunk = None
        if stream_chunk is not None and w.n_perm > 0:
            chunk = min(bucket_size(stream_chunk, buckets), bucket_size(w.n_perm, buckets))
        if w.kind == "cv":
            task = self._TASKS.get(w.estimator)
            if task is None:
                return  # third-party estimators: no warm-up task mapping
            if np.ndim(w.y) == 1:
                width = 1
            elif get_estimator(w.estimator).layout == "columns":
                width = np.shape(w.y)[1]
            else:
                width = np.shape(w.y)[0]
            self._add(
                task=task,
                bucket=bucket_size(width, buckets),
                num_classes=w.num_classes if task == "multiclass" else 0,
                adjust_bias=w.adjust_bias if task == "binary" else True,
            )
        elif w.kind == "permutation":
            entry = dict(
                task="permutation",
                num_classes=w.num_classes if w.estimator == "multiclass" else 0,
                metric=w.metric if w.estimator == "binary" else "accuracy",
                adjust_bias=w.adjust_bias if w.estimator == "binary" else True,
            )
            self._add(bucket=bucket_size(w.n_perm, buckets), **entry)
            if chunk is not None:
                self._add(bucket=chunk, **entry)
        elif w.kind == "rsa":
            n_pairs = w.num_classes * (w.num_classes - 1) // 2
            entry = dict(
                task="rsa",
                num_classes=w.num_classes,
                dissimilarity=w.dissimilarity,
                adjust_bias=w.adjust_bias,
            )
            if w.contrast == "binary" and n_pairs:
                self._add(bucket=bucket_size(n_pairs, buckets), **entry)
            else:
                # confusion contrast: one Algorithm-2 row through the
                # multiclass eval — warm that program, not the pair path
                self._add(task="multiclass", bucket=1, num_classes=w.num_classes, adjust_bias=True)
            if w.model_rdms is not None and w.n_perm > 0:
                model_entry = dict(
                    comparison=w.comparison,
                    num_model_rdms=int(np.shape(w.model_rdms)[0]),
                    **entry,
                )
                self._add(bucket=bucket_size(w.n_perm, buckets), **model_entry)
                if chunk is not None:
                    self._add(bucket=chunk, **model_entry)
        # tune/grid build no plans: nothing to warm; update runs in host
        # numpy (no jitted program), so it records nothing either

    # -- persistence -------------------------------------------------------

    #: Schema versions this build replays. Entries are (task, bucket)
    #: coordinate dicts whose meaning is unchanged since v1, so old
    #: recorded logs keep warming new builds (``serve_cv --warmup-from``).
    _ACCEPTED_SCHEMAS = (1, WORKLOAD_SCHEMA_VERSION)

    def to_json(self) -> str:
        return json.dumps({"schema": WORKLOAD_SCHEMA_VERSION, "entries": self.entries()}, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "TrafficLog":
        d = json.loads(text)
        if d.get("schema") not in cls._ACCEPTED_SCHEMAS:
            raise ValueError(f"unsupported traffic-log schema {d.get('schema')!r}")
        return cls(d["entries"])

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path) -> "TrafficLog":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- replay ------------------------------------------------------------

    def replay(self, engine, dataset, *, pin: bool = False) -> list[dict]:
        """Warm ``engine`` for ``dataset`` from the recorded traffic.

        One :meth:`~repro.serve.engine.CVEngine.warmup` call per recorded
        entry (pre-compilation dedups shared programs); returns the
        warm-up summaries.
        """
        summaries = []
        for e in self.entries():
            kw = dict(
                tasks=(e["task"],),
                buckets=(e["bucket"],),
                pin=pin,
                num_classes=e.get("num_classes", 0),
                adjust_bias=e.get("adjust_bias", True),
            )
            if e["task"] == "permutation":
                kw["metric"] = e.get("metric", "accuracy")
            if e["task"] == "rsa":
                kw.update(
                    dissimilarity=e.get("dissimilarity", "accuracy"),
                    comparison=e.get("comparison", "spearman"),
                    num_model_rdms=e.get("num_model_rdms", 0),
                )
                if kw["num_model_rdms"] and kw["num_classes"] < 2:
                    kw["num_classes"] = 2
            summaries.append(engine.warmup(dataset, **kw))
        return summaries
