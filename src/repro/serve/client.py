"""repro.serve.client — one Client, three transports.

Every driver in this package executes the same
:class:`~repro.serve.workload.Workload` spec through the same engine; the
only real choice a caller makes is *how submissions travel*: inline on
the calling thread, through the thread-backed queue, or through the
asyncio gather window. :class:`Client` makes that a constructor argument
instead of three APIs:

    client = Client(engine)                      # sync, in-process
    client = Client(engine, transport="thread")  # EngineServer futures
    client = Client(engine, transport="async")   # AsyncEngineServer

``submit`` / ``gather`` / ``stream`` then have transport-appropriate
return types (response vs Future vs awaitable; generator vs async
generator) but identical semantics and — by the parity tests —
bit-identical results. The client also fronts the engine's dataset
registry (``register`` → :class:`~repro.serve.workload.DatasetHandle`)
and, given a :class:`~repro.serve.workload.TrafficLog`, records the
(task, bucket) set of everything submitted so a later boot can warm the
engine from observed traffic (``serve_cv --record-traffic`` /
``--warmup-from``).
"""

from __future__ import annotations

import asyncio
from typing import Optional, Sequence

from repro.serve.aio import AsyncEngineServer
from repro.serve.api import EngineServer
from repro.serve.engine import CVEngine
from repro.serve.workload import (
    DatasetHandle,
    TrafficLog,
    as_workload,
    run_workloads,
    stream_workload,
)

__all__ = ["Client"]

_TRANSPORTS = ("sync", "thread", "async")


class Client:
    """Unified front door: submit/stream/gather over a chosen transport.

    transport="sync"    ``submit`` returns the response, ``gather`` the
                        response list (whole batch coalesced through one
                        driver call), ``stream`` a plain generator.
    transport="thread"  ``submit`` returns a ``concurrent.futures.Future``
                        from a lazily-started
                        :class:`~repro.serve.api.EngineServer`; ``gather``
                        blocks for all results; ``stream`` runs on the
                        calling thread (the engine is thread-safe, so
                        chunks interleave with the worker's batches).
    transport="async"   use ``async with Client(...)``; ``submit`` /
                        ``gather`` are awaitables and ``stream`` an async
                        iterator over an
                        :class:`~repro.serve.aio.AsyncEngineServer`.
    """

    def __init__(
        self,
        engine: Optional[CVEngine] = None,
        transport: str = "sync",
        *,
        max_batch: int = 64,
        gather_window_ms: float = 2.0,
        stream_chunk: int = 64,
        record: Optional[TrafficLog] = None,
    ):
        if transport not in _TRANSPORTS:
            raise ValueError(f"transport must be one of {_TRANSPORTS}, got {transport!r}")
        self.engine = engine if engine is not None else CVEngine()
        self.transport = transport
        self.max_batch = max_batch
        self.gather_window_ms = gather_window_ms
        self.stream_chunk = stream_chunk
        self.record = record
        self._server = None  # EngineServer | AsyncEngineServer | None

    # -- dataset registry passthrough --------------------------------------

    def register(self, x, folds, lam: float, mode: str = "auto") -> DatasetHandle:
        """Register a dataset once; subsequent workloads carry the handle."""
        return self.engine.register(x, folds, lam, mode=mode)

    def datasets(self) -> tuple:
        return self.engine.datasets()

    def append(self, handle: DatasetHandle, x_new, folds_delta=None) -> DatasetHandle:
        """Append rows to a registered dataset; returns the version n+1
        handle (the old version stays servable until released)."""
        return self.engine.append(handle, x_new, folds_delta=folds_delta)

    def retire(self, handle: DatasetHandle, idx) -> DatasetHandle:
        """Retire rows of a registered dataset; returns the version n+1
        handle."""
        return self.engine.retire(handle, idx)

    def warmup(self, dataset, **kwargs) -> dict:
        return self.engine.warmup(dataset, **kwargs)

    # -- submission --------------------------------------------------------

    def _note(self, w, stream_chunk: Optional[int] = None) -> None:
        if self.record is not None:
            self.record.record(w, self.engine.config.buckets, stream_chunk=stream_chunk)

    def submit(self, workload):
        """One workload in; transport-appropriate handle out
        (response / Future / awaitable)."""
        w = as_workload(workload)
        self._note(w)
        if self.transport == "sync":
            (resp,) = run_workloads(self.engine, [w])
            return resp
        if self.transport == "thread":
            return self._thread_server().submit(w)
        return self._async_server().submit(w)

    def gather(self, workloads: Sequence, *, return_errors: bool = False):
        """Submit a batch; return (or await) the aligned response list.

        The sync transport coalesces the whole batch through one driver
        call (maximal micro-batching); thread/async submit individually so
        the batch interleaves with other clients' traffic.

        With ``return_errors=True`` a failing workload yields its
        exception object in the corresponding slot instead of aborting the
        batch: sibling workloads still get real responses. The default
        (``False``) keeps raise-on-first-error semantics.
        """
        conv: list = []
        for w in workloads:
            try:
                wl = as_workload(w)
                self._note(wl)
                conv.append(wl)
            except Exception as e:  # noqa: BLE001 - surfaced per entry
                if not return_errors:
                    raise
                conv.append(e)
        live = [(i, w) for i, w in enumerate(conv) if not isinstance(w, Exception)]
        results = list(conv)  # conversion errors stay in their slots

        if self.transport == "sync":
            out = run_workloads(self.engine, [w for _, w in live], return_errors=return_errors)
            for (i, _), r in zip(live, out):
                results[i] = r
            return results
        if self.transport == "thread":
            futures = [(i, self._thread_server().submit(w)) for i, w in live]
            for i, f in futures:
                if return_errors:
                    e = f.exception()
                    results[i] = e if e is not None else f.result()
                else:
                    results[i] = f.result()
            return results

        async def _gather():
            server = self._async_server()
            out = await asyncio.gather(
                *(server.submit(w) for _, w in live), return_exceptions=return_errors
            )
            for (i, _), r in zip(live, out):
                results[i] = r
            return results

        return _gather()

    def stream(self, workload):
        """Progress events for one workload: a generator (sync/thread
        transports) or an async iterator (async transport)."""
        w = as_workload(workload)
        self._note(w, stream_chunk=self.stream_chunk)
        if self.transport == "async":
            return self._async_server().stream(w)
        return stream_workload(self.engine, w, chunk=self.stream_chunk)

    # -- lifecycle ---------------------------------------------------------

    def _thread_server(self) -> EngineServer:
        if self._server is None:
            self._server = EngineServer(
                self.engine, max_batch=self.max_batch, max_wait_ms=self.gather_window_ms
            ).start()
        return self._server

    def _async_server(self) -> AsyncEngineServer:
        if self._server is None:
            raise RuntimeError(
                "async Client must be entered first: `async with Client(engine, "
                "transport='async') as client:`"
            )
        return self._server

    def close(self) -> None:
        if self.transport == "thread" and self._server is not None:
            self._server.stop()
            self._server = None

    def __enter__(self) -> "Client":
        if self.transport == "async":
            raise RuntimeError("async Client needs `async with`, not `with`")
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    async def __aenter__(self) -> "Client":
        if self.transport != "async":
            raise RuntimeError(f"`async with` needs transport='async', not {self.transport!r}")
        self._server = await AsyncEngineServer(
            self.engine,
            max_batch=self.max_batch,
            gather_window_ms=self.gather_window_ms,
            stream_chunk=self.stream_chunk,
        ).start()
        return self

    async def __aexit__(self, *exc) -> None:
        if self._server is not None:
            await self._server.stop()
            self._server = None

    # -- observability -----------------------------------------------------

    @property
    def server(self):
        """The backing server (None for the sync transport)."""
        return self._server

    def stats(self) -> dict:
        return self.engine.stats()

    def metrics(self):
        """The engine's :class:`~repro.serve.obs.MetricsRegistry` (live view)."""
        return self.engine.metrics

    def trace_summary(self) -> dict:
        """Per-stage {count, p50_s, p95_s} over the tracer's ring buffer."""
        return self.engine.tracer.summary()
