"""repro.serve.http — the HTTP/SSE edge over the Workload API.

PR 4 made :class:`~repro.serve.workload.Workload` wire-ready —
``to_dict``/``from_dict`` round-trip the versioned schema as plain JSON —
and this module is the wire. :class:`HTTPEdge` is an asyncio HTTP server
(stdlib ``asyncio`` streams; no new hard dependencies) mounted directly
on an :class:`~repro.serve.aio.AsyncEngineServer`, so HTTP traffic rides
the same gather-window micro-batching, plan cache, and shape-bucketed
jitted evals as in-process clients — the wire-conformance suite
(``tests/test_http.py``) pins HTTP results *bit-identical* to the
in-process :class:`~repro.serve.client.Client` for every workload kind
and every registered estimator, with zero extra compiles once warm.

Routes (all payloads are JSON):

  ``POST /v1/workloads``         one workload object or ``{"workloads":
                                 [...]}``; each entry is served through
                                 the async gather window and answered
                                 with a **result-or-error** — one bad
                                 workload never aborts its siblings.
  ``POST /v1/workloads/stream``  one workload; the response is a
                                 Server-Sent-Events stream with one
                                 event per
                                 :class:`~repro.serve.workload.ProgressEvent`
                                 — the *same* chunks, in the same order,
                                 as :func:`~repro.serve.workload
                                 .stream_workload` (prefix-stable null
                                 chunks, identical draws to the
                                 monolithic path).
  ``POST /v1/datasets``          register a feature matrix + folds + λ
                                 into the engine's dataset registry;
                                 returns a
                                 :class:`~repro.serve.workload.DatasetHandle`
                                 token so subsequent requests carry
                                 handles, not arrays.
  ``POST /v1/datasets/{fp}/append``  advance a registered dataset (append
                                 rows, retire rows, or both — the
                                 sliding window); ``{fp}`` is the
                                 handle's fingerprint prefix, the body
                                 carries the full handle plus ``x`` /
                                 ``drop_idx``; returns the version n+1
                                 handle.
  ``GET /v1/datasets``           the registry introspection view
                                 (including ``version``/``n_appended``
                                 per dataset).
  ``GET /v1/stats``              engine stats + async-server + edge
                                 counters.
  ``GET /v1/metrics``            Prometheus text exposition (format
                                 0.0.4) of the engine's metrics
                                 registry — counters, gauges, and
                                 per-stage latency histograms.
  ``GET /v1/trace``              last-``n`` finished request span trees
                                 (``?n=`` query, default 32) plus the
                                 per-stage p50/p95 summary; JSON.
  ``GET /healthz``               liveness.

Errors are structured JSON — ``{"error": {"type", "status", "message"}}``
— carrying the Workload validation message verbatim; malformed JSON,
unknown schema versions, unknown/evicted handles, and oversized bodies
are all rejected before any engine work, so ``stats()`` and
``compile_count()`` stay untouched.

:class:`HTTPClient` mirrors the in-process ``Client`` surface
(``register`` / ``submit`` / ``gather`` / ``stream`` / ``datasets`` /
``stats``) over stdlib ``http.client``, so examples and benchmarks swap
transports by construction. :class:`EdgeThread` runs an edge on a daemon
thread with its own event loop — the in-process harness used by the
conformance tests, the ``http_quickstart`` example, and ``bench_http``.

Deployment entry point: ``python -m repro.launch.serve_cv --http PORT``
(composes with ``--warmup/--pin/--record-traffic``).
"""

from __future__ import annotations

import asyncio
import contextlib
import dataclasses
import http.client
import json
import threading
import time
import urllib.parse
from typing import Iterator, Optional

import numpy as np

# reprolint: monotonic-time
# (Edge decode/encode stage stamps — wall clocks would jump under NTP.)

from repro.core import tuning
from repro.serve.aio import AsyncEngineServer
from repro.serve.engine import CVEngine
from repro.serve.trace import attach_trace
from repro.serve.workload import (
    CVResponse,
    DatasetHandle,
    DatasetSpec,
    GridResponse,
    PermutationResponse,
    ProgressEvent,
    RSAResponse,
    TuneResponse,
    UpdateResponse,
    Workload,
    _decode_array,
    _decode_dataset,
    _encode_array,
    _encode_dataset,
    as_workload,
)

__all__ = [
    "HTTPEdge",
    "HTTPClient",
    "EdgeThread",
    "WireError",
    "response_to_dict",
    "response_from_dict",
    "event_to_dict",
    "event_from_dict",
    "assert_responses_equal",
]

DEFAULT_MAX_BODY_BYTES = 64 << 20

_REASONS = {
    200: "OK",
    400: "Bad Request",
    404: "Not Found",
    405: "Method Not Allowed",
    411: "Length Required",
    413: "Payload Too Large",
    500: "Internal Server Error",
}


# ---------------------------------------------------------------------------
# Wire codecs: responses and progress events <-> JSON-ready dicts
# ---------------------------------------------------------------------------


def response_to_dict(resp) -> dict:
    """JSON-ready form of any workload response (exact array round-trip).

    Arrays ride the same ``{"__array__": ..., "dtype": ...}`` encoding as
    :meth:`Workload.to_dict`; float64 values survive JSON bit-exactly
    (Python's float repr is shortest-round-trip), which is what the
    wire-conformance suite's bit-identical assertions rest on.
    """
    if isinstance(resp, CVResponse):
        d = {
            "type": "cv",
            "task": resp.task,
            "values": _encode_array(resp.values),
            "y_te": _encode_array(resp.y_te),
            "score": _encode_array(resp.score),
            "plan_key": list(resp.plan_key),
        }
    elif isinstance(resp, PermutationResponse):
        d = {
            "type": "permutation",
            "observed": _encode_array(resp.observed),
            "null": _encode_array(resp.null),
            "p": _encode_array(resp.p),
            "plan_key": list(resp.plan_key),
        }
    elif isinstance(resp, RSAResponse):
        d = {
            "type": "rsa",
            "rdm": _encode_array(resp.rdm),
            "pair_values": _encode_array(resp.pair_values),
            "model_scores": _encode_array(resp.model_scores),
            "null": _encode_array(resp.null),
            "p": _encode_array(resp.p),
            "plan_key": list(resp.plan_key),
        }
    elif isinstance(resp, TuneResponse):
        r = resp.result
        d = {
            "type": "tune",
            "best_lambda": _encode_array(r.best_lambda),
            "best_score": _encode_array(r.best_score),
            "lambdas": _encode_array(r.lambdas),
            "scores": _encode_array(r.scores),
        }
    elif isinstance(resp, GridResponse):
        d = {"type": "grid", "accuracies": _encode_array(resp.accuracies)}
    elif isinstance(resp, UpdateResponse):
        d = {
            "type": "update",
            "handle": resp.handle.to_dict(),
            "version": int(resp.version),
            "appended": int(resp.appended),
            "dropped": int(resp.dropped),
            "rank": int(resp.rank),
            "plan_key": list(resp.plan_key),
        }
    else:
        raise TypeError(f"cannot encode response of type {type(resp).__name__}")
    # Optional, tracing-only: absent when tracing is off, so the wire
    # payload is byte-identical to the pre-observability schema (and the
    # conformance fields never include it).
    if getattr(resp, "timings", None) is not None:
        d["timings"] = resp.timings
    return d


def response_from_dict(d: dict):
    """Invert :func:`response_to_dict` back into the response dataclass."""
    t = d.get("type")
    if t == "cv":
        resp = CVResponse(
            d["task"],
            _decode_array(d["values"]),
            _decode_array(d["y_te"]),
            _decode_array(d["score"]),
            tuple(d["plan_key"]),
        )
    elif t == "permutation":
        resp = PermutationResponse(
            _decode_array(d["observed"]),
            _decode_array(d["null"]),
            _decode_array(d["p"]),
            tuple(d["plan_key"]),
        )
    elif t == "rsa":
        resp = RSAResponse(
            _decode_array(d["rdm"]),
            _decode_array(d["pair_values"]),
            _decode_array(d["model_scores"]),
            _decode_array(d["null"]),
            _decode_array(d["p"]),
            tuple(d["plan_key"]),
        )
    elif t == "tune":
        resp = TuneResponse(
            tuning.RidgeTuneResult(
                _decode_array(d["best_lambda"]),
                _decode_array(d["best_score"]),
                _decode_array(d["lambdas"]),
                _decode_array(d["scores"]),
            )
        )
    elif t == "grid":
        resp = GridResponse(_decode_array(d["accuracies"]))
    elif t == "update":
        resp = UpdateResponse(
            DatasetHandle.from_dict(d["handle"]),
            int(d["version"]),
            int(d["appended"]),
            int(d["dropped"]),
            int(d["rank"]),
            tuple(d["plan_key"]),
        )
    else:
        raise ValueError(f"unknown response type {t!r}")
    if "timings" in d:
        resp.timings = dict(d["timings"])
    return resp


def event_to_dict(ev: ProgressEvent) -> dict:
    """JSON-ready form of one streamed :class:`ProgressEvent`."""
    if ev.kind == "plan":
        payload = {"plan_key": list(ev.payload)}
    elif ev.kind == "done":
        payload = response_to_dict(ev.payload)
    elif ev.kind == "update":
        payload = dict(ev.payload)  # per-increment metrics delta: plain JSON
    else:
        payload = _encode_array(ev.payload)
    return {"kind": ev.kind, "done": ev.done, "total": ev.total, "payload": payload}


def event_from_dict(d: dict) -> ProgressEvent:
    kind = d["kind"]
    payload = d["payload"]
    if kind == "plan":
        payload = tuple(payload["plan_key"])
    elif kind == "done":
        payload = response_from_dict(payload)
    elif kind == "update":
        payload = dict(payload)
    else:
        payload = _decode_array(payload)
    return ProgressEvent(kind, int(d["done"]), int(d["total"]), payload)


_CONFORMANCE_FIELDS = (
    "values",
    "y_te",
    "score",
    "observed",
    "null",
    "p",
    "rdm",
    "pair_values",
    "model_scores",
    "accuracies",
)


def assert_responses_equal(got, want, label: str = "") -> None:
    """Assert two workload responses are bit-identical, field by field.

    The single equality contract both conformance harnesses check —
    tests/test_http.py in-process and benchmarks/http_smoke.py against a
    live server — so a new response field cannot silently drop out of
    wire-conformance coverage in one of them.
    """
    prefix = f"{label}." if label else ""
    assert type(got) is type(want), f"{label}: {type(got).__name__} != {type(want).__name__}"
    for field in _CONFORMANCE_FIELDS:
        a, b = getattr(got, field, None), getattr(want, field, None)
        assert (a is None) == (b is None), f"{prefix}{field} presence"
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=f"{prefix}{field}")
    if hasattr(want, "result"):
        for field in ("best_lambda", "best_score", "lambdas", "scores"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got.result, field)),
                np.asarray(getattr(want.result, field)),
                err_msg=f"{prefix}result.{field}",
            )


# ---------------------------------------------------------------------------
# Structured errors
# ---------------------------------------------------------------------------


class WireError(RuntimeError):
    """A structured error answered by the HTTP edge.

    Carries the HTTP ``status``, the edge's error ``etype`` tag
    (``bad_json`` / ``validation`` / ``unknown_dataset`` / ``oversized`` /
    ``not_found`` / ``internal``), and the server-side message — for
    validation failures, the eager :class:`Workload` validation message
    verbatim.
    """

    def __init__(self, status: int, etype: str, message: str):
        super().__init__(message)
        self.status = int(status)
        self.etype = etype

    def __repr__(self) -> str:
        return f"WireError(status={self.status}, etype={self.etype!r}, message={str(self)!r})"


class _NotFound(Exception):
    pass


def _exc_message(e: BaseException) -> str:
    if isinstance(e, KeyError) and e.args:
        return str(e.args[0])
    return str(e) or type(e).__name__


def _classify(e: BaseException, phase: str = "decode") -> tuple:
    """(status, type) for an exception, by failure phase.

    ``phase="decode"`` covers everything before engine work — request
    parsing, JSON decoding, eager Workload validation — where a
    ValueError genuinely means the *client* sent something malformed.
    ``phase="serve"`` covers engine execution: inputs already passed the
    eager validators, so apart from unknown/evicted dataset handles a
    failure there is a server fault and reports as 500, not 400 — a
    client retrying a "validation" error that is really an engine bug
    could never succeed.
    """
    if isinstance(e, _NotFound):
        return 404, "not_found"
    if isinstance(e, KeyError) and "not registered" in _exc_message(e):
        return 404, "unknown_dataset"
    if phase == "decode":
        if isinstance(e, (json.JSONDecodeError, UnicodeDecodeError)):
            return 400, "bad_json"
        if isinstance(e, (KeyError, ValueError, TypeError)):
            return 400, "validation"
    return 500, "internal"


def _error_entry(e: BaseException, phase: str = "decode") -> dict:
    status, etype = _classify(e, phase)
    return {"ok": False, "error": {"type": etype, "status": status, "message": _exc_message(e)}}


def _error_body(etype: str, status: int, message: str) -> dict:
    return {"error": {"type": etype, "status": status, "message": message}}


# ---------------------------------------------------------------------------
# The edge
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Request:
    method: str
    path: str
    headers: dict
    body: bytes
    oversized: int = 0
    chunked: bool = False


def _write_chunk(writer, data: bytes) -> None:
    writer.write(f"{len(data):X}\r\n".encode("latin-1") + data + b"\r\n")


def _sse_event_bytes(ev: ProgressEvent) -> bytes:
    data = json.dumps(event_to_dict(ev))
    return f"event: {ev.kind}\ndata: {data}\n\n".encode("utf-8")


class HTTPEdge:
    """asyncio HTTP/SSE server over an :class:`AsyncEngineServer`.

    One edge owns one engine and one async server: HTTP submissions land
    in the same gather window as in-process async clients, so wire
    traffic coalesces onto shared plans and shared padded evals. The
    edge performs *no* computation of its own — JSON decoding yields the
    exact :class:`Workload` the in-process path would construct, which
    is what makes the wire bit-conformant.

    ``record`` (a :class:`~repro.serve.workload.TrafficLog`) notes every
    wire workload's (task, bucket) coordinates, so ``serve_cv
    --record-traffic`` / ``--warmup-from`` compose with the HTTP edge.
    """

    def __init__(
        self,
        engine: Optional[CVEngine] = None,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        max_batch: int = 64,
        gather_window_ms: float = 2.0,
        stream_chunk: int = 64,
        max_body_bytes: int = DEFAULT_MAX_BODY_BYTES,
        record=None,
    ):
        self.engine = engine if engine is not None else CVEngine()
        self.host = host
        self.port = port
        self.max_body_bytes = max_body_bytes
        self.record = record
        self.server = AsyncEngineServer(
            self.engine,
            max_batch=max_batch,
            gather_window_ms=gather_window_ms,
            stream_chunk=stream_chunk,
        )
        self._http: Optional[asyncio.AbstractServer] = None
        self._conn_tasks: set = set()
        self.http_requests = 0
        self.http_streams = 0
        self.http_errors = 0

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> "HTTPEdge":
        if self._http is not None:
            raise RuntimeError("edge already started")
        await self.server.start()
        try:
            self._http = await asyncio.start_server(self._handle, self.host, self.port)
        except BaseException:
            # e.g. EADDRINUSE: don't leak the engine worker/executor thread
            await self.server.stop()
            raise
        self.port = self._http.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._http is not None:
            self._http.close()
            await self._http.wait_closed()
            self._http = None
        # Idle keep-alive connections park in readline() forever; cancel
        # them so shutdown never strands a handler task.
        for task in list(self._conn_tasks):
            task.cancel()
        if self._conn_tasks:
            await asyncio.gather(*self._conn_tasks, return_exceptions=True)
        await self.server.stop()

    async def serve_forever(self) -> None:
        await self._http.serve_forever()

    async def __aenter__(self) -> "HTTPEdge":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def _note(self, w: Workload, stream_chunk: Optional[int] = None) -> None:
        if self.record is not None:
            self.record.record(w, self.engine.config.buckets, stream_chunk=stream_chunk)

    def _offload(self, fn, *args):
        """Run work on the engine's executor thread.

        Two invariants ride on this: engine state is only ever touched
        from one thread (registration inserts vs. stats/datasets reads),
        and the event loop never blocks on multi-MB JSON codecs or
        ``jnp.asarray`` device puts — so concurrent SSE streams and
        health checks stay live while a big request is (de)serialised.
        """
        return self.server._run(fn, *args)

    # -- connection handling ----------------------------------------------

    async def _handle(self, reader, writer) -> None:
        task = asyncio.current_task()
        self._conn_tasks.add(task)
        try:
            while True:
                try:
                    req = await self._read_request(reader, writer)
                except (asyncio.IncompleteReadError, ValueError):
                    break  # torn request / over-long header line: drop quietly
                if req is None:
                    break
                keep = await self._dispatch(req, writer)
                await writer.drain()
                if not keep:
                    break
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            self._conn_tasks.discard(task)
            with contextlib.suppress(Exception):
                writer.close()
                await writer.wait_closed()

    async def _read_request(self, reader, writer) -> Optional[_Request]:
        line = await reader.readline()
        if not line:
            return None  # clean EOF between keep-alive requests
        parts = line.decode("latin-1").split()
        if len(parts) != 3:
            raise ValueError("malformed request line")
        method, path = parts[0].upper(), parts[1]
        headers: dict = {}
        while True:
            hline = await reader.readline()
            if hline in (b"\r\n", b"\n", b""):
                break
            name, _, value = hline.decode("latin-1").partition(":")
            headers[name.strip().lower()] = value.strip()
        if "chunked" in headers.get("transfer-encoding", "").lower():
            # No chunked request bodies: without a length the body would
            # desync the keep-alive parser. Flagged so dispatch answers a
            # structured 411 instead of misreading frames as requests.
            return _Request(method, path, headers, b"", chunked=True)
        length = int(headers.get("content-length") or 0)
        if length > self.max_body_bytes:
            return _Request(method, path, headers, b"", oversized=length)
        if length and "100-continue" in headers.get("expect", "").lower():
            # curl sends Expect for >1KB bodies and stalls ~1s waiting for
            # this interim response before transmitting the body
            writer.write(b"HTTP/1.1 100 Continue\r\n\r\n")
            await writer.drain()
        body = await reader.readexactly(length) if length > 0 else b""
        return _Request(method, path, headers, body)

    async def _dispatch(self, req: _Request, writer) -> bool:
        self.http_requests += 1
        path = req.path.split("?", 1)[0]
        if req.chunked:
            self.http_errors += 1
            self._respond(
                writer,
                411,
                _error_body(
                    "length_required",
                    411,
                    "chunked request bodies are not supported; send Content-Length",
                ),
                keep_alive=False,
            )
            return False
        if req.oversized:
            # The body was never read, so the connection cannot be reused —
            # and, by construction, the engine was never touched.
            self.http_errors += 1
            self._respond(
                writer,
                413,
                _error_body(
                    "oversized",
                    413,
                    f"request body of {req.oversized} bytes exceeds the "
                    f"{self.max_body_bytes}-byte limit",
                ),
                keep_alive=False,
            )
            return False
        try:
            if req.method == "GET":
                if path == "/healthz":
                    self._respond(writer, 200, {"status": "ok"})
                elif path == "/v1/stats":
                    # engine reads run on the engine thread, like every
                    # other engine touch (registration mutates dicts there)
                    self._respond(writer, 200, await self._offload(self._stats))
                elif path == "/v1/datasets":
                    self._respond(writer, 200, await self._offload(self._datasets_payload))
                elif path == "/v1/metrics":
                    # Prometheus text exposition; rendering walks every
                    # series under the registry lock, so it runs on the
                    # engine thread like any other engine-state read.
                    text = await self._offload(self.engine.metrics.render_prometheus)
                    self._respond(
                        writer,
                        200,
                        text.encode("utf-8"),
                        content_type="text/plain; version=0.0.4; charset=utf-8",
                    )
                elif path == "/v1/trace":
                    query = urllib.parse.parse_qs(req.path.partition("?")[2])
                    n = int(query.get("n", ["32"])[0])
                    self._respond(writer, 200, await self._offload(self._trace_payload, n))
                else:
                    raise _NotFound(f"no route for GET {path}")
                return True
            if req.method == "POST":
                if path == "/v1/workloads":
                    self._respond(writer, 200, await self._serve_batch(req.body))
                    return True
                if path == "/v1/datasets":
                    self._respond(writer, 200, await self._register(req.body))
                    return True
                if path.startswith("/v1/datasets/") and path.endswith("/append"):
                    fp = path[len("/v1/datasets/"):-len("/append")]
                    self._respond(writer, 200, await self._append(fp, req.body))
                    return True
                if path == "/v1/workloads/stream":
                    return await self._serve_stream(req.body, writer)
                raise _NotFound(f"no route for POST {path}")
            self.http_errors += 1
            self._respond(
                writer,
                405,
                _error_body("method_not_allowed", 405, f"{req.method} is not supported"),
            )
            return True
        except Exception as e:  # noqa: BLE001 - mapped to a structured error
            self.http_errors += 1
            status, etype = _classify(e)
            self._respond(writer, status, _error_body(etype, status, _exc_message(e)))
            return True

    def _respond(
        self,
        writer,
        status: int,
        payload,
        keep_alive: bool = True,
        content_type: str = "application/json",
    ) -> None:
        """Write one response; ``payload`` is a dict or pre-encoded bytes."""
        body = payload if isinstance(payload, bytes) else json.dumps(payload).encode("utf-8")
        head = (
            f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}\r\n"
            f"Content-Type: {content_type}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: {'keep-alive' if keep_alive else 'close'}\r\n"
            "\r\n"
        )
        writer.write(head.encode("latin-1") + body)

    # -- routes ------------------------------------------------------------

    @staticmethod
    def _decode_batch(body: bytes):
        """(results, live): error entries slotted, valid Workloads decoded."""
        payload = json.loads(body.decode("utf-8"))
        if isinstance(payload, dict) and "workloads" in payload:
            items = payload["workloads"]
            if not isinstance(items, list):
                raise ValueError("'workloads' must be a list of workload objects")
        elif isinstance(payload, dict):
            items = [payload]
        else:
            raise ValueError("body must be a workload object or {'workloads': [...]}")
        results: list = [None] * len(items)
        live = []
        for i, d in enumerate(items):
            try:
                if not isinstance(d, dict):
                    raise ValueError(f"workload entry {i} is not an object")
                live.append((i, Workload.from_dict(d)))
            except Exception as e:  # noqa: BLE001 - result-or-error per entry
                results[i] = _error_entry(e)
        return results, live

    async def _serve_batch(self, body: bytes) -> bytes:
        tracer = self.engine.tracer
        t0 = time.perf_counter() if tracer.enabled else 0.0
        results, live = await self._offload(self._decode_batch, body)
        if tracer.enabled:
            # Wire decode is shared by the whole batch; attribute the full
            # duration to each member (exact for the single-workload case,
            # which is how latency budgets are measured).
            dt_decode = time.perf_counter() - t0
            for _i, w in live:
                tr = tracer.trace()
                tr.add("decode", dt_decode)
                attach_trace(w, tr)
        self.http_errors += sum(r is not None for r in results)
        for _i, w in live:
            self._note(w)
        outs = await asyncio.gather(
            *(self.server.submit(w) for _, w in live), return_exceptions=True
        )
        self.http_errors += sum(isinstance(o, BaseException) for o in outs)

        def encode() -> bytes:
            # Traces are already finished by run_workloads, so the wire
            # encode goes straight into the stage histogram rather than a
            # span (the "encode" span inside the trace covers response
            # construction; this covers JSON serialisation).
            t_enc = time.perf_counter() if tracer.enabled else 0.0
            for (i, _), out in zip(live, outs):
                if isinstance(out, BaseException):
                    results[i] = _error_entry(out, phase="serve")
                else:
                    results[i] = {"ok": True, "response": response_to_dict(out)}
            encoded = json.dumps({"results": results}).encode("utf-8")
            if tracer.enabled:
                self.engine.metrics.observe(
                    "stage_latency_seconds", time.perf_counter() - t_enc, stage="encode"
                )
            return encoded

        return await self._offload(encode)

    @staticmethod
    def _decode_register(body: bytes) -> DatasetSpec:
        payload = json.loads(body.decode("utf-8"))
        if not isinstance(payload, dict) or "__dataset__" not in payload:
            raise ValueError(
                "register body must be an encoded dataset: "
                '{"__dataset__": {"x": {"__array__": ..., "dtype": ...}, '
                '"folds": {"te_idx": ..., "tr_idx": ...}, "lam": ..., "mode": ...}}'
            )
        ds = _decode_dataset(payload)
        if ds.x is None or ds.folds is None:
            raise ValueError("dataset registration needs both x and folds")
        return ds

    async def _register(self, body: bytes) -> dict:
        ds = await self._offload(self._decode_register, body)
        handle = await self.server.register(ds.x, ds.folds, ds.lam, mode=ds.mode)
        return {"handle": handle.to_dict()}

    @staticmethod
    def _decode_append(fp: str, body: bytes):
        payload = json.loads(body.decode("utf-8"))
        if not isinstance(payload, dict) or "handle" not in payload:
            raise ValueError(
                'append body must carry the full handle: {"handle": {...}, '
                '"x": <array|null>, "drop_idx": <array|null>}'
            )
        handle = DatasetHandle.from_dict(payload["handle"])
        if fp and not str(handle.key[0]).startswith(fp):
            raise ValueError(
                f"path fingerprint {fp!r} does not match the handle in the body "
                f"({str(handle.key[0])[:12]})"
            )
        x_new = payload.get("x")
        x_new = None if x_new is None else _decode_array(x_new)
        drop_idx = payload.get("drop_idx")
        drop_idx = None if drop_idx is None else _decode_array(drop_idx)
        folds_delta = payload.get("folds_delta")
        folds_delta = None if folds_delta is None else _decode_array(folds_delta)
        if x_new is None and drop_idx is None:
            raise ValueError("append body needs x (append), drop_idx (retire), or both")
        return handle, x_new, drop_idx, folds_delta

    async def _append(self, fp: str, body: bytes) -> dict:
        handle, x_new, drop_idx, folds_delta = await self._offload(self._decode_append, fp, body)
        new_handle = await self.server.append(
            handle, x_new, drop_idx=drop_idx, folds_delta=folds_delta
        )
        return {"handle": new_handle.to_dict()}

    @staticmethod
    def _decode_workload(body: bytes) -> Workload:
        return Workload.from_dict(json.loads(body.decode("utf-8")))

    async def _serve_stream(self, body: bytes, writer) -> bool:
        # Decode + validate *before* committing to SSE, so malformed input
        # gets a structured JSON error via the generic handler.
        tracer = self.engine.tracer
        t0 = time.perf_counter() if tracer.enabled else 0.0
        w = await self._offload(self._decode_workload, body)
        if tracer.enabled:
            tr = tracer.trace()
            tr.add("decode", time.perf_counter() - t0)
            attach_trace(w, tr)
        self._note(w, stream_chunk=self.server.stream_chunk)
        self.http_streams += 1
        head = (
            "HTTP/1.1 200 OK\r\n"
            "Content-Type: text/event-stream\r\n"
            "Cache-Control: no-cache\r\n"
            "Transfer-Encoding: chunked\r\n"
            "Connection: keep-alive\r\n"
            "\r\n"
        )
        writer.write(head.encode("latin-1"))
        gen = self.server.stream(w)
        try:
            async for ev in gen:
                # event encoding includes the full response on "done" —
                # potentially large, so it serialises off the loop too
                _write_chunk(writer, await self._offload(_sse_event_bytes, ev))
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            return False  # client went away mid-stream; stop computing chunks
        except Exception as e:  # noqa: BLE001 - headers are sent: error as SSE
            self.http_errors += 1
            status, etype = _classify(e, phase="serve")
            err = json.dumps(
                {
                    "kind": "error",
                    "error": {"type": etype, "status": status, "message": _exc_message(e)},
                }
            )
            _write_chunk(writer, f"event: error\ndata: {err}\n\n".encode("utf-8"))
        finally:
            await gen.aclose()
        _write_chunk(writer, b"")  # terminal chunk: the stream is complete
        await writer.drain()
        return True

    # -- introspection -----------------------------------------------------

    def _trace_payload(self, n: int) -> dict:
        tracer = self.engine.tracer
        return {
            "enabled": tracer.enabled,
            "ring": tracer.ring_size,
            "traces": tracer.last(n),
            "summary": tracer.summary(),
        }

    def _stats(self) -> dict:
        return {
            "engine": dict(self.engine.stats()),
            "server": {
                "batches_served": self.server.batches_served,
                "requests_served": self.server.requests_served,
                "streams_served": self.server.streams_served,
            },
            "edge": {
                "http_requests": self.http_requests,
                "http_streams": self.http_streams,
                "http_errors": self.http_errors,
            },
        }

    def _datasets_payload(self) -> dict:
        out = []
        for info in self.engine.datasets():
            d = dict(info)
            d["handle"] = info["handle"].to_dict()
            out.append(d)
        return {"datasets": out}


# ---------------------------------------------------------------------------
# In-process harness: an edge on a daemon thread with its own loop
# ---------------------------------------------------------------------------


class EdgeThread:
    """Run an :class:`HTTPEdge` on a daemon thread with its own event loop.

    The harness the wire-conformance tests, the ``http_quickstart``
    example, and ``bench_http`` use to get a live TCP edge while the test
    body stays synchronous (and keeps direct access to the underlying
    engine for compile-count / stats assertions).
    """

    def __init__(self, engine: Optional[CVEngine] = None, **kwargs):
        self.edge = HTTPEdge(engine, **kwargs)
        self._started = threading.Event()
        self._stop: Optional[asyncio.Event] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._main, daemon=True, name="cv-http-edge")
        self._thread.start()
        started = self._started.wait(timeout=120)
        if self._error is not None:
            raise self._error
        if not started:
            raise RuntimeError("HTTP edge failed to start within 120s")

    def _main(self) -> None:
        self._loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self._loop)
        try:
            self._loop.run_until_complete(self._serve())
        finally:
            self._loop.close()

    async def _serve(self) -> None:
        self._stop = asyncio.Event()
        try:
            await self.edge.start()
        except Exception as e:  # noqa: BLE001 - surfaced to the constructor
            self._error = e
            self._started.set()
            return
        self._started.set()
        await self._stop.wait()
        await self.edge.stop()

    def stop(self) -> None:
        if self._thread.is_alive() and self._loop is not None:
            with contextlib.suppress(RuntimeError):  # loop already closed
                self._loop.call_soon_threadsafe(self._stop.set)
            self._thread.join(timeout=120)

    def __enter__(self) -> "EdgeThread":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def engine(self) -> CVEngine:
        return self.edge.engine

    @property
    def url(self) -> str:
        return self.edge.url

    @property
    def port(self) -> int:
        return self.edge.port


# ---------------------------------------------------------------------------
# The wire client: the Client surface over http.client
# ---------------------------------------------------------------------------


class HTTPClient:
    """Wire mirror of :class:`repro.serve.client.Client`.

    ``register`` / ``append`` / ``retire`` / ``submit`` / ``gather`` /
    ``stream`` / ``datasets`` / ``stats`` have the same shapes as the
    in-process client — responses
    decode back into the same dataclasses, ``stream`` yields
    :class:`ProgressEvent`\\ s — so swapping an example or benchmark onto
    the wire is a constructor change. Batch submissions mirror
    ``Client.gather(..., return_errors=True)``: the edge answers
    result-or-error per entry, surfaced here as :class:`WireError`
    objects (or raised, for ``submit`` and plain ``gather``).

    Not mirrored: ``warmup`` (an operator-side engine API — warm over
    ``serve_cv --warmup``/``--warmup-from`` at boot instead).
    """

    def __init__(self, base_url: str, timeout: float = 300.0):
        u = urllib.parse.urlsplit(base_url if "//" in base_url else f"//{base_url}")
        if u.scheme not in ("", "http"):
            raise ValueError(f"only http:// is supported, got {u.scheme!r}")
        self.host = u.hostname or "127.0.0.1"
        self.port = u.port or 80
        self.timeout = timeout
        self._conn: Optional[http.client.HTTPConnection] = None

    # -- plumbing ----------------------------------------------------------

    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(self.host, self.port, timeout=self.timeout)
        return self._conn

    def close(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self) -> "HTTPClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _request(self, method: str, path: str, payload=None, *, decode: bool = True):
        body = None if payload is None else json.dumps(payload).encode("utf-8")
        headers = {"Content-Type": "application/json"} if body is not None else {}
        resp = raw = None
        reused = self._conn is not None
        for attempt in (0, 1):
            conn = self._connection()
            try:
                conn.request(method, path, body=body, headers=headers)
                resp = conn.getresponse()
                raw = resp.read()
                break
            except TimeoutError:
                # The request may still be executing server-side: re-sending
                # a non-idempotent POST would double the engine work.
                self.close()
                raise
            except (http.client.HTTPException, ConnectionError, OSError):
                self.close()
                # Retry exactly once, and only when the failure is plausibly
                # a stale keep-alive connection (the server closed an idle
                # conn between our requests) — never on a fresh connection.
                if attempt or not reused:
                    raise
        try:
            data = json.loads(raw.decode("utf-8")) if raw else {}
        except ValueError:
            data = {}
        if resp.status >= 400:
            err = data.get("error", {}) if isinstance(data, dict) else {}
            raise WireError(
                resp.status, err.get("type", "http"), err.get("message", f"HTTP {resp.status}")
            )
        if not decode:  # non-JSON routes (e.g. Prometheus text)
            return raw.decode("utf-8")
        return data

    @staticmethod
    def _entry(entry: dict, raise_errors: bool):
        if entry.get("ok"):
            return response_from_dict(entry["response"])
        err = entry.get("error", {})
        exc = WireError(
            err.get("status", 500),
            err.get("type", "internal"),
            err.get("message", ""),
        )
        if raise_errors:
            raise exc
        return exc

    # -- the Client surface ------------------------------------------------

    def healthz(self) -> dict:
        return self._request("GET", "/healthz")

    def register(self, x, folds, lam: float, mode: str = "auto") -> DatasetHandle:
        """Register a dataset on the remote engine; returns its handle."""
        spec = DatasetSpec(x, folds, float(lam), mode)
        out = self._request("POST", "/v1/datasets", _encode_dataset(spec))
        return DatasetHandle.from_dict(out["handle"])

    def append(
        self, handle: DatasetHandle, x_new=None, *, drop_idx=None, folds_delta=None
    ) -> DatasetHandle:
        """Advance a registered dataset on the remote engine; returns the
        version n+1 handle. ``x_new`` alone appends, ``drop_idx`` alone
        retires, both slide the window (mirrors
        :meth:`CVEngine.update_dataset`)."""
        fp = str(handle.key[0])[:12]
        payload = {
            "handle": handle.to_dict(),
            "x": None if x_new is None else _encode_array(np.asarray(x_new)),
            "drop_idx": None if drop_idx is None else _encode_array(np.asarray(drop_idx)),
            "folds_delta": (
                None if folds_delta is None else _encode_array(np.asarray(folds_delta))
            ),
        }
        out = self._request("POST", f"/v1/datasets/{fp}/append", payload)
        return DatasetHandle.from_dict(out["handle"])

    def retire(self, handle: DatasetHandle, idx) -> DatasetHandle:
        """Retire rows of a registered dataset on the remote engine."""
        return self.append(handle, None, drop_idx=idx)

    def datasets(self) -> tuple:
        out = self._request("GET", "/v1/datasets")["datasets"]
        return tuple({**d, "handle": DatasetHandle.from_dict(d["handle"])} for d in out)

    def stats(self) -> dict:
        """Remote stats: {"engine": ..., "server": ..., "edge": ...}."""
        return self._request("GET", "/v1/stats")

    def metrics_text(self) -> str:
        """Raw Prometheus text from ``GET /v1/metrics`` (format 0.0.4)."""
        return self._request("GET", "/v1/metrics", decode=False)

    def trace(self, n: int = 32) -> dict:
        """Last-``n`` span trees + per-stage summary from ``GET /v1/trace``."""
        return self._request("GET", f"/v1/trace?n={int(n)}")

    def submit(self, workload):
        """One workload in; its decoded response out (raises WireError)."""
        w = as_workload(workload)
        out = self._request("POST", "/v1/workloads", {"workloads": [w.to_dict()]})
        (entry,) = out["results"]
        return self._entry(entry, raise_errors=True)

    def gather(self, workloads, *, return_errors: bool = False) -> list:
        """Submit a batch; aligned responses (or WireError objects) out."""
        ws = [as_workload(w) for w in workloads]
        out = self._request("POST", "/v1/workloads", {"workloads": [w.to_dict() for w in ws]})
        return [self._entry(e, raise_errors=not return_errors) for e in out["results"]]

    def stream(self, workload) -> Iterator[ProgressEvent]:
        """SSE stream of one workload as decoded :class:`ProgressEvent`\\ s.

        Uses a dedicated connection so long streams don't block the
        client's keep-alive request connection.
        """
        w = as_workload(workload)
        return self._stream(w)

    def _stream(self, w: Workload) -> Iterator[ProgressEvent]:
        conn = http.client.HTTPConnection(self.host, self.port, timeout=self.timeout)
        try:
            conn.request(
                "POST",
                "/v1/workloads/stream",
                body=json.dumps(w.to_dict()).encode("utf-8"),
                headers={"Content-Type": "application/json"},
            )
            resp = conn.getresponse()
            if resp.status >= 400:
                raw = resp.read()
                try:
                    err = json.loads(raw.decode("utf-8")).get("error", {})
                except ValueError:
                    err = {}
                raise WireError(
                    resp.status, err.get("type", "http"), err.get("message", f"HTTP {resp.status}")
                )
            data_lines: list = []
            while True:
                line = resp.readline()
                if not line:
                    break
                text = line.decode("utf-8").rstrip("\r\n")
                if text.startswith("data:"):
                    data_lines.append(text[5:].lstrip())
                elif not text and data_lines:
                    d = json.loads("\n".join(data_lines))
                    data_lines = []
                    if d.get("kind") == "error":
                        err = d.get("error", {})
                        raise WireError(
                            err.get("status", 500),
                            err.get("type", "internal"),
                            err.get("message", ""),
                        )
                    ev = event_from_dict(d)
                    yield ev
                    if ev.kind == "done":
                        break
        finally:
            conn.close()
