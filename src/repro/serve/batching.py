"""Micro-batching: coalesce same-plan label queries into padded batches.

Analytical-CV evaluation is label-batched for free — ``fastcv.cv_errors``
broadcasts the cached fold solves over a trailing batch dimension — so the
cheapest way to serve many small requests (permutation chunks from many
clients, searchlight probes, RSA model RDMs) is to stack their label
vectors into one (N, B) batch, pad B up to a *shape bucket*, and run a
single jitted evaluation. Static bucket sizes bound the number of distinct
compiled programs: after one warm-up per bucket no request ever recompiles.

Two layouts, matching the engine's eval paths:
  * columns  — binary / ridge: each query contributes (N,) or (N, b)
               response columns; batch is (N, B).
  * rows     — multi-class: each query contributes (N,) or (b, N) integer
               label rows; batch is (B, N).

Coalescing and un-padding run in HOST numpy, not jnp, on purpose: jax
compiles even eager ops per (primitive, shapes) signature, so stacking a
*novel* combination of query widths with ``jnp.concatenate`` + ``jnp.pad``
+ per-request output slices costs a fresh flock of tiny XLA compiles
(~tens of ms each on CPU) every time traffic composition shifts — which
under a gather-window server is nearly every batch. Host-side assembly
makes batch composition free; the single bucketed jitted eval is the only
XLA entry point, so the engine's no-recompile guarantee extends to ragged,
never-repeating traffic mixes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.folds import Folds

# reprolint: host-path
# reprolint: monotonic-time
# (The whole module is the host coalescing path the docstring above
# describes: assembly stays in numpy, jnp.asarray is the only device
# entry, and any timing added here must use a monotonic clock. The
# RL001 pragmas make that contract machine-checked.)

__all__ = ["DEFAULT_BUCKETS", "bucket_size", "as_folds", "MicroBatcher"]

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


def bucket_size(b: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= b; beyond the largest, the next multiple of it."""
    if b <= 0:
        raise ValueError(f"batch size must be positive, got {b}")
    for s in buckets:
        if b <= s:
            return s
    top = buckets[-1]
    return -(-b // top) * top


def as_folds(folds) -> Folds:
    """Normalise a folds spec: a Folds, or a raw (te_idx, tr_idx) pair.

    Requests may ship bare index arrays (e.g. sliced out of a grid of fold
    assignments); :meth:`Folds.with_indices` rebuilds the static-shape view.
    """
    if isinstance(folds, Folds):
        return folds
    te_idx, tr_idx = folds
    return Folds.with_indices(jnp.asarray(te_idx, jnp.int32), jnp.asarray(tr_idx, jnp.int32))


@dataclasses.dataclass(frozen=True)
class _Segment:
    start: int  # first column/row of this query in the batch
    stop: int
    squeeze: bool  # query was a single vector, not a matrix


class MicroBatcher:
    """Coalesce ragged label queries; un-pad per-request on the way out.

    ``metrics``, when given, is a :class:`repro.serve.obs.MetricsRegistry`
    with a ``batch_coalesced_size`` histogram: each coalesce observes the
    *unpadded* total width, so the distribution shows how full batches run
    relative to their shape buckets (padding waste = bucket − observed).

    Donation contract: every coalesce assembles a *fresh* device array
    (host-numpy concat → ``jnp.asarray``) and the split methods read only
    the eval *output* — the coalesced input is never touched after
    ``eval_fn`` returns. Callers may therefore hand the batch to a
    donating jit (it is engine-owned, single-use by construction); pinned
    by ``tests/test_donation.py`` with delete-after-eval checks.
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_BUCKETS, metrics=None):
        self.buckets = tuple(buckets)
        self.metrics = metrics

    def _observe(self, offset: int) -> None:
        if self.metrics is not None:
            self.metrics.observe("batch_coalesced_size", offset)

    # -- columns layout: binary / ridge ------------------------------------

    def coalesce_columns(self, ys: Sequence[jax.Array]):
        """Stack queries into (N, B_bucket); returns (batch, segments, B)."""
        segments, cols, offset = [], [], 0
        for y in ys:
            arr = np.asarray(y)
            squeeze = arr.ndim == 1
            yc = arr[:, None] if squeeze else arr
            segments.append(_Segment(offset, offset + yc.shape[1], squeeze))
            cols.append(yc)
            offset += yc.shape[1]
        batch = np.concatenate(cols, axis=1)
        self._observe(offset)
        padded = bucket_size(offset, self.buckets)
        if padded > offset:
            batch = np.pad(batch, ((0, 0), (0, padded - offset)))
        return jnp.asarray(batch), segments, offset

    def split_columns(self, out: jax.Array, segments: Sequence[_Segment]):
        """Invert :meth:`coalesce_columns` on an output with trailing B."""
        out = np.asarray(out)  # one host sync; per-request slices are free
        results = []
        for seg in segments:
            r = out[..., seg.start : seg.stop]
            results.append(r[..., 0] if seg.squeeze else r)
        return results

    def run_columns(self, ys: Sequence[jax.Array], eval_fn: Callable[[jax.Array], jax.Array]):
        """One padded eval for all queries; per-query unpadded outputs."""
        batch, segments, _ = self.coalesce_columns(ys)
        return self.split_columns(eval_fn(batch), segments)

    # -- rows layout: multi-class ------------------------------------------

    def coalesce_rows(self, ys: Sequence[jax.Array]):
        """Stack queries into (B_bucket, N); returns (batch, segments, B).

        Padding rows repeat the first label row (all-zero "labels" would
        make the per-fold class-count matrix D_π singular in Algorithm 2's
        eigensolve; a real label vector is always well-posed)."""
        segments, rows, offset = [], [], 0
        for y in ys:
            arr = np.asarray(y)
            squeeze = arr.ndim == 1
            yr = arr[None, :] if squeeze else arr
            segments.append(_Segment(offset, offset + yr.shape[0], squeeze))
            rows.append(yr)
            offset += yr.shape[0]
        batch = np.concatenate(rows, axis=0)
        self._observe(offset)
        padded = bucket_size(offset, self.buckets)
        if padded > offset:
            batch = np.concatenate(
                [batch, np.broadcast_to(batch[:1], (padded - offset,) + batch.shape[1:])],
                axis=0,
            )
        return jnp.asarray(batch), segments, offset

    def split_rows(self, out: jax.Array, segments: Sequence[_Segment]):
        out = np.asarray(out)  # one host sync; per-request slices are free
        results = []
        for seg in segments:
            r = out[seg.start : seg.stop]
            results.append(r[0] if seg.squeeze else r)
        return results

    def run_rows(self, ys: Sequence[jax.Array], eval_fn: Callable[[jax.Array], jax.Array]):
        batch, segments, _ = self.coalesce_rows(ys)
        return self.split_rows(eval_fn(batch), segments)
