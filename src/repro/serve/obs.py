"""repro.serve.obs — zero-dependency metrics registry with Prometheus exposition.

The serving stack (PRs 1-5) could answer "how many compiles happened?"
(``CVEngine.compile_count``) and "how is the plan cache doing?"
(``PlanCache.stats``) but not "where do a request's milliseconds go?".
This module is the *metrics* half of the observability layer: a small,
thread-safe registry of counters, gauges and fixed-bucket histograms that
the engine, batcher, servers and HTTP edge populate, rendered on demand
as Prometheus text exposition format 0.0.4 (``GET /v1/metrics``) — no
third-party client library involved. The *tracing* half (per-request span
trees) lives in :mod:`repro.serve.trace` and feeds its per-stage
durations into this registry's ``stage_latency_seconds`` histogram.

Design notes
------------
* **Counters** only go up (``inc``); **gauges** are either set directly
  (``set``) or — the common case here — registered with a zero-arg
  callback so existing sources of truth (``cache.stats.hits``,
  ``engine.compile_count()``) stay canonical and the registry is a pure
  *view*: ``engine.stats()`` keeps its schema bit-for-bit.
* **Histograms** use fixed bucket boundaries chosen at registration
  (:data:`LATENCY_BUCKETS_S` for stage latencies, :data:`SIZE_BUCKETS`
  for occupancy/coalesced-size distributions). Buckets are cumulative in
  the exposition (``le`` semantics) but stored as per-bucket counts.
* **Label cardinality cap** — every labelled metric folds label-sets
  beyond ``max_series_per_metric`` into a single ``_other`` overflow
  series (and counts the fold in ``registry.dropped_series``) so a
  misbehaving client cannot grow the registry without bound.
* **Thread safety** — one ``RLock`` around every mutation and render;
  the hot-path cost of ``inc``/``observe`` is one lock + dict update,
  cheap enough to leave permanently on (tracing, by contrast, is opt-in).
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Optional, Sequence, Tuple

__all__ = [
    "LATENCY_BUCKETS_S",
    "SIZE_BUCKETS",
    "BUCKET_FAMILIES",
    "METRICS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]

# Stage latencies span ~100 microseconds (a warm bucketed eval) to ~10 s
# (a cold O(N^2 P) plan build); 16 roughly-logarithmic edges cover it.
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    1e-4,
    2.5e-4,
    5e-4,
    1e-3,
    2.5e-3,
    5e-3,
    1e-2,
    2.5e-2,
    5e-2,
    1e-1,
    2.5e-1,
    5e-1,
    1.0,
    2.5,
    5.0,
    10.0,
)

# Occupancy / coalesced-size distributions: powers of two up to the
# largest jit shape bucket (DEFAULT_BUCKETS tops out at 1024).
SIZE_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)

_OTHER = "_other"

# Bucket families referenced *by name* from METRICS, so the declaration
# table below stays a pure literal that `python -m repro.analysis` can
# read via ast.literal_eval without importing this module.
BUCKET_FAMILIES = {"latency": LATENCY_BUCKETS_S, "size": SIZE_BUCKETS}

#: Central metric declarations — THE single source of truth for every
#: metric name, kind and label-key set in the serving stack. The engine
#: registers exactly this table (`CVEngine._declare_metrics`), reprolint
#: rule RL003 checks every literal call site against it, and gauge
#: callbacks are supplied by the engine at registration time. Keep it a
#: pure literal: reprolint AST-extracts it via `ast.literal_eval`.
METRICS = {
    "requests_total": {
        "kind": "counter",
        "labels": ("kind", "estimator"),
        "help": "Workloads served, by kind and estimator",
    },
    "plan_updates_total": {
        "kind": "counter",
        "labels": ("op",),
        "help": "Incremental dataset updates applied, by operation",
    },
    "stage_latency_seconds": {
        "kind": "histogram",
        "labels": ("stage",),
        "buckets": "latency",
        "help": "Per-stage request latency (traced requests only)",
    },
    "gather_window_occupancy": {
        "kind": "histogram",
        "labels": (),
        "buckets": "size",
        "help": "Requests coalesced per server gather window",
    },
    "batch_coalesced_size": {
        "kind": "histogram",
        "labels": (),
        "buckets": "size",
        "help": "Unpadded label-batch width per coalesced eval",
    },
    "plan_update_rank": {
        "kind": "histogram",
        "labels": (),
        "buckets": "size",
        "help": "Correction rank (rows appended + retired) per incremental update",
    },
    "plan_cache_hits": {"kind": "gauge", "labels": (), "help": "Plan cache hits"},
    "plan_cache_misses": {"kind": "gauge", "labels": (), "help": "Plan cache misses (builds)"},
    "plan_cache_evictions": {"kind": "gauge", "labels": (), "help": "Plan cache evictions"},
    "plan_cache_oversized": {
        "kind": "gauge",
        "labels": (),
        "help": "Builds served un-cached (over byte budget)",
    },
    "plan_cache_bytes_in_use": {
        "kind": "gauge",
        "labels": (),
        "help": "Plan cache resident bytes",
    },
    "plan_store_hits": {
        "kind": "gauge",
        "labels": (),
        "help": "Plans loaded (verified) from the disk store",
    },
    "plan_store_misses": {
        "kind": "gauge",
        "labels": (),
        "help": "Disk-store probes that found nothing usable",
    },
    "plan_store_writes": {
        "kind": "gauge",
        "labels": (),
        "help": "Plans committed to the disk store",
    },
    "plan_store_bytes": {
        "kind": "gauge",
        "labels": (),
        "help": "Committed plan-store bytes on disk",
    },
    "compile_events": {
        "kind": "gauge",
        "labels": (),
        "help": "jit cache entries across every eval path",
    },
    "rdm_hits": {"kind": "gauge", "labels": (), "help": "Empirical-RDM memo hits"},
    "plans_built": {"kind": "gauge", "labels": (), "help": "CVPlans built by this engine"},
    "plans_updated": {
        "kind": "gauge",
        "labels": (),
        "help": "CVPlans advanced by incremental rank-k correction",
    },
    "labels_evaluated": {"kind": "gauge", "labels": (), "help": "Label vectors evaluated"},
    "datasets_registered": {
        "kind": "gauge",
        "labels": (),
        "help": "Registered dataset handles",
    },
}


def _label_values(label_names: Tuple[str, ...], labels: dict) -> Tuple[str, ...]:
    if set(labels) != set(label_names):
        raise ValueError(f"expected labels {label_names}, got {tuple(sorted(labels))}")
    return tuple(str(labels[k]) for k in label_names)


def _fmt_value(v: float) -> str:
    # Prometheus text format: render integral values without the trailing
    # ".0" so `compile_events 0` greps cleanly in CI.
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _fmt_labels(label_names: Tuple[str, ...], values: Tuple[str, ...], extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in zip(label_names, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Metric:
    """Shared labelled-series bookkeeping (cardinality cap included)."""

    kind = "untyped"

    def __init__(
        self, registry: "MetricsRegistry", name: str, help: str, label_names: Sequence[str]
    ):
        self.registry = registry
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._series: Dict[Tuple[str, ...], object] = {}

    def _series_key(self, labels: dict) -> Tuple[str, ...]:
        key = _label_values(self.label_names, labels)
        if key not in self._series and len(self._series) >= self.registry.max_series_per_metric:
            self.registry.dropped_series += 1
            key = (_OTHER,) * len(self.label_names)
        return key


class Counter(_Metric):
    """Monotonically increasing count, optionally labelled."""

    kind = "counter"

    def inc(self, value: float = 1, **labels) -> None:
        if value < 0:
            raise ValueError("counters only go up")
        with self.registry._lock:
            key = self._series_key(labels)
            self._series[key] = self._series.get(key, 0) + value

    def value(self, **labels) -> float:
        with self.registry._lock:
            return self._series.get(_label_values(self.label_names, labels), 0)

    def render(self) -> list:
        lines = [f"# HELP {self.name} {self.help}", f"# TYPE {self.name} counter"]
        for key, v in self._series.items():
            lines.append(f"{self.name}{_fmt_labels(self.label_names, key)} {_fmt_value(v)}")
        if not self._series:
            lines.append(f"{self.name} 0")
        return lines

    def as_dict(self) -> dict:
        if not self.label_names:
            return {"value": self._series.get((), 0)}
        return {",".join(k): v for k, v in self._series.items()}


class Gauge(_Metric):
    """Point-in-time value: set directly or backed by a zero-arg callback.

    Callback gauges (``fn=``) are evaluated lazily at render/read time so
    existing counters (cache stats, jit cache sizes) stay the single
    source of truth — the registry never shadows them with a stale copy.
    """

    kind = "gauge"

    def __init__(
        self, registry, name, help, label_names=(), fn: Optional[Callable[[], float]] = None
    ):
        super().__init__(registry, name, help, label_names)
        if fn is not None and self.label_names:
            raise ValueError("callback gauges cannot be labelled")
        self.fn = fn

    def set(self, value: float, **labels) -> None:
        if self.fn is not None:
            raise ValueError(f"gauge {self.name} is callback-backed")
        with self.registry._lock:
            self._series[self._series_key(labels)] = value

    def value(self, **labels) -> float:
        if self.fn is not None:
            return self.fn()
        with self.registry._lock:
            return self._series.get(_label_values(self.label_names, labels), 0)

    def render(self) -> list:
        lines = [f"# HELP {self.name} {self.help}", f"# TYPE {self.name} gauge"]
        if self.fn is not None:
            lines.append(f"{self.name} {_fmt_value(self.fn())}")
            return lines
        for key, v in self._series.items():
            lines.append(f"{self.name}{_fmt_labels(self.label_names, key)} {_fmt_value(v)}")
        if not self._series:
            lines.append(f"{self.name} 0")
        return lines

    def as_dict(self) -> dict:
        if self.fn is not None:
            return {"value": self.fn()}
        if not self.label_names:
            return {"value": self._series.get((), 0)}
        return {",".join(k): v for k, v in self._series.items()}


class _HistSeries:
    __slots__ = ("counts", "total", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets  # per-bucket (non-cumulative)
        self.total = 0.0
        self.count = 0


class Histogram(_Metric):
    """Fixed-bucket histogram with Prometheus cumulative-``le`` exposition."""

    kind = "histogram"

    def __init__(self, registry, name, help, buckets: Sequence[float], label_names=()):
        super().__init__(registry, name, help, label_names)
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError("buckets must be a non-empty ascending sequence")
        self.buckets = tuple(float(b) for b in buckets)

    def declare(self, **labels) -> None:
        """Pre-create a zero series so the exposition lists every declared
        label-set (e.g. all stage names) before any traffic arrives."""
        with self.registry._lock:
            key = self._series_key(labels)
            if key not in self._series:
                self._series[key] = _HistSeries(len(self.buckets))

    def observe(self, value: float, **labels) -> None:
        with self.registry._lock:
            key = self._series_key(labels)
            series = self._series.get(key)
            if series is None:
                series = self._series[key] = _HistSeries(len(self.buckets))
            for i, edge in enumerate(self.buckets):
                if value <= edge:
                    series.counts[i] += 1
                    break
            series.total += value
            series.count += 1

    def snapshot(self, **labels) -> dict:
        """``{count, sum, buckets}`` for one series (zeros when absent)."""
        with self.registry._lock:
            series = self._series.get(_label_values(self.label_names, labels))
            if series is None:
                return {"count": 0, "sum": 0.0, "buckets": [0] * len(self.buckets)}
            return {
                "count": series.count,
                "sum": series.total,
                "buckets": list(series.counts),
            }

    def render(self) -> list:
        lines = [f"# HELP {self.name} {self.help}", f"# TYPE {self.name} histogram"]
        for key, series in self._series.items():
            cum = 0
            for edge, n in zip(self.buckets, series.counts):
                cum += n
                le = f'le="{_fmt_value(edge)}"'
                lines.append(f"{self.name}_bucket{_fmt_labels(self.label_names, key, le)} {cum}")
            labels = _fmt_labels(self.label_names, key)
            inf = _fmt_labels(self.label_names, key, 'le="+Inf"')
            lines.append(f"{self.name}_bucket{inf} {series.count}")
            lines.append(f"{self.name}_sum{labels} {_fmt_value(series.total)}")
            lines.append(f"{self.name}_count{labels} {series.count}")
        return lines

    def as_dict(self) -> dict:
        out = {}
        for key in self._series:
            out[",".join(key) if key else "value"] = self.snapshot(
                **dict(zip(self.label_names, key))
            )
        return out


class MetricsRegistry:
    """Insertion-ordered registry of counters/gauges/histograms.

    Registration is idempotent — re-registering an existing name returns
    the existing metric (so the engine can declare unconditionally) but a
    *type* mismatch raises. Convenience ``inc``/``observe``/``set_gauge``
    dispatch by name and raise ``KeyError`` on unknown metrics: silently
    dropping an instrumentation point would defeat the purpose.
    """

    # Concurrency contract, machine-checked by reprolint RL004.
    # (`dropped_series` is also lock-guarded, but it is incremented from
    # _Metric._series_key under the *caller's* lock acquisition, which a
    # lexical per-class checker cannot see — the per-metric mutators all
    # take `self.registry._lock` before touching series state.)
    _GUARDED_BY = {"_metrics": "_lock"}

    def __init__(self, max_series_per_metric: int = 64):
        self._lock = threading.RLock()
        self._metrics: "Dict[str, _Metric]" = {}
        self.max_series_per_metric = max_series_per_metric
        self.dropped_series = 0

    def _register(self, cls, name, help, **kw):
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if type(existing) is not cls:
                    raise ValueError(
                        f"metric {name!r} already registered as {existing.kind}"
                    )
                return existing
            metric = cls(self, name, help, **kw)
            self._metrics[name] = metric
            return metric

    def counter(self, name: str, help: str = "", labels: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, label_names=labels)

    def gauge(
        self, name: str, help: str = "", fn: Optional[Callable[[], float]] = None
    ) -> Gauge:
        return self._register(Gauge, name, help, fn=fn)

    def histogram(
        self,
        name: str,
        help: str = "",
        buckets: Sequence[float] = LATENCY_BUCKETS_S,
        labels: Sequence[str] = (),
    ) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets, label_names=labels)

    def get(self, name: str) -> _Metric:
        with self._lock:
            return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._metrics

    # -- by-name conveniences (hot-path instrumentation calls) -------------

    def inc(self, name: str, value: float = 1, **labels) -> None:
        metric = self.get(name)
        if not isinstance(metric, Counter):
            raise TypeError(f"metric {name!r} is a {metric.kind}, not a counter")
        metric.inc(value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        metric = self.get(name)
        if not isinstance(metric, Histogram):
            raise TypeError(f"metric {name!r} is a {metric.kind}, not a histogram")
        metric.observe(value, **labels)

    # -- exposition --------------------------------------------------------

    def render_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4 (trailing newline)."""
        with self._lock:
            lines = []
            for metric in self._metrics.values():
                lines.extend(metric.render())
            if self.dropped_series:
                lines.append(
                    "# HELP obs_dropped_series "
                    "Label-sets folded into _other by the cardinality cap"
                )
                lines.append("# TYPE obs_dropped_series counter")
                lines.append(f"obs_dropped_series {self.dropped_series}")
            return "\n".join(lines) + "\n"

    def as_dict(self) -> dict:
        with self._lock:
            return {name: m.as_dict() for name, m in self._metrics.items()}
