"""Deterministic synthetic token pipeline for LM training/serving.

A real deployment would stream tokenised corpora; offline we provide a
seeded, reproducible, infinitely-repeatable token source with the same
interface a production loader would have: global-batch iteration,
per-process sharding (each data-parallel group reads only its slice),
checkpointable cursor (resume from a step), and modality stubs for the
[vlm]/[audio] architectures (precomputed patch/frame embeddings per the
assignment: frontends are STUBS, only the backbone is modelled).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np

__all__ = ["TokenStreamConfig", "TokenStream"]


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality stubs
    num_codebooks: int = 0            # [audio] musicgen: >0 => multi-codebook
    vision_tokens: int = 0            # [vlm] llama-vision: >0 => patch embeds
    vision_dim: int = 0


class TokenStream:
    """Seeded synthetic token batches with a checkpointable cursor.

    Tokens are a Zipf-ish mixture (realistic rank-frequency profile) drawn
    from a counter-based RNG keyed on (seed, step, shard), so any shard of
    any step is reproducible in O(1) — the property that makes elastic
    restarts and straggler re-assignment trivial.
    """

    def __init__(self, cfg: TokenStreamConfig, step: int = 0):
        self.cfg = cfg
        self.step = step

    def checkpoint_state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @staticmethod
    def restore(cfg: TokenStreamConfig, state: dict) -> "TokenStream":
        assert state["seed"] == cfg.seed, "data seed changed across restart"
        return TokenStream(cfg, step=int(state["step"]))

    def _batch_at(self, step: int, batch: int, seq_plus_one: bool) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        s = cfg.seq_len + (1 if seq_plus_one else 0)
        # Zipf-like: exponential-rank sampling keeps a heavy head like text.
        u = rng.random((batch, s))
        ranks = (-np.log1p(-u * (1 - np.exp(-12.0))) / 12.0 * cfg.vocab_size)
        toks = np.clip(ranks.astype(np.int32), 0, cfg.vocab_size - 1)
        out = {"tokens": toks}
        if cfg.num_codebooks:
            out["tokens"] = np.clip(
                rng.integers(0, cfg.vocab_size, (batch, cfg.num_codebooks, s),
                             dtype=np.int32), 0, cfg.vocab_size - 1)
        if cfg.vision_tokens:
            out["vision_embeds"] = rng.standard_normal(
                (batch, cfg.vision_tokens, cfg.vision_dim)).astype(np.float32)
        return out

    def next_batch(self, shard_index: int = 0, num_shards: int = 1) -> dict:
        """One step's shard: batch rows [shard*b/ns, (shard+1)*b/ns)."""
        assert self.cfg.global_batch % num_shards == 0
        local = self.cfg.global_batch // num_shards
        full = self._batch_at(self.step, self.cfg.global_batch, seq_plus_one=True)
        out = {}
        for k, v in full.items():
            sl = v[shard_index * local:(shard_index + 1) * local]
            if k == "tokens":
                out["tokens"] = jnp.asarray(sl[..., :-1])
                out["labels"] = jnp.asarray(sl[..., 1:])
            else:
                out[k] = jnp.asarray(sl)
        self.step += 1
        return out

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
