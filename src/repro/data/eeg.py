"""EEG/MEG-like dataset simulator mirroring the paper's §2.13 analysis.

The Wakeman-Henson dataset is not available offline, so we synthesise data
with the same *statistical shape*: multi-subject epoched recordings with
380 channels, 200 Hz sampling, epochs from -0.5 s to 1 s, a class-dependent
evoked response (faces vs scrambled; faces split into 3 sub-classes for the
multi-class analysis), and spatially correlated noise. The two feature
constructions of the paper are provided:

  * per-timepoint features: 380 channels at one sample        (P = 380)
  * windowed features: channel amplitudes averaged in 100/200 ms windows
    and concatenated                                           (P = 3800/1900)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["EEGDataset", "simulate_subject", "timepoint_features", "windowed_features"]

N_CHANNELS = 380
FS = 200.0
T_MIN, T_MAX = -0.5, 1.0


class EEGDataset(NamedTuple):
    epochs: jax.Array   # (n_trials, n_channels, n_times)
    y: jax.Array        # (n_trials,) int class labels
    times: jax.Array    # (n_times,) seconds relative to stimulus onset


def simulate_subject(key: jax.Array, n_trials: int = 787, num_classes: int = 2,
                     snr: float = 0.5, dtype=jnp.float32) -> EEGDataset:
    """One subject's epoched data with a class-specific N170-like component."""
    n_times = int(round((T_MAX - T_MIN) * FS)) + 1
    times = jnp.linspace(T_MIN, T_MAX, n_times, dtype=dtype)
    k_pat, k_noise, k_mix = jax.random.split(key, 3)

    # class-specific spatial patterns and latencies (ERP component ~170 ms)
    patterns = jax.random.normal(k_pat, (num_classes, N_CHANNELS), dtype)
    patterns = patterns / jnp.linalg.norm(patterns, axis=1, keepdims=True)
    latencies = 0.17 + 0.03 * jnp.arange(num_classes, dtype=dtype)
    width = 0.05
    erp = jnp.exp(-0.5 * ((times[None, :] - latencies[:, None]) / width) ** 2)
    erp = erp * (times[None, :] > 0)                     # causal
    signal = patterns[:, :, None] * erp[:, None, :]      # (C, ch, t)

    y = jnp.arange(n_trials, dtype=jnp.int32) % num_classes
    # spatially correlated noise: white noise mixed through a random matrix
    mix = jax.random.normal(k_mix, (N_CHANNELS, N_CHANNELS), dtype) / jnp.sqrt(N_CHANNELS)
    white = jax.random.normal(k_noise, (n_trials, N_CHANNELS, n_times), dtype)
    noise = jnp.einsum("cd,ndt->nct", mix, white)
    epochs = snr * signal[y] + noise
    # baseline correction on the pre-stimulus interval (paper §2.13)
    base = jnp.mean(jnp.where(times[None, None, :] < 0, epochs, 0.0), axis=2,
                    keepdims=True) / jnp.mean((times < 0).astype(dtype))
    return EEGDataset(epochs - base, y, times)


def timepoint_features(ds: EEGDataset, t_index: int) -> jax.Array:
    """(n_trials, 380) — channel amplitudes at one time point."""
    return ds.epochs[:, :, t_index]


def windowed_features(ds: EEGDataset, window_ms: float) -> jax.Array:
    """Post-stimulus window-averaged amplitudes, concatenated over windows.

    100 ms windows -> 10*380 = 3800 features; 200 ms -> 5*380 = 1900.
    """
    post = np.asarray(ds.times) > 0
    t_post = np.flatnonzero(post)
    samples_per_win = int(round(window_ms / 1000.0 * FS))
    n_win = len(t_post) // samples_per_win
    feats = []
    for w in range(n_win):
        sl = t_post[w * samples_per_win:(w + 1) * samples_per_win]
        feats.append(jnp.mean(ds.epochs[:, :, sl], axis=2))
    return jnp.concatenate(feats, axis=1)
