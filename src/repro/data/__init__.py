from repro.data import synthetic, eeg, tokens  # noqa: F401
