"""Simulated classification/regression data (paper §2.12).

"Each class centroid is randomly placed on the surface of a unit
hypersphere in feature space. A common covariance matrix is randomly
sampled from a Wishart distribution. Samples are then created by randomly
sampling from a multivariate normal distribution parameterised by the
corresponding class centroid and the common covariance matrix."
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_classification", "make_regression"]


def _wishart_cholesky(key: jax.Array, p: int, dof: int, dtype) -> jax.Array:
    """Cholesky factor of a Wishart(I, dof)/dof sample, via its Bartlett-free
    construction A = GᵀG/dof with G ~ N(0,1)^{dof×p} (dof >= p)."""
    g = jax.random.normal(key, (dof, p), dtype)
    a = g.T @ g / dof + 1e-6 * jnp.eye(p, dtype=dtype)
    return jnp.linalg.cholesky(a)


def make_classification(key: jax.Array, n: int, p: int, num_classes: int = 2,
                        dtype=jnp.float64, class_sep: float = 1.0):
    """Paper §2.12 generator. Returns (x (N,P), y int (N,) in [0, C)).

    Equal class proportions; centroids uniform on the unit hypersphere
    scaled by ``class_sep``; shared Wishart covariance.
    """
    k_cent, k_wish, k_noise = jax.random.split(key, 3)
    cent = jax.random.normal(k_cent, (num_classes, p), dtype)
    cent = class_sep * cent / jnp.linalg.norm(cent, axis=1, keepdims=True)
    chol = _wishart_cholesky(k_wish, p, max(p, 2 * p), dtype)
    y = jnp.arange(n, dtype=jnp.int32) % num_classes
    z = jax.random.normal(k_noise, (n, p), dtype)
    x = cent[y] + z @ chol.T
    return x, y


def make_regression(key: jax.Array, n: int, p: int, noise: float = 0.1,
                    dtype=jnp.float64):
    """Linear model y = Xw* + b* + ε for regression CV tests/benchmarks."""
    k_x, k_w, k_e = jax.random.split(key, 3)
    x = jax.random.normal(k_x, (n, p), dtype)
    w = jax.random.normal(k_w, (p,), dtype) / jnp.sqrt(p)
    y = x @ w + 0.5 + noise * jax.random.normal(k_e, (n,), dtype)
    return x, y
