"""Model-RDM comparison: rank correlations, cosine, permutation nulls.

RSA's second half: vectorise the empirical RDM's upper triangle, score it
against each candidate model RDM, and calibrate with a condition-label
permutation test — permuting condition identities (rows+columns of the
empirical RDM jointly) is the standard exchangeable null for RDM
correlations. Permutations come from
:func:`repro.core.permutation.permutation_indices`, so engine-served nulls
are prefix-stable under shape-bucket rounding exactly like the CV
permutation path.

Everything here is jit-friendly with static method dispatch; sizes are
tiny (B = C(C−1)/2 pairs, M models, T permutations), so the O(B²) Kendall
pairwise form is the right trade against a sort-based O(B log B) one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "upper_triangle",
    "rankdata",
    "pearson",
    "spearman",
    "kendall",
    "cosine",
    "compare_rdms",
    "permutation_null",
    "make_compare",
    "make_compare_null",
]

_EPS = 1e-12


def upper_triangle(rdm: jax.Array) -> jax.Array:
    """Vectorise the strict upper triangle of (..., C, C) into (..., B)."""
    c = rdm.shape[-1]
    iu, ju = np.triu_indices(c, 1)
    return rdm[..., iu, ju]


def rankdata(v: jax.Array) -> jax.Array:
    """Average ranks (1-based, ties get mid-ranks), jit-friendly."""
    order = jnp.argsort(v)
    sv = v[order]
    first = jnp.searchsorted(sv, sv, side="left")
    last = jnp.searchsorted(sv, sv, side="right")
    mid = 0.5 * (first + last + 1).astype(v.dtype)
    return jnp.zeros_like(v).at[order].set(mid)


def pearson(a: jax.Array, b: jax.Array) -> jax.Array:
    ac = a - jnp.mean(a)
    bc = b - jnp.mean(b)
    denom = jnp.sqrt(jnp.sum(ac * ac) * jnp.sum(bc * bc))
    return jnp.sum(ac * bc) / jnp.maximum(denom, _EPS)


def spearman(a: jax.Array, b: jax.Array) -> jax.Array:
    """Spearman ρ = Pearson correlation of average ranks."""
    return pearson(rankdata(a), rankdata(b))


def kendall(a: jax.Array, b: jax.Array) -> jax.Array:
    """Kendall τ-b (tie-corrected), via the O(B²) pairwise sign form."""
    da = jnp.sign(a[:, None] - a[None, :])
    db = jnp.sign(b[:, None] - b[None, :])
    s = 0.5 * jnp.sum(da * db)  # concordant − discordant
    n = a.shape[0]
    n0 = 0.5 * n * (n - 1)
    ties_a = 0.5 * (jnp.sum(da == 0) - n)  # tied pairs in a
    ties_b = 0.5 * (jnp.sum(db == 0) - n)
    denom = jnp.sqrt((n0 - ties_a) * (n0 - ties_b))
    return s / jnp.maximum(denom, _EPS)


def cosine(a: jax.Array, b: jax.Array) -> jax.Array:
    denom = jnp.sqrt(jnp.sum(a * a) * jnp.sum(b * b))
    return jnp.sum(a * b) / jnp.maximum(denom, _EPS)


_METHODS = {
    "spearman": spearman,
    "kendall": kendall,
    "pearson": pearson,
    "cosine": cosine,
}


def _method(name: str):
    fn = _METHODS.get(name)
    if fn is None:
        raise ValueError(
            f"unknown comparison {name!r}; expected one of {tuple(_METHODS)}"
        )
    return fn


def compare_rdms(
    empirical: jax.Array, model_rdms: jax.Array, method: str = "spearman"
) -> jax.Array:
    """Score (M, C, C) model RDMs against the (C, C) empirical RDM → (M,)."""
    fn = _method(method)
    ev = upper_triangle(empirical)
    mv = upper_triangle(model_rdms)
    return jax.vmap(lambda m: fn(ev, m))(mv)


def permutation_null(
    empirical: jax.Array,
    model_rdms: jax.Array,
    perms: jax.Array,
    method: str = "spearman",
) -> jax.Array:
    """(M, T) null scores: condition labels permuted per perms (T, C).

    Permuting the empirical RDM's rows and columns jointly (not the model
    RDMs) yields one draw from the no-correspondence null per permutation.
    """
    fn = _method(method)
    mv = upper_triangle(model_rdms)  # (M, B)

    def one(pi):
        ev = upper_triangle(empirical[pi][:, pi])
        return jax.vmap(lambda m: fn(ev, m))(mv)  # (M,)

    return jax.vmap(one)(perms).T  # (M, T)


def make_compare(method: str = "spearman"):
    """Fresh jitted ``(empirical (C,C), models (M,C,C)) -> (M,)`` scorer.

    Independently cached per call (``fn._cache_size()``), matching the
    serve engine's compile-count observability convention.
    """
    return jax.jit(functools.partial(compare_rdms, method=method))


def make_compare_null(method: str = "spearman"):
    """Fresh jitted ``(empirical, models, perms (T,C)) -> (M, T)`` null."""
    return jax.jit(functools.partial(permutation_null, method=method))
