"""repro.rsa — Representational Similarity Analysis as a first-class workload.

The paper's §4.2 application family: cross-validated condition
dissimilarities (pairwise-contrast or confusion RDMs) from shared
:class:`~repro.core.fastcv.CVPlan` fold solves, model-RDM scoring with
rank correlations and condition-permutation nulls, Pallas-kernelled
pattern RDMs, and mesh-sharded searchlight sweeps.

  rdm      empirical RDMs from CVPlan fold solves; searchlight sharding.
  compare  Spearman/Kendall/Pearson/cosine model scoring + permutation nulls.

Served end-to-end via ``repro.serve.Workload(kind="rsa", ...)``.
"""

from repro.rsa.compare import (  # noqa: F401
    compare_rdms,
    cosine,
    kendall,
    make_compare,
    make_compare_null,
    pearson,
    permutation_null,
    rankdata,
    spearman,
    upper_triangle,
)
from repro.rsa.rdm import (  # noqa: F401
    condition_means,
    condition_pairs,
    euclidean_rdm,
    make_eval_pairs,
    pair_contrast_columns,
    pair_dissimilarities,
    rdm_binary,
    rdm_from_confusion,
    rdm_from_pair_values,
    rdm_multiclass,
    ring_rdm,
    searchlight_rdm,
)
