"""Cross-validated representational dissimilarity matrices (RDMs).

The paper names Representational Similarity Analysis as a headline
application of analytical CV (§1, §4.2): once the hat matrix and per-fold
factorisations are built, *every* contrast between conditions is just
another label column through the cached fold solves, at O(K·m²) each.

This module makes that concrete. Conditions are integer labels
``y_cond ∈ [0, C)`` over the N samples; the empirical RDM is built from
one shared :class:`~repro.core.fastcv.CVPlan`:

* **binary contrasts** — each of the B = C(C−1)/2 condition pairs (a, b)
  becomes one ±1/0 label column (+1 on a's samples, −1 on b's, 0
  elsewhere). All B columns ride a *single* batched fold solve
  (``fastcv.cv_errors`` broadcasts over the trailing dim), and each pair's
  dissimilarity is scored from the cross-validated decision values:
  ``"accuracy"`` (cross-validated pairwise decodability, with the paper's
  §2.5 LDA bias correction computed from the training-fold decision
  values) or ``"contrast"`` (the cross-validated mean decision-value
  contrast — a continuous, crossnobis-flavoured measure).
* **multi-class contrasts** — one Algorithm-2 multi-class CV run; the
  RDM is the symmetrised confusion dissimilarity 1 − (p(b|a) + p(a|b))/2.

Non-cross-validated baselines (condition-mean Euclidean RDMs, also the
usual way to *construct* model RDMs from feature embeddings) route through
the Pallas ``pairdist`` kernel on TPU. Searchlight sweeps — Q independent
RDM problems — shard over the mesh's problem axes via
:func:`repro.core.distributed.sharded_problems`.
"""

from __future__ import annotations

import functools
import threading
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastcv, metrics, multiclass
from repro.core.folds import Folds

__all__ = [
    "RDMCache",
    "condition_pairs",
    "pair_contrast_columns",
    "pair_dissimilarities",
    "rdm_from_pair_values",
    "rdm_binary",
    "rdm_from_confusion",
    "rdm_multiclass",
    "condition_means",
    "ring_rdm",
    "euclidean_rdm",
    "searchlight_rdm",
    "make_eval_pairs",
]

_DISSIMILARITIES = ("accuracy", "contrast")


class RDMCache:
    """Memoised empirical RDMs, keyed by (plan, labels-fingerprint, spec).

    An empirical RDM is a pure function of the plan (features × folds × λ)
    and the condition labels — so repeated model-RDM scoring against the
    same data (a model-comparison sweep, a dashboard refresh) can skip the
    fold solves entirely. Entries hold ``(rdm, pair_values)`` tuples; the
    serving engine owns one instance and exposes ``hits`` in its stats
    (ROADMAP "RDM caching" item). Bounded LRU: RDMs are tiny (C², not N²),
    so an entry *count* cap is the right unit, unlike the byte-budgeted
    plan cache.
    """

    def __init__(self, max_entries: int = 256):
        if max_entries <= 0:
            raise ValueError("max_entries must be positive")
        self.max_entries = max_entries
        # Locked like PlanCache: thread-transport streams run on the
        # calling thread while the queue worker serves batches, so get/put
        # race without it.
        self._lock = threading.Lock()
        self._entries: "OrderedDict" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def get(self, key):
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return hit

    def put(self, key, value) -> None:
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


def condition_pairs(num_classes: int) -> np.ndarray:
    """Static (B, 2) int32 array of condition pairs, B = C(C−1)/2.

    Row order is the upper-triangle order of ``np.triu_indices`` — the
    same order :func:`rdm_from_pair_values` scatters back from and
    ``repro.rsa.compare.upper_triangle`` vectorises RDMs into.
    """
    a, b = np.triu_indices(num_classes, 1)
    return np.stack([a, b], axis=1).astype(np.int32)


def pair_contrast_columns(y_cond: jax.Array, num_classes: int, dtype=jnp.float64) -> jax.Array:
    """(N, B) matrix of ±1/0 pairwise contrast columns.

    Column j encodes pair (a, b) = ``condition_pairs(C)[j]``: +1 on
    samples of condition a, −1 on b, 0 elsewhere. These are exactly the
    label batch the serving engine's column path consumes.
    """
    oh = jax.nn.one_hot(y_cond, num_classes, dtype=dtype)  # (N, C)
    pairs = condition_pairs(num_classes)
    return oh[:, pairs[:, 0]] - oh[:, pairs[:, 1]]  # (N, B)


def pair_dissimilarities(
    plan: fastcv.CVPlan,
    cols: jax.Array,
    dissimilarity: str = "accuracy",
    adjust_bias: bool = True,
    fused: bool = False,
) -> jax.Array:
    """Per-column dissimilarity from one batched fold solve. cols: (N, B).

    The contrast columns double as test/train masks: ``cols[te_idx]`` is
    the ±1/0 test label of every (fold, sample, pair), so scoring needs no
    side-channel condition information — which is what lets padded
    (all-zero) columns pass through harmlessly in the serving engine.

    ``"accuracy"``: sign agreement of the bias-adjusted decision values
    with the ±1 labels, restricted to the pair's own test samples.
    ``"contrast"``: mean decision value over the pair's positive test
    samples minus the mean over its negative ones.
    """
    if dissimilarity not in _DISSIMILARITIES:
        raise ValueError(f"dissimilarity must be one of {_DISSIMILARITIES}")
    cols = cols.astype(plan.h.dtype)
    y_dot_te, y_dot_tr = fastcv.cv_errors(plan, cols, fused=fused)  # (K, m, B)
    te_lab = cols[plan.te_idx]  # (K, m, B)
    dv = y_dot_te
    if adjust_bias:
        if y_dot_tr is None:
            raise ValueError("plan must be prepared with with_train_block=True")
        tr_lab = cols[plan.tr_idx]  # (K, N-m, B)
        pos = (tr_lab > 0).astype(cols.dtype)
        neg = (tr_lab < 0).astype(cols.dtype)
        mu1 = jnp.sum(y_dot_tr * pos, axis=1) / jnp.maximum(jnp.sum(pos, axis=1), 1.0)  # (K, B)
        mu2 = jnp.sum(y_dot_tr * neg, axis=1) / jnp.maximum(jnp.sum(neg, axis=1), 1.0)
        dv = dv - 0.5 * (mu1 + mu2)[:, None, :]
    if dissimilarity == "accuracy":
        mask = (jnp.abs(te_lab) > 0).astype(cols.dtype)
        pred = jnp.where(dv >= 0, 1.0, -1.0).astype(cols.dtype)
        hit = jnp.where(mask > 0, (pred == te_lab).astype(cols.dtype), 0.0)
        return jnp.sum(hit, axis=(0, 1)) / jnp.maximum(jnp.sum(mask, axis=(0, 1)), 1.0)
    pos = (te_lab > 0).astype(cols.dtype)
    neg = (te_lab < 0).astype(cols.dtype)
    m_pos = jnp.sum(dv * pos, axis=(0, 1)) / jnp.maximum(jnp.sum(pos, axis=(0, 1)), 1.0)
    m_neg = jnp.sum(dv * neg, axis=(0, 1)) / jnp.maximum(jnp.sum(neg, axis=(0, 1)), 1.0)
    return m_pos - m_neg


def rdm_from_pair_values(values: jax.Array, num_classes: int) -> jax.Array:
    """Scatter (B,) pair values into a symmetric (C, C) RDM, zero diagonal."""
    pairs = condition_pairs(num_classes)
    rdm = jnp.zeros((num_classes, num_classes), values.dtype)
    rdm = rdm.at[pairs[:, 0], pairs[:, 1]].set(values)
    return rdm + rdm.T


def rdm_binary(
    x: jax.Array,
    y_cond: jax.Array,
    folds: Folds,
    num_classes: int,
    lam: float = 1.0,
    *,
    dissimilarity: str = "accuracy",
    adjust_bias: bool = True,
    mode: str = "auto",
    plan: Optional[fastcv.CVPlan] = None,
) -> jax.Array:
    """One-shot cross-validated pairwise-contrast RDM. Returns (C, C).

    Builds (or reuses) a single plan over all N samples and evaluates all
    C(C−1)/2 contrasts as one label batch — the serving engine does the
    same thing through its cached-plan, shape-bucketed path.
    """
    if plan is None:
        plan = fastcv.prepare(x, folds, lam, mode=mode, with_train_block=adjust_bias)
    cols = pair_contrast_columns(y_cond, num_classes, plan.h.dtype)
    vals = pair_dissimilarities(plan, cols, dissimilarity=dissimilarity, adjust_bias=adjust_bias)
    return rdm_from_pair_values(vals, num_classes)


# ---------------------------------------------------------------------------
# Multi-class (confusion) contrasts
# ---------------------------------------------------------------------------


def rdm_from_confusion(preds: jax.Array, y_te: jax.Array, num_classes: int) -> jax.Array:
    """Symmetrised confusion-dissimilarity RDM from CV predictions.

    d(a, b) = 1 − (p(pred=b | true=a) + p(pred=a | true=b)) / 2 for a ≠ b,
    0 on the diagonal. Conditions the classifier confuses often are
    representationally close.
    """
    conf = metrics.confusion_matrix(preds.reshape(-1), y_te.reshape(-1), num_classes).astype(
        jnp.float64
    )
    rates = conf / jnp.maximum(jnp.sum(conf, axis=1, keepdims=True), 1.0)
    sim = 0.5 * (rates + rates.T)
    eye = jnp.eye(num_classes, dtype=bool)
    return jnp.where(eye, 0.0, 1.0 - sim)


def rdm_multiclass(plan: fastcv.CVPlan, y_cond: jax.Array, num_classes: int) -> jax.Array:
    """Confusion RDM from one Algorithm-2 multi-class CV run on the plan."""
    preds = multiclass.batch_predict(plan, y_cond[None, :], num_classes)[0]
    return rdm_from_confusion(preds, y_cond[plan.te_idx], num_classes)


# ---------------------------------------------------------------------------
# Non-cross-validated pattern RDMs (condition means / model-RDM building)
# ---------------------------------------------------------------------------


def condition_means(x: jax.Array, y_cond: jax.Array, num_classes: int) -> jax.Array:
    """(C, P) mean feature pattern per condition."""
    oh = jax.nn.one_hot(y_cond, num_classes, dtype=x.dtype)  # (N, C)
    counts = jnp.maximum(jnp.sum(oh, axis=0), 1.0)
    return (oh.T @ x) / counts[:, None]


def ring_rdm(num_classes: int, dtype=jnp.float64) -> jax.Array:
    """(C, C) circular-distance model RDM: d(a, b) = min(|a−b|, C−|a−b|).

    The standard "ring" candidate structure for ordered condition sets
    (orientations, positions, phases) — used by the demos and benchmarks
    as a model-RDM everybody can construct without data.
    """
    idx = jnp.arange(num_classes)
    d = jnp.abs(idx[:, None] - idx[None, :])
    return jnp.minimum(d, num_classes - d).astype(dtype)


def euclidean_rdm(patterns: jax.Array, impl: str = "auto") -> jax.Array:
    """(C, C) squared-Euclidean RDM over row patterns.

    ``impl``: "auto" (Pallas ``pairdist`` kernel on TPU, plain XLA
    elsewhere), "pallas", or "xla" — the same dispatch convention as the
    serving engine's Gram builds.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        from repro.kernels.pairdist.ops import pairwise_sq_dists

        return pairwise_sq_dists(patterns)
    from repro.kernels.pairdist.ref import pairwise_sq_dists_ref

    return pairwise_sq_dists_ref(patterns)


# ---------------------------------------------------------------------------
# Searchlight sweeps: Q independent RDM problems over the mesh
# ---------------------------------------------------------------------------


def searchlight_rdm(
    xs: jax.Array,
    y_cond: jax.Array,
    folds: Folds,
    lam: float,
    mesh,
    *,
    num_classes: int,
    dissimilarity: str = "accuracy",
    adjust_bias: bool = True,
    mode: str = "auto",
    problem_axes: tuple = ("pod", "data"),
) -> jax.Array:
    """Per-searchlight RDMs: xs (Q, N, P_local) → (Q, C, C).

    Each problem builds its own plan and scores all pairwise contrasts
    locally; problems shard over the mesh's problem axes with zero
    cross-problem traffic (the ``core.distributed`` problem-axis
    decomposition, paper §4.2).
    """
    from repro.core.distributed import sharded_problems

    te_idx, tr_idx = folds.te_idx, folds.tr_idx

    def one_problem(x):
        return rdm_binary(
            x,
            y_cond,
            Folds.with_indices(te_idx, tr_idx),
            num_classes,
            lam,
            dissimilarity=dissimilarity,
            adjust_bias=adjust_bias,
            mode=mode,
        )

    return sharded_problems(one_problem, xs, mesh, problem_axes=problem_axes)


# ---------------------------------------------------------------------------
# Serving support: fresh jitted evaluator for the engine's column path
# ---------------------------------------------------------------------------


def make_eval_pairs(
    dissimilarity: str = "accuracy", adjust_bias: bool = True,
    donate: bool = False, fused: bool = False
):
    """Fresh jitted evaluator ``(plan, cols (N, B)) -> (B,) dissimilarities``.

    Mirrors ``fastcv.make_eval_binary``: each call returns an
    independently-cached jit so the serve engine can count compiles via
    ``fn._cache_size()``; ``donate`` aliases the contrast batch on TPU/GPU,
    ``fused`` routes the fold solves through the Pallas kernels.
    """
    kw = {"donate_argnums": (1,)} if donate else {}
    return jax.jit(
        functools.partial(
            pair_dissimilarities, dissimilarity=dissimilarity,
            adjust_bias=adjust_bias, fused=fused
        ),
        **kw,
    )
