# The paper's primary contribution: analytical cross-validation and
# permutation testing for least-squares models and multi-class LDA.
from repro.core import (  # noqa: F401
    fastcv,
    folds,
    lda,
    metrics,
    multiclass,
    multidim,
    permutation,
    regression,
    shrinkage,
    tuning,
)
