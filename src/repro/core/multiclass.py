"""Multi-class LDA: direct form, optimal scoring, and analytical CV.

Implements the paper's novel extension (§2.8-2.10, Algorithm 2):

Step 1  Multivariate ridge regression of the class-indicator matrix Y on X̃.
        Cross-validated *exactly* via the hat-matrix identities (Eq. 14/15),
        column-wise over classes — shares ``repro.core.fastcv``.
Step 2  Optimal scores from the C×C eigenproblem of M = Ẏ_Trᵀ Y_Tr / N_Tr.
        We solve the *generalised* problem  M θ = α² D_π θ  with
        D_π = Y_Trᵀ Y_Tr / N_Tr (Hastie et al. 1995 constraint
        N⁻¹‖Yθ‖² = 1): whitening by D_π^{-1/2} turns it into a symmetric
        ``eigh`` — M is symmetric by construction (M = Y_Trᵀ X̃_Tr S_Tr
        X̃_Trᵀ Y_Tr / N_Tr), so this is exact, TPU-friendly (no
        non-symmetric ``eig``), and the trivial pair (α² = 1, θ = 1_C)
        is exact and unambiguous to drop.
Scaling W = B Θ D with D = N^{-1/2} diag(α²(1−α²))^{-1/2} (paper §2.9,
including the √N covariance-vs-scatter correction).

Classification is nearest-centroid in discriminant space; the intercept
column of X̃ shifts all scores and centroids equally, so distances (and
hence predictions) are unaffected (paper §2.10).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve, solve_triangular

from repro.core import fastcv
from repro.core.folds import Folds

__all__ = [
    "onehot",
    "MulticlassLDA",
    "fit_multiclass",
    "predict_multiclass",
    "optimal_scoring_fit",
    "standard_cv_multiclass",
    "analytical_cv_multiclass",
    "batch_predict",
    "make_eval_multiclass",
]

_EPS = 1e-10


def onehot(y: jax.Array, num_classes: int, dtype=jnp.float64) -> jax.Array:
    return jax.nn.one_hot(y, num_classes, dtype=dtype)


# ---------------------------------------------------------------------------
# Direct multi-class LDA (the paper's standard-approach comparator, §2.8)
# ---------------------------------------------------------------------------


class MulticlassLDA(NamedTuple):
    w: jax.Array          # (P, C-1) discriminant coordinates, Wᵀ(S_w+λI)W = I
    centroids: jax.Array  # (C, C-1) projected class means


def _scatter_matrices(x: jax.Array, y1h: jax.Array):
    """S_w, S_b and class means from one-hot labels (Eq. in §2.8)."""
    counts = jnp.sum(y1h, axis=0)                       # (C,)
    n = x.shape[0]
    m = (y1h.T @ x) / jnp.maximum(counts, 1.0)[:, None]  # (C, P) class means
    mbar = jnp.sum(counts[:, None] * m, axis=0) / n      # (P,) sample mean
    st = x.T @ x                                         # total raw scatter
    sw = st - (m * counts[:, None]).T @ m                # within-class
    mc = m - mbar[None, :]
    sb = (mc * counts[:, None]).T @ mc                   # between-classes
    return sw, sb, m, counts


def fit_multiclass(x: jax.Array, y1h: jax.Array, lam: float = 0.0) -> MulticlassLDA:
    """Generalised eigenproblem S_b W = (S_w + λI) W Λ via Cholesky whitening."""
    c = y1h.shape[1]
    p = x.shape[1]
    sw, sb, m, _ = _scatter_matrices(x, y1h)
    swr = sw + jnp.asarray(lam, x.dtype) * jnp.eye(p, dtype=x.dtype)
    l = jnp.linalg.cholesky(swr)
    a = solve_triangular(l, sb, lower=True)
    a = solve_triangular(l, a.T, lower=True)             # L⁻¹ S_b L⁻ᵀ
    a = 0.5 * (a + a.T)
    _, vecs = jnp.linalg.eigh(a)                         # ascending
    top = vecs[:, ::-1][:, : c - 1]                      # top C-1, descending
    w = solve_triangular(l.T, top, lower=False)          # W = L⁻ᵀ U
    centroids = m @ w
    return MulticlassLDA(w, centroids)


def predict_multiclass(x: jax.Array, model: MulticlassLDA) -> jax.Array:
    """Nearest-centroid classification in discriminant space."""
    scores = x @ model.w                                 # (N, C-1)
    d2 = jnp.sum((scores[:, None, :] - model.centroids[None]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=-1)


# ---------------------------------------------------------------------------
# Optimal scoring (full-data fit; Hastie et al. 1995, paper §2.9)
# ---------------------------------------------------------------------------


def _os_step2(m: jax.Array, d_pi: jax.Array, n_tr):
    """Solve M θ = α² D_π θ; drop the trivial pair; return Θ·D (C, C-1).

    m:    (C, C) Ẏ_Trᵀ Y_Tr / N_Tr (symmetric up to float noise)
    d_pi: (C,)   class proportions of the training fold
    """
    c = m.shape[0]
    dm = 1.0 / jnp.sqrt(jnp.maximum(d_pi, _EPS))
    ms = dm[:, None] * m * dm[None, :]
    ms = 0.5 * (ms + ms.T)
    evals, evecs = jnp.linalg.eigh(ms)                   # ascending; trivial α²=1 last
    keep = jnp.arange(c - 2, -1, -1)                     # descending, drop last
    a2 = jnp.clip(evals[keep], _EPS, 1.0 - _EPS)
    theta = dm[:, None] * evecs[:, keep]                 # (C, C-1), θᵀD_πθ = I
    d = 1.0 / (jnp.sqrt(jnp.asarray(n_tr, m.dtype)) * jnp.sqrt(a2 * (1.0 - a2)))
    return theta * d[None, :], a2


def optimal_scoring_fit(x: jax.Array, y1h: jax.Array, lam: float = 0.0):
    """Full-data optimal scoring. Returns (w_os, scores_fn_weights):
    w_os (P, C-1) equals the direct-LDA W up to per-column sign."""
    n, p = x.shape
    xa = jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)
    i0 = jnp.eye(p + 1, dtype=x.dtype).at[p, p].set(0.0)
    a = xa.T @ xa + jnp.asarray(lam, x.dtype) * i0
    b = cho_solve(cho_factor(a), xa.T @ y1h)             # (P+1, C)
    y_fit = xa @ b                                       # Ŷ = HY
    m = y_fit.T @ y1h / n
    d_pi = jnp.sum(y1h, axis=0) / n
    theta_d, a2 = _os_step2(m, d_pi, n)
    w_os = b[:-1] @ theta_d                              # B Θ D  (bias row dropped)
    return w_os, a2


# ---------------------------------------------------------------------------
# Standard approach: retrain direct LDA on every fold (O(KNP² + KP³))
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("num_classes",))
def _standard_cv_multiclass_jit(x, y, te_idx, tr_idx, lam, num_classes):
    y1h = onehot(y, num_classes, dtype=x.dtype)

    def one_fold(idx_pair):
        te, tr = idx_pair
        model = fit_multiclass(x[tr], y1h[tr], lam)
        return predict_multiclass(x[te], model)

    preds = jax.lax.map(one_fold, (te_idx, tr_idx))
    return preds, y[te_idx]


def standard_cv_multiclass(x: jax.Array, y: jax.Array, folds: Folds,
                           num_classes: int, lam: float = 0.0):
    """Retrain-per-fold direct multi-class LDA. Returns (pred (K,m), y_te)."""
    return _standard_cv_multiclass_jit(x, y, folds.te_idx, folds.tr_idx,
                                       jnp.asarray(lam, x.dtype), num_classes)


# ---------------------------------------------------------------------------
# Analytical approach (Algorithm 2)
# ---------------------------------------------------------------------------


def _fold_predict(y_dot_te, y_dot_tr, y1h_tr, dtype):
    """Step 2 + nearest centroid for one fold (vmapped over folds/perms).

    y_dot_te: (m, C) CV regression fits on the test fold
    y_dot_tr: (N-m, C) CV regression fits on the training fold
    y1h_tr:   (N-m, C) one-hot training labels
    """
    n_tr = y1h_tr.shape[0]
    counts = jnp.sum(y1h_tr, axis=0)
    m_mat = y_dot_tr.T @ y1h_tr / n_tr                   # Ẏ_Trᵀ Y_Tr / N_Tr
    theta_d, _ = _os_step2(m_mat, counts / n_tr, n_tr)
    scores_te = y_dot_te @ theta_d                       # (m, C-1)
    scores_tr = y_dot_tr @ theta_d                       # (N-m, C-1)
    centroids = (y1h_tr.T @ scores_tr) / jnp.maximum(counts, 1.0)[:, None]
    d2 = jnp.sum((scores_te[:, None, :] - centroids[None]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=-1)


def analytical_cv_multiclass(x: jax.Array, y: jax.Array, folds: Folds,
                             num_classes: int, lam: float = 0.0,
                             mode: str = "auto",
                             plan: fastcv.CVPlan | None = None):
    """Algorithm 2: exact CV for multi-class LDA from one full-data fit.

    Returns (pred (K, m), y_te (K, m)). Serving equivalent (bit-identical,
    plan-cached): ``Workload(kind="cv", estimator="multiclass", ...)``
    via ``repro.serve``.
    """
    if plan is None:
        plan = fastcv.prepare(x, folds, lam, mode=mode, with_train_block=True)
    y1h = onehot(y, num_classes, dtype=plan.h.dtype)
    y_dot_te, y_dot_tr = fastcv.cv_errors(plan, y1h)     # (K, m, C), (K, N-m, C)
    y1h_tr = y1h[plan.tr_idx]                            # (K, N-m, C)
    preds = jax.vmap(_fold_predict, in_axes=(0, 0, 0, None))(
        y_dot_te, y_dot_tr, y1h_tr, plan.h.dtype
    )
    return preds, y[plan.te_idx]


def batch_predict(plan: fastcv.CVPlan, y_batch: jax.Array,
                  num_classes: int, *, fused: bool = False) -> jax.Array:
    """Algorithm 2 for a batch of label vectors sharing one plan.

    ``y_batch``: int (B, N) — e.g. permutations or many client requests.
    Returns int predictions (B, K, m); step 1 reuses the plan's cached
    factorisations, step 2's C×C eigh is vmapped over (B × K).

    ``fused=True`` routes step 1 through the Pallas solve kernel — and,
    rather than vmapping a kernel launch per label vector, flattens the
    whole batch into one (N, B·C) column block so all B·C indicator
    columns share a single launch (multiclass plans carry train blocks,
    so this is the solve-stage fusion of ``fastcv.cv_errors_fused``).
    """
    dtype = plan.h.dtype
    if fused:
        bsz, n = y_batch.shape
        y1h = onehot(y_batch, num_classes, dtype=dtype)       # (B, N, C)
        cols = jnp.transpose(y1h, (1, 0, 2)).reshape(n, bsz * num_classes)
        y_dot_te, y_dot_tr = fastcv.cv_errors(plan, cols, fused=True)
        k, m = y_dot_te.shape[:2]
        y_dot_te = y_dot_te.reshape(k, m, bsz, num_classes)
        y_dot_tr = y_dot_tr.reshape(k, y_dot_tr.shape[1], bsz, num_classes)
        y1h_tr = y1h[:, plan.tr_idx]                          # (B, K, N-m, C)
        per_b = jax.vmap(_fold_predict, in_axes=(0, 0, 0, None))
        return jax.vmap(per_b, in_axes=(2, 2, 0, None))(
            y_dot_te, y_dot_tr, y1h_tr, dtype)

    def one(yb):
        y1h = onehot(yb, num_classes, dtype=dtype)
        y_dot_te, y_dot_tr = fastcv.cv_errors(plan, y1h)
        y1h_tr = y1h[plan.tr_idx]
        return jax.vmap(_fold_predict, in_axes=(0, 0, 0, None))(
            y_dot_te, y_dot_tr, y1h_tr, dtype)

    return jax.vmap(one)(y_batch)


def make_eval_multiclass(num_classes: int, donate: bool = False,
                         fused: bool = False):
    """Fresh jitted evaluator ``(plan, y (B, N) int) -> preds (B, K, m)``
    for the serve engine; ``donate`` aliases the label batch on TPU/GPU,
    ``fused`` routes the fold solves through the Pallas kernels."""
    kw = {"donate_argnums": (1,)} if donate else {}
    return jax.jit(
        lambda plan, y: batch_predict(plan, y, num_classes, fused=fused),
        **kw)
