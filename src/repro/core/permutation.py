"""Permutation testing with the analytical approach (paper §2.7, Alg. 1 & 2).

The hat matrix H depends on features only, so it is computed ONCE; each
permutation σ only needs ŷ = H yσ and the per-fold solves against the
*pre-factorised* (I − H_Te). Beyond the paper: the Cholesky factors are
shared across permutations (O(m³) → O(m²) per permutation per fold) and
permutations are processed in static-size chunks via ``lax.map`` so T can
be large without exhausting memory; chunks are the unit the distributed
engine shards over the ("pod", "data") mesh axes.

Standard-approach baselines (retrain K models per permutation) are provided
for the benchmark comparison (Fig. 3 right panels, Fig. 4).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fastcv, lda, metrics, multiclass
from repro.core.folds import Folds

__all__ = [
    "PermutationResult",
    "permutation_indices",
    "analytical_permutation_binary",
    "standard_permutation_binary",
    "analytical_permutation_multiclass",
    "standard_permutation_multiclass",
    "p_value",
]


class PermutationResult(NamedTuple):
    observed: jax.Array    # () metric on unpermuted labels
    null: jax.Array        # (T,) null distribution
    p: jax.Array           # () permutation p-value


def p_value(observed: jax.Array, null: jax.Array) -> jax.Array:
    """(1 + #{null >= obs}) / (1 + T) — standard permutation p-value."""
    t = null.shape[0]
    return (1.0 + jnp.sum(null >= observed)) / (1.0 + t)


@partial(jax.jit, static_argnames=("n", "n_perm"))
def permutation_indices(key: jax.Array, n: int, n_perm: int) -> jax.Array:
    """(T, N) independent label permutations.

    Jitted (the serve engine regenerates these per request, so dispatch
    overhead matters) and *prefix-stable*: permutation t depends only on
    (key, t) via ``fold_in``, so requesting a larger T — e.g. the engine
    rounding T up to a shape bucket — yields the same leading rows as a
    direct call. That keeps engine null distributions identical to the
    library's for any shared key.
    """
    keys = jax.vmap(lambda t: jax.random.fold_in(key, t))(jnp.arange(n_perm))
    return jax.vmap(lambda k: jax.random.permutation(k, n))(keys)


def _fold_metric_binary(dvals, y_te, metric: str):
    """Per-fold metric averaged over folds. dvals/y_te: (K, m[, B])."""
    if metric == "accuracy":
        pred = jnp.where(dvals >= 0, 1.0, -1.0)
        return jnp.mean(pred == jnp.sign(y_te), axis=(0, 1))
    if metric == "auc":
        if dvals.ndim == 2:
            return jnp.mean(jax.vmap(metrics.auc)(dvals, y_te))
        per_fold = jax.vmap(jax.vmap(metrics.auc, in_axes=-1), in_axes=0)
        return jnp.mean(per_fold(dvals, y_te), axis=0)
    raise ValueError(f"unknown metric {metric!r}")


def analytical_permutation_binary(
    x: jax.Array, y: jax.Array, folds: Folds, lam: float, n_perm: int,
    key: jax.Array, metric: str = "accuracy", mode: str = "auto",
    chunk: int = 256, adjust_bias: bool = True,
) -> PermutationResult:
    """Algorithm 1: H once, then T permutations of cheap fold-solves."""
    plan = fastcv.prepare(x, folds, lam, mode=mode, with_train_block=adjust_bias)
    y = y.astype(plan.h.dtype)

    dv_obs = fastcv.binary_dvals(plan, y, adjust_bias=adjust_bias)
    observed = _fold_metric_binary(dv_obs, y[plan.te_idx], metric)

    perms = permutation_indices(key, y.shape[0], n_perm)      # (T, N)
    chunk = min(chunk, n_perm)
    n_chunks = -(-n_perm // chunk)
    pad = n_chunks * chunk - n_perm
    perms = jnp.pad(perms, ((0, pad), (0, 0)), mode="edge")
    perms = perms.reshape(n_chunks, chunk, -1)

    def one_chunk(perm_chunk):
        yp = y[perm_chunk].T                                  # (N, chunk)
        dv = fastcv.binary_dvals(plan, yp, adjust_bias=adjust_bias)
        y_te = yp[plan.te_idx]                                # (K, m, chunk)
        return _fold_metric_binary(dv, y_te, metric)          # (chunk,)

    null = jax.lax.map(one_chunk, perms).reshape(-1)[:n_perm]
    return PermutationResult(observed, null, p_value(observed, null))


def standard_permutation_binary(
    x: jax.Array, y: jax.Array, folds: Folds, lam: float, n_perm: int,
    key: jax.Array, metric: str = "accuracy",
) -> PermutationResult:
    """Paper's standard approach: retrain K classifiers per permutation."""
    y = y.astype(x.dtype)
    dv_obs, y_te = lda.standard_cv_binary(x, y, folds, lam=lam)
    observed = _fold_metric_binary(dv_obs, y_te, metric)
    perms = permutation_indices(key, y.shape[0], n_perm)

    @jax.jit
    def one_perm(perm):
        yp = y[perm]
        dv, yte = lda._standard_cv_binary_jit(
            x, yp, folds.te_idx, folds.tr_idx, jnp.asarray(lam, x.dtype), "lda")
        return _fold_metric_binary(dv, yte, metric)

    null = jax.lax.map(one_perm, perms)
    return PermutationResult(observed, null, p_value(observed, null))


def analytical_permutation_multiclass(
    x: jax.Array, y: jax.Array, folds: Folds, num_classes: int, lam: float,
    n_perm: int, key: jax.Array, mode: str = "auto", chunk: int = 64,
) -> PermutationResult:
    """Algorithm 2 under permutations: step 1 batched through the shared
    plan; step 2 (C×C eigh) vmapped over (folds × permutations)."""
    plan = fastcv.prepare(x, folds, lam, mode=mode, with_train_block=True)
    dtype = plan.h.dtype

    pred_obs, y_te_obs = multiclass.analytical_cv_multiclass(
        x, y, folds, num_classes, lam, mode=mode, plan=plan)
    observed = metrics.multiclass_accuracy(pred_obs, y_te_obs)

    perms = permutation_indices(key, y.shape[0], n_perm)
    chunk = min(chunk, n_perm)
    n_chunks = -(-n_perm // chunk)
    pad = n_chunks * chunk - n_perm
    perms = jnp.pad(perms, ((0, pad), (0, 0)), mode="edge")
    perms = perms.reshape(n_chunks, chunk, -1)

    def one_perm(yp):
        y1h = multiclass.onehot(yp, num_classes, dtype=dtype)
        y_dot_te, y_dot_tr = fastcv.cv_errors(plan, y1h)
        y1h_tr = y1h[plan.tr_idx]
        preds = jax.vmap(multiclass._fold_predict, in_axes=(0, 0, 0, None))(
            y_dot_te, y_dot_tr, y1h_tr, dtype)
        return metrics.multiclass_accuracy(preds, yp[plan.te_idx])

    def one_chunk(perm_chunk):
        return jax.vmap(lambda p: one_perm(y[p]))(perm_chunk)

    null = jax.lax.map(one_chunk, perms).reshape(-1)[:n_perm]
    return PermutationResult(observed, null, p_value(observed, null))


def standard_permutation_multiclass(
    x: jax.Array, y: jax.Array, folds: Folds, num_classes: int, lam: float,
    n_perm: int, key: jax.Array,
) -> PermutationResult:
    """Standard approach: retrain direct multi-class LDA K times per σ."""
    pred_obs, y_te_obs = multiclass.standard_cv_multiclass(
        x, y, folds, num_classes, lam)
    observed = metrics.multiclass_accuracy(pred_obs, y_te_obs)
    perms = permutation_indices(key, y.shape[0], n_perm)

    @jax.jit
    def one_perm(perm):
        yp = y[perm]
        pred, yte = multiclass._standard_cv_multiclass_jit(
            x, yp, folds.te_idx, folds.tr_idx, jnp.asarray(lam, x.dtype),
            num_classes)
        return metrics.multiclass_accuracy(pred, yte)

    null = jax.lax.map(one_perm, perms)
    return PermutationResult(observed, null, p_value(observed, null))
