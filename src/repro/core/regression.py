"""Linear / ridge regression: standard and analytical cross-validation.

The paper (§2.4, §4.3): "If the vector of class labels is replaced by a
vector of continuous responses, then all equations and results apply
equally." The analytical machinery is shared with binary LDA via
``repro.core.fastcv``; here we expose a regression-flavoured API plus the
standard retrain-per-fold baseline.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core import fastcv
from repro.core.folds import Folds

__all__ = ["fit_ridge", "predict", "standard_cv", "analytical_cv"]


def fit_ridge(x: jax.Array, y: jax.Array, lam: float = 0.0):
    """β̂ = (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ y with unpenalised intercept (Eq. 17).

    For P >= N the dual form is used: with centered data,
    w = X_cᵀ (G_c + λI)⁻¹ y_c and b = ȳ − x̄ᵀw (min-norm ridge solution).
    Returns (w (P, ...), b (...)). ``y`` may be (N,) or (N, Q).
    """
    n, p = x.shape
    y = y.astype(x.dtype)
    if p < n:
        xa = jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)
        i0 = jnp.eye(p + 1, dtype=x.dtype).at[p, p].set(0.0)
        a = xa.T @ xa + jnp.asarray(lam, x.dtype) * i0
        beta = cho_solve(cho_factor(a), xa.T @ y)
        return beta[:-1], beta[-1]
    if lam <= 0:
        raise ValueError("P >= N requires lam > 0")
    mu = jnp.mean(x, axis=0, keepdims=True)
    xc = x - mu
    yc = y - jnp.mean(y, axis=0, keepdims=True) if y.ndim > 1 else y - jnp.mean(y)
    g = xc @ xc.T + jnp.asarray(lam, x.dtype) * jnp.eye(n, dtype=x.dtype)
    alpha = cho_solve(cho_factor(g), yc)
    w = xc.T @ alpha
    b = jnp.mean(y, axis=0) - jnp.squeeze(mu) @ w
    return w, b


def predict(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return x @ w + b


@partial(jax.jit, static_argnames=("lam",))
def _standard_cv_jit(x, y, te_idx, tr_idx, lam):
    y = y.astype(x.dtype)

    def one_fold(idx_pair):
        te, tr = idx_pair
        w, b = fit_ridge(x[tr], y[tr], lam)
        return x[te] @ w + b

    preds = jax.lax.map(one_fold, (te_idx, tr_idx))
    return preds, y[te_idx]


def standard_cv(x: jax.Array, y: jax.Array, folds: Folds, lam: float = 0.0):
    """Retrain-per-fold ridge regression CV (standard approach baseline)."""
    return _standard_cv_jit(x, y, folds.te_idx, folds.tr_idx, float(lam))


def analytical_cv(x: jax.Array, y: jax.Array, folds: Folds, lam: float = 0.0,
                  mode: str = "auto"):
    """Analytical ridge-regression CV (Eq. 14): exact fold predictions from
    a single full-data hat matrix. Returns (preds_te, y_te), both (K, m).

    Serving equivalent (bit-identical, plan-cached):
    ``Workload(kind="cv", estimator="ridge", ...)`` via ``repro.serve``;
    multi-target responses register as ``estimator="ridge_multi"``."""
    plan = fastcv.prepare(x, folds, lam, mode=mode, with_train_block=False)
    preds, _ = fastcv.cv_errors(plan, y.astype(x.dtype))
    return preds, y[folds.te_idx]
