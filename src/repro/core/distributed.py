"""Distributed analytical CV: shard_map building blocks (DESIGN.md §5).

The paper's workload decomposes onto the mesh as:

  * feature axis ("model"): the O(N²P) Gram reduction — each shard computes
    a partial X_c X_cᵀ over its feature slice, one ``psum`` combines them.
    This is the only cross-"model" collective in the whole CV pipeline.
  * permutation axis ("data"): Algorithm 1/2's T permutations are
    embarrassingly parallel given H — each shard evaluates its slice
    against the replicated (N×N) hat matrix and fold factors.
  * problem axis ("pod"): searchlights / time points / RSA pairs — fully
    independent CV problems, zero cross-pod traffic after data layout.

N is bounded by the paper's own premise (P ≫ N, N ≤ ~10⁴), so H and the
fold factors replicate comfortably; everything that scales (features,
permutations, problems) is sharded.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import fastcv
from repro.core.compat import shard_map
from repro.core.folds import Folds

__all__ = [
    "distributed_gram",
    "distributed_hat_matrix",
    "distributed_permutation_binary",
    "sharded_null_from_plan",
    "sharded_problems",
    "searchlight_cv",
]


def distributed_gram(x: jax.Array, mesh: Mesh, *, center: bool = True,
                     feature_axis: str = "model") -> jax.Array:
    """G_c = X_c X_cᵀ with X sharded (replicated_N, features/"model").

    Local partial Gram per feature shard + one psum over the feature axis.
    """
    if center:
        x = x - jnp.mean(x, axis=0, keepdims=True)

    def local_gram(x_shard):
        g = x_shard @ x_shard.T
        return jax.lax.psum(g, feature_axis)

    fn = shard_map(
        local_gram, mesh=mesh,
        in_specs=P(None, feature_axis),
        out_specs=P(None, None))
    return fn(x)


def distributed_hat_matrix(x: jax.Array, lam: float, mesh: Mesh,
                           feature_axis: str = "model") -> jax.Array:
    """Dual hat matrix from the feature-sharded Gram (λ > 0)."""
    g = distributed_gram(x, mesh, center=True, feature_axis=feature_axis)
    return fastcv.hat_matrix_dual(x, lam, gram=g)


def distributed_permutation_binary(
    x: jax.Array, y: jax.Array, folds: Folds, lam: float, n_perm: int,
    key: jax.Array, mesh: Mesh, *, metric: str = "accuracy",
    perm_axes: tuple = ("data",), feature_axis: str = "model",
    adjust_bias: bool = True,
):
    """Algorithm 1 at scale: Gram sharded over features, permutations over
    the DP axes. Returns PermutationResult-compatible (observed, null, p).

    n_perm must divide by the product of perm-axis sizes (pad up if not).
    """
    from repro.core import permutation as perm_lib

    h = distributed_hat_matrix(x, lam, mesh, feature_axis)
    plan = _plan_from_h(h, folds, adjust_bias)
    y = y.astype(h.dtype)

    dv_obs = fastcv.binary_dvals(plan, y, adjust_bias=adjust_bias)
    observed = perm_lib._fold_metric_binary(dv_obs, y[plan.te_idx], metric)

    n_shards = 1
    for a in perm_axes:
        n_shards *= mesh.shape[a]
    t_pad = -(-n_perm // n_shards) * n_shards
    perms = perm_lib.permutation_indices(key, y.shape[0], t_pad)  # (T, N)

    null = sharded_null_from_plan(plan, y, perms, mesh, metric=metric,
                                  perm_axes=perm_axes,
                                  adjust_bias=adjust_bias)[:n_perm]
    return perm_lib.PermutationResult(observed, null,
                                      perm_lib.p_value(observed, null))


def sharded_null_from_plan(plan: fastcv.CVPlan, y: jax.Array,
                           perms: jax.Array, mesh: Mesh, *,
                           metric: str = "accuracy",
                           perm_axes: tuple = ("data",),
                           adjust_bias: bool = True) -> jax.Array:
    """Null-distribution metrics for ``perms`` (T, N), T sharded over
    ``perm_axes``; the plan (hat matrix + fold factors) is replicated.

    This is the serve engine's distributed permutation path: the plan is
    built once (possibly via :func:`distributed_gram`) and every batch of
    permutation requests fans out over the mesh's data-parallel axes.
    """
    from repro.core import permutation as perm_lib

    def shard_fn(perm_shard):
        yp = y[perm_shard].T                                   # (N, T_local)
        dv = fastcv.binary_dvals(plan, yp, adjust_bias=adjust_bias)
        y_te = yp[plan.te_idx]
        return perm_lib._fold_metric_binary(dv, y_te, metric)  # (T_local,)

    fn = shard_map(shard_fn, mesh=mesh, in_specs=P(perm_axes),
                   out_specs=P(perm_axes))
    return fn(perms)


def _plan_from_h(h, folds: Folds, with_train_block: bool) -> fastcv.CVPlan:
    h_te = h[folds.te_idx[:, :, None], folds.te_idx[:, None, :]]
    eye = jnp.eye(h_te.shape[-1], dtype=h.dtype)
    from jax.scipy.linalg import cho_factor
    chol = jax.vmap(lambda a: cho_factor(a, lower=True)[0])(eye[None] - h_te)
    h_tr_te = (h[folds.tr_idx[:, :, None], folds.te_idx[:, None, :]]
               if with_train_block else None)
    return fastcv.CVPlan(h, folds.te_idx, folds.tr_idx, chol, h_tr_te)


def sharded_problems(fn, xs: jax.Array, mesh: Mesh, *,
                     problem_axes: tuple = ("pod", "data")) -> jax.Array:
    """Map ``fn`` over the problem axis of ``xs`` (Q, ...), Q sharded over
    the mesh's problem axes (those present in the mesh are used).

    This is the generic problem-axis decomposition (paper §4.2:
    searchlights, time points, RSA sweeps): every problem is a fully
    independent CV computation, so the only collective is the final
    all-gather of the P(axes)-sharded output. ``fn`` takes one problem's
    leading-axis slice and may return any array (or pytree of arrays)
    whose leading output dimension is the problem dimension after vmap.
    """
    axes = tuple(a for a in problem_axes if a in mesh.axis_names)

    def shard_fn(xs_shard):
        return jax.vmap(fn)(xs_shard)

    mapped = shard_map(shard_fn, mesh=mesh, in_specs=P(axes),
                       out_specs=P(axes))
    return mapped(xs)


def searchlight_cv(xs: jax.Array, y: jax.Array, folds: Folds, lam: float,
                   mesh: Mesh, *, problem_axes: tuple = ("pod", "data"),
                   adjust_bias: bool = True):
    """Many independent CV problems (paper §4.2: searchlight / time points /
    RSA pairs): xs (Q, N, P_local_features) sharded over the problem axes.

    Each problem runs the full analytical CV locally — zero cross-problem
    communication. Returns per-problem accuracy (Q,).
    """
    te_idx, tr_idx = folds.te_idx, folds.tr_idx

    def one_problem(x):
        dv, y_te = fastcv.binary_cv(x, y, Folds.with_indices(te_idx, tr_idx),
                                    lam=lam, adjust_bias=adjust_bias)
        pred = jnp.where(dv >= 0, 1.0, -1.0)
        return jnp.mean(pred == jnp.sign(y_te))

    return sharded_problems(one_problem, xs, mesh, problem_axes=problem_axes)
