"""Fold generation for k-fold / leave-one-out cross-validation.

Folds are represented as *dense index arrays* with static shapes so that the
whole cross-validation (all folds at once) can be expressed as a single
``vmap``/batched computation and lowered to one XLA program:

  te_idx : (K, m)      indices of the test samples of each fold, m = N // K
  tr_idx : (K, N - m)  indices of the training samples of each fold

If ``N % K != 0`` the trailing ``N % K`` samples (after shuffling) are
assigned round-robin to the *training* side of every fold, i.e. every sample
is still used for training but only ``K * (N // K)`` samples are ever tested.
This keeps shapes static (a hard requirement for jit/vmap/pjit) and matches
the paper's "equally sized folds" setup (§2.1).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Folds", "kfold", "loo", "stratified_kfold", "repeated_kfold"]


@dataclasses.dataclass(frozen=True)
class Folds:
    """Static-shape fold index sets.

    Attributes:
      te_idx: int32 (K, m) test-sample indices per fold.
      tr_idx: int32 (K, N - m) training-sample indices per fold.
      n: total number of samples N.
    """

    te_idx: jax.Array
    tr_idx: jax.Array
    n: int

    @property
    def k(self) -> int:
        return self.te_idx.shape[0]

    @property
    def test_size(self) -> int:
        return self.te_idx.shape[1]

    @property
    def train_size(self) -> int:
        return self.tr_idx.shape[1]

    @classmethod
    def with_indices(cls, te_idx, tr_idx, n: Optional[int] = None) -> "Folds":
        """Folds from raw (possibly traced) index arrays.

        Used wherever fold indices flow through jit/vmap/shard_map as traced
        values (grid CV, searchlights, the serve batcher): shapes stay
        static, so ``k``/``test_size``/``train_size`` remain Python ints.
        ``n`` defaults to ``test_size + train_size``, which equals N whenever
        K divides N (leftover samples are train-only and uncounted).
        """
        if n is None:
            n = int(te_idx.shape[1] + tr_idx.shape[1])
        return cls(te_idx, tr_idx, n)

    def tree_flatten(self):  # pragma: no cover - convenience
        return (self.te_idx, self.tr_idx), self.n


def _complement(te_idx: np.ndarray, n: int) -> np.ndarray:
    """Training indices = complement of each fold's test indices (+ leftovers)."""
    k = te_idx.shape[0]
    tr = np.empty((k, n - te_idx.shape[1]), dtype=np.int32)
    full = np.arange(n, dtype=np.int32)
    for i in range(k):
        mask = np.ones(n, dtype=bool)
        mask[te_idx[i]] = False
        tr[i] = full[mask]
    return tr


def kfold(n: int, k: int, seed: int = 0, shuffle: bool = True) -> Folds:
    """Plain k-fold partition with equal fold sizes m = n // k."""
    if not 2 <= k <= n:
        raise ValueError(f"need 2 <= k <= n, got k={k}, n={n}")
    m = n // k
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n) if shuffle else np.arange(n)
    te = perm[: k * m].reshape(k, m).astype(np.int32)
    tr = _complement(te, n)
    return Folds(jnp.asarray(te), jnp.asarray(tr), n)


def loo(n: int) -> Folds:
    """Leave-one-out: K = N folds of size 1."""
    te = np.arange(n, dtype=np.int32).reshape(n, 1)
    tr = _complement(te, n)
    return Folds(jnp.asarray(te), jnp.asarray(tr), n)


def stratified_kfold(labels, k: int, seed: int = 0) -> Folds:
    """Stratified k-fold: class proportions approximately preserved per fold.

    Samples of each class are shuffled and dealt round-robin across folds;
    the concatenated per-fold lists are trimmed to the minimum fold size so
    shapes stay static.
    """
    y = np.asarray(labels)
    n = y.shape[0]
    rng = np.random.default_rng(seed)
    buckets: list[list[int]] = [[] for _ in range(k)]
    for c in np.unique(y):
        idx = np.flatnonzero(y == c)
        rng.shuffle(idx)
        for j, sample in enumerate(idx):
            buckets[j % k].append(int(sample))
    m = min(len(b) for b in buckets)
    te = np.stack([rng.permutation(np.asarray(b, dtype=np.int32))[:m] for b in buckets])
    tr = _complement(te, n)
    return Folds(jnp.asarray(te), jnp.asarray(tr), n)


def repeated_kfold(n: int, k: int, repeats: int, seed: int = 0) -> list[Folds]:
    """Repeated k-fold (paper §2.1: average across repeats)."""
    return [kfold(n, k, seed=seed + r) for r in range(repeats)]
