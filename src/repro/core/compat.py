"""Version compatibility shims for the jax API surface we depend on.

``jax.shard_map`` only became a top-level export in newer jax releases;
older installed versions (e.g. 0.4.x) ship it as
``jax.experimental.shard_map.shard_map``. Everything in this repo that
shards (distributed CV, the serve engine's distributed plan builds) goes
through :func:`shard_map` below so a single import works everywhere.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - exercised on older jax installs
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]
