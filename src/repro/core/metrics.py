"""Classification / regression performance metrics (jit-friendly).

All metrics consume *decision values* (the paper's ``dvals``) or discriminant
scores and return scalars; everything is expressible inside jit/vmap so the
permutation engine can evaluate thousands of null-distribution entries in a
single XLA program.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "binary_accuracy",
    "auc",
    "multiclass_accuracy",
    "confusion_matrix",
    "mse",
    "r2",
]


def binary_accuracy(dvals: jax.Array, y: jax.Array) -> jax.Array:
    """Accuracy of sign(dval) against labels coded ±1 (paper §2.2)."""
    pred = jnp.where(dvals >= 0, 1.0, -1.0)
    return jnp.mean(pred == jnp.sign(y).astype(pred.dtype))


def auc(dvals: jax.Array, y: jax.Array) -> jax.Array:
    """Area under the ROC curve via the rank-sum (Mann-Whitney U) statistic.

    Ties in ``dvals`` are handled with mid-ranks. Labels are ±1.
    Bias-term independent, as noted in paper §2.5.
    """
    dvals = dvals.reshape(-1)
    y = y.reshape(-1)
    n = dvals.shape[0]
    order = jnp.argsort(dvals)
    sorted_d = dvals[order]
    ranks_sorted = jnp.arange(1, n + 1, dtype=dvals.dtype)
    # mid-ranks for ties: average rank within groups of equal dvals
    # group id = number of strictly-smaller elements
    first_ge = jnp.searchsorted(sorted_d, sorted_d, side="left")
    last_ge = jnp.searchsorted(sorted_d, sorted_d, side="right")
    mid = (first_ge + 1 + last_ge).astype(dvals.dtype) / 2.0
    ranks = jnp.zeros(n, dvals.dtype).at[order].set(mid + 0 * ranks_sorted)
    pos = y > 0
    n_pos = jnp.sum(pos)
    n_neg = n - n_pos
    rank_sum_pos = jnp.sum(jnp.where(pos, ranks, 0.0))
    u = rank_sum_pos - n_pos * (n_pos + 1) / 2.0
    denom = jnp.maximum(n_pos * n_neg, 1).astype(dvals.dtype)
    return u / denom


def multiclass_accuracy(pred_labels: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean((pred_labels == y).astype(jnp.float32))


def confusion_matrix(pred_labels: jax.Array, y: jax.Array, num_classes: int) -> jax.Array:
    """(C, C) matrix: rows = true class, cols = predicted class."""
    idx = y * num_classes + pred_labels
    counts = jnp.bincount(idx, length=num_classes * num_classes)
    return counts.reshape(num_classes, num_classes)


def mse(y_pred: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean((y_pred - y) ** 2)


def r2(y_pred: jax.Array, y: jax.Array) -> jax.Array:
    ss_res = jnp.sum((y - y_pred) ** 2)
    ss_tot = jnp.sum((y - jnp.mean(y)) ** 2)
    return 1.0 - ss_res / jnp.maximum(ss_tot, jnp.finfo(y.dtype).tiny)
