"""Multi-dimensional analyses (paper §4.2): CV grids, fold weights (Eq. 12),
time-generalization.

* :func:`cv_grid` — a classifier validated at every point of a feature
  grid (time points, frequencies, searchlights): vmapped analytical CV,
  one XLA program for the whole grid. The distributed variant shards the
  grid axis over ("pod", "data") — see repro.core.distributed.searchlight_cv.

* :func:`fold_weights` — the paper derives the updated weights β̇ (Eq. 12)
  but never materialises them ("does not need to be calculated
  explicitly"). For *time-generalization* — train at time t₁, test at
  t₂ ≠ t₁ — the test features differ from the training features, so the
  decision values ẏ_Te = X̃[t₂] β̇[t₁] genuinely need β̇. We operationalise
  Eq. 12 in the dual form: with centered training features,

      w_k = X_cᵀ α_k,   α_k = (G_c + λI)⁻¹ (y_c − 1_{Te_k} ⊙ corr_k)

  equivalently (implemented): β̇ via  ẏ_Tr fits — we recover (w_k, b_k)
  by solving the dual ridge on the training fold's *exact* CV fits,
  which the plan already provides — O(N²) per fold, never P×P.

* :func:`time_generalization` — the full (t_train × t_test) accuracy
  matrix, diagonal = ordinary CV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fastcv
from repro.core.folds import Folds

__all__ = ["cv_grid", "fold_weights", "time_generalization"]


def cv_grid(xs: jax.Array, y: jax.Array, folds: Folds, lam: float,
            adjust_bias: bool = True):
    """Analytical binary CV at every grid point.

    xs: (Q, N, P) — Q independent feature sets sharing labels and folds.
    Returns accuracies (Q,). Serving equivalent:
    ``Workload(kind="grid", xs=xs, ...)`` via ``repro.serve``.
    """
    y = y.astype(xs.dtype)
    te_idx, tr_idx = folds.te_idx, folds.tr_idx

    def one(x):
        dv, y_te = fastcv.binary_cv(
            x, y, Folds.with_indices(te_idx, tr_idx), lam=lam,
            adjust_bias=adjust_bias)
        pred = jnp.where(dv >= 0, 1.0, -1.0)
        return jnp.mean(pred == jnp.sign(y_te))

    return jax.lax.map(one, xs)


def fold_weights(x: jax.Array, y: jax.Array, folds: Folds, lam: float):
    """Exact per-fold ridge weights (w_k (K, P), b_k (K,)) in dual form.

    Never forms a P×P matrix: per fold, solve the (N_tr × N_tr) dual on
    the training rows — O(K·N³ + K·N²P) total, the Eq.-12 path made
    explicit for cross-feature-set evaluation. Verified against
    retrained primal ridge in tests.
    """
    y = y.astype(x.dtype)
    tr_idx = folds.tr_idx
    n_tr = tr_idx.shape[1]

    def one_fold(tr):
        x_tr = x[tr]
        y_tr = y[tr]
        mu = jnp.mean(x_tr, axis=0, keepdims=True)
        xc = x_tr - mu
        yc = y_tr - jnp.mean(y_tr)
        g = xc @ xc.T + jnp.asarray(lam, x.dtype) * jnp.eye(n_tr, dtype=x.dtype)
        alpha = jnp.linalg.solve(g, yc)
        w = xc.T @ alpha
        b = jnp.mean(y_tr) - jnp.squeeze(mu) @ w
        return w, b

    return jax.lax.map(one_fold, tr_idx)


def time_generalization(xs: jax.Array, y: jax.Array, folds: Folds,
                        lam: float):
    """(T_train, T_test) CV-accuracy matrix (King & Dehaene-style).

    xs: (T, N, P). Each fold's model trained on xs[t1][train rows] is
    evaluated on xs[t2][test rows] for every t2; the diagonal reproduces
    :func:`cv_grid` up to the bias convention.
    """
    t_pts = xs.shape[0]
    y = y.astype(xs.dtype)
    te_idx = folds.te_idx
    y_te = y[te_idx]                                   # (K, m)

    def train_t(x_t1):
        ws, bs = fold_weights(x_t1, y, folds, lam)     # (K, P), (K,)

        def eval_t(x_t2):
            x_te = x_t2[te_idx]                        # (K, m, P)
            dv = jnp.einsum("kmp,kp->km", x_te, ws) + bs[:, None]
            pred = jnp.where(dv >= 0, 1.0, -1.0)
            return jnp.mean(pred == jnp.sign(y_te))

        return jax.lax.map(eval_t, xs)                 # (T,)

    return jax.lax.map(train_t, xs)                    # (T, T)
