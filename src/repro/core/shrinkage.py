"""Shrinkage regularisation and its ridge equivalence (paper §2.6.2).

Shrinkage replaces S_w by (1−λ)S_w + λνI with ν = trace(S_w)/P. As shown
in the paper, this breaks the low-rank update structure (ν changes per
training fold), so the analytical approach supports it only through the
conversion Eq. (18): given λ_shrink, the ridge parameter

    λ_ridge = λ_shrink / (1 − λ_shrink) · ν

produces a *proportional* regularised scatter matrix and therefore an
identical classifier (decision values scale; labels/AUC unchanged).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["trace_scaling", "shrink_to_ridge", "ledoit_wolf_lambda"]


def trace_scaling(x: jax.Array, y: jax.Array | None = None) -> jax.Array:
    """ν = trace(S_w)/P (binary labels ±1) or trace of total scatter if y=None."""
    if y is None:
        xc = x - jnp.mean(x, axis=0, keepdims=True)
        return jnp.sum(xc * xc) / x.shape[1]
    pos = (y > 0).astype(x.dtype)
    neg = 1.0 - pos
    m1 = (pos @ x) / jnp.maximum(jnp.sum(pos), 1.0)
    m2 = (neg @ x) / jnp.maximum(jnp.sum(neg), 1.0)
    xc = x - jnp.where((y > 0)[:, None], m1[None], m2[None])
    return jnp.sum(xc * xc) / x.shape[1]


def shrink_to_ridge(lam_shrink: jax.Array, nu: jax.Array) -> jax.Array:
    """Eq. (18): λ_ridge = λ_shrink/(1−λ_shrink) · ν."""
    return lam_shrink / (1.0 - lam_shrink) * nu


def ledoit_wolf_lambda(x: jax.Array) -> jax.Array:
    """Ledoit-Wolf optimal shrinkage intensity for the covariance of x.

    Convenience for choosing λ_shrink automatically (Blankertz et al. 2011
    practice referenced by the paper); combined with :func:`shrink_to_ridge`
    it gives a data-driven ridge λ usable by the analytical approach.
    """
    n, p = x.shape
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    s = xc.T @ xc / n
    mu = jnp.trace(s) / p
    d2 = jnp.sum((s - mu * jnp.eye(p, dtype=x.dtype)) ** 2)
    # (1/n²)Σᵢ‖xᵢxᵢᵀ − S‖²_F = (Σᵢ‖xᵢ‖⁴)/n² − ‖S‖²_F/n  (no N×P×P temporary)
    b2 = jnp.sum(jnp.sum(xc * xc, axis=1) ** 2) / n**2 - jnp.sum(s * s) / n
    b2 = jnp.minimum(jnp.maximum(b2, 0.0), d2)
    return jnp.clip(b2 / jnp.maximum(d2, 1e-30), 0.0, 1.0)
