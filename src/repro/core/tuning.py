"""Hyperparameter tuning with the analytical machinery (beyond the paper).

The hat matrix makes LOO cross-validation *algebraically free* per ridge
λ once the centered Gram is eigendecomposed:

    G_c = U diag(g) Uᵀ            (one O(N³) eigh)
    H(λ) = 1/N·11ᵀ + U diag(g/(g+λ)) Uᵀ       (O(N²) per λ)
    LOO:  ė_i = ê_i / (1 − H_ii(λ))            (Eq. 14 with m = 1)

so a whole λ grid costs little more than a single fit — the natural
companion to the paper's §2.6 recommendation to use ridge, removing the
one hyperparameter the analytical approach asks for. (The paper tunes
nothing; shrinkage practice uses Ledoit-Wolf — also available via
repro.core.shrinkage and convertible with Eq. 18.)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["RidgeTuneResult", "loo_curve", "tune_ridge"]


class RidgeTuneResult(NamedTuple):
    best_lambda: jax.Array      # ()
    best_score: jax.Array       # ()
    lambdas: jax.Array          # (L,)
    scores: jax.Array           # (L,) criterion per λ (lower is better)


def _eig_gram(x: jax.Array):
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    g = xc @ xc.T
    evals, u = jnp.linalg.eigh(g)
    return jnp.maximum(evals, 0.0), u


def loo_curve(x: jax.Array, y: jax.Array, lambdas: jax.Array,
              criterion: str = "mse"):
    """LOO CV curve over a λ grid from one eigendecomposition.

    y: (N,) continuous response or ±1 labels. criterion: "mse" (squared
    LOO residual) or "error" (misclassification of sign(ẏ)).
    Returns (L,) scores, exact per Eq. 14 (m=1).
    """
    n = x.shape[0]
    y = y.astype(jnp.float64 if x.dtype == jnp.float64 else jnp.float32)
    evals, u = _eig_gram(x)
    uy = u.T @ y                                   # (N,)
    ones_coef = u.T @ jnp.ones((n,), y.dtype)      # for H 11ᵀ/N part

    def one_lambda(lam):
        w = evals / (evals + lam)                  # (N,) spectral filter
        # ŷ = H y = 1/N Σy + U diag(w) Uᵀ y
        y_hat = jnp.mean(y) + u @ (w * uy)
        # H_ii = 1/N + Σ_k w_k U_ik²  ... plus cross term from 11ᵀ/N and
        # U diag(w) Uᵀ? The two parts are NOT orthogonal in general, but
        # H = 1/N·11ᵀ + U W Uᵀ exactly (DESIGN §2), so
        # H_ii = 1/N + Σ_k w_k U_ik² + 0 (the decomposition is additive).
        h_diag = 1.0 / n + jnp.sum(w[None, :] * u * u, axis=1)
        e_hat = y - y_hat
        e_loo = e_hat / jnp.maximum(1.0 - h_diag, 1e-12)
        if criterion == "error":
            y_loo = y - e_loo
            return jnp.mean((jnp.sign(y_loo) != jnp.sign(y)).astype(y.dtype))
        return jnp.mean(e_loo**2)

    return jax.vmap(one_lambda)(lambdas.astype(y.dtype))


def tune_ridge(x: jax.Array, y: jax.Array, lambdas=None,
               criterion: str = "mse") -> RidgeTuneResult:
    """Pick λ by exact LOO over a (default log-spaced) grid.

    Serving equivalent: ``Workload(kind="tune", x=x, y=y, ...)``."""
    if lambdas is None:
        xc = x - jnp.mean(x, axis=0, keepdims=True)
        scale = jnp.trace(xc @ xc.T) / x.shape[0]
        lambdas = scale * jnp.logspace(-4, 2, 25)
    lambdas = jnp.asarray(lambdas)
    scores = loo_curve(x, y, lambdas, criterion=criterion)
    i = jnp.argmin(scores)
    return RidgeTuneResult(lambdas[i], scores[i], lambdas, scores)
