"""Analytical k-fold cross-validation for least-squares models.

This module is the paper's primary contribution (Treder 2018, §2.4-2.6):
exact cross-validated decision values for any ridge-regularised
least-squares model (linear regression, ridge regression, binary LDA in
regression form) from a *single* full-data fit.

    H  = X̃ (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ          (hat matrix, Eq. 8 + §2.6.1)
    ŷ  = H y,   ê = y − ŷ
    ė_Te = (I − H_Te)⁻¹ ê_Te            (Eq. 14 — the analytical approach)
    ẏ_Te = y_Te − ė_Te
    ė_Tr = ê_Tr + H_{Tr,Te} (I − H_Te)⁻¹ ê_Te        (Eq. 15, bias adjust)

TPU-adapted design decisions (DESIGN.md §2):

* Two hat-matrix paths, selected by shape:
    - *primal* (N > P): the paper's explicit augmented form with the
      unpenalised-intercept matrix I₀.
    - *dual* (P ≫ N, the paper's own target regime): column-center X, then
      ``H = 1/N·11ᵀ + G_c (G_c + λI)⁻¹`` with ``G_c = X_c X_cᵀ``. This is
      algebraically identical to the primal form (push-through identity +
      unpenalised intercept ≡ centering) but only ever materialises N×N
      objects; the O(N²P) Gram product is the MXU-friendly hot-spot served
      by the Pallas ``gram`` kernel.
* Folds are static-shape index arrays; all K fold-solves are one batched
  Cholesky (``vmap(cho_factor)``), and the factorisation is *reused across
  permutations* — a beyond-paper optimisation (the paper re-solves per
  permutation; we factor once per fold: O(m³) → O(m²) per permutation).
"""

from __future__ import annotations

import dataclasses
import hashlib
import weakref
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core.folds import Folds

# reprolint: host-float64
# (The incremental update lineage — update_plan/downdate_plan/
# sliding_window and their helpers — is bit-exact against from-scratch
# rebuilds only because every host correction stays IEEE float64, per
# arXiv 2401.13185. RL005 flags any sub-float64 dtype in this module.)

__all__ = [
    "hat_matrix",
    "hat_matrix_primal",
    "hat_matrix_dual",
    "CVPlan",
    "prepare",
    "cv_errors",
    "cv_errors_fused",
    "binary_dvals",
    "binary_cv",
    "fingerprint",
    "plan_key",
    "plan_to_arrays",
    "plan_from_arrays",
    "make_eval_binary",
    "make_eval_cv",
    "update_plan",
    "downdate_plan",
    "sliding_window",
]


def _augment(x: jax.Array) -> jax.Array:
    """X̃ = [X, 1] — append the intercept column (paper §2.3)."""
    n = x.shape[0]
    return jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)


def hat_matrix_primal(x: jax.Array, lam: float = 0.0) -> jax.Array:
    """H = X̃ (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ — the paper's explicit form.

    O(NP² + P³). Requires X̃ᵀX̃ + λI₀ to be positive definite (N > P or
    λ > 0 with a full-rank intercept-augmented design).
    """
    xa = _augment(x)
    p1 = xa.shape[1]
    # I₀: identity with the intercept entry zeroed (bias never penalised).
    i0 = jnp.eye(p1, dtype=x.dtype).at[p1 - 1, p1 - 1].set(0.0)
    a = xa.T @ xa + jnp.asarray(lam, x.dtype) * i0
    c = cho_factor(a)
    return xa @ cho_solve(c, xa.T)


def hat_matrix_dual(x: jax.Array, lam: float, gram: Optional[jax.Array] = None) -> jax.Array:
    """H = 1/N·11ᵀ + G_c (G_c + λI)⁻¹, G_c = X_c X_cᵀ — dual / kernel form.

    O(N²P + N³); never materialises a P×P matrix. Exact for λ > 0 (the
    paper's recommended operating point in high dimensions). ``gram`` may
    be supplied precomputed (e.g. by the Pallas kernel or the distributed
    feature-sharded reduction).
    """
    n = x.shape[0]
    if gram is None:
        xc = x - jnp.mean(x, axis=0, keepdims=True)
        gram = xc @ xc.T
    lam = jnp.asarray(lam, x.dtype)
    c = cho_factor(gram + lam * jnp.eye(n, dtype=x.dtype))
    # G (G+λI)⁻¹ is symmetric (G and (G+λI)⁻¹ share an eigenbasis).
    h_c = cho_solve(c, gram)
    h_c = 0.5 * (h_c + h_c.T)
    return h_c + jnp.full((n, n), 1.0 / n, x.dtype)


def hat_matrix(x: jax.Array, lam: float = 0.0, mode: str = "auto",
               gram: Optional[jax.Array] = None) -> jax.Array:
    """Dispatch between primal and dual hat-matrix construction.

    mode="auto" picks dual when P >= N (the paper's P ≫ N regime), primal
    otherwise. λ = 0 in the P >= N regime is rejected: the unregularised
    interpolator has H_Te → I and Eq. (14) becomes singular (the paper
    implicitly assumes ridge regularisation there).
    """
    n, p = x.shape
    if mode == "auto":
        mode = "dual" if p >= n else "primal"
    if mode == "dual":
        # Only checkable when lam is a concrete Python number (outside jit).
        if isinstance(lam, (int, float)) and lam <= 0.0:
            raise ValueError("dual hat matrix requires lam > 0 (P >= N regime)")
        return hat_matrix_dual(x, lam, gram=gram)
    if mode == "primal":
        return hat_matrix_primal(x, lam)
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# CV plan: everything that depends on (X, folds, λ) but not on labels.
# Reused across permutations (§2.7: H is label-invariant).
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CVPlan:
    """Precomputed label-independent quantities for analytical CV.

    Attributes:
      h: (N, N) hat matrix.
      te_idx: (K, m) test indices.  tr_idx: (K, N-m) train indices.
      chol_ih: (K, m, m) Cholesky factors (lower) of I − H_Te per fold.
      h_tr_te: (K, N-m, m) cross blocks H_{Tr,Te} (None unless bias adjust).
    """

    h: jax.Array
    te_idx: jax.Array
    tr_idx: jax.Array
    chol_ih: jax.Array
    h_tr_te: Optional[jax.Array]

    def tree_flatten(self):
        return (self.h, self.te_idx, self.tr_idx, self.chol_ih, self.h_tr_te), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def k(self) -> int:
        return self.te_idx.shape[0]

    @property
    def nbytes(self) -> int:
        """Device bytes held by the plan — the plan-cache accounting unit."""
        leaves = [self.h, self.te_idx, self.tr_idx, self.chol_ih]
        if self.h_tr_te is not None:
            leaves.append(self.h_tr_te)
        return int(sum(a.size * a.dtype.itemsize for a in leaves))


@partial(jax.jit, static_argnames=("mode", "with_train_block", "lam"))
def _prepare_jit(x, te_idx, tr_idx, lam, mode, with_train_block, gram=None):
    h = hat_matrix(x, lam, mode=mode, gram=gram)
    h_te = h[te_idx[:, :, None], te_idx[:, None, :]]           # (K, m, m)
    eye = jnp.eye(h_te.shape[-1], dtype=h.dtype)
    ih = eye[None] - h_te
    chol = jax.vmap(lambda a: cho_factor(a, lower=True)[0])(ih)
    h_tr_te = (
        h[tr_idx[:, :, None], te_idx[:, None, :]] if with_train_block else None
    )
    return h, chol, h_tr_te


def prepare(x: jax.Array, folds: Folds, lam: float = 0.0, mode: str = "auto",
            with_train_block: bool = True,
            gram: Optional[jax.Array] = None,
            precision: Optional[str] = None) -> CVPlan:
    """Build a :class:`CVPlan`: hat matrix + per-fold factorisations.

    This is the one-time O(N²P + N³ + K·m³) setup; every subsequent label
    vector (CV run or permutation) costs only O(K·m²) per evaluation.

    ``gram`` may carry a precomputed *centered* Gram G_c = X_c X_cᵀ (dual
    mode only) — the serve engine feeds the Pallas ``gram`` kernel's or the
    feature-sharded ``distributed_gram``'s output here, keeping the O(N²P)
    hot path off the XLA default lowering.

    ``precision="bf16_gram"`` (dual mode only) builds the Gram product —
    the only O(N²P) contraction — from a bf16 cast of the centered design
    with f32 accumulation, while every solve stays full precision (see
    :mod:`repro.kernels.gram.ops` for the error bound). Primal-mode builds
    have no Gram and are always full precision; requesting the mode there
    is an error rather than a silent no-op. A caller-supplied ``gram`` is
    trusted to already honour the requested precision (the engine computes
    it through the same helpers).
    """
    n, p = x.shape
    if mode == "auto":
        mode = "dual" if p >= n else "primal"
    if mode == "dual" and lam <= 0.0:
        raise ValueError("analytical CV with P >= N requires lam > 0 "
                         "(unregularised interpolation makes I - H_Te singular)")
    if gram is not None and mode != "dual":
        raise ValueError("precomputed gram only applies to dual mode")
    from repro.kernels.gram.ops import centered_gram_xla, check_precision
    precision = check_precision(precision)
    if precision != "fp32":
        if mode != "dual":
            raise ValueError(
                f"precision={precision!r} only applies to dual-mode plans "
                "(the primal build has no Gram product to down-cast)")
        if gram is None:
            gram = centered_gram_xla(x, precision=precision)
    h, chol, h_tr_te = _prepare_jit(
        x, folds.te_idx, folds.tr_idx, float(lam), mode, with_train_block,
        gram
    )
    return CVPlan(h, folds.te_idx, folds.tr_idx, chol, h_tr_te)


def _chol_solve_lower(chol_l: jax.Array, b: jax.Array) -> jax.Array:
    return cho_solve((chol_l, True), b)


def cv_errors(plan: CVPlan, y: jax.Array, *, fused: bool = False):
    """Eq. (14) + Eq. (15) for a label/response matrix ``y`` of shape (N, ...).

    Returns (y_dot_te, y_dot_tr):
      y_dot_te: (K, m, ...)    exact CV predictions on each test fold.
      y_dot_tr: (K, N-m, ...)  exact *training-set* predictions of each
                               fold model (None if plan lacks train blocks).

    ``y`` may carry trailing batch dims (e.g. permutations, classes); the
    fold solves broadcast over them using the cached Cholesky factors.

    ``fused=True`` routes through the Pallas kernels
    (:func:`cv_errors_fused`) — same results within kernel parity
    tolerances; worthwhile on TPU, interpret-mode slow elsewhere.
    """
    if fused:
        return cv_errors_fused(plan, y)
    squeeze = y.ndim == 1
    if squeeze:
        y = y[:, None]
    y_hat = plan.h @ y                          # (N, B)
    e_hat = y - y_hat
    e_te = e_hat[plan.te_idx]                   # (K, m, B)
    t = jax.vmap(_chol_solve_lower)(plan.chol_ih, e_te)   # (I−H_Te)⁻¹ ê_Te
    y_dot_te = y[plan.te_idx] - t               # ẏ_Te = y_Te − ė_Te
    y_dot_tr = None
    if plan.h_tr_te is not None:
        e_tr = e_hat[plan.tr_idx]               # (K, N-m, B)
        e_dot_tr = e_tr + jnp.einsum("knm,kmb->knb", plan.h_tr_te, t)
        y_dot_tr = y[plan.tr_idx] - e_dot_tr
    if squeeze:
        y_dot_te = y_dot_te[..., 0]
        y_dot_tr = None if y_dot_tr is None else y_dot_tr[..., 0]
    return y_dot_te, y_dot_tr


def cv_errors_fused(plan: CVPlan, y: jax.Array):
    """Pallas-kernel evaluation path; same contract as :func:`cv_errors`.

    Plans without train blocks take the fully fused ``fold_eval`` kernel:
    the hat-row contraction and the fold solves run in one launch and the
    intermediate (N, B) Ê is never materialised. Plans *with* train blocks
    (bias adjust, multiclass) need Ê on every training row for Eq. (15),
    so only the solve stage fuses there: one H·Y matmul, then the
    ``foldsolve`` kernel on the gathered fold blocks. Both routes solve
    I − H_Te directly (Gauss-Jordan with the residual-checked jitter
    fallback) instead of using the plan's cached Cholesky factors — the
    parity tests pin the two paths against each other at ≤1e-5 (fp32).
    """
    from repro.kernels.fold_eval.ops import fold_eval
    from repro.kernels.foldsolve.ops import foldsolve

    squeeze = y.ndim == 1
    if squeeze:
        y = y[:, None]
    te = plan.te_idx
    h_te = plan.h[te[:, :, None], te[:, None, :]]           # (K, m, m)
    y_te = y[te]                                            # (K, m, B)
    if plan.h_tr_te is None:
        t = fold_eval(plan.h[te], h_te, y, y_te)
        y_dot_te = y_te - t
        y_dot_tr = None
    else:
        e_hat = y - plan.h @ y
        t = foldsolve(h_te, e_hat[te])
        y_dot_te = y_te - t
        e_dot_tr = e_hat[plan.tr_idx] + jnp.einsum("knm,kmb->knb", plan.h_tr_te, t)
        y_dot_tr = y[plan.tr_idx] - e_dot_tr
    if squeeze:
        y_dot_te = y_dot_te[..., 0]
        y_dot_tr = None if y_dot_tr is None else y_dot_tr[..., 0]
    return y_dot_te, y_dot_tr


def binary_dvals(plan: CVPlan, y: jax.Array, adjust_bias: bool = True,
                 *, fused: bool = False):
    """Cross-validated decision values for binary LDA (labels ±1).

    ``y`` is (N,) or (N, B) — a trailing batch dim carries permutations
    (§2.7); all B label vectors share the plan's factorisations.

    With ``adjust_bias`` (paper §2.5) the regression bias b_LR is replaced
    by the LDA bias b_LDA using the cross-validated *training* decision
    values: dval ← ẏ_Te − (μ̂₁ + μ̂₂)/2 where μ̂_l is the mean training
    decision value of class l under the fold's model. This never forms w.
    """
    y = y.astype(plan.h.dtype)
    squeeze = y.ndim == 1
    yb = y[:, None] if squeeze else y                          # (N, B)
    y_dot_te, y_dot_tr = cv_errors(plan, yb, fused=fused)      # (K, m, B)
    if adjust_bias:
        if y_dot_tr is None:
            raise ValueError("plan must be prepared with with_train_block=True")
        y_tr = yb[plan.tr_idx]                                 # (K, N-m, B)
        pos = (y_tr > 0).astype(yb.dtype)
        neg = 1.0 - pos
        mu1 = jnp.sum(y_dot_tr * pos, axis=1) / jnp.maximum(jnp.sum(pos, axis=1), 1.0)
        mu2 = jnp.sum(y_dot_tr * neg, axis=1) / jnp.maximum(jnp.sum(neg, axis=1), 1.0)
        # ẏ − b_LR + b_LDA = ẏ − (μ₁ + μ₂)/2  (projected-class-mean midpoint)
        y_dot_te = y_dot_te - 0.5 * (mu1 + mu2)[:, None, :]
    return y_dot_te[..., 0] if squeeze else y_dot_te


def binary_cv(x: jax.Array, y: jax.Array, folds: Folds, lam: float = 0.0,
              mode: str = "auto", adjust_bias: bool = True):
    """One-shot analytical binary-LDA cross-validation.

    Returns (dvals_te, y_te): per-fold decision values and matching labels,
    both (K, m), ready for ``metrics.binary_accuracy`` / ``metrics.auc``.

    This is the library-level reference implementation; the serving
    equivalent is ``Workload(kind="cv", estimator="binary", ...)`` through
    ``repro.serve.Client`` (bit-identical by the parity tests), which adds
    plan caching, micro-batching, and shape-bucketed compilation.
    """
    plan = prepare(x, folds, lam, mode=mode, with_train_block=adjust_bias)
    dvals = binary_dvals(plan, y, adjust_bias=adjust_bias)
    return dvals, y[folds.te_idx]


# ---------------------------------------------------------------------------
# Serving support: plan fingerprinting + jitted (donated-buffer) eval entry
# points. The plan is label-invariant (§2.7), so a content fingerprint of
# (X, folds, λ, mode) identifies it exactly — the repro.serve.PlanCache key.
# ---------------------------------------------------------------------------

_FINGERPRINT_SAMPLE_CAP = 1 << 20  # elements hashed exactly before sampling

# (id, sample_cap) -> (weakref, digest). jax Arrays are immutable, so
# identity implies content identity while the object is alive; the weakref
# callback evicts the entry on GC so a recycled id can never alias a stale
# digest. The cap is part of the key because it changes the digest for
# arrays above it — memoising on id alone would let a small-cap probe
# poison every later default-cap lookup of the same array (and vice versa).
_fingerprint_memo: dict = {}


def fingerprint(x, *, sample_cap: int = _FINGERPRINT_SAMPLE_CAP) -> str:
    """Stable content digest of an array (shape + dtype + values).

    Arrays up to ``sample_cap`` elements are hashed exactly; larger ones by
    a deterministic strided subsample plus a global f64 checksum — O(cap)
    regardless of dataset size, with astronomically unlikely collisions for
    real feature matrices. The digest depends only on shape/dtype/values
    (plus ``sample_cap`` above it), never on process state — plan keys are
    stable across restarts, which is what lets the disk-backed plan store
    address entries by key. Digests of (immutable) jax arrays are memoised
    by (object identity, cap), so steady-state serving never re-hashes a
    dataset.
    """
    memoable = isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer)
    if memoable:
        memo_key = (id(x), sample_cap)
        hit = _fingerprint_memo.get(memo_key)
        if hit is not None and hit[0]() is x:
            return hit[1]
    arr = np.asarray(jax.device_get(x))
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((arr.shape, str(arr.dtype))).encode())
    if arr.size <= sample_cap:
        h.update(np.ascontiguousarray(arr).tobytes())
    else:
        flat = np.ascontiguousarray(arr).reshape(-1)
        stride = -(-arr.size // sample_cap)
        h.update(np.ascontiguousarray(flat[::stride]).tobytes())
        h.update(np.float64(flat.sum(dtype=np.float64)).tobytes())
    digest = h.hexdigest()
    if memoable:
        ref = weakref.ref(x, lambda _, k=memo_key: _fingerprint_memo.pop(k, None))
        _fingerprint_memo[memo_key] = (ref, digest)
    return digest


def plan_key(x, folds: Folds, lam: float, mode: str = "auto",
             with_train_block: bool = True, *, version: int = 0,
             precision: Optional[str] = None) -> tuple:
    """Hashable identity of the :class:`CVPlan` that ``prepare`` would build.

    Both index arrays are fingerprinted: tr_idx is not derivable from
    te_idx in general (leftover samples, custom schemes), and the plan's
    train blocks + bias adjustment depend on it.

    ``version`` is the dataset-registry version number (0 for a freshly
    registered dataset; n+1 after each ``append``/``retire``).
    ``precision`` is the Gram-build precision (None normalises to "fp32");
    plans built at different precisions hold numerically different hat
    matrices and must never alias. Both sit before ``with_train_block``,
    which stays the *last* element — the cache / engine idiom
    ``key[:-1] + (flag,)`` keeps working unchanged. All elements are
    JSON-stable, which is what lets the disk store address entries by key
    across processes.
    """
    from repro.kernels.gram.ops import check_precision
    n, p = x.shape
    if mode == "auto":
        mode = "dual" if p >= n else "primal"
    return (fingerprint(x), fingerprint(folds.te_idx),
            fingerprint(folds.tr_idx), float(lam), mode, int(version),
            check_precision(precision), bool(with_train_block))


#: Plan leaves in flattening order; ``h_tr_te`` is optional (None unless
#: the plan was prepared with train blocks).
PLAN_FIELDS = ("h", "te_idx", "tr_idx", "chol_ih", "h_tr_te")


def plan_to_arrays(plan: CVPlan) -> dict:
    """Host-side ``{leaf name: np.ndarray}`` snapshot of a plan.

    The serialisation codec for :class:`repro.serve.store.PlanStore`: every
    non-None leaf is fetched to host as-is (no dtype laundering — the store
    round-trip must be bit-exact for the rehydrated plan to serve
    bit-identical predictions). A None ``h_tr_te`` is simply omitted.
    """
    out = {}
    for name in PLAN_FIELDS:
        leaf = getattr(plan, name)
        if leaf is not None:
            out[name] = np.asarray(jax.device_get(leaf))
    return out


def plan_from_arrays(arrays) -> CVPlan:
    """Rebuild a :class:`CVPlan` from a :func:`plan_to_arrays` mapping.

    Leaves are placed on the default device; a mapping missing any of the
    four required leaves is rejected (the store treats that as a corrupt
    entry and quarantines it rather than serving a partial plan).
    """
    missing = [n for n in PLAN_FIELDS[:4] if n not in arrays]
    if missing:
        raise ValueError(f"plan arrays missing required leaves {missing}")
    h_tr_te = arrays.get("h_tr_te")
    return CVPlan(
        h=jnp.asarray(arrays["h"]),
        te_idx=jnp.asarray(arrays["te_idx"]),
        tr_idx=jnp.asarray(arrays["tr_idx"]),
        chol_ih=jnp.asarray(arrays["chol_ih"]),
        h_tr_te=None if h_tr_te is None else jnp.asarray(h_tr_te),
    )


def make_eval_binary(adjust_bias: bool = True, donate: bool = False,
                     fused: bool = False):
    """Fresh jitted evaluator ``(plan, y (N, B)) -> dvals (K, m, B)``.

    ``donate=True`` donates the label-batch buffer (permutation chunks are
    single-use) so XLA may alias it into the output — meaningful on
    TPU/GPU; CPU backends ignore donation. Only donate buffers you own:
    the donated array is invalidated for the caller. ``fused=True`` routes
    through the Pallas eval kernels (:func:`cv_errors_fused`). Each call
    returns an independently-cached jit, so callers (the serve engine) can
    count compiles via ``fn._cache_size()``.
    """
    kw = {"donate_argnums": (1,)} if donate else {}
    return jax.jit(
        lambda plan, y: binary_dvals(plan, y, adjust_bias=adjust_bias,
                                     fused=fused), **kw)


def make_eval_cv(donate: bool = False, fused: bool = False):
    """Fresh jitted evaluator ``(plan, y (N, B)) -> ẏ_Te (K, m, B)`` —
    the ridge-regression serving path (Eq. 14 only, no bias adjust)."""
    kw = {"donate_argnums": (1,)} if donate else {}
    return jax.jit(lambda plan, y: cv_errors(plan, y, fused=fused)[0], **kw)


# ---------------------------------------------------------------------------
# Incremental plan updates (streaming data): rank-k update / downdate of a
# cached dual-mode plan when rows arrive or retire, instead of a full
# rebuild. Follows the partition-incremental Gram idea of arXiv 2401.13185
# (fold-wise X^TX blocks admit exact updates with centering corrections),
# specialised to the paper's dual form:
#
#     A = G_c + λI,   S := H − 1/N·11ᵀ = G_c A⁻¹ = I − λA⁻¹
#
# so the *inverse is recoverable from the stored hat matrix* in O(N²):
# A⁻¹ = (I − S)/λ — no refactorisation needed to start an update. Appending
# k rows shifts the column means μ → μ′, which perturbs the old-row block by
# the rank-2 correction R = u1ᵀ + 1uᵀ + (δᵀδ)11ᵀ (δ = μ−μ′, u = X_cδ);
# Woodbury absorbs R, a Schur complement bolts on the k new rows, and
# H′ = I − λA′⁻¹ + 1/N′·11ᵀ. Dropping rows is the principal-submatrix
# inverse identity plus the same mean-shift correction. Total cost is
# O(N²k + NPk) per update — never O(N³) or O(N²P).
#
# Everything here runs in host NumPy (float64) on purpose: update traffic
# arrives with ever-changing N, and a jitted implementation would recompile
# per shape — the serve engine's compile_events stays flat because this
# path never enters XLA. Tolerances vs a from-scratch ``prepare`` rebuild
# are pinned at ≤1e-5 by the parity tests.
# ---------------------------------------------------------------------------


def _np64(a) -> np.ndarray:
    return np.asarray(jax.device_get(a), dtype=np.float64)


def _resolve_update_mode(mode: str, x_shape) -> str:
    if mode == "auto":
        n, p = x_shape
        mode = "dual" if p >= n else "primal"
    if mode != "dual":
        raise ValueError(
            "incremental plan updates require a dual-mode plan (P >= N "
            "regime): the N×N hat matrix determines (G_c + λI)⁻¹ exactly, "
            "which is what the rank-k correction advances. Rebuild primal "
            "plans with prepare() instead.")
    return mode


def _dual_inverse_from_plan(plan: CVPlan, lam: float) -> np.ndarray:
    """Recover A⁻¹ = (G_c + λI)⁻¹ from the stored dual hat matrix, O(N²)."""
    h = _np64(plan.h)
    n = h.shape[0]
    m = (np.eye(n) - (h - 1.0 / n)) / float(lam)
    return 0.5 * (m + m.T)


def _mean_shift_inverse(m: np.ndarray, x_c: np.ndarray,
                        delta: np.ndarray) -> np.ndarray:
    """(A + R)⁻¹ from M = A⁻¹ for the centering correction R.

    R = u1ᵀ + 1uᵀ + (δᵀδ)11ᵀ with u = X_cδ — the exact perturbation of a
    centered Gram when the centering mean shifts by δ. Factor R = W K Wᵀ,
    W = [u, 1], K = [[0,1],[1,δᵀδ]] (det −1, always invertible — robust
    even at δ = 0), and apply Woodbury.
    """
    n = m.shape[0]
    u = x_c @ delta
    w = np.stack([u, np.ones(n)], axis=1)                     # (N, 2)
    c = float(delta @ delta)
    k_inv = np.array([[-c, 1.0], [1.0, 0.0]])                 # K⁻¹
    mw = m @ w                                                # (N, 2)
    core = k_inv + w.T @ mw                                   # (2, 2)
    out = m - mw @ np.linalg.solve(core, mw.T)
    return 0.5 * (out + out.T)


def _append_inverse(m: np.ndarray, x_old: np.ndarray, x_new: np.ndarray,
                    lam: float) -> np.ndarray:
    """A′⁻¹ for [x_old; x_new] (centered at the new mean) from M = A⁻¹."""
    n, k = x_old.shape[0], x_new.shape[0]
    mu = x_old.mean(axis=0)
    mu2 = (n * mu + x_new.sum(axis=0)) / (n + k)
    # Re-center the old block at μ′ (rank-2 Woodbury), then Schur-bolt the
    # k new rows on. The old block A + R equals Z_oZ_oᵀ + λI exactly, with
    # Z_o = x_old − 1μ′ᵀ, so the assembled blocks form (G′_c + λI)⁻¹.
    m_c = _mean_shift_inverse(m, x_old - mu, mu - mu2)
    z_old = x_old - mu2
    z_new = x_new - mu2
    b = z_old @ z_new.T                                       # (N, k)
    c = z_new @ z_new.T + float(lam) * np.eye(k)              # (k, k)
    mb = m_c @ b                                              # (N, k)
    schur = c - b.T @ mb
    schur = 0.5 * (schur + schur.T)
    s_inv = np.linalg.inv(schur)
    s_inv = 0.5 * (s_inv + s_inv.T)
    out = np.empty((n + k, n + k))
    out[:n, :n] = m_c + mb @ s_inv @ mb.T
    out[:n, n:] = -mb @ s_inv
    out[n:, :n] = out[:n, n:].T
    out[n:, n:] = s_inv
    return out


def _downdate_inverse(m: np.ndarray, x_old: np.ndarray,
                      drop: np.ndarray, lam: float) -> np.ndarray:
    """A′⁻¹ for x_old minus ``drop`` rows (centered at the kept mean)."""
    del lam  # identity needs no λ: A_κκ already contains it
    n = x_old.shape[0]
    keep = np.setdiff1d(np.arange(n), drop)
    # Principal-submatrix inverse: (A_κκ)⁻¹ = M_κκ − M_κd (M_dd)⁻¹ M_dκ.
    m_kd = m[np.ix_(keep, drop)]
    m_dd = m[np.ix_(drop, drop)]
    a_kk_inv = m[np.ix_(keep, keep)] - m_kd @ np.linalg.solve(m_dd, m_kd.T)
    x_kept = x_old[keep]
    mu = x_old.mean(axis=0)
    return _mean_shift_inverse(a_kk_inv, x_kept - mu, mu - x_kept.mean(axis=0))


def _finish_plan(m_inv: np.ndarray, lam: float, te: np.ndarray,
                 tr: np.ndarray, with_train_block: bool, dtype) -> CVPlan:
    """H′ = I − λA′⁻¹ + 1/N′·11ᵀ and the per-fold blocks, all in NumPy."""
    n = m_inv.shape[0]
    h = np.eye(n) - float(lam) * m_inv + 1.0 / n
    h = 0.5 * (h + h.T)
    h_te = h[te[:, :, None], te[:, None, :]]                  # (K, m, m)
    chol = np.linalg.cholesky(np.eye(te.shape[1])[None] - h_te)
    h_tr_te = (
        h[tr[:, :, None], te[:, None, :]] if with_train_block else None
    )
    return CVPlan(
        h=jnp.asarray(h, dtype),
        te_idx=jnp.asarray(te, jnp.int32),
        tr_idx=jnp.asarray(tr, jnp.int32),
        chol_ih=jnp.asarray(chol, dtype),
        h_tr_te=None if h_tr_te is None else jnp.asarray(h_tr_te, dtype),
    )


def _complement_folds(te: np.ndarray, n: int) -> np.ndarray:
    """Training side = ascending complement of each fold's test set."""
    k, m = te.shape
    tr = np.empty((k, n - m), dtype=np.int64)
    for i in range(k):
        mask = np.ones(n, dtype=bool)
        mask[te[i]] = False
        tr[i] = np.nonzero(mask)[0]
    return tr


def _check_complement(te: np.ndarray, tr: np.ndarray, n: int) -> None:
    for i in range(te.shape[0]):
        mask = np.ones(n, dtype=bool)
        mask[te[i]] = False
        if not np.array_equal(np.sort(tr[i]), np.nonzero(mask)[0]):
            raise ValueError(
                "incremental fold derivation assumes complement training "
                "sets (every non-test sample trains, as all built-in fold "
                "generators produce); pass folds_delta as a full Folds for "
                "custom schemes")


def _extend_folds(te: np.ndarray, n: int, assign: np.ndarray) -> np.ndarray:
    """New te after appending rows with per-row fold assignment.

    ``assign[j]`` is the fold of appended row j (new sample id n+j), or −1
    for a train-only row (the leftover convention of :mod:`repro.core.folds`
    when K does not divide N). Per-fold counts must stay rectangular.
    """
    k = te.shape[0]
    if assign.ndim != 1:
        raise ValueError("folds_delta assignment must be 1-D (one fold id "
                         "per appended row)")
    if assign.size and (assign.min() < -1 or assign.max() >= k):
        raise ValueError(
            f"fold assignment out of range: got values in "
            f"[{assign.min()}, {assign.max()}], plan has {k} folds")
    tested = assign[assign >= 0]
    counts = np.bincount(tested, minlength=k)
    if counts.max() != counts.min():
        raise ValueError(
            "appending would make per-fold test sizes ragged "
            f"(counts per fold {counts.tolist()}); static shapes require "
            "equal fold sizes — assign equally many rows to every fold "
            "(or -1 for train-only rows)")
    new_ids = n + np.arange(assign.size)
    return np.stack(
        [np.concatenate([te[f], new_ids[assign == f]]) for f in range(k)])


def _drop_folds(te: np.ndarray, n: int, drop: np.ndarray) -> np.ndarray:
    """New te (renumbered over the kept rows) after dropping ``drop``."""
    keep_mask = np.ones(n, dtype=bool)
    keep_mask[drop] = False
    remap = np.cumsum(keep_mask) - 1
    rows = [remap[row[keep_mask[row]]] for row in te]
    sizes = {len(r) for r in rows}
    if len(sizes) != 1:
        raise ValueError(
            "dropping those rows would make per-fold test sizes ragged "
            f"(sizes {sorted(len(r) for r in rows)}); drop equally many "
            "test samples from every fold, or use sliding_window to "
            "backfill the slots with appended rows")
    return np.stack(rows).astype(np.int64)


def _window_folds(te: np.ndarray, n: int, drop: np.ndarray,
                  assign: np.ndarray) -> np.ndarray:
    """New te for drop+append in one move, ragged-checked only at the end.

    Unbalanced drops are fine here (unlike :func:`_drop_folds`) as long as
    the appended rows backfill the holes to equal per-fold sizes. Kept rows
    are renumbered by rank among survivors; appended row j becomes sample
    ``n - len(drop) + j``.
    """
    k_new = assign.size
    rows = []
    for f in range(te.shape[0]):
        kept = te[f][~np.isin(te[f], drop)]
        add = n + np.nonzero(assign == f)[0]
        rows.append(np.concatenate([kept, add]))
    sizes = {len(r) for r in rows}
    if len(sizes) != 1:
        raise ValueError(
            "window advance would make per-fold test sizes ragged "
            f"(sizes {sorted(len(r) for r in rows)}); appended rows must "
            "backfill dropped test slots to equal per-fold counts")
    keep = np.setdiff1d(np.arange(n), drop)
    remap = np.full(n + k_new, -1, dtype=np.int64)
    remap[keep] = np.arange(keep.size)
    remap[n:] = keep.size + np.arange(k_new)
    return np.stack([remap[r] for r in rows]).astype(np.int64)


def _fold_of(te: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """Fold membership of each sample id in ``idx`` (−1 if train-only)."""
    out = np.full(idx.shape, -1, dtype=np.int64)
    for f in range(te.shape[0]):
        out[np.isin(idx, te[f])] = f
    return out


def _validate_drop(drop, n: int) -> np.ndarray:
    drop = np.asarray(jax.device_get(drop))
    if drop.ndim != 1 or drop.size == 0:
        raise ValueError("drop_idx must be a non-empty 1-D index array")
    if not np.issubdtype(drop.dtype, np.integer):
        raise ValueError(f"drop_idx must be integer, got dtype {drop.dtype}")
    drop = drop.astype(np.int64)
    if drop.min() < 0 or drop.max() >= n:
        raise ValueError(f"drop_idx out of range for N={n}")
    if np.unique(drop).size != drop.size:
        raise ValueError("drop_idx contains duplicate rows")
    if drop.size >= n:
        raise ValueError("cannot drop every row of the dataset")
    return drop


def _update_inputs(plan: CVPlan, x, lam: float, mode: str):
    """Shared validation; returns (x64, te, tr, with_train_block, dtype)."""
    x_np = _np64(x)
    if x_np.ndim != 2:
        raise ValueError("x must be the 2-D feature matrix the plan was "
                         "built from")
    n = plan.h.shape[0]
    if x_np.shape[0] != n:
        raise ValueError(
            f"x has {x_np.shape[0]} rows but the plan was built over {n} "
            "samples — pass the exact feature matrix behind this plan")
    if not isinstance(lam, (int, float)) or float(lam) <= 0.0:
        raise ValueError("incremental updates require a concrete lam > 0 "
                         "(the dual-mode operating point)")
    _resolve_update_mode(mode, x_np.shape)
    te = np.asarray(jax.device_get(plan.te_idx)).astype(np.int64)
    tr = np.asarray(jax.device_get(plan.tr_idx)).astype(np.int64)
    _check_complement(te, tr, n)
    return x_np, te, tr, plan.h_tr_te is not None, plan.h.dtype


def _coerce_folds_delta(folds_delta, k_new: int):
    """folds_delta is a full Folds (custom schemes) or a per-row assignment."""
    if isinstance(folds_delta, Folds):
        return folds_delta
    assign = np.asarray(jax.device_get(folds_delta))
    if not np.issubdtype(assign.dtype, np.integer):
        raise ValueError("per-row fold assignment must be integer "
                         f"(got dtype {assign.dtype})")
    assign = assign.astype(np.int64).reshape(-1)
    if assign.size != k_new:
        raise ValueError(
            f"fold assignment has {assign.size} entries for "
            f"{k_new} appended rows")
    return assign


def update_plan(plan: CVPlan, x_new, folds_delta, *, x, lam: float,
                mode: str = "dual") -> CVPlan:
    """Advance a dual-mode plan by appending rows — a rank-k correction.

    Args:
      plan: the cached plan for ``x`` (dual mode, built by :func:`prepare`
        or a previous update).
      x_new: (k, P) appended feature rows; the updated dataset is
        ``concat([x, x_new])`` in that order.
      folds_delta: either a per-appended-row fold assignment (1-D int array,
        −1 = train-only leftover) or a full :class:`Folds` over N+k samples
        for custom schemes.
      x: the (N, P) feature matrix the plan was built from (keyword-only —
        the plan itself stores only N×N objects).
      lam: the plan's ridge strength (> 0).
      mode: must resolve to "dual"; primal plans cannot be advanced.

    Returns a new :class:`CVPlan` over N+k samples, equal to
    ``prepare(concat([x, x_new]), new_folds, lam, "dual")`` to ≤1e-5
    without ever rebuilding the Gram or re-entering XLA. Cost O(N²k + NPk).
    """
    x_np, te, tr, wtb, dtype = _update_inputs(plan, x, lam, mode)
    n = x_np.shape[0]
    xn = _np64(x_new)
    if xn.ndim != 2 or xn.shape[1] != x_np.shape[1]:
        raise ValueError(
            f"x_new must be (k, {x_np.shape[1]}) to match the dataset, got "
            f"shape {xn.shape}")
    if folds_delta is None:
        raise ValueError("update_plan needs folds_delta: a fold id per "
                         "appended row (-1 = train-only) or a full Folds")
    delta = _coerce_folds_delta(folds_delta, xn.shape[0])
    if isinstance(delta, Folds):
        te2 = np.asarray(jax.device_get(delta.te_idx)).astype(np.int64)
        tr2 = np.asarray(jax.device_get(delta.tr_idx)).astype(np.int64)
    else:
        te2 = _extend_folds(te, n, delta)
        tr2 = _complement_folds(te2, n + xn.shape[0])
    m = _dual_inverse_from_plan(plan, lam)
    m2 = _append_inverse(m, x_np, xn, lam)
    return _finish_plan(m2, lam, te2, tr2, wtb, dtype)


def downdate_plan(plan: CVPlan, drop_idx, *, x, lam: float,
                  mode: str = "dual") -> CVPlan:
    """Retire rows from a dual-mode plan — the inverse rank-k correction.

    ``drop_idx`` indexes rows of ``x``; surviving rows keep their relative
    order and are renumbered densely (new id = old rank among kept rows),
    so the updated dataset is ``x[keep]`` with ``keep`` sorted. Per-fold
    test sizes must stay rectangular after the drop (drop equally many test
    samples per fold, or train-only rows); use :func:`sliding_window` to
    backfill slots instead. Cost O(N²d + d³).
    """
    x_np, te, tr, wtb, dtype = _update_inputs(plan, x, lam, mode)
    n = x_np.shape[0]
    drop = _validate_drop(drop_idx, n)
    te2 = _drop_folds(te, n, drop)
    tr2 = _complement_folds(te2, n - drop.size)
    m = _dual_inverse_from_plan(plan, lam)
    m2 = _downdate_inverse(m, x_np, drop, lam)
    return _finish_plan(m2, lam, te2, tr2, wtb, dtype)


def sliding_window(plan: CVPlan, x_new, drop_idx, *, x, lam: float,
                   mode: str = "dual", folds_delta=None) -> CVPlan:
    """Append + drop in one correction — the streaming steady state.

    The window advances: ``drop_idx`` rows retire and ``x_new`` rows arrive,
    with N (and therefore every downstream eval shape) unchanged whenever
    ``len(x_new) == len(drop_idx)``. By default each appended row inherits
    the fold slot of a dropped row (matched in sorted drop order), so the
    fold geometry — and the jitted eval cache — is preserved exactly; pass
    ``folds_delta`` to re-assign instead. The updated dataset is
    ``concat([x[keep], x_new])``.
    """
    x_np, te, tr, wtb, dtype = _update_inputs(plan, x, lam, mode)
    n = x_np.shape[0]
    drop = _validate_drop(drop_idx, n)
    xn = _np64(x_new)
    if xn.ndim != 2 or xn.shape[1] != x_np.shape[1]:
        raise ValueError(
            f"x_new must be (k, {x_np.shape[1]}) to match the dataset, got "
            f"shape {xn.shape}")
    n_kept = n - drop.size
    if folds_delta is None:
        if xn.shape[0] != drop.size:
            raise ValueError(
                "sliding_window without folds_delta requires "
                "len(x_new) == len(drop_idx) so appended rows can inherit "
                f"the dropped rows' fold slots (got {xn.shape[0]} new vs "
                f"{drop.size} dropped)")
        assign = _fold_of(te, np.sort(drop))
        te2 = _window_folds(te, n, drop, assign)
        tr2 = _complement_folds(te2, n_kept + xn.shape[0])
    else:
        delta = _coerce_folds_delta(folds_delta, xn.shape[0])
        if isinstance(delta, Folds):
            te2 = np.asarray(jax.device_get(delta.te_idx)).astype(np.int64)
            tr2 = np.asarray(jax.device_get(delta.tr_idx)).astype(np.int64)
        else:
            te2 = _window_folds(te, n, drop, delta)
            tr2 = _complement_folds(te2, n_kept + xn.shape[0])
    m = _dual_inverse_from_plan(plan, lam)
    m_dropped = _downdate_inverse(m, x_np, drop, lam)
    keep = np.setdiff1d(np.arange(n), drop)
    m2 = _append_inverse(m_dropped, x_np[keep], xn, lam)
    return _finish_plan(m2, lam, te2, tr2, wtb, dtype)
