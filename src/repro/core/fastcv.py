"""Analytical k-fold cross-validation for least-squares models.

This module is the paper's primary contribution (Treder 2018, §2.4-2.6):
exact cross-validated decision values for any ridge-regularised
least-squares model (linear regression, ridge regression, binary LDA in
regression form) from a *single* full-data fit.

    H  = X̃ (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ          (hat matrix, Eq. 8 + §2.6.1)
    ŷ  = H y,   ê = y − ŷ
    ė_Te = (I − H_Te)⁻¹ ê_Te            (Eq. 14 — the analytical approach)
    ẏ_Te = y_Te − ė_Te
    ė_Tr = ê_Tr + H_{Tr,Te} (I − H_Te)⁻¹ ê_Te        (Eq. 15, bias adjust)

TPU-adapted design decisions (DESIGN.md §2):

* Two hat-matrix paths, selected by shape:
    - *primal* (N > P): the paper's explicit augmented form with the
      unpenalised-intercept matrix I₀.
    - *dual* (P ≫ N, the paper's own target regime): column-center X, then
      ``H = 1/N·11ᵀ + G_c (G_c + λI)⁻¹`` with ``G_c = X_c X_cᵀ``. This is
      algebraically identical to the primal form (push-through identity +
      unpenalised intercept ≡ centering) but only ever materialises N×N
      objects; the O(N²P) Gram product is the MXU-friendly hot-spot served
      by the Pallas ``gram`` kernel.
* Folds are static-shape index arrays; all K fold-solves are one batched
  Cholesky (``vmap(cho_factor)``), and the factorisation is *reused across
  permutations* — a beyond-paper optimisation (the paper re-solves per
  permutation; we factor once per fold: O(m³) → O(m²) per permutation).
"""

from __future__ import annotations

import dataclasses
import hashlib
import weakref
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core.folds import Folds

__all__ = [
    "hat_matrix",
    "hat_matrix_primal",
    "hat_matrix_dual",
    "CVPlan",
    "prepare",
    "cv_errors",
    "binary_dvals",
    "binary_cv",
    "fingerprint",
    "plan_key",
    "plan_to_arrays",
    "plan_from_arrays",
    "make_eval_binary",
    "make_eval_cv",
]


def _augment(x: jax.Array) -> jax.Array:
    """X̃ = [X, 1] — append the intercept column (paper §2.3)."""
    n = x.shape[0]
    return jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)


def hat_matrix_primal(x: jax.Array, lam: float = 0.0) -> jax.Array:
    """H = X̃ (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ — the paper's explicit form.

    O(NP² + P³). Requires X̃ᵀX̃ + λI₀ to be positive definite (N > P or
    λ > 0 with a full-rank intercept-augmented design).
    """
    xa = _augment(x)
    p1 = xa.shape[1]
    # I₀: identity with the intercept entry zeroed (bias never penalised).
    i0 = jnp.eye(p1, dtype=x.dtype).at[p1 - 1, p1 - 1].set(0.0)
    a = xa.T @ xa + jnp.asarray(lam, x.dtype) * i0
    c = cho_factor(a)
    return xa @ cho_solve(c, xa.T)


def hat_matrix_dual(x: jax.Array, lam: float, gram: Optional[jax.Array] = None) -> jax.Array:
    """H = 1/N·11ᵀ + G_c (G_c + λI)⁻¹, G_c = X_c X_cᵀ — dual / kernel form.

    O(N²P + N³); never materialises a P×P matrix. Exact for λ > 0 (the
    paper's recommended operating point in high dimensions). ``gram`` may
    be supplied precomputed (e.g. by the Pallas kernel or the distributed
    feature-sharded reduction).
    """
    n = x.shape[0]
    if gram is None:
        xc = x - jnp.mean(x, axis=0, keepdims=True)
        gram = xc @ xc.T
    lam = jnp.asarray(lam, x.dtype)
    c = cho_factor(gram + lam * jnp.eye(n, dtype=x.dtype))
    # G (G+λI)⁻¹ is symmetric (G and (G+λI)⁻¹ share an eigenbasis).
    h_c = cho_solve(c, gram)
    h_c = 0.5 * (h_c + h_c.T)
    return h_c + jnp.full((n, n), 1.0 / n, x.dtype)


def hat_matrix(x: jax.Array, lam: float = 0.0, mode: str = "auto",
               gram: Optional[jax.Array] = None) -> jax.Array:
    """Dispatch between primal and dual hat-matrix construction.

    mode="auto" picks dual when P >= N (the paper's P ≫ N regime), primal
    otherwise. λ = 0 in the P >= N regime is rejected: the unregularised
    interpolator has H_Te → I and Eq. (14) becomes singular (the paper
    implicitly assumes ridge regularisation there).
    """
    n, p = x.shape
    if mode == "auto":
        mode = "dual" if p >= n else "primal"
    if mode == "dual":
        # Only checkable when lam is a concrete Python number (outside jit).
        if isinstance(lam, (int, float)) and lam <= 0.0:
            raise ValueError("dual hat matrix requires lam > 0 (P >= N regime)")
        return hat_matrix_dual(x, lam, gram=gram)
    if mode == "primal":
        return hat_matrix_primal(x, lam)
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# CV plan: everything that depends on (X, folds, λ) but not on labels.
# Reused across permutations (§2.7: H is label-invariant).
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CVPlan:
    """Precomputed label-independent quantities for analytical CV.

    Attributes:
      h: (N, N) hat matrix.
      te_idx: (K, m) test indices.  tr_idx: (K, N-m) train indices.
      chol_ih: (K, m, m) Cholesky factors (lower) of I − H_Te per fold.
      h_tr_te: (K, N-m, m) cross blocks H_{Tr,Te} (None unless bias adjust).
    """

    h: jax.Array
    te_idx: jax.Array
    tr_idx: jax.Array
    chol_ih: jax.Array
    h_tr_te: Optional[jax.Array]

    def tree_flatten(self):
        return (self.h, self.te_idx, self.tr_idx, self.chol_ih, self.h_tr_te), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def k(self) -> int:
        return self.te_idx.shape[0]

    @property
    def nbytes(self) -> int:
        """Device bytes held by the plan — the plan-cache accounting unit."""
        leaves = [self.h, self.te_idx, self.tr_idx, self.chol_ih]
        if self.h_tr_te is not None:
            leaves.append(self.h_tr_te)
        return int(sum(a.size * a.dtype.itemsize for a in leaves))


@partial(jax.jit, static_argnames=("mode", "with_train_block", "lam"))
def _prepare_jit(x, te_idx, tr_idx, lam, mode, with_train_block, gram=None):
    h = hat_matrix(x, lam, mode=mode, gram=gram)
    h_te = h[te_idx[:, :, None], te_idx[:, None, :]]           # (K, m, m)
    eye = jnp.eye(h_te.shape[-1], dtype=h.dtype)
    ih = eye[None] - h_te
    chol = jax.vmap(lambda a: cho_factor(a, lower=True)[0])(ih)
    h_tr_te = (
        h[tr_idx[:, :, None], te_idx[:, None, :]] if with_train_block else None
    )
    return h, chol, h_tr_te


def prepare(x: jax.Array, folds: Folds, lam: float = 0.0, mode: str = "auto",
            with_train_block: bool = True,
            gram: Optional[jax.Array] = None) -> CVPlan:
    """Build a :class:`CVPlan`: hat matrix + per-fold factorisations.

    This is the one-time O(N²P + N³ + K·m³) setup; every subsequent label
    vector (CV run or permutation) costs only O(K·m²) per evaluation.

    ``gram`` may carry a precomputed *centered* Gram G_c = X_c X_cᵀ (dual
    mode only) — the serve engine feeds the Pallas ``gram`` kernel's or the
    feature-sharded ``distributed_gram``'s output here, keeping the O(N²P)
    hot path off the XLA default lowering.
    """
    n, p = x.shape
    if mode == "auto":
        mode = "dual" if p >= n else "primal"
    if mode == "dual" and lam <= 0.0:
        raise ValueError("analytical CV with P >= N requires lam > 0 "
                         "(unregularised interpolation makes I - H_Te singular)")
    if gram is not None and mode != "dual":
        raise ValueError("precomputed gram only applies to dual mode")
    h, chol, h_tr_te = _prepare_jit(
        x, folds.te_idx, folds.tr_idx, float(lam), mode, with_train_block,
        gram
    )
    return CVPlan(h, folds.te_idx, folds.tr_idx, chol, h_tr_te)


def _chol_solve_lower(chol_l: jax.Array, b: jax.Array) -> jax.Array:
    return cho_solve((chol_l, True), b)


def cv_errors(plan: CVPlan, y: jax.Array):
    """Eq. (14) + Eq. (15) for a label/response matrix ``y`` of shape (N, ...).

    Returns (y_dot_te, y_dot_tr):
      y_dot_te: (K, m, ...)    exact CV predictions on each test fold.
      y_dot_tr: (K, N-m, ...)  exact *training-set* predictions of each
                               fold model (None if plan lacks train blocks).

    ``y`` may carry trailing batch dims (e.g. permutations, classes); the
    fold solves broadcast over them using the cached Cholesky factors.
    """
    squeeze = y.ndim == 1
    if squeeze:
        y = y[:, None]
    y_hat = plan.h @ y                          # (N, B)
    e_hat = y - y_hat
    e_te = e_hat[plan.te_idx]                   # (K, m, B)
    t = jax.vmap(_chol_solve_lower)(plan.chol_ih, e_te)   # (I−H_Te)⁻¹ ê_Te
    y_dot_te = y[plan.te_idx] - t               # ẏ_Te = y_Te − ė_Te
    y_dot_tr = None
    if plan.h_tr_te is not None:
        e_tr = e_hat[plan.tr_idx]               # (K, N-m, B)
        e_dot_tr = e_tr + jnp.einsum("knm,kmb->knb", plan.h_tr_te, t)
        y_dot_tr = y[plan.tr_idx] - e_dot_tr
    if squeeze:
        y_dot_te = y_dot_te[..., 0]
        y_dot_tr = None if y_dot_tr is None else y_dot_tr[..., 0]
    return y_dot_te, y_dot_tr


def binary_dvals(plan: CVPlan, y: jax.Array, adjust_bias: bool = True):
    """Cross-validated decision values for binary LDA (labels ±1).

    ``y`` is (N,) or (N, B) — a trailing batch dim carries permutations
    (§2.7); all B label vectors share the plan's factorisations.

    With ``adjust_bias`` (paper §2.5) the regression bias b_LR is replaced
    by the LDA bias b_LDA using the cross-validated *training* decision
    values: dval ← ẏ_Te − (μ̂₁ + μ̂₂)/2 where μ̂_l is the mean training
    decision value of class l under the fold's model. This never forms w.
    """
    y = y.astype(plan.h.dtype)
    squeeze = y.ndim == 1
    yb = y[:, None] if squeeze else y                          # (N, B)
    y_dot_te, y_dot_tr = cv_errors(plan, yb)                   # (K, m, B)
    if adjust_bias:
        if y_dot_tr is None:
            raise ValueError("plan must be prepared with with_train_block=True")
        y_tr = yb[plan.tr_idx]                                 # (K, N-m, B)
        pos = (y_tr > 0).astype(yb.dtype)
        neg = 1.0 - pos
        mu1 = jnp.sum(y_dot_tr * pos, axis=1) / jnp.maximum(jnp.sum(pos, axis=1), 1.0)
        mu2 = jnp.sum(y_dot_tr * neg, axis=1) / jnp.maximum(jnp.sum(neg, axis=1), 1.0)
        # ẏ − b_LR + b_LDA = ẏ − (μ₁ + μ₂)/2  (projected-class-mean midpoint)
        y_dot_te = y_dot_te - 0.5 * (mu1 + mu2)[:, None, :]
    return y_dot_te[..., 0] if squeeze else y_dot_te


def binary_cv(x: jax.Array, y: jax.Array, folds: Folds, lam: float = 0.0,
              mode: str = "auto", adjust_bias: bool = True):
    """One-shot analytical binary-LDA cross-validation.

    Returns (dvals_te, y_te): per-fold decision values and matching labels,
    both (K, m), ready for ``metrics.binary_accuracy`` / ``metrics.auc``.

    This is the library-level reference implementation; the serving
    equivalent is ``Workload(kind="cv", estimator="binary", ...)`` through
    ``repro.serve.Client`` (bit-identical by the parity tests), which adds
    plan caching, micro-batching, and shape-bucketed compilation.
    """
    plan = prepare(x, folds, lam, mode=mode, with_train_block=adjust_bias)
    dvals = binary_dvals(plan, y, adjust_bias=adjust_bias)
    return dvals, y[folds.te_idx]


# ---------------------------------------------------------------------------
# Serving support: plan fingerprinting + jitted (donated-buffer) eval entry
# points. The plan is label-invariant (§2.7), so a content fingerprint of
# (X, folds, λ, mode) identifies it exactly — the repro.serve.PlanCache key.
# ---------------------------------------------------------------------------

_FINGERPRINT_SAMPLE_CAP = 1 << 20  # elements hashed exactly before sampling

# (id, sample_cap) -> (weakref, digest). jax Arrays are immutable, so
# identity implies content identity while the object is alive; the weakref
# callback evicts the entry on GC so a recycled id can never alias a stale
# digest. The cap is part of the key because it changes the digest for
# arrays above it — memoising on id alone would let a small-cap probe
# poison every later default-cap lookup of the same array (and vice versa).
_fingerprint_memo: dict = {}


def fingerprint(x, *, sample_cap: int = _FINGERPRINT_SAMPLE_CAP) -> str:
    """Stable content digest of an array (shape + dtype + values).

    Arrays up to ``sample_cap`` elements are hashed exactly; larger ones by
    a deterministic strided subsample plus a global f64 checksum — O(cap)
    regardless of dataset size, with astronomically unlikely collisions for
    real feature matrices. The digest depends only on shape/dtype/values
    (plus ``sample_cap`` above it), never on process state — plan keys are
    stable across restarts, which is what lets the disk-backed plan store
    address entries by key. Digests of (immutable) jax arrays are memoised
    by (object identity, cap), so steady-state serving never re-hashes a
    dataset.
    """
    memoable = isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer)
    if memoable:
        memo_key = (id(x), sample_cap)
        hit = _fingerprint_memo.get(memo_key)
        if hit is not None and hit[0]() is x:
            return hit[1]
    arr = np.asarray(jax.device_get(x))
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((arr.shape, str(arr.dtype))).encode())
    if arr.size <= sample_cap:
        h.update(np.ascontiguousarray(arr).tobytes())
    else:
        flat = np.ascontiguousarray(arr).reshape(-1)
        stride = -(-arr.size // sample_cap)
        h.update(np.ascontiguousarray(flat[::stride]).tobytes())
        h.update(np.float64(flat.sum(dtype=np.float64)).tobytes())
    digest = h.hexdigest()
    if memoable:
        ref = weakref.ref(x, lambda _, k=memo_key: _fingerprint_memo.pop(k, None))
        _fingerprint_memo[memo_key] = (ref, digest)
    return digest


def plan_key(x, folds: Folds, lam: float, mode: str = "auto",
             with_train_block: bool = True) -> tuple:
    """Hashable identity of the :class:`CVPlan` that ``prepare`` would build.

    Both index arrays are fingerprinted: tr_idx is not derivable from
    te_idx in general (leftover samples, custom schemes), and the plan's
    train blocks + bias adjustment depend on it.
    """
    n, p = x.shape
    if mode == "auto":
        mode = "dual" if p >= n else "primal"
    return (fingerprint(x), fingerprint(folds.te_idx),
            fingerprint(folds.tr_idx), float(lam), mode,
            bool(with_train_block))


#: Plan leaves in flattening order; ``h_tr_te`` is optional (None unless
#: the plan was prepared with train blocks).
PLAN_FIELDS = ("h", "te_idx", "tr_idx", "chol_ih", "h_tr_te")


def plan_to_arrays(plan: CVPlan) -> dict:
    """Host-side ``{leaf name: np.ndarray}`` snapshot of a plan.

    The serialisation codec for :class:`repro.serve.store.PlanStore`: every
    non-None leaf is fetched to host as-is (no dtype laundering — the store
    round-trip must be bit-exact for the rehydrated plan to serve
    bit-identical predictions). A None ``h_tr_te`` is simply omitted.
    """
    out = {}
    for name in PLAN_FIELDS:
        leaf = getattr(plan, name)
        if leaf is not None:
            out[name] = np.asarray(jax.device_get(leaf))
    return out


def plan_from_arrays(arrays) -> CVPlan:
    """Rebuild a :class:`CVPlan` from a :func:`plan_to_arrays` mapping.

    Leaves are placed on the default device; a mapping missing any of the
    four required leaves is rejected (the store treats that as a corrupt
    entry and quarantines it rather than serving a partial plan).
    """
    missing = [n for n in PLAN_FIELDS[:4] if n not in arrays]
    if missing:
        raise ValueError(f"plan arrays missing required leaves {missing}")
    h_tr_te = arrays.get("h_tr_te")
    return CVPlan(
        h=jnp.asarray(arrays["h"]),
        te_idx=jnp.asarray(arrays["te_idx"]),
        tr_idx=jnp.asarray(arrays["tr_idx"]),
        chol_ih=jnp.asarray(arrays["chol_ih"]),
        h_tr_te=None if h_tr_te is None else jnp.asarray(h_tr_te),
    )


def make_eval_binary(adjust_bias: bool = True, donate: bool = False):
    """Fresh jitted evaluator ``(plan, y (N, B)) -> dvals (K, m, B)``.

    ``donate=True`` donates the label-batch buffer (permutation chunks are
    single-use) so XLA may alias it into the output — meaningful on
    TPU/GPU; CPU backends ignore donation. Only donate buffers you own:
    the donated array is invalidated for the caller. Each call returns an
    independently-cached jit, so callers (the serve engine) can count
    compiles via ``fn._cache_size()``.
    """
    kw = {"donate_argnums": (1,)} if donate else {}
    return jax.jit(
        lambda plan, y: binary_dvals(plan, y, adjust_bias=adjust_bias), **kw)


def make_eval_cv(donate: bool = False):
    """Fresh jitted evaluator ``(plan, y (N, B)) -> ẏ_Te (K, m, B)`` —
    the ridge-regression serving path (Eq. 14 only, no bias adjust)."""
    kw = {"donate_argnums": (1,)} if donate else {}
    return jax.jit(lambda plan, y: cv_errors(plan, y)[0], **kw)
