"""Binary LDA: direct (scatter-matrix) form and the regression form.

These are the paper's *standard approach* comparators: the classifier is
retrained from scratch on every training fold (O(KNP² + KP³), Table 1).
Folds are processed with ``lax.map`` (sequentially inside one compiled
program) so the benchmark reflects the standard approach's true cost
rather than letting XLA batch the K fits.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core.folds import Folds

__all__ = [
    "BinaryLDA",
    "fit_binary",
    "fit_binary_regression",
    "decision_values",
    "standard_cv_binary",
]


class BinaryLDA(NamedTuple):
    w: jax.Array    # (P,)
    b: jax.Array    # ()


def scatter_within(x: jax.Array, y: jax.Array):
    """Within-class scatter S_w and class means (labels ±1), Eq. (1)."""
    pos = (y > 0).astype(x.dtype)
    neg = 1.0 - pos
    n1 = jnp.maximum(jnp.sum(pos), 1.0)
    n2 = jnp.maximum(jnp.sum(neg), 1.0)
    m1 = (pos @ x) / n1
    m2 = (neg @ x) / n2
    xc = x - jnp.where((y > 0)[:, None], m1[None, :], m2[None, :])
    sw = xc.T @ xc
    return sw, m1, m2


def fit_binary(x: jax.Array, y: jax.Array, lam: float = 0.0) -> BinaryLDA:
    """w = (S_w + λI)⁻¹ (m₁ − m₂); b = −wᵀ(m₁ + m₂)/2  (Eqs. 3, 4, 16)."""
    sw, m1, m2 = scatter_within(x, y)
    p = x.shape[1]
    a = sw + jnp.asarray(lam, x.dtype) * jnp.eye(p, dtype=x.dtype)
    w = cho_solve(cho_factor(a), m1 - m2)
    b = -0.5 * jnp.dot(w, m1 + m2)
    return BinaryLDA(w, b)


def fit_binary_regression(x: jax.Array, y: jax.Array, lam: float = 0.0):
    """β̂ = (X̃ᵀX̃ + λI₀)⁻¹ X̃ᵀ y  (Eq. 17) — the regression form of LDA.

    Returns (w, b_LR). Identical direction to :func:`fit_binary` (App. A/B);
    the *decision values* of this form are exactly what the analytical CV
    approach reproduces fold-wise.
    """
    n = x.shape[0]
    xa = jnp.concatenate([x, jnp.ones((n, 1), x.dtype)], axis=1)
    p1 = xa.shape[1]
    i0 = jnp.eye(p1, dtype=x.dtype).at[p1 - 1, p1 - 1].set(0.0)
    a = xa.T @ xa + jnp.asarray(lam, x.dtype) * i0
    beta = cho_solve(cho_factor(a), xa.T @ y.astype(x.dtype))
    return beta[:-1], beta[-1]


def decision_values(x: jax.Array, model: BinaryLDA) -> jax.Array:
    return x @ model.w + model.b


@partial(jax.jit, static_argnames=("form",))
def _standard_cv_binary_jit(x, y, te_idx, tr_idx, lam, form):
    y = y.astype(x.dtype)

    def one_fold(idx_pair):
        te, tr = idx_pair
        x_tr, y_tr = x[tr], y[tr]
        x_te = x[te]
        if form == "lda":
            model = fit_binary(x_tr, y_tr, lam)
            return decision_values(x_te, model)
        w, b = fit_binary_regression(x_tr, y_tr, lam)
        return x_te @ w + b

    dvals = jax.lax.map(one_fold, (te_idx, tr_idx))
    return dvals, y[te_idx]


def standard_cv_binary(x: jax.Array, y: jax.Array, folds: Folds,
                       lam: float = 0.0, form: str = "lda"):
    """Standard-approach k-fold CV: retrain on every training fold.

    form="lda"        direct scatter-matrix LDA (paper's standard baseline)
    form="regression" regression-form ridge fit — produces decision values
                      that must match the analytical approach *exactly*
                      (used by the exactness tests).

    Returns (dvals_te, y_te) of shape (K, m).
    """
    return _standard_cv_binary_jit(x, y, folds.te_idx, folds.tr_idx,
                                   jnp.asarray(lam, x.dtype), form)
