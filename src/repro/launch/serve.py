"""Batched serving driver: continuous prefill + decode with the substrate.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
        --batch 4 --prompt-len 32 --gen-len 32 [--kv-quant]

Demonstrates the full serving path on the reduced (smoke) configs:
prefill a batch of prompts into KV caches (optionally int8-quantised),
then step the decode loop with greedy sampling; reports tokens/s and the
cache memory footprint. On real hardware the same steps are jitted with
the mesh shardings (identical code path to the dry-run's prefill/decode
cells).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_archs
from repro.models import model as M
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if args.kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    total_len = args.prompt_len + args.gen_len
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)

    tok_shape = ((args.batch, cfg.num_codebooks, args.prompt_len)
                 if cfg.num_codebooks else (args.batch, args.prompt_len))
    prompts = jax.random.randint(jax.random.PRNGKey(1), tok_shape, 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.vision_tokens, cfg.vision_dim), jnp.float32)

    # ---- prefill into a full-length cache --------------------------------
    t0 = time.time()
    last_logits, prefill_caches = jax.jit(
        lambda p, b: M.prefill_step(p, b, cfg))(params, batch)
    jax.block_until_ready(last_logits)
    t_prefill = time.time() - t0

    # place the prefill caches into a total_len-capacity cache
    caches = T.init_trunk_cache(cfg, args.batch, total_len)

    def graft(full, part):
        if full.ndim >= 3 and part.ndim == full.ndim and \
                part.shape[:2] == full.shape[:2] and full.shape[2] >= part.shape[2]:
            return jax.lax.dynamic_update_slice_in_dim(full, part.astype(full.dtype), 0, axis=2)
        if part.shape == full.shape:
            return part.astype(full.dtype)
        # recurrent states / ring buffers: take the prefill state directly
        return part.astype(full.dtype) if part.shape == full.shape else full

    caches = {"stack": [jax.tree.map(graft, c_full, c_pre) for c_full, c_pre
                        in zip(caches["stack"], prefill_caches["stack"])],
              "tail": [jax.tree.map(graft, c_full, c_pre) for c_full, c_pre
                       in zip(caches["tail"], prefill_caches["tail"])]}

    decode = jax.jit(lambda tok, pos, c: M.decode_step(params, tok, pos, c, cfg))
    tok = jnp.argmax(last_logits, axis=-1)
    if cfg.num_codebooks:
        tok = tok[:, :, None]
    else:
        tok = tok[:, None]
    generated = [tok]

    t0 = time.time()
    for step in range(args.gen_len - 1):
        pos = jnp.asarray(args.prompt_len + step, jnp.int32)
        logits, caches = decode(tok, pos, caches)
        tok = jnp.argmax(logits[:, -1] if not cfg.num_codebooks else
                         logits[:, 0], axis=-1)
        tok = tok[:, :, None] if cfg.num_codebooks else tok[:, None]
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(caches))
    n_tok = args.batch * (args.gen_len - 1)
    print(f"[serve] {cfg.name} kv_quant={cfg.kv_quant}")
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s")
    print(f"[serve] decoded {n_tok} tokens in {t_decode:.2f}s "
          f"({n_tok / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"[serve] cache footprint: {cache_bytes / 2**20:.1f} MiB")
    out = jnp.concatenate(generated, axis=-1)
    print(f"[serve] sample output ids: {list(map(int, jnp.ravel(out)[:16]))}")


if __name__ == "__main__":
    main()
