"""Layer-probe driver: the paper's technique applied to model representations.

The modern analogue of the paper's MVPA workloads (DESIGN.md §3): extract
hidden states from a (smoke-sized) assigned architecture, then run
analytical-CV LDA probes + permutation testing per layer — the
"classifier per time point" of §2.13 becomes "probe per layer", with the
identical K·T training-iteration explosion that Algorithm 1 collapses.

    PYTHONPATH=src python -m repro.launch.probe --arch gemma2-2b \
        --n-per-class 48 --n-perm 200
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_archs
from repro.core import folds as foldlib, permutation
from repro.models import model as M
from repro.models import transformer as T


def layerwise_hidden_states(params, tokens, cfg, vision_embeds=None):
    """Forward pass capturing the residual stream after every block repeat.

    Returns (n_points, N, d_model) float32 — one feature set per scan
    repeat (pattern group), mean-pooled over the sequence.
    """
    positions = jnp.arange(tokens.shape[-1], dtype=jnp.int32)[None, :]
    h = M._embed(params, tokens, cfg, positions)
    vis_kv = M._vision_kv(params, vision_embeds, cfg)
    pat, n_rep, tail = T._pattern_split(cfg)

    def repeat_body(carry, rep_params):
        x, _ = carry
        for pos, kind in enumerate(pat):
            x, _, _ = T.apply_block_full(rep_params[pos], x, kind, cfg,
                                         positions=positions, vis_kv=vis_kv)
        return (x, jnp.zeros(())), jnp.mean(x, axis=1)   # pooled snapshot

    (h, _), snaps = jax.lax.scan(repeat_body, (h, jnp.zeros(())),
                                 tuple(params["blocks"]["stack"]))
    return snaps.astype(jnp.float32)                     # (n_rep, N, D)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--n-per-class", type=int, default=48)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--n-perm", type=int, default=100)
    ap.add_argument("--folds", type=int, default=6)
    ap.add_argument("--lam", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)

    # two synthetic "stimulus classes": token sequences drawn from two
    # disjoint vocabulary bands (a decodable condition difference)
    n = 2 * args.n_per_class
    half_v = cfg.vocab_size // 2
    k1, k2, k3 = jax.random.split(key, 3)
    tok_a = jax.random.randint(k1, (args.n_per_class, args.seq_len), 0, half_v)
    tok_b = jax.random.randint(k2, (args.n_per_class, args.seq_len),
                               half_v, cfg.vocab_size)
    tokens = jnp.concatenate([tok_a, tok_b], axis=0)
    y = jnp.concatenate([-jnp.ones(args.n_per_class), jnp.ones(args.n_per_class)])
    vis = (jax.random.normal(k3, (n, cfg.vision_tokens, cfg.vision_dim),
                             jnp.float32) if cfg.vision_tokens else None)
    if cfg.num_codebooks:
        tokens = jnp.tile(tokens[:, None, :], (1, cfg.num_codebooks, 1))

    feats = layerwise_hidden_states(params, tokens, cfg, vision_embeds=vis)
    f = foldlib.kfold(n, args.folds, seed=0)

    print(f"[probe] arch={cfg.name} layers(points)={feats.shape[0]} "
          f"N={n} P={feats.shape[2]} perms={args.n_perm}")
    print("point | observed acc | p-value | null mean")
    for li in range(feats.shape[0]):
        x = feats[li].astype(jnp.float64)
        res = permutation.analytical_permutation_binary(
            x, y.astype(jnp.float64), f, args.lam, n_perm=args.n_perm,
            key=jax.random.PRNGKey(li), chunk=min(args.n_perm, 64))
        print(f"{li:5d} | {float(res.observed):.3f}        | "
              f"{float(res.p):.4f}  | {float(jnp.mean(res.null)):.3f}")


if __name__ == "__main__":
    main()
