"""Stand up the analytical-CV serving engine and measure throughput.

    PYTHONPATH=src python -m repro.launch.serve_cv --requests 64
    PYTHONPATH=src python -m repro.launch.serve_cv --data eeg --clients 4
    PYTHONPATH=src python -m repro.launch.serve_cv --rsa --conditions 8
    PYTHONPATH=src python -m repro.launch.serve_cv --warmup --pin --async 8
    PYTHONPATH=src python -m repro.launch.serve_cv --record-traffic t.json
    PYTHONPATH=src python -m repro.launch.serve_cv --warmup-from t.json
    PYTHONPATH=src python -m repro.launch.serve_cv --http 8000 --warmup --pin
    PYTHONPATH=src python -m repro.launch.serve_cv --window 16

Builds a :class:`repro.serve.CVEngine` fronted by the unified
:class:`repro.serve.Client`, registers a small fleet of datasets
(synthetic hypersphere-classification or EEG-like windowed features) as
:class:`~repro.serve.DatasetHandle`\\ s, and plays a mixed
:class:`~repro.serve.Workload` stream against it — binary-LDA CV, ridge
CV, multi-class CV, permutation tests, and λ-tuning — first cold (plans
built, evals compiled), then warm (everything cached). With ``--rsa``
the stream becomes RSA traffic instead: cross-validated RDMs
(pairwise-contrast and confusion), scored against model RDMs with
condition-permutation nulls, all riding the same cached plans and
coalesced label batches. With ``--clients > 1`` the same stream is
replayed through a thread-transport Client so concurrent submitters
coalesce onto shared micro-batches; with ``--async N`` through an
async-transport Client (N coroutine clients), followed by a streamed
permutation workload printing its null chunks as they land. ``--warmup``
pre-builds every plan and pre-compiles the bucketed eval family before
the first timed pass (``--pin`` additionally pins the warmed plans
against eviction). ``--record-traffic FILE`` dumps the (task, bucket)
set the session served; ``--warmup-from FILE`` replays a recorded set at
boot, warming the per-workload shapes yesterday's traffic needed. Reports
requests/s and the engine's cache / compile statistics.

``--plan-store DIR`` adds the durable plan tier: cache misses read
verified plans from DIR before rebuilding, and with ``--save-plans``
every fresh build is persisted (write-behind) for the next boot.
``--compilation-cache DIR`` turns on jax's persistent XLA compilation
cache. Together they make the full warm-boot sequence::

    serve_cv --http 0 --plan-store X --compilation-cache Y \\
             --warmup-from traffic.json --save-plans

reach 0-plan-build, ~0-compile-time steady state in seconds (CI's
restart-smoke job SIGKILLs a warmed server and asserts exactly that).

With ``--http PORT`` the process becomes a network service instead of a
local replay: datasets register, warm-up runs as requested, then an
:class:`repro.serve.HTTPEdge` serves ``Workload`` JSON over HTTP —
batched results at ``POST /v1/workloads``, SSE progress streams at
``POST /v1/workloads/stream``, wire-side dataset registration at ``POST
/v1/datasets`` — until interrupted. ``--record-traffic`` composes: the
(task, bucket) set observed *over the wire* is dumped on shutdown.
"""

from __future__ import annotations

import argparse
import asyncio
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro import rsa
from repro.core import folds as foldlib
from repro.data import eeg, synthetic
from repro.serve import Client, CVEngine, EngineConfig, TrafficLog, Workload


def build_workloads(args, client):
    """Alternating binary (C=2) and multi-class (C=3) datasets, mixed
    workload stream: CV (binary/ridge/multiclass), permutations, tuning.
    Datasets register once; workloads carry handles. Returns
    (workloads, datasets)."""
    datasets = []
    for d in range(args.datasets):
        num_classes = 2 if d % 2 == 0 else 3
        key = jax.random.PRNGKey(args.seed + d)
        if args.data == "eeg":
            ds = eeg.simulate_subject(key, n_trials=args.n,
                                      num_classes=num_classes, dtype=jnp.float64)
            x, y_int = eeg.windowed_features(ds, 200.0), ds.y
        else:
            x, y_int = synthetic.make_classification(
                key, args.n, args.p, num_classes=num_classes, class_sep=2.0)
        n = int(x.shape[0])
        handle = client.register(x, foldlib.kfold(n, args.k, seed=d), args.lam)
        y_bin = jnp.where(y_int % 2 == 0, -1.0, 1.0)
        datasets.append((handle, x, y_bin, y_int, num_classes))

    workloads = []
    for i in range(args.requests):
        handle, x, y_bin, y_int, c = datasets[i % len(datasets)]
        slot = i % 8
        if slot == 7:
            if c > 2:
                workloads.append(Workload(
                    kind="permutation", dataset=handle, y=y_int,
                    estimator="multiclass", num_classes=c,
                    n_perm=args.perm, seed=i))
            else:
                workloads.append(Workload(
                    kind="permutation", dataset=handle, y=y_bin,
                    n_perm=args.perm, seed=i))
        elif slot == 6:
            workloads.append(Workload(kind="tune", x=x, y=y_bin))
        elif slot in (4, 5) and c > 2:
            workloads.append(Workload(kind="cv", dataset=handle, y=y_int,
                                      estimator="multiclass", num_classes=c))
        elif slot == 3:
            workloads.append(Workload(kind="cv", dataset=handle, y=y_bin,
                                      estimator="ridge"))
        else:
            workloads.append(Workload(kind="cv", dataset=handle, y=y_bin,
                                      estimator="binary"))
    return workloads, datasets


def build_rsa_workloads(args, client):
    """RSA stream: C-condition datasets, RDM workloads alternating pairwise
    dissimilarities and confusion contrasts, scored against model RDMs."""
    c = args.conditions
    datasets = []
    for d in range(args.datasets):
        key = jax.random.PRNGKey(args.seed + d)
        x, y_cond = synthetic.make_classification(
            key, args.n, args.p, num_classes=c, class_sep=2.0)
        handle = client.register(
            x, foldlib.stratified_kfold(y_cond, args.k, seed=d), args.lam)
        mu = rsa.condition_means(x, y_cond, c)
        models = jnp.stack([rsa.euclidean_rdm(mu), rsa.ring_rdm(c)])
        datasets.append((handle, x, y_cond, models, c))

    workloads = []
    for i in range(args.requests):
        handle, _x, y_cond, models, _c = datasets[i % len(datasets)]
        slot = i % 4
        if slot == 3:
            workloads.append(Workload(kind="rsa", dataset=handle, y=y_cond,
                                      num_classes=c, contrast="multiclass",
                                      model_rdms=models, n_perm=args.perm,
                                      seed=i))
        elif slot == 2:
            workloads.append(Workload(kind="rsa", dataset=handle, y=y_cond,
                                      num_classes=c,
                                      dissimilarity="contrast",
                                      adjust_bias=False))
        else:
            workloads.append(Workload(kind="rsa", dataset=handle, y=y_cond,
                                      num_classes=c, model_rdms=models,
                                      n_perm=args.perm, seed=i))
    return workloads, datasets


def warmup_engine(engine, args, datasets):
    """Pre-build (and optionally pin) every plan; pre-compile eval buckets."""
    t0 = time.perf_counter()
    small = (1, 2, 4, 8, 16)
    for entry in datasets:
        handle = entry[0]
        if args.rsa:
            c = args.conditions
            n_pairs = c * (c - 1) // 2
            # same-plan RSA workloads coalesce: cover up to two requests'
            # worth of contrast columns in one padded batch
            engine.warmup(handle, tasks=("rsa", "multiclass"),
                          buckets=small + (n_pairs, 2 * n_pairs, args.perm),
                          num_classes=c, num_model_rdms=2, pin=args.pin)
            # the stream's slot-2 variant: continuous contrast, no bias adjust
            engine.warmup(handle, tasks=("rsa",), buckets=(n_pairs,),
                          num_classes=c, dissimilarity="contrast",
                          adjust_bias=False)
        else:
            c = entry[4]
            tasks = ("binary", "ridge", "permutation")
            if c > 2:
                tasks = tasks + ("multiclass",)
            engine.warmup(handle, tasks, buckets=small + (args.perm,),
                          num_classes=c, pin=args.pin)
    t_warm = time.perf_counter() - t0
    s = engine.stats()
    print(f"[serve_cv] warmup: {t_warm:.3f}s, {s['plans_built']} plans built"
          f" ({s['pinned']} pinned), {s['compiles']} programs compiled")


def warmup_from_traffic(engine, path, datasets, pin):
    """Boot-time warm-up from a recorded (task, bucket) traffic set."""
    log = TrafficLog.load(path)
    t0 = time.perf_counter()
    for entry in datasets:
        log.replay(engine, entry[0], pin=pin)
    t_warm = time.perf_counter() - t0
    s = engine.stats()
    print(f"[serve_cv] warmup-from {path}: {len(log)} recorded entries, "
          f"{t_warm:.3f}s, {s['plans_built']} plans built "
          f"({s['pinned']} pinned), {s['compiles']} programs compiled")


async def replay_async(engine, workloads, n_clients, perm_demo=None):
    """Replay the stream through an async-transport Client with N coroutine
    clients, then stream one permutation workload chunk by chunk."""
    per_client = -(-len(workloads) // n_clients)
    results = [None] * len(workloads)
    async with Client(engine, transport="async", max_batch=per_client) as client:

        async def one_client(cid):
            lo = cid * per_client
            for j in range(lo, min(lo + per_client, len(workloads))):
                results[j] = await client.submit(workloads[j])

        t0 = time.perf_counter()
        await asyncio.gather(*(one_client(c) for c in range(n_clients)))
        t_async = time.perf_counter() - t0
        print(f"[serve_cv] async ({n_clients} clients): {t_async:.3f}s "
              f"({len(workloads) / t_async:.1f} req/s) in "
              f"{client.server.batches_served} micro-batches")

        if perm_demo is not None:
            t0 = time.perf_counter()
            async for ev in client.stream(perm_demo):
                if ev.kind == "null":
                    print(f"[serve_cv]   stream: {ev.done}/{ev.total} null "
                          f"draws at {time.perf_counter() - t0:.3f}s")
                elif ev.kind == "done":
                    print(f"[serve_cv]   stream: done, p = "
                          f"{float(ev.payload.p):.4f}")
    assert all(r is not None for r in results)


def run_window(client, args, datasets):
    """Sliding-window mode (``--window N``): the streaming steady state.

    Advances the first dataset N times — each step retires one test row
    per fold (the oldest slots) and appends equally many fresh rows, so
    the sample count, fold geometry, and therefore every jitted eval
    shape stay fixed — and serves a binary-CV workload against each new
    version. Dataset versions march 1..N while plans advance by rank-k
    correction (``kind="update"`` → :meth:`CVEngine.update_dataset`), so
    once the first step is served the compile count must stay flat: the
    loop prints per-step update/CV latency and the compile delta.
    """
    engine = client.engine
    handle, x, y_bin, _y_int, _c = datasets[0]
    y = np.asarray(y_bin)
    key = jax.random.PRNGKey(args.seed + 1_000_003)
    upd_times, cv_times = [], []
    compiles0 = None
    for step in range(args.window):
        rec = engine.dataset_record(handle)
        n = int(rec.x.shape[0])
        drop = np.asarray(rec.folds.te_idx)[:, 0]  # oldest slot per fold
        key, sub, ysub = jax.random.split(key, 3)
        x_new = jax.random.normal(sub, (drop.size, int(rec.x.shape[1])),
                                  dtype=rec.x.dtype)
        t0 = time.perf_counter()
        resp = client.submit(Workload(kind="update", dataset=handle,
                                      x=x_new, drop_idx=drop))
        upd_times.append(time.perf_counter() - t0)
        handle = resp.handle
        keep = np.setdiff1d(np.arange(n), drop)
        y = np.concatenate([
            y[keep],
            np.where(np.asarray(jax.random.bernoulli(ysub, shape=(drop.size,))),
                     1.0, -1.0),
        ])
        t0 = time.perf_counter()
        cv = client.submit(Workload(kind="cv", dataset=handle,
                                    y=jnp.asarray(y, dtype=rec.x.dtype)))
        cv_times.append(time.perf_counter() - t0)
        if compiles0 is None:
            compiles0 = engine.compile_count()  # after the first warm step
        if step < 3 or step == args.window - 1:
            print(f"[serve_cv]   window step {step + 1}/{args.window}: "
                  f"v{resp.version}, rank {resp.rank}, update "
                  f"{upd_times[-1] * 1e3:.1f}ms, cv {cv_times[-1] * 1e3:.1f}ms, "
                  f"score {float(cv.score):.3f}")
    steady_upd = sorted(upd_times[1:] or upd_times)[len(upd_times[1:] or upd_times) // 2]
    steady_cv = sorted(cv_times[1:] or cv_times)[len(cv_times[1:] or cv_times) // 2]
    recompiles = engine.compile_count() - compiles0
    s = engine.stats()
    print(f"[serve_cv] window: {args.window} advances, steady-state update "
          f"p50 {steady_upd * 1e3:.1f}ms, cv p50 {steady_cv * 1e3:.1f}ms, "
          f"plans updated: {s['plans_updated']}, "
          f"recompiles after first step: {recompiles}")
    if recompiles:
        print("[serve_cv] WARNING: sliding window recompiled — fold "
              "geometry was not preserved")


def setup_compilation_cache(path):
    """Point jax's persistent compilation cache at ``path`` (opt-in).

    Thresholds drop to zero so even this workload's small CPU programs
    persist — the restart-smoke job needs every program cached, not just
    the slow ones. Failures degrade to a warning: the persistent cache
    is a warm-boot accelerator, never a serving prerequisite.
    """
    if not path:
        return False
    try:
        from jax.experimental.compilation_cache import compilation_cache as cc

        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
        cc.set_cache_dir(path)
        print(f"[serve_cv] XLA compilation cache -> {path}")
        return True
    except Exception as e:  # noqa: BLE001 - best-effort accelerator
        print(f"[serve_cv] warning: compilation cache unavailable: {e}")
        return False


def start_profile(profile_dir):
    """Begin a jax.profiler capture; returns True when it actually started.

    Failures (unsupported backend, missing tensorboard plugin, already
    active) degrade to a warning — profiling is an extra, never a
    prerequisite for serving.
    """
    if not profile_dir:
        return False
    try:
        jax.profiler.start_trace(profile_dir)
        print(f"[serve_cv] profiling -> {profile_dir}")
        return True
    except Exception as e:  # noqa: BLE001 - best-effort tooling
        print(f"[serve_cv] warning: profiler failed to start: {e}")
        return False


def stop_profile(started):
    if not started:
        return
    try:
        jax.profiler.stop_trace()
        print("[serve_cv] profile capture complete")
    except Exception as e:  # noqa: BLE001 - best-effort tooling
        print(f"[serve_cv] warning: profiler failed to stop: {e}")


def print_stage_summary(engine):
    """Per-stage p50/p95 over the tracer ring (with --metrics)."""
    summary = engine.tracer.summary()
    if not summary:
        print("[serve_cv] no traces recorded")
        return
    print("[serve_cv] stage latency (over last "
          f"{len(engine.tracer.last(engine.tracer.ring_size))} traces):")
    for stage, s in summary.items():
        print(f"[serve_cv]   {stage:<12} n={s['count']:<5} "
              f"p50={s['p50_s'] * 1e3:8.3f}ms  p95={s['p95_s'] * 1e3:8.3f}ms")


def serve_http(engine, args, record):
    """Expose the engine over the HTTP/SSE edge until interrupted."""
    import signal

    from repro.serve.http import HTTPEdge

    # Process supervisors (systemd, docker stop, k8s) stop services with
    # SIGTERM; route it through KeyboardInterrupt so the shutdown path —
    # including the --record-traffic dump below — runs either way.
    signal.signal(signal.SIGTERM, signal.default_int_handler)

    async def run_edge():
        edge = HTTPEdge(engine, host=args.http_host, port=args.http,
                        record=record)
        await edge.start()
        print(f"[serve_cv] http edge listening on {edge.url} "
              f"(POST /v1/workloads, /v1/workloads/stream, /v1/datasets; "
              f"GET /v1/stats, /v1/datasets, /v1/metrics, /v1/trace, "
              f"/healthz)", flush=True)
        try:
            await edge.serve_forever()
        finally:
            await edge.stop()

    try:
        asyncio.run(run_edge())
    except KeyboardInterrupt:
        print("[serve_cv] http edge shut down")
    finally:
        # The edge's stop path flushes too, but a KeyboardInterrupt can
        # land before/after it — make write-behind durability explicit.
        engine.flush_store()
        if args.record_traffic and record is not None:
            record.save(args.record_traffic)
            print(f"[serve_cv] recorded {len(record)} (task, bucket) "
                  f"entries -> {args.record_traffic}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--data", default="synthetic", choices=("synthetic", "eeg"))
    ap.add_argument("--datasets", type=int, default=3,
                    help="distinct datasets cycled through the stream")
    ap.add_argument("--n", type=int, default=96, help="samples per dataset")
    ap.add_argument("--p", type=int, default=768,
                    help="features (synthetic only; eeg fixes P=1900)")
    ap.add_argument("--k", type=int, default=6, help="CV folds")
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--perm", type=int, default=64,
                    help="permutations per permutation workload")
    ap.add_argument("--clients", type=int, default=0,
                    help="if > 1, replay warm through this many threads")
    ap.add_argument("--async", type=int, default=0, dest="async_clients",
                    metavar="N", help="if > 1, replay warm through the "
                    "asyncio transport with N coroutine clients + stream demo")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-build plans + pre-compile eval buckets "
                    "before the first timed pass")
    ap.add_argument("--pin", action="store_true",
                    help="with --warmup/--warmup-from: pin the warmed "
                    "plans (never LRU-evicted)")
    ap.add_argument("--record-traffic", metavar="FILE", default=None,
                    help="dump the served (task, bucket) set as JSON")
    ap.add_argument("--warmup-from", metavar="FILE", default=None,
                    help="replay a recorded traffic set at boot "
                    "(pre-builds plans + pre-compiles exactly the "
                    "programs that traffic needed)")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve the Workload API over HTTP/SSE on this "
                    "port (after any --warmup/--warmup-from) instead of "
                    "replaying a local stream; 0 picks a free port")
    ap.add_argument("--http-host", default="127.0.0.1",
                    help="bind address for --http (default loopback)")
    ap.add_argument("--metrics", action="store_true",
                    help="enable request tracing + per-stage latency "
                    "histograms (served at GET /v1/metrics and /v1/trace "
                    "with --http; printed as a p50/p95 stage summary "
                    "otherwise)")
    ap.add_argument("--trace-ring", type=int, default=256, metavar="N",
                    help="finished traces kept for /v1/trace and the "
                    "stage summary (with --metrics; default 256)")
    ap.add_argument("--profile-dir", metavar="DIR", default=None,
                    help="capture a jax.profiler trace of warm-up plus "
                    "the first timed pass into DIR (view with "
                    "TensorBoard or Perfetto)")
    ap.add_argument("--plan-store", metavar="DIR", default=None,
                    help="durable plan-store directory: cache misses load "
                    "verified plans from here before rebuilding")
    ap.add_argument("--save-plans", action="store_true",
                    help="with --plan-store: persist every freshly built "
                    "plan (write-behind) for the next boot")
    ap.add_argument("--compilation-cache", metavar="DIR", default=None,
                    help="persistent XLA compilation cache directory "
                    "(jax.experimental.compilation_cache); repeat boots "
                    "skip compile time for already-seen programs")
    ap.add_argument("--store-mb", type=int, default=4096,
                    help="plan-store byte budget in MiB (GC evicts oldest "
                    "entries over it; default 4096)")
    ap.add_argument("--cache-mb", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--window", type=int, default=0, metavar="N",
                    help="sliding-window mode: advance the first dataset "
                    "N times (retire the oldest test row per fold, append "
                    "fresh rows via kind=\"update\" rank-k corrections) "
                    "and serve CV against each new version; prints "
                    "steady-state latency and compile flatness")
    ap.add_argument("--rsa", action="store_true",
                    help="serve an RSA workload stream instead of mixed CV")
    ap.add_argument("--conditions", type=int, default=6,
                    help="RSA conditions per dataset (with --rsa)")
    ap.add_argument("--debug-nans", action="store_true",
                    help="enable jax_debug_nans: every jitted eval re-runs "
                    "eagerly on a NaN and raises at the producing op "
                    "(slow; for triaging numeric blowups, not serving)")
    args = ap.parse_args()

    if args.save_plans and not args.plan_store:
        ap.error("--save-plans requires --plan-store DIR")
    if args.debug_nans:
        jax.config.update("jax_debug_nans", True)
        print("[serve_cv] jax_debug_nans on: evals re-run de-optimized on NaN")
    setup_compilation_cache(args.compilation_cache)

    engine = CVEngine(EngineConfig(
        cache_bytes=args.cache_mb << 20,
        plan_store=args.plan_store,
        save_plans=args.save_plans,
        store_bytes=args.store_mb << 20,
    ))
    if args.plan_store:
        print(f"[serve_cv] plan store -> {args.plan_store} "
              f"({len(engine.store)} entries, "
              f"{engine.store.stats.bytes_in_store / 2**20:.1f} MiB resident"
              f"{', save-plans' if args.save_plans else ', read-only'})")
    if args.metrics:
        engine.enable_tracing(ring=args.trace_ring)
    record = TrafficLog() if args.record_traffic else None
    client = Client(engine, record=record)
    if args.rsa:
        workloads, datasets = build_rsa_workloads(args, client)
        print(f"[serve_cv] RSA mode: {len(workloads)} workloads over "
              f"{args.datasets} datasets, C={args.conditions}, λ={args.lam}, "
              f"K={args.k}, T={args.perm}")
    else:
        workloads, datasets = build_workloads(args, client)
        print(f"[serve_cv] {len(workloads)} workloads over {args.datasets} "
              f"datasets ({args.data}), λ={args.lam}, K={args.k}, "
              f"T={args.perm}")

    # Profile window: warm-up (plan builds + compiles) plus the first
    # timed pass — the region where all the interesting XLA work happens.
    profiling = start_profile(args.profile_dir)

    if args.warmup_from:
        warmup_from_traffic(engine, args.warmup_from, datasets, args.pin)
    if args.warmup:
        warmup_engine(engine, args, datasets)

    if args.http is not None:
        stop_profile(profiling)
        serve_http(engine, args, record)
        return

    if args.window:
        if args.rsa:
            ap.error("--window composes with the mixed-CV stream, not --rsa")
        run_window(client, args, datasets)
        stop_profile(profiling)
        return

    def ready(rs):
        jax.block_until_ready([r.values for r in rs if hasattr(r, "values")]
                              + [r.rdm for r in rs if hasattr(r, "rdm")])

    t0 = time.perf_counter()
    responses = client.gather(workloads)
    ready(responses)
    t_cold = time.perf_counter() - t0
    stop_profile(profiling)

    compiles_after_cold = engine.compile_count()
    t0 = time.perf_counter()
    responses = client.gather(workloads)
    ready(responses)
    t_warm = time.perf_counter() - t0
    warm_recompiles = engine.compile_count() - compiles_after_cold

    print(f"[serve_cv] cold: {t_cold:.3f}s ({len(workloads)/t_cold:.1f} req/s)"
          f"   warm: {t_warm:.3f}s ({len(workloads)/t_warm:.1f} req/s)"
          f"   speedup {t_cold/t_warm:.1f}x, "
          f"recompiles on warm replay: {warm_recompiles}")

    if args.clients > 1:
        import threading
        per_client = -(-len(workloads) // args.clients)
        with Client(engine, transport="thread", max_batch=per_client) as tclient:
            results = [None] * len(workloads)

            def one_client(cid):
                lo = cid * per_client
                futs = [(j, tclient.submit(workloads[j]))
                        for j in range(lo, min(lo + per_client, len(workloads)))]
                for j, f in futs:
                    results[j] = f.result(timeout=600)

            t0 = time.perf_counter()
            threads = [threading.Thread(target=one_client, args=(c,))
                       for c in range(args.clients)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            t_threaded = time.perf_counter() - t0
            print(f"[serve_cv] threaded ({args.clients} clients): "
                  f"{t_threaded:.3f}s ({len(workloads)/t_threaded:.1f} req/s) "
                  f"in {tclient.server.batches_served} micro-batches")
        assert all(r is not None for r in results)

    if args.async_clients > 1:
        demo = None
        if not args.rsa:
            handle, _x, y_bin = datasets[0][0], datasets[0][1], datasets[0][2]
            demo = Workload(kind="permutation", dataset=handle, y=y_bin,
                            n_perm=4 * args.perm, seed=99)
        asyncio.run(replay_async(engine, workloads, args.async_clients,
                                 perm_demo=demo))

    if args.record_traffic:
        record.save(args.record_traffic)
        print(f"[serve_cv] recorded {len(record)} (task, bucket) entries "
              f"-> {args.record_traffic}")

    engine.flush_store()
    stats = engine.stats()
    if args.plan_store:
        print(f"[serve_cv] plan store: {stats['store_hits']} hits / "
              f"{stats['store_misses']} misses / {stats['store_writes']} "
              f"writes, {stats['store_bytes'] / 2**20:.1f} MiB on disk")
    print(f"[serve_cv] cache: {stats['hits']} hits / {stats['misses']} misses "
          f"/ {stats['evictions']} evictions / {stats['pinned']} pinned, "
          f"{stats['bytes_in_use'] / 2**20:.1f} MiB in use "
          f"(budget {stats['byte_budget'] / 2**20:.0f} MiB)")
    print(f"[serve_cv] plans built: {stats['plans_built']}, "
          f"labels evaluated: {stats['labels_evaluated']}, "
          f"compiled programs: {stats['compiles']}, "
          f"RDM cache hits: {stats['rdm_hits']}")
    scored = [float(r.score) for r in responses if hasattr(r, "score")]
    if scored:
        print(f"[serve_cv] mean CV score over {len(scored)} CV workloads: "
              f"{sum(scored)/len(scored):.3f}")
    rsa_scored = [r for r in responses
                  if hasattr(r, "model_scores") and r.model_scores is not None]
    if rsa_scored:
        best = [float(jnp.max(r.model_scores)) for r in rsa_scored]
        sig = [float(jnp.min(r.p)) for r in rsa_scored if r.p is not None]
        print(f"[serve_cv] RSA: best-model score mean "
              f"{sum(best)/len(best):.3f} over {len(rsa_scored)} scored "
              f"workloads" + (f", min p {min(sig):.4f}" if sig else ""))
    if args.metrics:
        print_stage_summary(engine)


if __name__ == "__main__":
    main()
