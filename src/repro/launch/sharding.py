"""Logical-axis sharding: rules mapping model tensors onto the mesh.

Megatron-style TP over the "model" axis, DP over ("pod", "data"), optional
sequence parallelism (residual stream sharded over "model" on the seq dim
between blocks), expert parallelism (experts over "model"), and ZeRO-1
(optimizer state additionally sharded over "data").

Models never name mesh axes directly; they call :func:`constrain` with
*logical* axis names which resolve through ``LOGICAL_RULES`` against the
currently active mesh (no-op when no mesh is active — CPU smoke tests).
"""

from __future__ import annotations

import re
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "axis_ctx", "constrain", "param_spec", "param_sharding_tree",
    "opt_state_spec", "data_spec", "LOGICAL_RULES", "set_sequence_parallel",
]

_state = threading.local()

# logical axis -> mesh axis (None = replicate)
LOGICAL_RULES: dict[str, Optional[object]] = {
    "batch": ("pod", "data"),
    "batch_dp": ("pod", "data"),   # always DP-only (MoE dispatch: "model" carries experts)
    "batch_unembed": ("pod", "data"),  # embed/unembed batch: must match the
                                       # vocab-sharded logits' batch axes, or
                                       # the tied-embedding backward all-gathers
                                       # the GLOBAL (B,S,V) logits (§Perf H1 it.3)
    "seq": None,              # "model" when sequence parallelism is on
    "embed": None,
    "heads": "model",
    "kv_heads": None,         # too few kv heads on most archs; see kv rule
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "rnn": "model",
    "vision_seq": None,
    "codebooks": None,
}


def set_sequence_parallel(enabled: bool) -> None:
    LOGICAL_RULES["seq"] = "model" if enabled else None


# Embedding lookup strategy (§Perf hillclimb): with a vocab-sharded table,
# a plain gather makes GSPMD mask-and-psum a full (B,S,D) activation —
# huge. "gathered" instead all-gathers the (V,D) table once per step
# (bounded by the table size) and gathers locally.
GATHERED_EMBED = False


def set_gathered_embed(enabled: bool) -> None:
    global GATHERED_EMBED
    GATHERED_EMBED = enabled


_PROFILES = {
    # megatron-style TP over "model" (baseline)
    "tp": {"heads": "model", "ffn": "model", "rnn": "model",
           "experts": "model", "vocab": "model",
           "batch": ("pod", "data")},
    # DP-heavy: weights replicated over "model" (ZeRO-1 still shards the
    # optimizer over "data"); vocab stays sharded so (B,S,V) logits never
    # materialise unsharded; experts stay sharded (MoE params don't fit
    # replicated). Right call for small-d_model archs where per-layer TP
    # all-reduces dwarf compute (§Perf H1/H2).
    # batch shards over "model" too (full 256/512-way DP) — without this
    # the model axis idles and compute is replicated 16x (§Perf H1 iter 1,
    # refuted-then-fixed hypothesis).
    "dp": {"heads": None, "ffn": None, "rnn": None,
           "experts": "model", "vocab": "model",
           "batch": ("pod", "data", "model")},
    # pure DP over (pod, data) with the model axis idle except vocab/experts:
    # for tiny recurrent archs (xlstm) whose sequential scans emit a
    # collective per step under any "model" sharding of the cell state
    # (§Perf H2 iter 3) — trading replicated compute for a collective-free
    # inner loop.
    "dp16": {"heads": None, "ffn": None, "rnn": None,
             "experts": "model", "vocab": "model",
             "batch": ("pod", "data")},
    # FSDP: like "dp" (replicated compute layout, 256-way batch) but the
    # weights are stored fully sharded over (data, model) and all-gathered
    # at use — params/optimizer resident bytes drop ~256x for the cost of
    # one weight AG per layer per pass (§Perf H1 final iteration).
    "fsdp": {"heads": None, "ffn": None, "rnn": None,
             "experts": "model", "vocab": "model",
             "batch": ("pod", "data", "model")},
}

FSDP = False


def apply_profile(name: str) -> None:
    global FSDP
    FSDP = name == "fsdp"
    for k, v in _PROFILES[name].items():
        LOGICAL_RULES[k] = v


class axis_ctx:
    """Context manager activating a mesh for :func:`constrain`."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        _state.mesh = self.mesh
        return self.mesh

    def __exit__(self, *exc):
        _state.mesh = None


def _active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def _resolve(logical_axes, mesh: Mesh) -> P:
    raw = []
    for ax in logical_axes:
        mesh_ax = LOGICAL_RULES.get(ax) if ax is not None else None
        if mesh_ax is None:
            raw.append(())
            continue
        axes = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
        raw.append(tuple(a for a in axes if a in mesh.axis_names))
    # resolve duplicates: single-axis entries (e.g. vocab -> "model") claim
    # their axis first; multi-axis (batch) tuples drop already-claimed axes
    claimed = {a for axes in raw if len(axes) == 1 for a in axes}
    spec = []
    seen = set()
    for axes in raw:
        if len(axes) > 1:
            axes = tuple(a for a in axes if a not in claimed and a not in seen)
        else:
            axes = tuple(a for a in axes if a not in seen)
        seen.update(axes)
        spec.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*spec)


def constrain(x: jax.Array, logical_axes) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    spec = _resolve(logical_axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules: path-pattern -> logical axes per dimension.
# Scanned parameter stacks carry a leading "layers" dim (replicated).
# ---------------------------------------------------------------------------

_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/tokens$", ("vocab", "embed")),
    (r"embed/codebook_\d+$", ("vocab", "embed")),
    (r"lm_head$", ("embed", "vocab")),
    (r"lm_head_\d+$", ("embed", "vocab")),
    (r"vision_proj/w$", (None, "embed")),
    # attention
    (r"attn/wq$", ("embed", "heads", "head_dim")),
    (r"attn/wk$", ("embed", "kv_heads", "head_dim")),
    (r"attn/wv$", ("embed", "kv_heads", "head_dim")),
    (r"attn/wo$", ("heads", "head_dim", "embed")),
    (r"attn/(q_norm|k_norm)$", ("head_dim",)),
    # dense mlp
    (r"mlp/w_(gate|up)$", ("embed", "ffn")),
    (r"mlp/w_down$", ("ffn", "embed")),
    # moe: expert-parallel over "model"; per-expert F is small (768-1024),
    # so weights shard on the expert axis only (EP, not EP+TP)
    (r"moe/router$", ("embed", None)),
    (r"moe/w_(gate|up)$", ("experts", None, None)),
    (r"moe/w_down$", ("experts", None, None)),
    # rg-lru
    (r"rglru/w_(x|gate)$", ("embed", "rnn")),
    (r"rglru/w_out$", ("rnn", "embed")),
    (r"rglru/(conv_w)$", (None, "rnn")),
    (r"rglru/(conv_b|a_param|w_a_b|w_x_b)$", ("rnn",)),
    (r"rglru/w_a$", ("rnn",)),
    (r"rglru/w_input_gate$", ("rnn",)),
    # xlstm
    (r"(mlstm|slstm)/w_(up|ffgate)$", ("embed", "ffn")),
    (r"(mlstm|slstm)/w_down$", ("ffn", "embed")),
    (r"(mlstm|slstm)/w_(q|k|v|i|f|o|zg)$", ("embed", "ffn")),
    (r"(mlstm|slstm)/r_(i|f|z|o)$", (None, "ffn", None)),
    (r"(mlstm|slstm)/conv_w$", (None, "ffn")),
    (r"(mlstm|slstm)/(conv_b|b_.*|skip_scale)$", ("ffn",)),
    (r"(mlstm|slstm)/gn$", ("ffn",)),
]


def param_spec(path: str, ndim: int) -> P:
    """PartitionSpec for a parameter given its tree path and rank."""
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            axes = tuple(axes)
            if ndim == len(axes) + 1:          # scanned stack: leading layer dim
                axes = (None,) + axes
            if len(axes) > ndim:
                axes = tuple(axes[:ndim])
            elif len(axes) < ndim:
                axes = axes + (None,) * (ndim - len(axes))
            return P(*[
                (LOGICAL_RULES.get(a) if isinstance(a, str) else None)
                for a in axes
            ])
    return P(*([None] * ndim))                  # norms, biases, gates: replicate


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
        out.append(("/".join(parts), leaf))
    return out, treedef


def _fsdp_spec(spec: P, shape, mesh: Mesh) -> P:
    """Shard the largest still-replicated dim over the unused DP axes."""
    spec = list(spec)
    used = set()
    for ax in spec:
        for a in ((ax,) if isinstance(ax, str) else (ax or ())):
            used.add(a)
    axes = tuple(a for a in ("data", "model") if a in mesh.axis_names
                 and a not in used)
    if not axes:
        return P(*spec)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    best, best_dim = None, 0
    for i, ax in enumerate(spec):
        if ax is None and shape[i] % size == 0 and shape[i] > best_dim:
            best, best_dim = i, shape[i]
    if best is not None and best_dim >= size:
        spec[best] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def param_sharding_tree(params, mesh: Mesh):
    """NamedSharding tree for a parameter pytree."""
    flat, treedef = _flatten_with_paths(params)
    shardings = []
    for path, leaf in flat:
        spec = _sanitize(param_spec(path, np.ndim(leaf)), np.shape(leaf), mesh)
        if FSDP and int(np.prod(np.shape(leaf))) > 1 << 16:
            spec = _fsdp_spec(spec, np.shape(leaf), mesh)
        shardings.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, shardings)


def _sanitize(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim evenly."""
    out = []
    for i, ax in enumerate(spec):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if shape[i] % size == 0 else None)
    return P(*out)


def opt_state_spec(path: str, ndim: int, shape, mesh: Mesh) -> P:
    """ZeRO-1: optimizer moments/master take the param spec plus an extra
    shard over the unused DP axes on the largest replicated dim."""
    spec = list(_sanitize(param_spec(path, ndim), shape, mesh))
    used = set()
    for ax in spec:
        if isinstance(ax, str):
            used.add(ax)
        elif isinstance(ax, tuple):
            used.update(ax)
    for extra in (("data", "model"), ("data",)):
        axes = tuple(a for a in extra if a in mesh.axis_names and a not in used)
        if not axes:
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        best, best_dim = None, 0
        for i, ax in enumerate(spec):
            if ax is None and shape[i] % size == 0 and shape[i] > best_dim:
                best, best_dim = i, shape[i]
        if best is not None:
            spec[best] = axes if len(axes) > 1 else axes[0]
            return P(*spec)
    return P(*spec)


def data_spec(mesh: Mesh, *logical_axes) -> NamedSharding:
    return NamedSharding(mesh, _resolve(logical_axes, mesh))


def constrain_like_opt(tree):
    """Constrain a param-shaped pytree (e.g. the f32 gradient accumulator
    in microbatched training) to the ZeRO-1 optimizer sharding: the
    accumulator then costs 1/|data| of the param bytes instead of a full
    f32 copy per chip. No-op without an active mesh."""
    mesh = _active_mesh()
    if mesh is None:
        return tree
    flat, treedef = _flatten_with_paths(tree)
    out = [jax.lax.with_sharding_constraint(
        leaf, NamedSharding(mesh, opt_state_spec(
            path, np.ndim(leaf), np.shape(leaf), mesh)))
        for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
