import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first init): the dry-run — and only the dry-run — sees 512
placeholder host devices so ``jax.make_mesh`` can build the production
meshes (16×16 single-pod, 2×16×16 multi-pod).

For every cell this driver:
  1. builds ShapeDtypeStruct stand-ins for params / optimizer state /
     batch / caches (jax.eval_shape — no allocation),
  2. attaches NamedShardings from repro.launch.sharding's rules,
  3. ``jax.jit(step).lower(...).compile()`` — success proves the
     distribution config is coherent,
  4. records memory_analysis / cost_analysis / per-collective bytes
     (parsed from the post-SPMD HLO) into results/dryrun/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, get_config, list_archs
from repro.launch.hlo_analysis import analyze_hlo
from repro.configs import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.launch import sharding as sh
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import optimizer as O
from repro.train import steps

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


# ------------------------------------------------------------- input specs --

def _dp_axes(mesh: Mesh):
    rule = sh.LOGICAL_RULES.get("batch") or ("pod", "data")
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    return tuple(a for a in axes if a in mesh.axis_names)


def _sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _tree_sds(shapes_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh_: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh_),
        shapes_tree, sharding_tree)


def _activation_like_spec(shape, batch_sizes, mesh: Mesh) -> P:
    """Heuristic cache/state spec: batch dim -> DP axes; largest remaining
    model-divisible dim -> "model" (memory-first layout for decode caches)."""
    dp = _dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    m = mesh.shape.get("model", 1)
    spec = [None] * len(shape)
    for i, s in enumerate(shape):
        if s in batch_sizes and s % dp_size == 0:
            spec[i] = dp if len(dp) > 1 else (dp[0] if dp else None)
            break
    best, best_size = None, 0
    for i, s in enumerate(shape):
        if spec[i] is None and s % m == 0 and s > best_size and s >= m:
            best, best_size = i, s
    if best is not None and m > 1:
        spec[best] = "model"
    return P(*spec)


def _cache_sharding(cache_shapes, batch, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _activation_like_spec(s.shape, {batch}, mesh)),
        cache_shapes)


def _opt_sharding(opt_shapes, mesh: Mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shapes)
    out = []
    for path, leaf in flat:
        parts = [str(getattr(p, "key", getattr(p, "idx", "")))
                 for p in path]
        pathstr = "/".join(parts)
        spec = sh.opt_state_spec(pathstr, len(leaf.shape), leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def input_specs(cfg: ArchConfig, shape: shp.Shape, mesh: Mesh,
                microbatches: int = 1, accum_dtype=None):
    """ShapeDtypeStruct stand-ins (+shardings) for one cell. Returns
    (step_fn, example_args dict ready for .lower(**args))."""
    dp = _dp_axes(mesh)
    b, s = shape.global_batch, shape.seq_len
    # drop axes from the right until the global batch divides evenly
    while dp and b % int(np.prod([mesh.shape[a] for a in dp])) != 0:
        dp = dp[:-1]
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    key = jax.random.PRNGKey(0)

    params_shapes = jax.eval_shape(lambda: M.init_params(key, cfg))
    params_sh = sh.param_sharding_tree(params_shapes, mesh)
    params = _tree_sds(params_shapes, params_sh)

    def batch_specs(seq):
        specs = {}
        if cfg.num_codebooks:
            specs["tokens"] = _sds((b, cfg.num_codebooks, seq), jnp.int32,
                                   mesh, P(dp_spec, None, None))
            specs["labels"] = _sds((b, cfg.num_codebooks, seq), jnp.int32,
                                   mesh, P(dp_spec, None, None))
        else:
            specs["tokens"] = _sds((b, seq), jnp.int32, mesh, P(dp_spec, None))
            specs["labels"] = _sds((b, seq), jnp.int32, mesh, P(dp_spec, None))
        if cfg.vision_tokens:
            specs["vision_embeds"] = _sds(
                (b, cfg.vision_tokens, cfg.vision_dim), jnp.float32, mesh,
                P(dp_spec, None, None))
        return specs

    if shape.kind == "train":
        opt_cfg = O.AdamWConfig()
        opt_shapes = jax.eval_shape(
            lambda p: O.init_opt_state(p, opt_cfg), params_shapes)
        opt_sh_tree = _opt_sharding(opt_shapes, mesh)
        opt_state = _tree_sds(opt_shapes, opt_sh_tree)
        fn = steps.make_train_step(cfg, opt_cfg, microbatches=microbatches,
                                   accum_dtype=accum_dtype or jnp.float32)
        args = dict(params=params, opt_state=opt_state,
                    batch=batch_specs(s))
        donate = ("params", "opt_state")
        return fn, args, donate

    if shape.kind == "prefill":
        fn = steps.make_prefill_step(cfg, microbatches=microbatches)
        batch = batch_specs(s)
        batch.pop("labels")
        return fn, dict(params=params, batch=batch), ()

    # decode: one new token against a cache of seq_len
    cache_shapes = jax.eval_shape(
        lambda: T.init_trunk_cache(cfg, b, s))
    caches = _tree_sds(cache_shapes, _cache_sharding(cache_shapes, b, mesh))
    tok_shape = (b, cfg.num_codebooks, 1) if cfg.num_codebooks else (b, 1)
    tok_spec = P(dp_spec, None, None) if cfg.num_codebooks else P(dp_spec, None)
    fn = steps.make_decode_step(cfg)
    args = dict(params=params,
                tokens=_sds(tok_shape, jnp.int32, mesh, tok_spec),
                pos=_sds((), jnp.int32, mesh, P()),
                caches=caches)
    return fn, args, ("caches",)


# ---------------------------------------------------------- HLO collectives --

def _shape_bytes(shape_str: str) -> int:
    """'bf16[48,16,4096]{...}' -> bytes. Scalars: 'f32[]'."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    pattern = re.compile(
        r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start|-done)?\(")
    seen_done = set()
    for line in hlo_text.splitlines():
        m = pattern.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue   # count the -start only (async pairs)
        shapes, op = m.groups()
        total = sum(_shape_bytes(s) for s in
                    re.findall(r"[a-z0-9]+\[[0-9,]*\][^,)\s]*", shapes))
        out[op] += total
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# -------------------------------------------------------------------- cell --

def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             donate: bool = True, profile: str = "tp",
             gathered_embed: bool = False, tag: str = "",
             microbatches: int = 1, kv_quant: bool = False,
             accum_dtype=None) -> dict:
    cfg = get_config(arch)
    shape = shp.get_shape(shape_name)
    if kv_quant and shape.kind in ("prefill", "decode"):
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if cfg.moe_experts and shape.kind != "train":
        # inference capacity factor 1.0 (standard serving practice):
        # shrinks dispatch transients ~20% with negligible routing drops
        cfg = dataclasses.replace(cfg, moe_capacity_factor=1.0)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    sh.apply_profile(profile)
    sh.set_gathered_embed(gathered_embed)
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "profile": profile, "gathered_embed": gathered_embed, "tag": tag,
              "microbatches": microbatches, "kv_quant": kv_quant,
              "kind": shape.kind, "seq_len": shape.seq_len,
              "global_batch": shape.global_batch,
              "num_chips": int(np.prod(list(mesh.shape.values())))}
    try:
        fn, args, donated = input_specs(cfg, shape, mesh,
                                        microbatches=microbatches,
                                        accum_dtype=accum_dtype)
        argnames = list(args.keys())
        donate_argnums = tuple(argnames.index(d) for d in donated) if donate else ()

        with sh.axis_ctx(mesh):
            jitted = jax.jit(fn, donate_argnums=donate_argnums)
            lowered = jitted.lower(*[args[k] for k in argnames])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        loop_aware = analyze_hlo(hlo)
        result.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            "cost": {
                "flops": cost.get("flops"),
                "bytes_accessed": cost.get("bytes accessed"),
                "transcendentals": cost.get("transcendentals"),
            },
            "collectives": coll,
            "loop_aware": {
                "flops": loop_aware["flops"],
                "dot_hbm_bytes": loop_aware["dot_hbm_bytes"],
                "collective_bytes": loop_aware["collective_bytes"],
                "collective_counts": loop_aware["collective_counts"],
                "collective_total_bytes": loop_aware["collective_total_bytes"],
            },
            "hlo_lines": hlo.count("\n"),
        })
        print(f"[dryrun] OK   {cell_id}  lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s flops={loop_aware['flops']:.3e} "
              f"coll={loop_aware['collective_total_bytes']:.3e}B")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]})
        print(f"[dryrun] FAIL {cell_id}: {type(e).__name__}: {e}")
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell_id}.json").write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--profile", default="tp", choices=["tp", "dp", "dp16", "fsdp"])
    ap.add_argument("--gathered-embed", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--microbatches", type=int, default=8,
                    help="gradient-accumulation steps for train cells "
                    "(activation memory scales 1/mu; see steps.make_train_step)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV caches for prefill/decode cells")
    args = ap.parse_args()

    # per-arch memory plans (validated against 16 GB/chip; EXPERIMENTS §Dry-run)
    # keep µ-chunks >= DP size or GSPMD replicates compute across the idle
    # DP shards (measured 4.4x flops at µ=64 on internlm2; §Dry-run)
    train_mu = {"internlm2-20b": 16, "qwen3-moe-30b-a3b": 16}
    train_accum = {"internlm2-20b": jnp.bfloat16,
                   "qwen3-moe-30b-a3b": jnp.bfloat16}
    prefill_mu = {"olmoe-1b-7b": 2, "qwen3-moe-30b-a3b": 2}

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        shape_names = (shp.cells_for(cfg) if args.shape == "all"
                       else args.shape.split(","))
        for shape_name in shape_names:
            if shape_name not in shp.cells_for(cfg):
                print(f"[dryrun] SKIP {arch}×{shape_name} (documented skip)")
                n_skip += 1
                continue
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                suffix = f"__{args.tag}" if args.tag else ""
                f = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
                if args.skip_existing and f.exists() and \
                        json.loads(f.read_text()).get("ok"):
                    n_ok += 1
                    continue
                shape = shp.get_shape(shape_name)
                mesh0 = make_production_mesh(multi_pod=multi)
                dpn0 = int(np.prod([mesh0.shape[a] for a in ("pod", "data")
                                    if a in mesh0.axis_names]))
                if shape.kind == "train":
                    # µ-chunks must stay >= DP size (see train_mu note)
                    mb = min(train_mu.get(arch, args.microbatches),
                             max(shape.global_batch // dpn0, 1))
                elif shape.kind == "prefill":
                    # MoE archs only: chunk while keeping chunks >= DP size
                    mesh = make_production_mesh(multi_pod=multi)
                    dpn = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                                       if a in mesh.axis_names]))
                    mb = min(prefill_mu.get(arch, 1),
                             max(shape.global_batch // dpn, 1))
                else:
                    mb = 1
                r = run_cell(arch, shape_name, multi, out_dir,
                             profile=args.profile,
                             gathered_embed=args.gathered_embed, tag=args.tag,
                             microbatches=mb, kv_quant=args.kv_quant,
                             accum_dtype=(train_accum.get(arch)
                                          if shape.kind == "train" else None))
                n_ok += int(r["ok"])
                n_fail += int(not r["ok"])
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
