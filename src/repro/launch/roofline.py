"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape) on the single-pod mesh:

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_dot_HBM_bytes_per_chip / HBM_bw   (+ optimizer traffic)
  collective term = collective_bytes_per_chip / link_bw

All three in seconds-per-step; the max identifies the bottleneck. FLOPs
and bytes come from the loop-aware HLO analysis (repro.launch.hlo_analysis)
— XLA's cost_analysis counts while bodies once, which undercounts
scan-over-layers programs (calibrated in tests/test_hlo_analysis.py); the
raw cost_analysis numbers are kept in the JSON for reference.

MODEL_FLOPS = 6·N·D (train, dense) / 6·N_active·D (MoE); 2·N·D for
prefill/decode (forward only). The ratio MODEL_FLOPS / (HLO_FLOPs × chips)
shows how much compiled compute is "useful" (remat and attention terms
push it below 1; values ≫1 would indicate undercounting).

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs.base import get_config
from repro.configs import shapes as shp

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

__all__ = ["roofline_row", "build_table", "main"]


def _model_flops(cfg, shape) -> float:
    n = cfg.active_param_count() if cfg.moe_experts else cfg.param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n * tokens


def _opt_traffic_per_chip(cfg, num_chips) -> float:
    """AdamW: read+write master/mu/nu (f32) + read grads + write params."""
    n = cfg.param_count()
    return (3 * 2 * 4 + 4 + 2) * n / num_chips


def roofline_row(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = shp.get_shape(rec["shape"])
    chips = rec["num_chips"]
    la = rec["loop_aware"]
    flops = la["flops"]                       # per-chip
    mem_bytes = la["dot_hbm_bytes"]
    if shape.kind == "train":
        mem_bytes += _opt_traffic_per_chip(cfg, chips)
    coll_bytes = la["collective_total_bytes"]

    t_compute = flops / PEAK_FLOPS
    t_memory = mem_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_fl = _model_flops(cfg, shape)
    useful = model_fl / max(flops * chips, 1.0)
    # roofline fraction: useful work at peak vs the time the dominant
    # term needs — how close the step is to the hardware's best case
    t_ideal = model_fl / chips / PEAK_FLOPS
    frac = t_ideal / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "flops_per_chip": flops, "mem_bytes_per_chip": mem_bytes,
        "coll_bytes_per_chip": coll_bytes,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_fl, "useful_ratio": useful,
        "roofline_fraction": frac,
        "temp_bytes": (rec.get("memory") or {}).get("temp_bytes"),
    }


_SUGGEST = {
    "compute": "reduce recompute (remat policy) or use lower-precision matmuls",
    "memory": "fuse/elide HBM round-trips; larger microbatch amortises weight reads",
    "collective": "reshard to cut all-gathers (SP/ZeRO tuning) or overlap collectives with compute",
}


def build_table(dryrun_dir: Path, mesh: str = "16x16") -> tuple[str, list]:
    rows = []
    for f in sorted(dryrun_dir.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("ok") and "loop_aware" in rec:
            rows.append(roofline_row(rec))
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful | roofline frac | next move |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2%} | "
            f"{_SUGGEST[r['dominant']]} |")
    return "\n".join(lines), rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()
    table, rows = build_table(Path(args.dryrun_dir), args.mesh)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / f"roofline_{args.mesh}.md").write_text(table + "\n")
    (out / f"roofline_{args.mesh}.json").write_text(json.dumps(rows, indent=2))
    print(table)


if __name__ == "__main__":
    main()
