"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
        --steps 200 --seq-len 64 --batch 8

On the CPU container this drives the reduced (smoke) configs; on real
hardware the same driver takes ``--arch <id>`` full configs with the
production mesh (sharding rules resolve against whatever devices exist).
All fault-tolerance machinery is live: atomic async checkpoints, restart
(rerun the command, it resumes), straggler monitor, non-finite skipping.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import apply_overrides, get_config, list_archs
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.launch import sharding as sh
from repro.optim import optimizer as O
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd", "const"])
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--profile", default="tp", choices=["tp", "dp"])
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="config override field=value")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.overrides:
        cfg = apply_overrides(cfg, args.overrides)
    sh.apply_profile(args.profile)

    # MiniCPM ships with WSD (its paper's contribution); honour it by default
    schedule = args.schedule
    if args.arch == "minicpm-2b" and args.schedule == "cosine":
        schedule = "wsd"

    opt_cfg = O.AdamWConfig(lr_peak=args.lr, schedule=schedule,
                            warmup_steps=max(args.steps // 20, 5),
                            total_steps=args.steps,
                            compress_grads=args.compress_grads)
    scfg = TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, seed=0,
        num_codebooks=cfg.num_codebooks,
        vision_tokens=cfg.vision_tokens, vision_dim=cfg.vision_dim)
    tcfg = TrainerConfig(total_steps=args.steps,
                         checkpoint_every=args.checkpoint_every,
                         checkpoint_dir=args.checkpoint_dir,
                         log_every=max(args.steps // 20, 1))

    print(f"[train] arch={cfg.name} params≈{cfg.param_count():,} "
          f"devices={len(jax.devices())}")
    trainer = Trainer(cfg, opt_cfg, tcfg, TokenStream(scfg))
    summary = trainer.run()
    print(f"[train] done: final_loss={summary['final_loss']:.4f} "
          f"wall={summary['wall_s']:.1f}s skipped={summary['skipped']} "
          f"stragglers={summary['straggler_events']}")


if __name__ == "__main__":
    main()
