"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain placeholder devices; everything else sees the real
device count.

Mesh axes:
  single-pod:  ("data", "model")         = (16, 16)  -> 256 chips (v5e pod)
  multi-pod:   ("pod", "data", "model")  = (2, 16, 16) -> 512 chips

"model" carries TP/SP/EP; ("pod", "data") carry DP; "data" additionally
carries ZeRO-1 optimizer-state sharding; the paper's permutation/searchlight
workloads shard their embarrassingly-parallel problem axis over "pod".
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
