"""Loop-aware HLO analysis: FLOPs and collective bytes with trip counts.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — with
scan-over-layers (and inner scans: sLSTM over sequence, mLSTM chunks,
query-chunked attention) that undercounts by the trip count, and the same
applies to collectives inside loop bodies. This module parses the
post-SPMD HLO text into its computations, extracts while-loop trip counts
from their condition computations, propagates multipliers through the
call graph (while/fusion/call/conditional), and accumulates:

  * dot FLOPs:       2 · prod(result_shape) · prod(lhs contracting dims)
  * dot HBM bytes:   lhs + rhs + out bytes per dot (perfect-fusion lower
                     bound for the memory term)
  * collective bytes per op kind (result-shape convention)

Numbers are per-device (the HLO is the per-device SPMD program).
Verified against unrolled compilations in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

__all__ = ["analyze_hlo", "COLLECTIVE_OPS"]

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_info(s: str):
    m = _SHAPE_RE.match(s)
    if not m:
        return None
    dt, dims = m.groups()
    shape = [int(d) for d in dims.split(",")] if dims else []
    return dt, shape


def _nbytes(s: str) -> int:
    info = _shape_info(s)
    if info is None:
        return 0
    dt, shape = info
    n = 1
    for d in shape:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 0)


_HEADER_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*\S.*\{\s*$")


def _split_computations(hlo: str) -> Dict[str, list[str]]:
    comps: Dict[str, list[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _HEADER_RE.match(line)
        if m and "=" not in line.split("(")[0]:
            current = m.group(1)
            comps[current] = []
            continue
        if stripped in ("}", "})"):
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps


_CALL_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=\s*%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_RE = re.compile(
    r"=\s*\S+\s+while\(.*?body=\s*%?([\w\.\-]+).*?"
    r"|while\(.*", re.DOTALL)


def _while_edges(line: str):
    """(body, cond) names if this line is a while op."""
    if " while(" not in line:
        return None
    body = re.search(r"body=\s*%?([\w\.\-]+)", line)
    cond = re.search(r"condition=\s*%?([\w\.\-]+)", line)
    if body and cond:
        return body.group(1), cond.group(1)
    return None


def _trip_count(cond_lines: list[str]) -> int:
    """Max integer constant in the while condition ~ trip count.

    Scan-lowered conds compare the induction variable against the length;
    fallback 1 if nothing parses (counts the body once, like XLA)."""
    best = 1
    for line in cond_lines:
        if "constant(" not in line:
            continue
        for m in re.finditer(r"constant\((-?\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+\[[0-9,]*\])")


def _symbol_table(lines: list[str]) -> dict:
    """SSA name -> shape string, from definition lines."""
    tab = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            tab[m.group(1)] = m.group(2)
    return tab


def _dot_flops_bytes(line: str, symtab: dict):
    """(flops, hbm_bytes) for a dot op line, else None.

    Operand shapes come inline (`dot(f32[..] %a, ...)`) when present, else
    from the computation's symbol table (`dot(%a, %b)`, final-HLO style).
    """
    m = re.search(r"=\s*(\S+)\s+dot\((.*?)\)", line)
    if not m:
        return None
    result_s, operands_s = m.groups()
    res = _shape_info(result_s)
    if res is None:
        return None
    _, res_shape = res
    out_elems = 1
    for d in res_shape:
        out_elems *= d
    ops = re.findall(r"([a-z0-9]+\[[0-9,]*\])", operands_s)
    if len(ops) < 2:
        names = re.findall(r"%([\w\.\-]+)", operands_s)
        ops = [symtab[n] for n in names if n in symtab]
    lhs_c = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = 1
    if ops and lhs_c is not None:
        lhs_info = _shape_info(ops[0])
        if lhs_info:
            _, lhs_shape = lhs_info
            for d in lhs_c.group(1).split(","):
                if d != "" and int(d) < len(lhs_shape):
                    contract *= lhs_shape[int(d)]
    flops = 2 * out_elems * contract
    hbm = _nbytes(result_s.split("{")[0]) + sum(_nbytes(o) for o in ops)
    return flops, hbm


def analyze_hlo(hlo: str) -> dict:
    comps = _split_computations(hlo)

    # local stats per computation
    local = {}
    edges = defaultdict(list)      # comp -> [(child, multiplier)]
    for name, lines in comps.items():
        flops = 0
        dot_bytes = 0
        coll = {k: 0 for k in COLLECTIVE_OPS}
        coll_n = {k: 0 for k in COLLECTIVE_OPS}
        symtab = _symbol_table(lines)
        for line in lines:
            d = _dot_flops_bytes(line, symtab)
            if d:
                flops += d[0]
                dot_bytes += d[1]
            for op in COLLECTIVE_OPS:
                if f" {op}(" in line or f" {op}-start(" in line:
                    m = re.search(r"=\s*((?:\([^)]*\))|(?:\S+))\s+" + op, line)
                    if m:
                        shapes = re.findall(r"[a-z0-9]+\[[0-9,]*\]", m.group(1))
                        coll[op] += sum(_nbytes(s) for s in shapes)
                        coll_n[op] += 1
            we = _while_edges(line)
            if we:
                body, cond = we
                trips = _trip_count(comps.get(cond, []))
                edges[name].append((body, trips))
                edges[name].append((cond, trips))
            else:
                for m in _CALL_RE.finditer(line):
                    child = m.group(1)
                    if child in comps:
                        edges[name].append((child, 1))
                bm = _BRANCHES_RE.search(line)
                if bm:
                    for child in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        if child in comps:
                            edges[name].append((child, 1))
        local[name] = {"flops": flops, "dot_bytes": dot_bytes,
                       "coll": coll, "coll_n": coll_n}

    # entry = computation not referenced by anyone (prefer one named ENTRY
    # or containing ".entry"/"main")
    referenced = {c for kids in edges.values() for c, _ in kids}
    entries = [c for c in comps if c not in referenced]
    entry = None
    for c in entries:
        if "main" in c or "entry" in c:
            entry = c
            break
    if entry is None and entries:
        entry = max(entries, key=lambda c: local[c]["flops"])

    mult = defaultdict(float)
    if entry is not None:
        stack = [(entry, 1.0)]
        seen_pairs = defaultdict(float)
        while stack:
            comp, m = stack.pop()
            mult[comp] += m
            for child, trips in edges.get(comp, []):
                stack.append((child, m * trips))

    total_flops = 0.0
    total_dot_bytes = 0.0
    coll_bytes = {k: 0.0 for k in COLLECTIVE_OPS}
    coll_counts = {k: 0.0 for k in COLLECTIVE_OPS}
    for name, stats in local.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        total_flops += m * stats["flops"]
        total_dot_bytes += m * stats["dot_bytes"]
        for op in COLLECTIVE_OPS:
            coll_bytes[op] += m * stats["coll"][op]
            coll_counts[op] += m * stats["coll_n"][op]

    return {
        "flops": total_flops,
        "dot_hbm_bytes": total_dot_bytes,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "collective_total_bytes": sum(coll_bytes.values()),
        "num_computations": len(comps),
        "entry": entry,
    }
