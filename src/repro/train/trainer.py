"""Training loop with fault tolerance: checkpoint/restart, straggler
monitoring, non-finite-step skipping, elastic mesh restore.

Single-controller JAX: the same loop drives 1 CPU device (smoke) or a
512-chip mesh (via shardings from repro.launch.sharding); on a fleet the
controller restarts after failures and resumes from ``latest_step`` —
including onto a *different* mesh (elastic), because restore places
arrays against the new job's sharding tree.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.tokens import TokenStream
from repro.optim import optimizer as O
from repro.train import checkpoint as ckpt
from repro.train import steps as steps_lib
from repro.train.straggler import StepTimeMonitor

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    skip_nonfinite: bool = True
    straggler_threshold: float = 2.5


class Trainer:
    def __init__(self, cfg: ArchConfig, opt_cfg: O.AdamWConfig,
                 tcfg: TrainerConfig, stream: TokenStream,
                 mesh=None, shardings: Optional[tuple] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.stream = stream
        self.mesh = mesh
        self.monitor = StepTimeMonitor(threshold=tcfg.straggler_threshold)
        self.metrics_log: list[dict] = []

        self.params, self.opt_state = steps_lib.init_train_state(
            jax.random.PRNGKey(seed), cfg, opt_cfg)
        self._step_fn = jax.jit(steps_lib.make_train_step(cfg, opt_cfg),
                                donate_argnums=(0, 1))
        self.start_step = 0
        self._maybe_restore()

    # ------------------------------------------------------------- resume --
    def _maybe_restore(self):
        last = ckpt.latest_step(self.tcfg.checkpoint_dir)
        if last is None:
            return
        state = {"params": self.params, "opt_state": self.opt_state}
        restored, meta = ckpt.restore(self.tcfg.checkpoint_dir, last, state)
        self.params = restored["params"]
        self.opt_state = restored["opt_state"]
        self.start_step = last
        if "data" in meta:
            self.stream = TokenStream.restore(self.stream.cfg, meta["data"])
        print(f"[trainer] restored step {last} from {self.tcfg.checkpoint_dir}")

    def _checkpoint(self, step: int):
        ckpt.save_async(
            self.tcfg.checkpoint_dir, step,
            {"params": self.params, "opt_state": self.opt_state},
            metadata={"data": self.stream.checkpoint_state(),
                      "arch": self.cfg.name},
            keep=self.tcfg.keep_checkpoints)

    # --------------------------------------------------------------- loop --
    def run(self) -> dict:
        t_total = time.time()
        skipped = 0
        for step in range(self.start_step, self.tcfg.total_steps):
            batch = self.stream.next_batch()
            t0 = time.time()
            new_params, new_opt, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0

            if self.tcfg.skip_nonfinite and not np.isfinite(loss):
                # fault tolerance: drop the update, keep going
                skipped += 1
                print(f"[trainer] step {step}: non-finite loss, skipped")
                continue
            self.params, self.opt_state = new_params, new_opt

            if self.monitor.record(step, dt):
                print(f"[trainer] step {step}: straggler "
                      f"({dt:.2f}s vs median {self.monitor.median:.2f}s)")
            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps - 1:
                entry = {"step": step, "loss": loss,
                         "grad_norm": float(metrics["grad_norm"]),
                         "lr": float(metrics["lr"]), "sec": dt}
                self.metrics_log.append(entry)
                print(f"[trainer] step {step} loss={loss:.4f} "
                      f"gnorm={entry['grad_norm']:.3f} lr={entry['lr']:.2e} "
                      f"({dt:.2f}s)")
            if (step + 1) % self.tcfg.checkpoint_every == 0:
                self._checkpoint(step + 1)

        ckpt.wait_for_pending()
        return {
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "steps": self.tcfg.total_steps - self.start_step,
            "skipped": skipped,
            "straggler_events": len(self.monitor.events),
            "wall_s": time.time() - t_total,
            "log": self.metrics_log,
        }
