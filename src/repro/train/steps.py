"""The jit-able training / serving step functions.

These are the programs the launcher jits with in/out shardings and the
multi-pod dry-run lowers for every (arch × shape × mesh) cell.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch import sharding as sh
from repro.models import model as M
from repro.optim import optimizer as O

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "init_train_state"]


def init_train_state(key, cfg: ArchConfig, opt_cfg: O.AdamWConfig):
    params = M.init_params(key, cfg)
    opt_state = O.init_opt_state(params, opt_cfg)
    return params, opt_state


def make_train_step(cfg: ArchConfig, opt_cfg: O.AdamWConfig,
                    microbatches: int = 1, accum_dtype=jnp.float32):
    """Training step with optional gradient accumulation.

    ``microbatches`` > 1 scans over µ-batches (leading batch split),
    accumulating gradients in ``accum_dtype``: per-step activation memory
    scales 1/µ — this is what fits the train_4k cells into 16 GB/chip
    (§Dry-run); the collective cost is unchanged (grads are reduced once,
    after accumulation, exactly as with a single large batch). The
    accumulator is ZeRO-sharded; ``accum_dtype=bfloat16`` additionally
    halves the per-µ gradient transient for the largest configs
    (internlm2-20b) at ~1e-2 relative accumulation error — below the
    batch gradient noise floor.
    """
    grad_fn = jax.value_and_grad(M.loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch, cfg)
            # emit gradients reduce-scattered into the ZeRO layout: the
            # full-size f32 gradient transient never materialises
            grads = sh.constrain_like_opt(grads)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            # ZeRO-sharded accumulator: 1/|data| of param bytes per chip
            zero_grads = sh.constrain_like_opt(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))

            def one_micro(acc, mb):
                g_acc, loss_acc, aux_acc = acc
                (l, m), g = grad_fn(params, mb, cfg)
                g_acc = sh.constrain_like_opt(jax.tree.map(
                    lambda a, b_: a + b_.astype(accum_dtype), g_acc, g))
                return (g_acc, loss_acc + l, aux_acc + m["aux"]), None

            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                one_micro, (zero_grads, jnp.zeros((), jnp.float32),
                            jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {"ce": loss - aux_sum / microbatches,
                       "aux": aux_sum / microbatches}
        params, opt_state, opt_stats = O.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_stats)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, microbatches: int = 1):
    """Prefill, optionally scanned over batch chunks: transient activation
    memory (MoE dispatch buffers, attention logit chunks) scales 1/µ.

    The collected KV caches are returned CHUNK-STACKED — leading (µ,) dim
    kept — because merging would reshape a sharded batch dim into an
    unsharded one, which GSPMD lowers by replicating the full cache
    (measured: 242 GB/chip on minicpm prefill_32k; EXPERIMENTS §Dry-run).
    Serving hosts address chunk c, row r; decode paths take per-chunk
    caches directly."""
    if microbatches == 1:
        def prefill_step(params, batch):
            return M.prefill_step(params, batch, cfg)
        return prefill_step

    def prefill_step(params, batch):
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def one(carry, mb):
            last, caches = M.prefill_step(params, mb, cfg)
            return carry, (last, caches)

        _, (last, caches) = jax.lax.scan(one, (), micro)
        last = last.reshape(-1, *last.shape[2:])    # logits: tiny, safe
        return last, caches

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, tokens, pos, caches):
        return M.decode_step(params, tokens, pos, caches, cfg)

    return decode_step
