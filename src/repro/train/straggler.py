"""Straggler detection and mitigation.

Two mechanisms, matched to the two workload families:

1. **Step-time monitor** (synchronous SPMD training): per-step wall times
   feed a robust EWMA; a step slower than ``threshold × median`` marks the
   step a straggler event. Mitigations at fleet scale are (a) flagging the
   slow pod for the scheduler, (b) micro-batch rebalancing away from it,
   (c) checkpoint-and-restart without it (elastic). Here the detector +
   policy decisions are implemented and unit-tested; the actuation is the
   cluster scheduler's job.

2. **Work-queue reassignment** (the paper's permutation testing, which is
   embarrassingly parallel over permutation slices): slices are leased to
   workers with deadlines; expired leases are re-queued, so a dead or slow
   pod only delays its own slice until another pod picks it up. Exactly
   the property that makes Algorithm 1/2 a great 1000-node workload
   (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

__all__ = ["StepTimeMonitor", "SliceQueue"]


class StepTimeMonitor:
    """Rolling-median step-time straggler detector."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 warmup_steps: int = 3):
        self.window = window
        self.threshold = threshold
        self.warmup = warmup_steps
        self.times: deque[float] = deque(maxlen=window)
        self.events: list[dict] = []
        self._seen = 0

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self._seen += 1
        if self._seen <= self.warmup:           # compile/init steps
            return False
        flagged = False
        if len(self.times) >= 5:
            med = sorted(self.times)[len(self.times) // 2]
            if seconds > self.threshold * med:
                flagged = True
                self.events.append({"step": step, "seconds": seconds,
                                    "median": med})
        self.times.append(seconds)
        return flagged

    @property
    def median(self) -> Optional[float]:
        if not self.times:
            return None
        return sorted(self.times)[len(self.times) // 2]


@dataclasses.dataclass
class _Lease:
    slice_id: int
    worker: str
    deadline: float


class SliceQueue:
    """Deadline-leased work queue for permutation/searchlight slices."""

    def __init__(self, n_slices: int, lease_seconds: float = 60.0,
                 clock=time.monotonic):
        self.todo: deque[int] = deque(range(n_slices))
        self.lease_seconds = lease_seconds
        self.leases: dict[int, _Lease] = {}
        self.done: set[int] = set()
        self.reassignments: list[tuple[int, str]] = []
        self._clock = clock

    def acquire(self, worker: str) -> Optional[int]:
        self._expire()
        if not self.todo:
            return None
        s = self.todo.popleft()
        self.leases[s] = _Lease(s, worker, self._clock() + self.lease_seconds)
        return s

    def complete(self, slice_id: int, worker: str) -> bool:
        """False if the lease had already expired and been reassigned."""
        lease = self.leases.get(slice_id)
        if lease is None or lease.worker != worker:
            return slice_id in self.done   # late duplicate: idempotent
        del self.leases[slice_id]
        self.done.add(slice_id)
        return True

    def _expire(self):
        now = self._clock()
        for s, lease in list(self.leases.items()):
            if lease.deadline < now:
                del self.leases[s]
                if s not in self.done:
                    self.todo.append(s)
                    self.reassignments.append((s, lease.worker))

    @property
    def finished(self) -> bool:
        self._expire()
        return not self.todo and not self.leases
