"""Fault-tolerant checkpointing: atomic, async, elastic-restore.

No orbax in the offline container, so this is a small self-contained
implementation with the production-critical properties:

  * **atomic**: a checkpoint is written to ``step_XXXXXXXX.tmp/`` and
    renamed to ``step_XXXXXXXX/`` only when complete — a crash mid-write
    can never corrupt the restore point (``latest_step`` ignores .tmp).
  * **async**: ``save_async`` snapshots device arrays to host (this is the
    only synchronous part) and writes in a background thread so training
    continues through the I/O.
  * **elastic**: ``restore`` takes the *target* sharding tree — arrays are
    ``device_put`` against whatever mesh the restarted job has, so a job
    can come back on a different pod count / mesh shape than it saved
    from (tested by saving under one mesh and restoring under another).
  * **self-describing**: a manifest records tree structure, shapes,
    dtypes, and user metadata (data-pipeline cursor, RNG, step).

On a real multi-host fleet each host writes only the shards it owns
(``jax.experimental.multihost_utils``-style); in this single-controller
container the full arrays are fetched — the commit protocol (tmp +
rename + manifest-last) is identical.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "gc_checkpoints",
           "wait_for_pending"]

_MANIFEST = "manifest.json"
_pending: list[threading.Thread] = []


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
        out.append(("__".join(parts) or "leaf", leaf))
    return out, treedef


def _ckpt_dir(root: Path, step: int) -> Path:
    return root / f"step_{step:08d}"


def save(root: str | Path, step: int, tree: Any, metadata: Optional[dict] = None,
         keep: int = 3) -> Path:
    """Synchronous atomic checkpoint write."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = _ckpt_dir(root, step)
    tmp = final.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = _flatten(tree)
    names = []
    for name, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{name}.npy", arr)
        names.append({"name": name, "shape": list(arr.shape),
                      "dtype": str(arr.dtype)})
    manifest = {"step": step, "leaves": names, "metadata": metadata or {}}
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # the atomic commit
    gc_checkpoints(root, keep=keep)
    return final


def save_async(root: str | Path, step: int, tree: Any,
               metadata: Optional[dict] = None, keep: int = 3) -> threading.Thread:
    """Snapshot to host now, write in the background."""
    flat, treedef = _flatten(tree)
    host = [(n, np.asarray(jax.device_get(x))) for n, x in flat]
    snapshot = jax.tree_util.tree_unflatten(treedef, [x for _, x in host])

    def _write():
        save(root, step, snapshot, metadata=metadata, keep=keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    _pending.append(t)
    return t


def wait_for_pending():
    for t in list(_pending):
        t.join()
        _pending.remove(t)


def latest_step(root: str | Path) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = []
    for d in root.iterdir():
        if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp") \
                and (d / _MANIFEST).exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(root: str | Path, step: int, like: Any,
            sharding_tree: Any = None):
    """Load checkpoint ``step`` shaped like ``like``; place with
    ``sharding_tree`` (elastic: any mesh the restarted job happens to have).

    Returns (tree, metadata).
    """
    d = _ckpt_dir(Path(root), step)
    manifest = json.loads((d / _MANIFEST).read_text())
    flat, treedef = _flatten(like)
    shard_flat = (None if sharding_tree is None
                  else jax.tree.leaves(sharding_tree))
    leaves = []
    for i, (name, leaf) in enumerate(flat):
        arr = np.load(d / f"{name}.npy")
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


def gc_checkpoints(root: str | Path, keep: int = 3):
    root = Path(root)
    steps = sorted(
        int(d.name.split("_")[1]) for d in root.iterdir()
        if d.is_dir() and d.name.startswith("step_")
        and not d.name.endswith(".tmp") and (d / _MANIFEST).exists())
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(_ckpt_dir(root, s), ignore_errors=True)
