"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544; GQA. [arXiv:2403.17297; hf]

Llama-style trunk: RMSNorm, SwiGLU, RoPE theta 1e6, untied embeddings.
The largest dense arch in the pool — the ZeRO-1 + TP + SP sharding case.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    source="[arXiv:2403.17297; hf]",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    layer_pattern=("attn",),
    rope_theta=1_000_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    norm_eps=1e-5,
    tie_embeddings=False,
    sub_quadratic=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="internlm2-20b-smoke", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=192, vocab_size=512,
    dtype="float32", param_dtype="float32",
)
