"""Assigned input shapes (per-arch shape set for the LM pool).

``train_*`` lowers train_step; ``prefill_*`` lowers prefill_step;
``decode_*`` / ``long_*`` lower decode_step (one new token with a KV cache
of seq_len). ``long_500k`` is sub-quadratic-only (cfg.sub_quadratic).
"""

from __future__ import annotations

import dataclasses

__all__ = ["Shape", "SHAPES", "get_shape", "cells_for"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> Shape:
    return SHAPES[name]


def cells_for(cfg) -> list[str]:
    """Runnable shape names for an arch (long_500k only if sub-quadratic)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names
