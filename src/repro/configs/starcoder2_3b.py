"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA + RoPE. [arXiv:2402.19173; hf]

StarCoder2 specifics: LayerNorm (not RMSNorm), plain (non-gated) GELU MLP
with 4x expansion, RoPE theta ~1e6, tied embeddings.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    source="[arXiv:2402.19173; hf]",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    layer_pattern=("attn",),
    rope_theta=1_000_000.0,
    mlp="gelu",
    norm="layernorm",
    norm_eps=1e-5,
    tie_embeddings=True,
    sub_quadratic=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="starcoder2-3b-smoke", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=512,
    dtype="float32", param_dtype="float32",
)
