"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (MHA kv=16) d_ff=1024/expert
vocab=50304; 64 experts top-8. [arXiv:2409.02060; hf]

OLMoE specifics: every MLP is an MoE (64 experts, top-8, gates softmax-
then-topk renormalised), QK-norm, SwiGLU experts, untied embeddings.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="[arXiv:2409.02060; hf]",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    layer_pattern=("attn",),
    qk_norm=True,
    mlp="swiglu",
    norm="rmsnorm",
    norm_eps=1e-5,
    moe_experts=64,
    moe_top_k=8,
    moe_capacity_factor=1.25,
    tie_embeddings=False,
    sub_quadratic=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="olmoe-1b-7b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=64, vocab_size=512, moe_experts=8,
    moe_top_k=2, dtype="float32", param_dtype="float32",
)
