"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000; local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]

Gemma-2 specifics: alternating sliding-window (4096) and global layers,
attention logit softcap 50, final logit softcap 30, pre+post block norms,
GeGLU, head_dim 256 with query scale 256^-1/2, sqrt(d) embedding scale,
tied embeddings.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    source="[arXiv:2408.00118; hf]",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_pattern=("local", "attn"),
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_norms=True,
    mlp="geglu",
    norm="rmsnorm",
    emb_scale=2304.0 ** 0.5,
    query_scale=256.0 ** -0.5,
    tie_embeddings=True,
    sub_quadratic=False,   # global layers are full attention
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="gemma2-2b-smoke", num_layers=4, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512, local_window=16,
    emb_scale=8.0, query_scale=16.0 ** -0.5, dtype="float32",
    param_dtype="float32",
)
