"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304; sLSTM + mLSTM
blocks. [arXiv:2405.04517; unverified]

xLSTM[1:1] layout: alternating mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory with memory mixing, sequential scan) blocks.
Blocks are self-contained (d_ff = 0): mLSTM wraps its cell in a 2x
up/down projection with SiLU output gating; sLSTM is followed by its
internal gated 4/3-factor FFN. O(1) decode state -> long_500k eligible.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    source="[arXiv:2405.04517; unverified]",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=("mlstm", "slstm"),
    conv_width=4,
    norm="rmsnorm",
    tie_embeddings=True,
    sub_quadratic=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="xlstm-125m-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, vocab_size=512, dtype="float32",
    param_dtype="float32",
)
